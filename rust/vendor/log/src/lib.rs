//! Minimal offline subset of the `log` facade.
//!
//! Provides the level enums, [`Record`]/[`Metadata`], the [`Log`] trait,
//! `set_boxed_logger`/`set_max_level`/`max_level`, and the five level
//! macros — exactly the surface `cfslda::util::logging` and the library's
//! `log::info!`-style call sites use. One global logger, installed once.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter (adds `Off` below `Error`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (level + target module path).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Sink for log records.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();

/// Install the global logger (first call wins).
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not public API.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level <= max_level() {
        if let Some(logger) = LOGGER.get() {
            let record = Record { metadata: Metadata { level, target }, args };
            if logger.enabled(record.metadata()) {
                logger.log(&record);
            }
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            let _ = format!("{}", record.args());
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn filtering_and_dispatch() {
        let _ = set_boxed_logger(Box::new(Counter));
        set_max_level(LevelFilter::Info);
        info!("hello {}", 1);
        debug!("dropped");
        assert!(HITS.load(Ordering::Relaxed) >= 1);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
    }
}
