//! Minimal offline subset of the `anyhow` API.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`. Error values carry a context
//! chain of plain strings; `{}` prints the outermost message, `{:#}` the
//! whole chain separated by `: ` (matching the upstream formatting the
//! binaries rely on).

use std::fmt;

/// A string-chained error value (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

/// `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn context_chains_render() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: disk on fire");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_format() {
        let x = 7;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 7");
        let e = anyhow!("bad value {}", 8);
        assert_eq!(e.to_string(), "bad value 8");
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3, "math broke: {}", 2);
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "math broke: 2");
        fn g() -> Result<()> {
            bail!("gone")
        }
        assert!(g().is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file/xyz")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
