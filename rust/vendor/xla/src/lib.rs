//! API-compatible **stub** of the `xla` PJRT crate.
//!
//! The offline build environment does not ship the PJRT C API plugin, so
//! this crate mirrors exactly the type/method surface
//! `cfslda::runtime::xla` compiles against and fails at *runtime* with a
//! clear error from the first entry point ([`PjRtClient::cpu`]). The
//! production image swaps in the real crate by replacing this vendor
//! directory; no source changes are needed because `EngineHandle::from_kind`
//! already falls back to the native engine when artifacts are absent.

use std::fmt;

/// Error type mirroring the real crate's (context-friendly: implements
/// `std::error::Error`).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "xla/PJRT support is stubbed in this build (offline vendor set); \
         use engine=native, or install the real `xla` crate under rust/vendor/xla"
            .to_string(),
    )
}

/// Host-side literal (tensor) handle.
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn scalar(_x: f32) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// A lowered computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Always fails in the stub: there is no PJRT plugin in this build.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> &'static str {
        "stub"
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_at_the_entry_point() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }
}
