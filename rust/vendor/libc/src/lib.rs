//! Minimal offline subset of `libc`: the thread-CPU-clock surface
//! `cfslda::util::timer` needs (`clock_gettime` + `CLOCK_THREAD_CPUTIME_ID`)
//! plus the readiness-loop surface `cfslda::serve::reactor` needs
//! (`epoll_*`, `fcntl` O_NONBLOCK, `accept4`, `eventfd`, raw fd
//! `read`/`write`/`close`) plus the out-of-core arena surface
//! `cfslda::data::arena_file` needs (`mmap`/`munmap`/`madvise`).
//! Linux x86_64/aarch64 ABI.

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type c_uint = u32;
pub type c_long = i64;
pub type time_t = i64;
pub type size_t = usize;
pub type ssize_t = isize;
pub type socklen_t = u32;
pub type off_t = i64;

pub use std::ffi::c_void;

/// POSIX per-thread CPU-time clock id (Linux).
pub const CLOCK_THREAD_CPUTIME_ID: c_int = 3;

#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct timespec {
    pub tv_sec: time_t,
    pub tv_nsec: c_long,
}

// ---------------------------------------------------------------------------
// epoll (Linux). The event struct is packed on x86_64 only — the kernel ABI
// has no padding between the u32 mask and the u64 payload there, while
// aarch64 uses the natural (aligned) layout.

#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy, Debug)]
pub struct epoll_event {
    pub events: u32,
    pub u64: u64,
}

#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct epoll_event {
    pub events: u32,
    pub u64: u64,
}

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

pub const EPOLL_CTL_ADD: c_int = 1;
pub const EPOLL_CTL_DEL: c_int = 2;
pub const EPOLL_CTL_MOD: c_int = 3;

pub const EPOLL_CLOEXEC: c_int = 0o2000000;

// fcntl — only the non-blocking toggle is needed.
pub const F_GETFL: c_int = 3;
pub const F_SETFL: c_int = 4;
pub const O_NONBLOCK: c_int = 0o4000;

// accept4 flags (Linux: same values as O_NONBLOCK / O_CLOEXEC).
pub const SOCK_NONBLOCK: c_int = 0o4000;
pub const SOCK_CLOEXEC: c_int = 0o2000000;

// eventfd flags.
pub const EFD_NONBLOCK: c_int = 0o4000;
pub const EFD_CLOEXEC: c_int = 0o2000000;

// ---------------------------------------------------------------------------
// mmap (Linux x86_64/aarch64) — the out-of-core arena surface
// `cfslda::data::arena_file` needs: read-only shared file mappings plus
// paging advice.

pub const PROT_READ: c_int = 1;
pub const PROT_WRITE: c_int = 2;

pub const MAP_SHARED: c_int = 0x01;
pub const MAP_PRIVATE: c_int = 0x02;

/// `mmap`'s error return: `(void *)-1`, not null.
pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

pub const MADV_NORMAL: c_int = 0;
pub const MADV_RANDOM: c_int = 1;
pub const MADV_SEQUENTIAL: c_int = 2;
pub const MADV_WILLNEED: c_int = 3;

/// Opaque-enough socket address for `accept4` when the peer address is
/// discarded (we always pass null pointers, but the signature needs it).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct sockaddr {
    pub sa_family: u16,
    pub sa_data: [u8; 14],
}

// ---------------------------------------------------------------------------
// Signals — the graceful-shutdown surface `cfslda::util::signal` needs:
// `sigaction` to install SIGINT/SIGTERM handlers, `raise` for tests.

pub const SIGINT: c_int = 2;
pub const SIGUSR1: c_int = 10;
pub const SIGTERM: c_int = 15;

/// Restart interrupted syscalls instead of surfacing EINTR everywhere.
pub const SA_RESTART: c_int = 0x1000_0000;

/// Kernel signal mask: 1024 bits on Linux, though glibc's `sigset_t` is
/// what `sigaction(2)` takes — 128 bytes on x86_64/aarch64.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct sigset_t {
    pub __val: [u64; 16],
}

impl sigset_t {
    /// An empty mask (`sigemptyset`): block nothing extra in the handler.
    pub const fn empty() -> sigset_t {
        sigset_t { __val: [0; 16] }
    }
}

/// glibc `struct sigaction` (Linux x86_64/aarch64 layout: handler first,
/// then the 128-byte mask, flags, and the unused restorer slot).
#[repr(C)]
pub struct sigaction {
    /// `sa_handler` / `sa_sigaction` union slot — an
    /// `extern "C" fn(c_int)` pointer cast to usize when SA_SIGINFO is off.
    pub sa_sigaction: usize,
    pub sa_mask: sigset_t,
    pub sa_flags: c_int,
    pub sa_restorer: usize,
}

extern "C" {
    pub fn clock_gettime(clk_id: c_int, tp: *mut timespec) -> c_int;

    pub fn epoll_create1(flags: c_int) -> c_int;
    pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut epoll_event) -> c_int;
    pub fn epoll_wait(epfd: c_int, events: *mut epoll_event, maxevents: c_int, timeout: c_int)
        -> c_int;

    pub fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
    pub fn accept4(
        sockfd: c_int,
        addr: *mut sockaddr,
        addrlen: *mut socklen_t,
        flags: c_int,
    ) -> c_int;
    pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;

    pub fn read(fd: c_int, buf: *mut c_void, count: size_t) -> ssize_t;
    pub fn write(fd: c_int, buf: *const c_void, count: size_t) -> ssize_t;
    pub fn close(fd: c_int) -> c_int;

    pub fn mmap(
        addr: *mut c_void,
        length: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;
    pub fn munmap(addr: *mut c_void, length: size_t) -> c_int;
    pub fn madvise(addr: *mut c_void, length: size_t, advice: c_int) -> c_int;

    pub fn sigaction(signum: c_int, act: *const sigaction, oldact: *mut sigaction) -> c_int;
    pub fn raise(signum: c_int) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_clock_ticks() {
        let mut ts = timespec { tv_sec: 0, tv_nsec: 0 };
        let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
        assert_eq!(rc, 0);
        assert!(ts.tv_sec >= 0 && ts.tv_nsec >= 0);
    }

    #[test]
    fn epoll_and_eventfd_round_trip() {
        unsafe {
            let ep = epoll_create1(EPOLL_CLOEXEC);
            assert!(ep >= 0);
            let ev = eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC);
            assert!(ev >= 0);

            let mut reg = epoll_event {
                events: EPOLLIN,
                u64: 42,
            };
            assert_eq!(epoll_ctl(ep, EPOLL_CTL_ADD, ev, &mut reg), 0);

            // Nothing pending yet: zero-timeout wait returns no events.
            let mut out = [epoll_event { events: 0, u64: 0 }; 4];
            assert_eq!(epoll_wait(ep, out.as_mut_ptr(), 4, 0), 0);

            // Signal the eventfd; the wait must report it with our cookie.
            let one: u64 = 1;
            let n = write(ev, &one as *const u64 as *const c_void, 8);
            assert_eq!(n, 8);
            let got = epoll_wait(ep, out.as_mut_ptr(), 4, 0);
            assert_eq!(got, 1);
            assert_eq!({ out[0].u64 }, 42);
            assert_ne!({ out[0].events } & EPOLLIN, 0);

            // Drain resets readiness.
            let mut v: u64 = 0;
            let n = read(ev, &mut v as *mut u64 as *mut c_void, 8);
            assert_eq!(n, 8);
            assert_eq!(v, 1);
            assert_eq!(epoll_wait(ep, out.as_mut_ptr(), 4, 0), 0);

            assert_eq!(epoll_ctl(ep, EPOLL_CTL_DEL, ev, std::ptr::null_mut()), 0);
            assert_eq!(close(ev), 0);
            assert_eq!(close(ep), 0);
        }
    }

    #[test]
    fn sigaction_installs_handler_and_raise_delivers() {
        use std::sync::atomic::{AtomicI32, Ordering};
        static SEEN: AtomicI32 = AtomicI32::new(0);
        extern "C" fn on_signal(sig: c_int) {
            SEEN.store(sig, Ordering::SeqCst);
        }
        unsafe {
            // SIGUSR1, not SIGTERM: the default SIGTERM disposition kills
            // the test process if the shim layout were wrong, and other
            // tests install their own SIGTERM handlers.
            let act = sigaction {
                sa_sigaction: on_signal as usize,
                sa_mask: sigset_t::empty(),
                sa_flags: SA_RESTART,
                sa_restorer: 0,
            };
            let mut old = sigaction {
                sa_sigaction: 0,
                sa_mask: sigset_t::empty(),
                sa_flags: 0,
                sa_restorer: 0,
            };
            assert_eq!(sigaction(SIGUSR1, &act, &mut old), 0);
            assert_eq!(raise(SIGUSR1), 0);
            assert_eq!(SEEN.load(Ordering::SeqCst), SIGUSR1);
            // Round-trip: re-reading the disposition returns our handler.
            let mut cur = sigaction {
                sa_sigaction: 0,
                sa_mask: sigset_t::empty(),
                sa_flags: 0,
                sa_restorer: 0,
            };
            assert_eq!(sigaction(SIGUSR1, std::ptr::null(), &mut cur), 0);
            assert_eq!(cur.sa_sigaction, on_signal as usize);
            // Restore whatever was installed before.
            assert_eq!(sigaction(SIGUSR1, &old, std::ptr::null_mut()), 0);
        }
    }

    #[test]
    fn mmap_round_trips_a_file() {
        // Write a file, map it shared read-only, read the bytes back
        // through the mapping, advise the kernel, unmap.
        use std::io::Write;
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_libc_mmap_{}", std::process::id()));
        let payload: Vec<u8> = (0..8192u32).map(|i| (i % 251) as u8).collect();
        {
            let mut f = std::fs::File::create(&p).unwrap();
            f.write_all(&payload).unwrap();
        }
        let f = std::fs::File::open(&p).unwrap();
        let fd = std::os::unix::io::AsRawFd::as_raw_fd(&f);
        unsafe {
            let ptr = mmap(
                std::ptr::null_mut(),
                payload.len(),
                PROT_READ,
                MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(ptr, MAP_FAILED);
            assert_eq!(madvise(ptr, payload.len(), MADV_SEQUENTIAL), 0);
            assert_eq!(madvise(ptr, payload.len(), MADV_WILLNEED), 0);
            let mapped = std::slice::from_raw_parts(ptr as *const u8, payload.len());
            assert_eq!(mapped, &payload[..]);
            // Page-aligned as the zero-copy slice casts in `data::arena_file`
            // require.
            assert_eq!(ptr as usize % 4096, 0);
            assert_eq!(munmap(ptr, payload.len()), 0);
        }
        drop(f);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mmap_rejects_bad_fd() {
        unsafe {
            let ptr = mmap(std::ptr::null_mut(), 4096, PROT_READ, MAP_SHARED, -1, 0);
            assert_eq!(ptr, MAP_FAILED);
        }
    }

    #[test]
    fn fcntl_toggles_nonblock() {
        unsafe {
            let ev = eventfd(0, 0);
            assert!(ev >= 0);
            let fl = fcntl(ev, F_GETFL);
            assert!(fl >= 0);
            assert_eq!(fl & O_NONBLOCK, 0);
            assert_eq!(fcntl(ev, F_SETFL, fl | O_NONBLOCK), 0);
            assert_ne!(fcntl(ev, F_GETFL) & O_NONBLOCK, 0);
            assert_eq!(close(ev), 0);
        }
    }
}
