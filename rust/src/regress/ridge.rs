//! Dense ridge regression via Cholesky (pure rust).
//!
//! Solves the MAP eta system of paper eq. (2):
//!   (Z^T W Z + lambda I) eta = Z^T W y + lambda mu,   lambda = rho / sigma.
//!
//! T <= 64 here, so an O(T^3) Cholesky is microseconds; the expensive
//! D x T Gram accumulation is the part that the XLA engine offloads to the
//! AOT Pallas `gram` kernel, with this module consuming the (G, b) moments.
//!
//! The native training loop instead accumulates the moments **straight from
//! the Gibbs count state** ([`gram_moments_from_counts`]): each document
//! contributes only its non-zero topic counts, so the eta step costs
//! O(Σ_d nnz_d²) instead of O(D·T²) and never materializes the [D, T] f32
//! zbar matrix. The zbar values are re-derived with the exact same
//! `u32 -> f32` rounding, so the moments are bitwise equal to
//! [`gram_moments`] on [`CountMatrices::zbar_matrix`]'s output.

use crate::model::counts::CountMatrices;

/// Symmetric positive-definite solve via Cholesky: a x = b, `a` row-major
/// n x n. Returns `None` if the factorization fails (not SPD).
pub fn cholesky_solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // L lower-triangular, row-major.
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    // forward: L z = b
    let mut z = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * z[k];
        }
        z[i] = s / l[i * n + i];
    }
    // backward: L^T x = z
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    Some(x)
}

/// Accumulate the weighted Gram moments G = Z^T W Z (row-major T x T),
/// b = Z^T W y, n = sum w, from a row-major [D, T] f32 matrix.
/// This is the native twin of the `gram` Pallas kernel.
pub fn gram_moments(zbar: &[f32], y: &[f64], w: &[f64], t: usize) -> (Vec<f64>, Vec<f64>, f64) {
    let d = y.len();
    debug_assert_eq!(zbar.len(), d * t);
    debug_assert_eq!(w.len(), d);
    let mut g = vec![0.0f64; t * t];
    let mut b = vec![0.0f64; t];
    let mut n = 0.0f64;
    for di in 0..d {
        let wd = w[di];
        if wd == 0.0 {
            continue;
        }
        n += wd;
        let row = &zbar[di * t..(di + 1) * t];
        for i in 0..t {
            let zi = wd * row[i] as f64;
            b[i] += zi * y[di];
            let gi = &mut g[i * t..(i + 1) * t];
            for j in 0..t {
                gi[j] += zi * row[j] as f64;
            }
        }
    }
    (g, b, n)
}

/// Weighted Gram moments G = Z̄ᵀWZ̄, b = Z̄ᵀWy, n = Σw straight from the
/// count matrices, accumulating over each document's non-zero topic counts
/// only — O(Σ_d nnz_d²) instead of O(D·T²), no [D, T] zbar buffer.
/// `w = None` means unit weights. Bitwise equal to [`gram_moments`] on the
/// matching zbar matrix: every contribution is the same f32-rounded value
/// (`N_dt as f32 / N_d as f32`) added in the same (doc, i, j) order, and
/// the skipped zero-count terms are exact IEEE no-ops there.
pub fn gram_moments_from_counts(
    counts: &CountMatrices,
    y: &[f64],
    w: Option<&[f64]>,
) -> (Vec<f64>, Vec<f64>, f64) {
    let t = counts.t;
    debug_assert_eq!(counts.d, y.len());
    let mut g = vec![0.0f64; t * t];
    let mut b = vec![0.0f64; t];
    let mut n = 0.0f64;
    let mut scratch: Vec<u16> = Vec::new();
    for d in 0..counts.d {
        let wd = w.map_or(1.0, |w| w[d]);
        if wd == 0.0 {
            continue;
        }
        n += wd;
        let nd = counts.nd[d].max(1) as f32;
        let row = counts.ndt_row(d);
        let nzs = counts.doc_nonzeros(d, &mut scratch);
        for &iu in nzs {
            let i = iu as usize;
            let zi = wd * (row[i] as f32 / nd) as f64;
            b[i] += zi * y[d];
            let gi = &mut g[i * t..(i + 1) * t];
            for &ju in nzs {
                let j = ju as usize;
                gi[j] += zi * (row[j] as f32 / nd) as f64;
            }
        }
    }
    (g, b, n)
}

/// Weighted train MSE of `eta` straight from the count matrices — the
/// count-sided twin of [`weighted_mse`], bitwise equal on the matching
/// zbar (same f32 rounding, same ascending accumulation order, skipped
/// terms are exact zeros). `w = None` means unit weights.
pub fn mse_from_counts(
    counts: &CountMatrices,
    eta: &[f64],
    y: &[f64],
    w: Option<&[f64]>,
) -> f64 {
    debug_assert_eq!(counts.d, y.len());
    let mut se = 0.0;
    let mut n = 0.0;
    let mut scratch: Vec<u16> = Vec::new();
    for d in 0..counts.d {
        let wd = w.map_or(1.0, |w| w[d]);
        if wd == 0.0 {
            continue;
        }
        let nd = counts.nd[d].max(1) as f32;
        let row = counts.ndt_row(d);
        let mut yhat = 0.0f64;
        for &tu in counts.doc_nonzeros(d, &mut scratch) {
            let ti = tu as usize;
            yhat += (row[ti] as f32 / nd) as f64 * eta[ti];
        }
        se += wd * (y[d] - yhat) * (y[d] - yhat);
        n += wd;
    }
    if n == 0.0 { 0.0 } else { se / n }
}

/// Full ridge solve from raw rows: returns (eta, weighted train MSE).
pub fn ridge_fit(
    zbar: &[f32],
    y: &[f64],
    w: &[f64],
    t: usize,
    lambda: f64,
    mu: f64,
) -> anyhow::Result<(Vec<f64>, f64)> {
    let (g, b, _) = gram_moments(zbar, y, w, t);
    ridge_solve_moments(&g, &b, t, lambda, mu).map(|eta| {
        let mse = weighted_mse(zbar, &eta, y, w, t);
        (eta, mse)
    })
}

/// Ridge solve given precomputed Gram moments (the chunked-XLA path).
pub fn ridge_solve_moments(
    g: &[f64],
    b: &[f64],
    t: usize,
    lambda: f64,
    mu: f64,
) -> anyhow::Result<Vec<f64>> {
    let mut a = g.to_vec();
    for i in 0..t {
        a[i * t + i] += lambda;
    }
    let rhs: Vec<f64> = b.iter().map(|&x| x + lambda * mu).collect();
    cholesky_solve(&a, &rhs, t)
        .ok_or_else(|| anyhow::anyhow!("ridge system not SPD (lambda = {lambda})"))
}

/// Weighted mean squared error of eta over rows.
pub fn weighted_mse(zbar: &[f32], eta: &[f64], y: &[f64], w: &[f64], t: usize) -> f64 {
    let d = y.len();
    let mut se = 0.0;
    let mut n = 0.0;
    for di in 0..d {
        if w[di] == 0.0 {
            continue;
        }
        let row = &zbar[di * t..(di + 1) * t];
        let yhat: f64 = row.iter().zip(eta).map(|(&z, &e)| z as f64 * e).sum();
        se += w[di] * (y[di] - yhat) * (y[di] - yhat);
        n += w[di];
    }
    if n == 0.0 { 0.0 } else { se / n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn cholesky_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let x = cholesky_solve(&a, &[3.0, -2.0], 2).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] + 2.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_known_system() {
        // A = [[4,2],[2,3]], b = [2, -1] -> x = [1, -1] since Ax = [2,-1]
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let x = cholesky_solve(&a, &[2.0, -1.0], 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // indefinite
        assert!(cholesky_solve(&a, &[1.0, 1.0], 2).is_none());
    }

    #[test]
    fn random_spd_solve_accuracy() {
        let mut rng = Pcg64::seed_from_u64(1);
        for n in [1usize, 3, 8, 32] {
            // A = M M^T + n I
            let m: Vec<f64> = (0..n * n).map(|_| rng.next_gaussian()).collect();
            let mut a = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut s = if i == j { n as f64 } else { 0.0 };
                    for k in 0..n {
                        s += m[i * n + k] * m[j * n + k];
                    }
                    a[i * n + j] = s;
                }
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| a[i * n + j] * x_true[j]).sum())
                .collect();
            let x = cholesky_solve(&a, &b, n).unwrap();
            for (xi, ti) in x.iter().zip(&x_true) {
                assert!((xi - ti).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn gram_moments_match_naive() {
        let mut rng = Pcg64::seed_from_u64(2);
        let (d, t) = (17, 4);
        let zbar: Vec<f32> = (0..d * t).map(|_| rng.next_f32()).collect();
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let w: Vec<f64> = (0..d).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        let (g, b, n) = gram_moments(&zbar, &y, &w, t);
        // naive
        for i in 0..t {
            let mut bi = 0.0;
            for di in 0..d {
                bi += w[di] * zbar[di * t + i] as f64 * y[di];
            }
            assert!((b[i] - bi).abs() < 1e-9);
            for j in 0..t {
                let mut gij = 0.0;
                for di in 0..d {
                    gij += w[di] * zbar[di * t + i] as f64 * zbar[di * t + j] as f64;
                }
                assert!((g[i * t + j] - gij).abs() < 1e-9);
            }
        }
        assert_eq!(n, w.iter().sum::<f64>());
    }

    #[test]
    fn count_sided_moments_equal_zbar_moments_bitwise() {
        let mut rng = Pcg64::seed_from_u64(12);
        let (d, t, w) = (23usize, 7usize, 15usize);
        let mut counts = CountMatrices::new(d, t, w);
        for di in 0..d {
            // ragged docs, one left empty (nd.max(1) guard)
            for _ in 0..(di * 5) % 29 {
                counts.inc(di, rng.gen_range(w) as u32, rng.gen_range(t));
            }
        }
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let wts: Vec<f64> =
            (0..d).map(|i| if i % 4 == 0 { 0.0 } else { 0.5 + rng.next_f64() }).collect();
        let ones = vec![1.0f64; d];
        let zbar = counts.zbar_matrix();
        let eta: Vec<f64> = (0..t).map(|_| rng.next_gaussian()).collect();

        // with and without the sparse index, weighted and unweighted, the
        // count-sided accumulation must be bitwise equal to the zbar path
        for indexed in [false, true] {
            if indexed {
                counts.enable_sparse_index();
            }
            let (g0, b0, n0) = gram_moments(&zbar, &y, &wts, t);
            let (g1, b1, n1) = gram_moments_from_counts(&counts, &y, Some(&wts));
            assert_eq!(g0, g1, "G diverged (indexed={indexed})");
            assert_eq!(b0, b1, "b diverged (indexed={indexed})");
            assert_eq!(n0, n1);

            let (g0, b0, n0) = gram_moments(&zbar, &y, &ones, t);
            let (g1, b1, n1) = gram_moments_from_counts(&counts, &y, None);
            assert_eq!(g0, g1, "unit-weight G diverged (indexed={indexed})");
            assert_eq!(b0, b1, "unit-weight b diverged (indexed={indexed})");
            assert_eq!(n0, n1);

            assert_eq!(
                weighted_mse(&zbar, &eta, &y, &wts, t),
                mse_from_counts(&counts, &eta, &y, Some(&wts)),
                "weighted mse diverged (indexed={indexed})"
            );
            assert_eq!(
                weighted_mse(&zbar, &eta, &y, &ones, t),
                mse_from_counts(&counts, &eta, &y, None),
                "unit-weight mse diverged (indexed={indexed})"
            );
        }
    }

    #[test]
    fn ridge_recovers_generating_eta() {
        let mut rng = Pcg64::seed_from_u64(3);
        let (d, t) = (400, 6);
        let eta_true: Vec<f64> = (0..t).map(|_| rng.next_gaussian()).collect();
        let mut zbar = vec![0.0f32; d * t];
        let mut y = vec![0.0f64; d];
        for di in 0..d {
            let theta = rng.next_dirichlet_sym(0.5, t);
            for ti in 0..t {
                zbar[di * t + ti] = theta[ti] as f32;
            }
            y[di] = theta.iter().zip(&eta_true).map(|(a, b)| a * b).sum();
        }
        let w = vec![1.0f64; d];
        let (eta, mse) = ridge_fit(&zbar, &y, &w, t, 1e-6, 0.0).unwrap();
        for (e, et) in eta.iter().zip(&eta_true) {
            assert!((e - et).abs() < 1e-2, "eta={eta:?} true={eta_true:?}");
        }
        assert!(mse < 1e-6, "mse={mse}");
    }

    #[test]
    fn ridge_shrinks_towards_mu() {
        // With an enormous lambda, eta -> mu regardless of data.
        let zbar = vec![0.5f32; 10 * 2];
        let y = vec![3.0f64; 10];
        let w = vec![1.0f64; 10];
        let (eta, _) = ridge_fit(&zbar, &y, &w, 2, 1e9, 0.7).unwrap();
        assert!((eta[0] - 0.7).abs() < 1e-3 && (eta[1] - 0.7).abs() < 1e-3);
    }

    #[test]
    fn zero_weights_are_ignored() {
        let mut rng = Pcg64::seed_from_u64(4);
        let t = 3;
        let mut zbar: Vec<f32> = (0..20 * t).map(|_| rng.next_f32()).collect();
        let y: Vec<f64> = (0..20).map(|_| rng.next_gaussian()).collect();
        let mut w = vec![1.0f64; 20];
        let (eta1, _) = ridge_fit(&zbar, &y, &w, t, 0.1, 0.0).unwrap();
        // corrupt rows 15.. but zero their weights
        for v in &mut zbar[15 * t..] {
            *v = 999.0;
        }
        for wi in &mut w[15..] {
            *wi = 0.0;
        }
        let y2: Vec<f64> =
            y.iter().enumerate().map(|(i, &v)| if i >= 15 { 1e6 } else { v }).collect();
        let zbar1: Vec<f32> = zbar[..15 * t].to_vec();
        let (eta_ref, _) = ridge_fit(&zbar1, &y[..15], &w[..15], t, 0.1, 0.0).unwrap();
        let (eta2, _) = ridge_fit(&zbar, &y2, &w, t, 0.1, 0.0).unwrap();
        for (a, b) in eta2.iter().zip(&eta_ref) {
            assert!((a - b).abs() < 1e-9);
        }
        let _ = eta1;
    }
}
