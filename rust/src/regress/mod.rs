//! Regression substrate: the ridge solve behind the stochastic-EM eta step
//! (paper eq. 2). The native path (`ridge`) is used directly by the native
//! engine and as the T x T back-end of the chunked-gram XLA path.

pub mod ridge;
