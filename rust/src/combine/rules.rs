//! Combination rules (paper eqs. 6-9).

use crate::config::schema::ResponseKind;
use crate::runtime::EngineHandle;

/// How Weighted Average derives its weights (paper §III-C-d).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightScheme {
    /// w_m ∝ 1 / MSE_train^(m) — continuous responses (eq. 8).
    InverseMse,
    /// w_m ∝ accuracy_train^(m) — binary responses.
    Accuracy,
    /// Equal weights (makes Weighted degenerate to Simple; ablation arm).
    Uniform,
}

impl WeightScheme {
    /// The paper's default scheme for a response kind.
    pub fn for_response(r: ResponseKind) -> WeightScheme {
        match r {
            ResponseKind::Continuous => WeightScheme::InverseMse,
            ResponseKind::Binary => WeightScheme::Accuracy,
        }
    }
}

/// Prediction-space combination rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CombineRule {
    /// Simple Average (eq. 7).
    Simple,
    /// Weighted Average (eqs. 8-9).
    Weighted(WeightScheme),
    /// Per-document median of the local predictions — the robust
    /// combination suggested by the median-posterior line of work the
    /// paper builds on (Minsker et al. 2014, paper ref. [5]). Extension
    /// beyond the paper: immune to a minority of corrupted shards.
    Median,
}

/// Compute unnormalized weights from per-shard training prediction quality.
/// `train_mse[m]` / `train_acc[m]` come from predicting the *whole* training
/// set with shard m's local model.
pub fn weights(
    rule: CombineRule,
    train_mse: &[f64],
    train_acc: &[f64],
) -> anyhow::Result<Vec<f64>> {
    let m = train_mse.len().max(train_acc.len());
    anyhow::ensure!(m > 0, "no shards to weight");
    let w = match rule {
        CombineRule::Simple
        | CombineRule::Median
        | CombineRule::Weighted(WeightScheme::Uniform) => vec![1.0; m],
        CombineRule::Weighted(WeightScheme::InverseMse) => {
            anyhow::ensure!(train_mse.len() == m, "missing train MSEs");
            train_mse
                .iter()
                .map(|&mse| {
                    anyhow::ensure!(mse.is_finite() && mse >= 0.0, "bad train MSE {mse}");
                    Ok(1.0 / mse.max(1e-12))
                })
                .collect::<anyhow::Result<Vec<f64>>>()?
        }
        CombineRule::Weighted(WeightScheme::Accuracy) => {
            anyhow::ensure!(train_acc.len() == m, "missing train accuracies");
            train_acc
                .iter()
                .map(|&acc| {
                    anyhow::ensure!((0.0..=1.0).contains(&acc), "bad train accuracy {acc}");
                    Ok(acc.max(1e-12))
                })
                .collect::<anyhow::Result<Vec<f64>>>()?
        }
    };
    Ok(w)
}

/// Combine local predictions into the global prediction (eq. 6) via the
/// engine (AOT `combine_M*` artifact on the XLA path).
pub fn combine_predictions(
    engine: &EngineHandle,
    local_preds: &[Vec<f64>],
    w: &[f64],
) -> anyhow::Result<Vec<f64>> {
    engine.combine(local_preds, w)
}

/// Per-document median combination (the [`CombineRule::Median`] rule).
/// Runs coordinator-side: an order statistic over M <= 16 values per
/// document is not worth an XLA round trip.
pub fn combine_median(local_preds: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
    anyhow::ensure!(!local_preds.is_empty(), "no predictions to combine");
    let b = local_preds[0].len();
    anyhow::ensure!(local_preds.iter().all(|p| p.len() == b), "ragged prediction rows");
    let m = local_preds.len();
    let mut buf = vec![0.0f64; m];
    let mut out = Vec::with_capacity(b);
    for j in 0..b {
        for (i, p) in local_preds.iter().enumerate() {
            buf[i] = p[j];
        }
        // total_cmp, not partial_cmp().unwrap(): a shard that emits NaN
        // (e.g. a degenerate eta fit) must not panic the coordinator. NaNs
        // order last, so they only influence the median when a majority of
        // shards are already broken.
        buf.sort_by(|a, b| a.total_cmp(b));
        out.push(if m % 2 == 1 {
            buf[m / 2]
        } else {
            0.5 * (buf[m / 2 - 1] + buf[m / 2])
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_is_uniform() {
        let w = weights(CombineRule::Simple, &[0.1, 0.2], &[]).unwrap();
        assert_eq!(w, vec![1.0, 1.0]);
    }

    #[test]
    fn inverse_mse_prefers_better_shards() {
        let w = weights(CombineRule::Weighted(WeightScheme::InverseMse), &[0.1, 0.4], &[]).unwrap();
        assert!((w[0] / w[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_weights() {
        let w = weights(CombineRule::Weighted(WeightScheme::Accuracy), &[], &[0.9, 0.6]).unwrap();
        assert!((w[0] / w[1] - 1.5).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_stats() {
        assert!(weights(CombineRule::Weighted(WeightScheme::InverseMse), &[f64::NAN], &[]).is_err());
        assert!(weights(CombineRule::Weighted(WeightScheme::Accuracy), &[], &[1.5]).is_err());
        assert!(weights(CombineRule::Simple, &[], &[]).is_err());
    }

    #[test]
    fn combine_through_native_engine() {
        let engine = EngineHandle::native();
        let preds = vec![vec![1.0, 3.0], vec![3.0, 5.0]];
        // simple average
        let w = weights(CombineRule::Simple, &[0.0, 0.0], &[]).unwrap();
        let out = combine_predictions(&engine, &preds, &w).unwrap();
        assert_eq!(out, vec![2.0, 4.0]);
        // weighted: shard 0 has mse 0.1, shard 1 mse 0.3 -> w = (10, 10/3)
        let w = weights(CombineRule::Weighted(WeightScheme::InverseMse), &[0.1, 0.3], &[]).unwrap();
        let out = combine_predictions(&engine, &preds, &w).unwrap();
        let w0 = 0.75;
        assert!((out[0] - (w0 * 1.0 + 0.25 * 3.0)).abs() < 1e-9);
    }

    #[test]
    fn median_combination() {
        // odd M: exact middle; robust to one wild shard
        let preds = vec![vec![1.0, 10.0], vec![2.0, 11.0], vec![999.0, -999.0]];
        let out = combine_median(&preds).unwrap();
        assert_eq!(out, vec![2.0, 10.0]);
        // even M: midpoint of the two central values
        let preds = vec![vec![1.0], vec![2.0], vec![3.0], vec![100.0]];
        assert_eq!(combine_median(&preds).unwrap(), vec![2.5]);
        // median weights are uniform (only used for accounting)
        let w = weights(CombineRule::Median, &[0.1], &[]).unwrap();
        assert_eq!(w, vec![1.0]);
        assert!(combine_median(&[]).is_err());
    }

    #[test]
    fn median_survives_nan_predictions() {
        // Regression: a NaN from one shard used to panic the
        // partial_cmp().unwrap() sort. With total_cmp the NaN orders last
        // and the median of the remaining healthy shards wins.
        let preds = vec![vec![1.0, f64::NAN], vec![2.0, 10.0], vec![f64::NAN, 11.0]];
        let out = combine_median(&preds).unwrap();
        assert_eq!(out[0], 2.0); // [1, 2, NaN] -> middle = 2
        assert_eq!(out[1], 11.0); // [10, 11, NaN] -> middle = 11
        // even M: midpoint of two central finite values
        let preds = vec![vec![1.0], vec![3.0], vec![f64::NAN], vec![2.0]];
        assert_eq!(combine_median(&preds).unwrap(), vec![2.5]);
    }

    #[test]
    fn scheme_for_response() {
        use crate::config::schema::ResponseKind::*;
        assert_eq!(WeightScheme::for_response(Continuous), WeightScheme::InverseMse);
        assert_eq!(WeightScheme::for_response(Binary), WeightScheme::Accuracy);
    }
}
