//! The paper's combination stage (§III-C): turn M per-shard local results
//! into one global prediction.
//!
//! * [`CombineRule::Simple`] — arithmetic average of local predictions (eq. 7).
//! * [`CombineRule::Weighted`] — weighted average (eqs. 8-9); weights are the
//!   inverse training-set MSE (continuous) or training-set accuracy (binary),
//!   computed by predicting the **whole training set** with each local model
//!   (this is exactly why the paper measures Weighted Average slower than
//!   Non-parallel).
//! * Naive Combination is not a prediction combiner — it pools topic samples
//!   before any prediction — and lives in `parallel::leader`.

pub mod artifact;
pub mod rules;

pub use artifact::ShardArtifact;
pub use rules::{combine_predictions, weights, CombineRule, WeightScheme};
