//! `CFSSHRD1` shard artifacts: the gather leg of multi-process training.
//!
//! `cfslda train-shard` persists one of these per worker process;
//! `cfslda combine` loads all M and applies the paper's combination rules.
//! The artifact carries exactly what [`run_prediction_combining`] consumes
//! from an in-process [`WorkerOutput`] — the local model, the shard's test
//! predictions, the test labels (so combining is standalone), and the
//! full-train quality pair behind the weighted rules — plus the config
//! fingerprint and `(shard_id, m)` coordinates so `combine` can refuse
//! mixing artifacts from different runs.
//!
//! Framing and hostile-input contract are the `ckpt/format` ones: 8-byte
//! magic | little-endian body | trailing FNV-1a-64, checksum verified
//! before structure, every length proven byte-backed before allocation.
//!
//! [`run_prediction_combining`]: crate::parallel::leader
//! [`WorkerOutput`]: crate::parallel::worker::WorkerOutput

use crate::config::schema::ResponseKind;
use crate::model::persist::fnv1a;
use crate::model::slda::SldaModel;
use anyhow::bail;

pub const ARTIFACT_MAGIC: &[u8; 8] = b"CFSSHRD1";

/// Plausibility ceilings (shared with the model loader / ckpt formats).
const MAX_T: usize = 1 << 16;
const MAX_W: usize = 1 << 28;
const MAX_D: usize = 1 << 28;
const MAX_SHARDS: usize = 1 << 10;
const MAX_NAME: usize = 64;

/// Everything one `train-shard` process hands to `combine`.
#[derive(Clone, Debug)]
pub struct ShardArtifact {
    /// [`config_fingerprint`] of the producing run — `combine` requires all
    /// M artifacts to agree.
    ///
    /// [`config_fingerprint`]: crate::ckpt::config_fingerprint
    pub fingerprint: u64,
    /// Combination algorithm name (`Algorithm::name()` of the run).
    pub algorithm: String,
    pub shard_id: u32,
    /// Total shard count M of the run.
    pub m: u32,
    pub response: ResponseKind,
    /// This shard's local model (eta, phi, rho, alpha, train quality).
    pub model: SldaModel,
    /// Local predictions on the shared test set.
    pub test_yhat: Vec<f64>,
    /// Test labels, in the same order (every artifact carries a copy;
    /// `combine` cross-checks them bit-for-bit across shards).
    pub test_labels: Vec<f64>,
    /// Full-train quality `(mse, acc)` — present for the weighted rules.
    pub full_train_quality: Option<(f64, f64)>,
    pub tokens_sampled: u64,
    /// Documents in this shard.
    pub docs: u64,
}

fn frame(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + body.len() + 8);
    out.extend_from_slice(ARTIFACT_MAGIC);
    out.extend_from_slice(body);
    out.extend_from_slice(&fnv1a(body).to_le_bytes());
    out
}

fn unframe(bytes: &[u8]) -> anyhow::Result<&[u8]> {
    if bytes.len() < 16 {
        bail!("truncated shard artifact: {} bytes, need at least 16", bytes.len());
    }
    if &bytes[..8] != ARTIFACT_MAGIC {
        bail!("not a shard artifact (bad magic {:02x?}, want \"CFSSHRD1\")", &bytes[..8]);
    }
    let (body, ck) = bytes[8..].split_at(bytes.len() - 16);
    let want = u64::from_le_bytes(ck.try_into().unwrap());
    if fnv1a(body) != want {
        bail!("shard artifact checksum mismatch — corrupted file");
    }
    Ok(body)
}

/// Bounds-checked little-endian cursor (the `ckpt/format` idiom).
struct Cur<'a> {
    body: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let avail = self.body.len() - self.off;
        if n > avail {
            bail!(
                "truncated shard artifact body at offset {}: need {n} bytes, {avail} available",
                self.off
            );
        }
        let s = &self.body[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn ensure_backed(&self, n: usize, elem_bytes: usize, field: &str) -> anyhow::Result<()> {
        let need = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| anyhow::anyhow!("artifact length {n} for '{field}' overflows"))?;
        let avail = self.body.len() - self.off;
        if need > avail {
            bail!(
                "truncated shard artifact body at offset {}: '{field}' needs {need} bytes, \
                 {avail} available",
                self.off
            );
        }
        Ok(())
    }

    fn vec_f32(&mut self, n: usize, field: &str) -> anyhow::Result<Vec<f32>> {
        self.ensure_backed(n, 4, field)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn vec_f64(&mut self, n: usize, field: &str) -> anyhow::Result<Vec<f64>> {
        self.ensure_backed(n, 8, field)?;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> anyhow::Result<()> {
        if self.off != self.body.len() {
            bail!(
                "trailing bytes in shard artifact body: {} past offset {}",
                self.body.len() - self.off,
                self.off
            );
        }
        Ok(())
    }
}

impl ShardArtifact {
    pub fn encode(&self) -> Vec<u8> {
        let m = &self.model;
        let mut b = Vec::with_capacity(
            128 + m.eta.len() * 8 + m.phi.len() * 4 + self.test_yhat.len() * 16,
        );
        b.extend_from_slice(&self.fingerprint.to_le_bytes());
        debug_assert!(self.algorithm.len() <= MAX_NAME);
        b.push(self.algorithm.len() as u8);
        b.extend_from_slice(self.algorithm.as_bytes());
        b.extend_from_slice(&self.shard_id.to_le_bytes());
        b.extend_from_slice(&self.m.to_le_bytes());
        b.push(match self.response {
            ResponseKind::Continuous => 0,
            ResponseKind::Binary => 1,
        });
        b.extend_from_slice(&(m.t as u32).to_le_bytes());
        b.extend_from_slice(&(m.w as u32).to_le_bytes());
        b.extend_from_slice(&m.rho.to_le_bytes());
        b.extend_from_slice(&m.alpha.to_le_bytes());
        b.extend_from_slice(&m.train_mse.to_le_bytes());
        b.extend_from_slice(&m.train_acc.to_le_bytes());
        for &e in &m.eta {
            b.extend_from_slice(&e.to_le_bytes());
        }
        for &p in &m.phi {
            b.extend_from_slice(&p.to_le_bytes());
        }
        b.extend_from_slice(&(self.test_yhat.len() as u64).to_le_bytes());
        for &y in &self.test_yhat {
            b.extend_from_slice(&y.to_le_bytes());
        }
        for &y in &self.test_labels {
            b.extend_from_slice(&y.to_le_bytes());
        }
        match self.full_train_quality {
            Some((mse, acc)) => {
                b.push(1);
                b.extend_from_slice(&mse.to_le_bytes());
                b.extend_from_slice(&acc.to_le_bytes());
            }
            None => b.push(0),
        }
        b.extend_from_slice(&self.tokens_sampled.to_le_bytes());
        b.extend_from_slice(&self.docs.to_le_bytes());
        frame(&b)
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<ShardArtifact> {
        let body = unframe(bytes)?;
        let mut c = Cur { body, off: 0 };
        let fingerprint = c.u64()?;
        let name_len = c.u8()? as usize;
        if name_len == 0 || name_len > MAX_NAME {
            bail!("implausible algorithm name length {name_len}");
        }
        let algorithm = std::str::from_utf8(c.take(name_len)?)
            .map_err(|_| anyhow::anyhow!("algorithm name is not UTF-8"))?
            .to_string();
        let shard_id = c.u32()?;
        let m = c.u32()?;
        if m == 0 || m as usize > MAX_SHARDS || shard_id >= m {
            bail!("implausible shard coordinates {shard_id}/{m}");
        }
        let response = match c.u8()? {
            0 => ResponseKind::Continuous,
            1 => ResponseKind::Binary,
            x => bail!("bad response kind byte {x}"),
        };
        let t = c.u32()? as usize;
        let w = c.u32()? as usize;
        if t < 2 || t > MAX_T || w == 0 || w > MAX_W {
            bail!("implausible model dims t={t} w={w}");
        }
        let rho = c.f64()?;
        let alpha = c.f64()?;
        let train_mse = c.f64()?;
        let train_acc = c.f64()?;
        let eta = c.vec_f64(t, "eta")?;
        let phi = c.vec_f32(w.checked_mul(t).unwrap_or(usize::MAX), "phi")?;
        let n_test = c.u64()? as usize;
        if n_test > MAX_D {
            bail!("implausible test-set size {n_test}");
        }
        let test_yhat = c.vec_f64(n_test, "test_yhat")?;
        let test_labels = c.vec_f64(n_test, "test_labels")?;
        let full_train_quality = match c.u8()? {
            0 => None,
            1 => Some((c.f64()?, c.f64()?)),
            x => bail!("bad full-train flag {x}"),
        };
        let tokens_sampled = c.u64()?;
        let docs = c.u64()?;
        c.done()?;
        Ok(ShardArtifact {
            fingerprint,
            algorithm,
            shard_id,
            m,
            response,
            model: SldaModel { t, w, eta, phi, rho, alpha, train_mse, train_acc },
            test_yhat,
            test_labels,
            full_train_quality,
            tokens_sampled,
            docs,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let bytes = self.encode();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &bytes)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<ShardArtifact> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading shard artifact {path:?}: {e}"))?;
        Self::decode(&bytes).map_err(|e| anyhow::anyhow!("decoding {path:?}: {e}"))
    }

    /// Conventional file name: `shard-<j>of<m>.shrd`.
    pub fn file_name(shard_id: u32, m: u32) -> String {
        format!("shard-{shard_id}of{m}.shrd")
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    pub(crate) fn sample(seed: u64, shard_id: u32, m: u32) -> ShardArtifact {
        let mut rng = Pcg64::seed_from_u64(seed);
        let (t, w, n_test) = (4usize, 7usize, 5usize);
        ShardArtifact {
            fingerprint: 0xFEED_F00D ^ seed,
            algorithm: "weighted-average".to_string(),
            shard_id,
            m,
            response: ResponseKind::Continuous,
            model: SldaModel {
                t,
                w,
                eta: (0..t).map(|_| rng.next_gaussian()).collect(),
                phi: (0..w * t).map(|_| rng.next_f32()).collect(),
                rho: 0.8,
                alpha: 1.25,
                train_mse: 0.4,
                train_acc: 0.75,
            },
            test_yhat: (0..n_test).map(|_| rng.next_gaussian()).collect(),
            test_labels: (0..n_test).map(|_| rng.next_gaussian()).collect(),
            full_train_quality: Some((0.31, 0.8)),
            tokens_sampled: 999,
            docs: 12,
        }
    }

    fn assert_artifact_eq(a: &ShardArtifact, b: &ShardArtifact) {
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.algorithm, b.algorithm);
        assert_eq!(a.shard_id, b.shard_id);
        assert_eq!(a.m, b.m);
        assert_eq!(a.response, b.response);
        assert_eq!(a.model.t, b.model.t);
        assert_eq!(a.model.w, b.model.w);
        assert_eq!(a.model.eta, b.model.eta);
        assert_eq!(a.model.phi, b.model.phi);
        assert_eq!(a.model.rho, b.model.rho);
        assert_eq!(a.model.alpha, b.model.alpha);
        assert_eq!(a.model.train_mse, b.model.train_mse);
        assert_eq!(a.model.train_acc, b.model.train_acc);
        assert_eq!(a.test_yhat, b.test_yhat);
        assert_eq!(a.test_labels, b.test_labels);
        assert_eq!(a.full_train_quality, b.full_train_quality);
        assert_eq!(a.tokens_sampled, b.tokens_sampled);
        assert_eq!(a.docs, b.docs);
    }

    #[test]
    fn artifact_roundtrips_exactly() {
        let a = sample(1, 2, 4);
        let back = ShardArtifact::decode(&a.encode()).unwrap();
        assert_artifact_eq(&a, &back);
        // no-full-train variant (simple / median rules)
        let mut a = sample(2, 0, 1);
        a.full_train_quality = None;
        a.response = ResponseKind::Binary;
        let back = ShardArtifact::decode(&a.encode()).unwrap();
        assert_artifact_eq(&a, &back);
    }

    #[test]
    fn save_load_roundtrips() {
        let a = sample(3, 1, 2);
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_artifact_{}.shrd", std::process::id()));
        a.save(&p).unwrap();
        let back = ShardArtifact::load(&p).unwrap();
        assert_artifact_eq(&a, &back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn hostile_coordinates_and_lengths_rejected() {
        let bytes = sample(4, 0, 2).encode();
        // bit flip → checksum error before any structure is trusted
        let mut b = bytes.clone();
        b[bytes.len() / 2] ^= 0x40;
        assert!(ShardArtifact::decode(&b).unwrap_err().to_string().contains("checksum"));
        // shard_id >= m (restamped)
        let body_of = |b: &[u8]| b[8..b.len() - 8].to_vec();
        let reframe = |body: &[u8]| {
            let mut out = Vec::new();
            out.extend_from_slice(ARTIFACT_MAGIC);
            out.extend_from_slice(body);
            out.extend_from_slice(&fnv1a(body).to_le_bytes());
            out
        };
        let mut body = body_of(&bytes);
        // shard_id sits after fingerprint (8) + name len (1) + name
        let name_len = body[8] as usize;
        let off = 9 + name_len;
        body[off..off + 4].copy_from_slice(&9u32.to_le_bytes());
        let err = ShardArtifact::decode(&reframe(&body)).unwrap_err().to_string();
        assert!(err.contains("shard coordinates"), "{err}");
        // hostile test count dies on byte-backing, not allocation
        let a = sample(5, 0, 2);
        let bytes = a.encode();
        let mut body = body_of(&bytes);
        let n_test_off = 8
            + 1
            + a.algorithm.len()
            + 4
            + 4
            + 1
            + 4
            + 4
            + 8 * 4
            + a.model.eta.len() * 8
            + a.model.phi.len() * 4;
        body[n_test_off..n_test_off + 8].copy_from_slice(&(1u64 << 60).to_le_bytes());
        let err = ShardArtifact::decode(&reframe(&body)).unwrap_err().to_string();
        assert!(err.contains("implausible test-set size"), "{err}");
    }

    #[test]
    fn mangled_artifact_never_panics() {
        use crate::testkit::{forall, usize_in};
        let base = sample(6, 1, 4).encode();
        forall(
            "mangled CFSSHRD1",
            150,
            |rng| {
                let mut b = base.clone();
                match rng.gen_range(3) {
                    0 => {
                        let i = rng.gen_range(b.len());
                        b[i] ^= 1 << rng.gen_range(8);
                        b
                    }
                    1 => {
                        let n = usize_in(rng, 0, b.len() - 1);
                        b.truncate(n);
                        b
                    }
                    _ => {
                        let body = &base[8..base.len() - 8];
                        let n = usize_in(rng, 0, body.len() - 1);
                        let mut out = Vec::new();
                        out.extend_from_slice(ARTIFACT_MAGIC);
                        out.extend_from_slice(&body[..n]);
                        out.extend_from_slice(&fnv1a(&body[..n]).to_le_bytes());
                        out
                    }
                }
            },
            |bytes| {
                let _ = ShardArtifact::decode(bytes);
            },
        );
    }
}
