//! Preregistered metric sets and Prometheus text-format exposition.
//!
//! All cells are created up front (`ServeMetrics` per server instance,
//! `TrainingMetrics`/`LogMetrics` in the process-global
//! [`crate::obs::registry`]), so the record path never allocates or takes
//! a lock. Exposition renders into a caller-owned reusable `String` (the
//! serve layer keeps one per connection, `JsonWriter`-style) in a fixed
//! metric order, so two renders of identical state are byte-identical.
//!
//! Naming scheme: `cfslda_<area>_<what>[_total|_seconds|_bytes]` with
//! low-cardinality labels only (`endpoint`, `level`, `shard`, `phase`).
//! Latency histograms record microseconds internally and are scaled to
//! seconds at render time.

use std::fmt::Write;

use super::cell::{Counter, Gauge};
use super::hist::{Histogram, BUCKETS};

/// Seconds per recorded microsecond: scale factor applied at render time.
const US_TO_SECS: f64 = 1e-6;

/// Endpoints with dedicated latency histograms, in render order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Endpoint {
    Healthz = 0,
    Metrics = 1,
    Predict = 2,
    PredictText = 3,
    Reload = 4,
    Stats = 5,
    Other = 6,
}

pub const ENDPOINT_COUNT: usize = 7;

impl Endpoint {
    pub fn classify(method: &str, path: &str) -> Endpoint {
        match (method, path) {
            ("GET", "/healthz") => Endpoint::Healthz,
            ("GET", "/metrics") => Endpoint::Metrics,
            ("POST", "/predict") => Endpoint::Predict,
            ("POST", "/predict/text") => Endpoint::PredictText,
            ("POST", "/reload") => Endpoint::Reload,
            ("GET", "/stats") => Endpoint::Stats,
            _ => Endpoint::Other,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Endpoint::Healthz => "healthz",
            Endpoint::Metrics => "metrics",
            Endpoint::Predict => "predict",
            Endpoint::PredictText => "predict_text",
            Endpoint::Reload => "reload",
            Endpoint::Stats => "stats",
            Endpoint::Other => "other",
        }
    }

    pub fn all() -> [Endpoint; ENDPOINT_COUNT] {
        [
            Endpoint::Healthz,
            Endpoint::Metrics,
            Endpoint::Predict,
            Endpoint::PredictText,
            Endpoint::Reload,
            Endpoint::Stats,
            Endpoint::Other,
        ]
    }
}

/// Serve-side metric set. One instance per [`crate::serve::Server`]
/// (shared with its batcher via `Arc`), replacing the old hand-rolled
/// `ServeStats` atomics.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub requests: Counter,
    pub errors: Counter,
    pub reloads: Counter,
    pub predict_docs: Counter,
    pub batches: Counter,
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    /// Client connections accepted (both backends; includes ones
    /// subsequently shed by the open-connection admission limit).
    pub accepted: Counter,
    /// Requests/connections refused by admission control (`503
    /// Retry-After`): over the open-connection limit or the batcher
    /// queue bound.
    pub shed: Counter,
    /// Currently open client connections.
    pub open_connections: Gauge,
    /// Work items queued in the batcher, sampled after each queue op.
    pub queue_depth: Gauge,
    /// Coalescing wait per formed batch, in microseconds.
    pub batch_wait: Histogram,
    /// Event-loop iteration time (epoll backend): microseconds spent
    /// processing one `epoll_wait` batch, excluding the wait itself.
    pub loop_iteration: Histogram,
    /// Request latency per endpoint, in microseconds.
    pub latency: [Histogram; ENDPOINT_COUNT],
}

impl ServeMetrics {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const HIST: Histogram = Histogram::new();
        ServeMetrics {
            requests: Counter::new(),
            errors: Counter::new(),
            reloads: Counter::new(),
            predict_docs: Counter::new(),
            batches: Counter::new(),
            cache_hits: Counter::new(),
            cache_misses: Counter::new(),
            accepted: Counter::new(),
            shed: Counter::new(),
            open_connections: Gauge::new(),
            queue_depth: Gauge::new(),
            batch_wait: HIST,
            loop_iteration: HIST,
            latency: [HIST; ENDPOINT_COUNT],
        }
    }

    #[inline]
    pub fn latency_for(&self, ep: Endpoint) -> &Histogram {
        &self.latency[ep as usize]
    }
}

/// Maximum number of per-shard gauges rendered; shards beyond this still
/// train, they just are not individually exposed.
pub const SHARD_SLOTS: usize = 64;

/// Training-side metric set. Lives in the process-global registry:
/// training runs once per process and serving can co-expose whatever the
/// trainer recorded.
#[derive(Debug)]
pub struct TrainingMetrics {
    pub sweeps: Counter,
    pub tokens: Counter,
    /// Tokens/s of the most recent completed sweep.
    pub tokens_per_sec: Gauge,
    pub resp_proposed: Counter,
    pub resp_accepted: Counter,
    pub alias_rebuilds: Counter,
    /// Configured alias staleness budget of the active kernel.
    pub alias_staleness: Gauge,
    pub shards_total: Gauge,
    pub shards_done: Gauge,
    /// Tokens sampled by each finished shard (first `SHARD_SLOTS` shards).
    pub shard_tokens: [Gauge; SHARD_SLOTS],
    pub comm_setup_bytes: Gauge,
    pub comm_corpus_bytes: Gauge,
    pub comm_model_bytes: Gauge,
    pub comm_predictions_bytes: Gauge,
    /// Shard snapshot files committed (renamed into place).
    pub ckpt_writes: Counter,
    /// Checkpoint generations committed (manifest landed).
    pub ckpt_generations: Counter,
    /// Checkpoint write attempts that failed (training continues).
    pub ckpt_failures: Counter,
    /// Shard states restored on `--resume`.
    pub ckpt_restores: Counter,
    /// Sweep index captured by the last committed generation.
    pub ckpt_last_sweep: Gauge,
    /// Total serialized bytes of the last committed generation.
    pub ckpt_last_bytes: Gauge,
    /// Wall time spent writing the last committed generation, microseconds.
    pub ckpt_last_write_us: Gauge,
    /// Unix timestamp of the last committed generation (0 = none yet);
    /// checkpoint age is `time() - this` in PromQL. Kept as a timestamp
    /// rather than an age so exposition stays byte-stable for fixed state.
    pub ckpt_last_unix_secs: Gauge,
}

impl Default for TrainingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl TrainingMetrics {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const GAUGE: Gauge = Gauge::new();
        TrainingMetrics {
            sweeps: Counter::new(),
            tokens: Counter::new(),
            tokens_per_sec: Gauge::new(),
            resp_proposed: Counter::new(),
            resp_accepted: Counter::new(),
            alias_rebuilds: Counter::new(),
            alias_staleness: Gauge::new(),
            shards_total: Gauge::new(),
            shards_done: Gauge::new(),
            shard_tokens: [GAUGE; SHARD_SLOTS],
            comm_setup_bytes: Gauge::new(),
            comm_corpus_bytes: Gauge::new(),
            comm_model_bytes: Gauge::new(),
            comm_predictions_bytes: Gauge::new(),
            ckpt_writes: Counter::new(),
            ckpt_generations: Counter::new(),
            ckpt_failures: Counter::new(),
            ckpt_restores: Counter::new(),
            ckpt_last_sweep: Gauge::new(),
            ckpt_last_bytes: Gauge::new(),
            ckpt_last_write_us: Gauge::new(),
            ckpt_last_unix_secs: Gauge::new(),
        }
    }
}

/// Counters fed by the logger: every record at `warn`/`error` level lands
/// here so `/metrics` reflects log noise without scraping stderr.
#[derive(Debug, Default)]
pub struct LogMetrics {
    pub warns: Counter,
    pub errors: Counter,
}

impl LogMetrics {
    pub const fn new() -> Self {
        LogMetrics {
            warns: Counter::new(),
            errors: Counter::new(),
        }
    }
}

/// Render the full exposition for one server instance plus the
/// process-global training/log registries.
pub fn render_prometheus(serve: &ServeMetrics, buf: &mut String) {
    let reg = super::registry();
    render_parts(serve, &reg.training, &reg.log, buf);
}

/// Deterministic render of explicit metric sets; `buf` is cleared first.
/// Separated from [`render_prometheus`] so tests can render isolated,
/// locally-owned sets without the process-global registry.
pub fn render_parts(
    serve: &ServeMetrics,
    train: &TrainingMetrics,
    log: &LogMetrics,
    buf: &mut String,
) {
    buf.clear();
    counter(buf, "cfslda_http_requests_total", "HTTP requests accepted.", serve.requests.get());
    counter(buf, "cfslda_http_errors_total", "HTTP responses with status >= 400.", serve.errors.get());
    counter(buf, "cfslda_model_reloads_total", "Successful POST /reload hot swaps.", serve.reloads.get());
    counter(buf, "cfslda_predict_docs_total", "Documents scored by the batcher.", serve.predict_docs.get());
    counter(buf, "cfslda_predict_batches_total", "Batches drained by batcher workers.", serve.batches.get());
    counter(buf, "cfslda_cache_hits_total", "Prediction LRU cache hits.", serve.cache_hits.get());
    counter(buf, "cfslda_cache_misses_total", "Prediction LRU cache misses.", serve.cache_misses.get());
    counter(buf, "cfslda_accepted_total", "Client connections accepted.", serve.accepted.get());
    counter(buf, "cfslda_shed_total", "Connections/requests shed by admission control (503 Retry-After).", serve.shed.get());
    gauge(buf, "cfslda_open_connections", "Currently open client connections.", serve.open_connections.get());
    gauge(buf, "cfslda_batch_queue_depth", "Work items waiting in the batcher queue.", serve.queue_depth.get());
    histogram(
        buf,
        "cfslda_batch_wait_seconds",
        "Coalescing wait before a batch is drained.",
        &[("", "", &serve.batch_wait)],
    );
    histogram(
        buf,
        "cfslda_event_loop_iteration_seconds",
        "Time processing one epoll_wait batch (epoll backend only).",
        &[("", "", &serve.loop_iteration)],
    );
    let lat: Vec<(&str, &str, &Histogram)> = Endpoint::all()
        .iter()
        .map(|&ep| ("endpoint", ep.label(), serve.latency_for(ep)))
        .collect();
    histogram(
        buf,
        "cfslda_request_duration_seconds",
        "Wall time from parsed request to flushed response.",
        &lat,
    );
    header(buf, "cfslda_log_messages_total", "Log records by severity (warn and above).", "counter");
    series_u64(buf, "cfslda_log_messages_total", "level", "error", log.errors.get());
    series_u64(buf, "cfslda_log_messages_total", "level", "warn", log.warns.get());

    counter(buf, "cfslda_train_sweeps_total", "Completed Gibbs sweeps across all shards.", train.sweeps.get());
    counter(buf, "cfslda_train_tokens_total", "Token-level sampling steps performed.", train.tokens.get());
    gauge(buf, "cfslda_train_tokens_per_sec", "Throughput of the most recent completed sweep.", train.tokens_per_sec.get());
    counter(buf, "cfslda_train_resp_proposed_total", "Metropolis-Hastings response proposals.", train.resp_proposed.get());
    counter(buf, "cfslda_train_resp_accepted_total", "Accepted Metropolis-Hastings response proposals.", train.resp_accepted.get());
    counter(buf, "cfslda_train_alias_rebuilds_total", "Alias tables rebuilt after staleness expiry.", train.alias_rebuilds.get());
    gauge(buf, "cfslda_train_alias_staleness", "Configured alias staleness budget (uses per table).", train.alias_staleness.get());
    gauge(buf, "cfslda_train_shards_total", "Shards in the current parallel run.", train.shards_total.get());
    gauge(buf, "cfslda_train_shards_done", "Shards that finished training.", train.shards_done.get());
    let shards = (train.shards_total.get() as usize).min(SHARD_SLOTS);
    if shards > 0 {
        header(buf, "cfslda_train_shard_tokens", "Tokens sampled by each finished shard.", "gauge");
        let mut label = String::with_capacity(4);
        for (i, cell) in train.shard_tokens.iter().take(shards).enumerate() {
            label.clear();
            let _ = write!(label, "{i}");
            series_u64(buf, "cfslda_train_shard_tokens", "shard", &label, cell.get());
        }
    }
    header(buf, "cfslda_comm_bytes", "Communication ledger totals by phase.", "gauge");
    series_u64(buf, "cfslda_comm_bytes", "phase", "corpus", train.comm_corpus_bytes.get());
    series_u64(buf, "cfslda_comm_bytes", "phase", "model", train.comm_model_bytes.get());
    series_u64(buf, "cfslda_comm_bytes", "phase", "predictions", train.comm_predictions_bytes.get());
    series_u64(buf, "cfslda_comm_bytes", "phase", "setup", train.comm_setup_bytes.get());
    counter(buf, "cfslda_ckpt_writes_total", "Shard snapshot files committed.", train.ckpt_writes.get());
    counter(buf, "cfslda_ckpt_generations_total", "Checkpoint generations committed (manifest landed).", train.ckpt_generations.get());
    counter(buf, "cfslda_ckpt_failures_total", "Checkpoint write attempts that failed.", train.ckpt_failures.get());
    counter(buf, "cfslda_ckpt_restores_total", "Shard states restored on resume.", train.ckpt_restores.get());
    gauge(buf, "cfslda_ckpt_last_sweep", "Sweep captured by the last committed generation.", train.ckpt_last_sweep.get());
    gauge(buf, "cfslda_ckpt_last_bytes", "Serialized bytes of the last committed generation.", train.ckpt_last_bytes.get());
    let last_us = train.ckpt_last_write_us.get();
    header(buf, "cfslda_ckpt_last_write_seconds", "Wall time writing the last committed generation.", "gauge");
    let _ = writeln!(buf, "cfslda_ckpt_last_write_seconds {}", last_us as f64 * US_TO_SECS);
    gauge(
        buf,
        "cfslda_ckpt_last_timestamp_seconds",
        "Unix time of the last committed generation (0 = none); age = time() - this.",
        train.ckpt_last_unix_secs.get(),
    );
}

fn header(buf: &mut String, name: &str, help: &str, kind: &str) {
    let _ = writeln!(buf, "# HELP {name} {help}");
    let _ = writeln!(buf, "# TYPE {name} {kind}");
}

fn series_u64(buf: &mut String, name: &str, key: &str, val: &str, v: u64) {
    let _ = writeln!(buf, "{name}{{{key}=\"{val}\"}} {v}");
}

fn counter(buf: &mut String, name: &str, help: &str, v: u64) {
    header(buf, name, help, "counter");
    let _ = writeln!(buf, "{name} {v}");
}

fn gauge(buf: &mut String, name: &str, help: &str, v: u64) {
    header(buf, name, help, "gauge");
    let _ = writeln!(buf, "{name} {v}");
}

/// Render one histogram family. Each entry is `(label_key, label_value,
/// hist)`; an empty `label_key` renders an unlabeled series. Bucket
/// bounds and sums are scaled from recorded microseconds to seconds.
fn histogram(buf: &mut String, name: &str, help: &str, series: &[(&str, &str, &Histogram)]) {
    header(buf, name, help, "histogram");
    for &(key, val, h) in series {
        let snap = h.snapshot();
        let mut cum = 0u64;
        for (i, &c) in snap.counts.iter().enumerate() {
            cum += c;
            let _ = write!(buf, "{name}_bucket{{");
            if !key.is_empty() {
                let _ = write!(buf, "{key}=\"{val}\",");
            }
            if i == BUCKETS {
                let _ = writeln!(buf, "le=\"+Inf\"}} {cum}");
            } else {
                let bound = (1u64 << i) as f64 * US_TO_SECS;
                let _ = writeln!(buf, "le=\"{bound}\"}} {cum}");
            }
        }
        let sum_secs = snap.sum as f64 * US_TO_SECS;
        if key.is_empty() {
            let _ = writeln!(buf, "{name}_sum {sum_secs}");
            let _ = writeln!(buf, "{name}_count {cum}");
        } else {
            let _ = writeln!(buf, "{name}_sum{{{key}=\"{val}\"}} {sum_secs}");
            let _ = writeln!(buf, "{name}_count{{{key}=\"{val}\"}} {cum}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_covers_routes() {
        assert_eq!(Endpoint::classify("GET", "/healthz"), Endpoint::Healthz);
        assert_eq!(Endpoint::classify("GET", "/metrics"), Endpoint::Metrics);
        assert_eq!(Endpoint::classify("POST", "/predict"), Endpoint::Predict);
        assert_eq!(Endpoint::classify("POST", "/predict/text"), Endpoint::PredictText);
        assert_eq!(Endpoint::classify("POST", "/reload"), Endpoint::Reload);
        assert_eq!(Endpoint::classify("GET", "/stats"), Endpoint::Stats);
        assert_eq!(Endpoint::classify("GET", "/nope"), Endpoint::Other);
        assert_eq!(Endpoint::classify("PUT", "/predict"), Endpoint::Other);
    }

    #[test]
    fn render_is_byte_stable_across_identical_states() {
        let serve = ServeMetrics::new();
        let train = TrainingMetrics::new();
        let log = LogMetrics::new();
        serve.requests.add(3);
        serve.latency_for(Endpoint::Predict).observe(250);
        train.sweeps.add(10);
        train.shards_total.set(2);
        train.shard_tokens[0].set(123);
        log.warns.inc();

        let mut a = String::new();
        let mut b = String::new();
        render_parts(&serve, &train, &log, &mut a);
        render_parts(&serve, &train, &log, &mut b);
        assert!(!a.is_empty());
        assert_eq!(a, b, "identical state must render identical bytes");
    }

    #[test]
    fn render_has_expected_series_and_shapes() {
        let serve = ServeMetrics::new();
        let train = TrainingMetrics::new();
        let log = LogMetrics::new();
        serve.requests.add(5);
        serve.errors.inc();
        serve.latency_for(Endpoint::Predict).observe(100);
        serve.latency_for(Endpoint::Predict).observe(100_000);
        serve.accepted.add(4);
        serve.shed.inc();
        serve.open_connections.set(3);
        serve.loop_iteration.observe(42);
        let mut out = String::new();
        render_parts(&serve, &train, &log, &mut out);

        assert!(out.contains("# TYPE cfslda_http_requests_total counter\ncfslda_http_requests_total 5\n"));
        assert!(out.contains("cfslda_http_errors_total 1\n"));
        assert!(out.contains("# TYPE cfslda_accepted_total counter\ncfslda_accepted_total 4\n"));
        assert!(out.contains("# TYPE cfslda_shed_total counter\ncfslda_shed_total 1\n"));
        assert!(out.contains("# TYPE cfslda_open_connections gauge\ncfslda_open_connections 3\n"));
        assert!(out.contains("# TYPE cfslda_event_loop_iteration_seconds histogram\n"));
        assert!(out.contains("cfslda_event_loop_iteration_seconds_count 1\n"));
        assert!(out.contains("cfslda_request_duration_seconds_bucket{endpoint=\"predict\",le=\"+Inf\"} 2\n"));
        assert!(out.contains("cfslda_request_duration_seconds_count{endpoint=\"predict\"} 2\n"));
        assert!(out.contains("cfslda_request_duration_seconds_sum{endpoint=\"predict\"} 0.1001\n"));
        assert!(out.contains("cfslda_log_messages_total{level=\"warn\"} 0\n"));
        assert!(out.contains("cfslda_comm_bytes{phase=\"setup\"} 0\n"));
        assert!(out.contains("# TYPE cfslda_ckpt_writes_total counter\ncfslda_ckpt_writes_total 0\n"));
        assert!(out.contains("# TYPE cfslda_ckpt_failures_total counter\ncfslda_ckpt_failures_total 0\n"));
        assert!(out.contains("# TYPE cfslda_ckpt_last_sweep gauge\ncfslda_ckpt_last_sweep 0\n"));
        assert!(out.contains("cfslda_ckpt_last_write_seconds 0\n"));
        assert!(out.contains("cfslda_ckpt_last_timestamp_seconds 0\n"));
        // No shard gauges when shards_total is 0.
        assert!(!out.contains("cfslda_train_shard_tokens{"));

        // Every non-comment line is `name[{labels}] value`.
        for line in out.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("space-separated sample");
            assert!(!series.is_empty());
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
        }
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_monotonic() {
        let serve = ServeMetrics::new();
        for v in [1u64, 10, 100, 1000, 10_000, 1 << 30] {
            serve.batch_wait.observe(v);
        }
        let mut out = String::new();
        render_parts(&serve, &TrainingMetrics::new(), &LogMetrics::new(), &mut out);
        let mut last = 0u64;
        let mut inf = 0u64;
        for line in out.lines() {
            if let Some(rest) = line.strip_prefix("cfslda_batch_wait_seconds_bucket{le=\"") {
                let (_, v) = rest.rsplit_once(' ').unwrap();
                let c: u64 = v.parse().unwrap();
                assert!(c >= last, "non-monotonic cumulative bucket in {line:?}");
                last = c;
                if rest.starts_with("+Inf") {
                    inf = c;
                }
            }
        }
        assert_eq!(inf, 6);
        assert!(out.contains("cfslda_batch_wait_seconds_count 6\n"));
    }
}
