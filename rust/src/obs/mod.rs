//! Lock-free, allocation-free-at-record-time observability core.
//!
//! Three layers:
//!
//! * [`cell`] — atomic [`Counter`]/[`Gauge`] cells, const-constructible.
//! * [`hist`] — log-bucketed fixed-array [`Histogram`]s (no allocation on
//!   `observe`, percentiles derived from cumulative bucket counts).
//! * [`expo`] — preregistered metric sets ([`ServeMetrics`],
//!   [`TrainingMetrics`], [`LogMetrics`]) and deterministic Prometheus
//!   text-format rendering into a reusable buffer.
//!
//! The process-global [`Registry`] holds the training and log metric
//! sets; serve metrics are per-server instances (so tests and benches can
//! boot isolated servers in one process) and are joined with the global
//! registry at exposition time by [`expo::render_prometheus`], served at
//! `GET /metrics`.
//!
//! Every record-path operation is a relaxed atomic RMW on a preallocated
//! cell: instrumenting the warmed `/predict` path keeps the
//! `bench-alloc` zero-allocation pin intact.

pub mod cell;
pub mod expo;
pub mod hist;

pub use cell::{Counter, Gauge};
pub use expo::{
    render_parts, render_prometheus, Endpoint, LogMetrics, ServeMetrics, TrainingMetrics,
    ENDPOINT_COUNT, SHARD_SLOTS,
};
pub use hist::{bucket_index, upper_bound, HistSnapshot, Histogram, BUCKETS};

/// Process-global metric sets: training telemetry (one training run per
/// process) and logger severity counters.
#[derive(Debug)]
pub struct Registry {
    pub training: TrainingMetrics,
    pub log: LogMetrics,
}

static REGISTRY: Registry = Registry {
    training: TrainingMetrics::new(),
    log: LogMetrics::new(),
};

/// The process-global registry. Cells are preregistered statics; callers
/// record directly into them with no setup step.
pub fn registry() -> &'static Registry {
    &REGISTRY
}
