//! Log-bucketed atomic histogram with a fixed bucket array.
//!
//! Values are recorded as raw `u64`s (the serve layer feeds in
//! microseconds); bucket `i` covers `(2^{i-1}, 2^i]` so the array spans
//! 1 µs … 2^27 µs ≈ 134 s with one extra overflow bucket. `observe` is
//! two relaxed `fetch_add`s — no locks, no allocation — so it is safe to
//! call from the zero-alloc warmed `/predict` path and from sampler inner
//! loops. Percentiles are derived from the cumulative bucket counts and
//! report the upper bound of the bucket containing the requested rank,
//! which is exact to within one power-of-two bucket.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of finite buckets; bucket `BUCKETS` is the +Inf overflow bucket.
pub const BUCKETS: usize = 28;

/// Upper bound (inclusive) of finite bucket `i`, in recorded units.
#[inline]
pub fn upper_bound(i: usize) -> u64 {
    1u64 << i.min(BUCKETS)
}

/// Index of the bucket that `v` falls in: the smallest `i` with
/// `v <= 2^i`, clamped to the overflow bucket.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        return 0;
    }
    let i = 64 - (v - 1).leading_zeros() as usize;
    if i >= BUCKETS {
        BUCKETS
    } else {
        i
    }
}

/// Fixed-size lock-free histogram. Const-constructible so metric sets can
/// live in `static`s.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS + 1],
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            counts: [ZERO; BUCKETS + 1],
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value. Allocation-free and lock-free.
    #[inline]
    pub fn observe(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Consistent-enough point-in-time copy (relaxed loads).
    pub fn snapshot(&self) -> HistSnapshot {
        let mut counts = [0u64; BUCKETS + 1];
        for (dst, src) in counts.iter_mut().zip(self.counts.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistSnapshot {
            counts,
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Plain-integer copy of a [`Histogram`], used for exposition and
/// percentile math.
#[derive(Debug, Clone, Copy)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS + 1],
    pub sum: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Upper bound of the bucket holding the `q`-quantile sample
    /// (`q` in `[0, 1]`), in recorded units. Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return upper_bound(i);
            }
        }
        upper_bound(BUCKETS)
    }

    pub fn mean(&self) -> f64 {
        let total = self.count();
        if total == 0 {
            0.0
        } else {
            self.sum as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 27), BUCKETS - 1);
        assert_eq!(bucket_index((1 << 27) + 1), BUCKETS);
        assert_eq!(bucket_index(u64::MAX), BUCKETS);
    }

    #[test]
    fn every_value_lands_at_or_below_its_bound() {
        for v in 1u64..=4096 {
            let i = bucket_index(v);
            assert!(v <= upper_bound(i), "v={v} i={i}");
            if i > 0 {
                assert!(v > upper_bound(i - 1), "v={v} i={i}");
            }
        }
    }

    #[test]
    fn quantiles_on_exact_distribution() {
        let h = Histogram::new();
        // 50 samples at 1us, 45 at 100us, 5 at 10_000us.
        for _ in 0..50 {
            h.observe(1);
        }
        for _ in 0..45 {
            h.observe(100);
        }
        for _ in 0..5 {
            h.observe(10_000);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        assert_eq!(s.sum, 50 + 45 * 100 + 5 * 10_000);
        // p50 rank = 50 -> still inside the 1us bucket.
        assert_eq!(s.quantile(0.50), 1);
        // p95 rank = 95 -> the bucket holding 100us is (64,128].
        assert_eq!(s.quantile(0.95), 128);
        // p99 rank = 99 -> the bucket holding 10_000us is (8192,16384].
        assert_eq!(s.quantile(0.99), 16_384);
        assert_eq!(s.quantile(1.0), 16_384);
        assert_eq!(s.quantile(0.0), 1);
    }

    #[test]
    fn overflow_bucket_catches_huge_values() {
        let h = Histogram::new();
        h.observe(u64::MAX);
        h.observe(1 << 30);
        let s = h.snapshot();
        assert_eq!(s.counts[BUCKETS], 2);
        assert_eq!(s.count(), 2);
        assert_eq!(s.quantile(0.5), upper_bound(BUCKETS));
        assert_eq!(s.sum, u64::MAX.wrapping_add(1 << 30));
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.99), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn concurrent_observe_sums_correctly() {
        let h = Arc::new(Histogram::new());
        let threads = 8;
        let per_thread = 10_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        // Mix of buckets, deterministic per thread.
                        h.observe((t as u64 * 37 + i) % 1000 + 1);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count(), threads as u64 * per_thread);
        let mut expect_sum = 0u64;
        for t in 0..threads as u64 {
            for i in 0..per_thread {
                expect_sum += (t * 37 + i) % 1000 + 1;
            }
        }
        assert_eq!(s.sum, expect_sum);
    }
}
