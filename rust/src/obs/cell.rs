//! Preregistered atomic metric cells: `Counter` and `Gauge`.
//!
//! Both are single `AtomicU64`s with relaxed ordering. They are
//! const-constructible so metric sets can live in `static`s, and every
//! record-path operation (`inc`/`add`/`set`) is a single `fetch_add` or
//! `store` — no locks, no allocation. That property is what lets the
//! warmed `/predict` path keep its zero-allocation pin (see
//! `tests/json_streaming.rs`) with metrics recording enabled.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge holding a `u64` (depths, byte totals, rates).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Wrapping decrement (connection counts and other up/down gauges).
    #[inline]
    pub fn sub(&self, n: u64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn gauge_last_write_wins() {
        let g = Gauge::new();
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
        g.add(4);
        assert_eq!(g.get(), 7);
        g.sub(5);
        assert_eq!(g.get(), 2);
    }
}
