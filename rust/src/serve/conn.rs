//! Per-connection state machine for the epoll backend.
//!
//! Each connection advances through
//! `ReadHead → ReadBody → (Dispatched) → WriteResponse → ReadHead …`
//! entirely from readiness callbacks — no thread ever blocks on it:
//!
//! * **ReadHead** — bytes accumulate in `inbuf`; [`http::parse_head`]
//!   re-parses the prefix on each arrival until the blank line lands.
//! * **ReadBody** — waits until `Content-Length` bytes follow the head.
//! * **Dispatched** — `/predict` and `/predict/text` ride the shared
//!   micro-batcher via [`Batcher::submit_streamed_notify`]; the worker
//!   that fills the last slot signals the reactor's eventfd and the
//!   reactor calls [`Conn::poll_completion`]. Everything else is answered
//!   inline through the same [`server::route`] the threads backend uses.
//! * **WriteResponse** — the response is rendered into `outbuf` by the
//!   *same* `http::write_response_*` writers as the threads backend
//!   (`Vec<u8>` implements `Write`), which makes the byte-identical
//!   response contract structural rather than aspirational; the buffer
//!   then drains through non-blocking writes.
//!
//! **Pipelining.** One request is in flight per connection; bytes of
//! follow-on pipelined requests simply accumulate in `inbuf` and parse as
//! soon as the current response finishes writing, so responses always
//! return in request order.
//!
//! **Buffer discipline.** `inbuf`/`outbuf`, the [`RequestScratch`] and the
//! [`ConnScratch`] (arena builder, pooled completion, results/yhat
//! staging, JSON writer) are all owned per connection and recycled across
//! keep-alive requests — a warmed `/predict` request is handled without
//! heap allocation, exactly as on the threads backend.
//!
//! [`Batcher::submit_streamed_notify`]: crate::serve::batcher::Batcher::submit_streamed_notify

use crate::data::corpus::TokenArena;
use crate::obs::Endpoint;
use crate::serve::batcher::Waker;
use crate::serve::http::{self, RequestScratch};
use crate::serve::protocol;
use crate::serve::server::{self, BodyKind, ConnScratch, HttpError, OpenConnGuard, State};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

/// What the reactor should do with the connection after a callback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Step {
    /// Keep the connection registered (re-derive interest from
    /// [`Conn::wants_write`]).
    Continue,
    /// Deregister and drop the connection.
    Close,
}

enum ConnState {
    /// Accumulating request-head bytes.
    ReadHead,
    /// Head parsed; waiting for the declared body bytes.
    ReadBody { head_len: usize, content_length: usize },
    /// A predict batch is in the micro-batcher; waiting on the eventfd.
    Dispatched,
    /// Draining `outbuf` to the socket.
    WriteResponse,
}

/// In-flight predict dispatch (the retry state for hot-swap races).
struct Dispatch {
    seed: u64,
    /// `/predict/text`: re-encode against the current vocabulary on retry.
    is_text: bool,
    attempts: usize,
    /// Version pin for the text path (ids only mean something under the
    /// vocabulary that produced them).
    want: Option<u64>,
    arena: Option<Arc<TokenArena>>,
}

pub(crate) struct Conn {
    stream: TcpStream,
    state: ConnState,
    /// Received-but-unconsumed bytes (incl. pipelined follow-on requests).
    inbuf: Vec<u8>,
    /// Rendered response bytes not yet written to the socket.
    outbuf: Vec<u8>,
    outpos: usize,
    req: RequestScratch,
    out: ConnScratch,
    /// Completion of the last request (idle-reap reference point).
    last_activity: Instant,
    /// Armed while a request is partially read; [`Conn::timed_out`].
    read_deadline: Option<Instant>,
    keep_alive: bool,
    close_after_write: bool,
    peer_eof: bool,
    dispatch: Option<Dispatch>,
    /// Request start (latency histograms span parse → response queued).
    t0: Instant,
    ep: Endpoint,
    _open: OpenConnGuard,
}

impl Conn {
    pub(crate) fn new(stream: TcpStream, open: OpenConnGuard) -> Conn {
        stream.set_nodelay(true).ok();
        Conn {
            stream,
            state: ConnState::ReadHead,
            inbuf: Vec::with_capacity(4 * 1024),
            outbuf: Vec::with_capacity(4 * 1024),
            outpos: 0,
            req: RequestScratch::new(),
            out: ConnScratch::new(),
            last_activity: Instant::now(),
            read_deadline: None,
            keep_alive: true,
            close_after_write: false,
            peer_eof: false,
            dispatch: None,
            t0: Instant::now(),
            ep: Endpoint::classify("GET", "/healthz"),
            _open: open,
        }
    }

    pub(crate) fn raw_fd(&self) -> i32 {
        use std::os::unix::io::AsRawFd;
        self.stream.as_raw_fd()
    }

    /// Does the reactor need EPOLLOUT for this connection right now?
    pub(crate) fn wants_write(&self) -> bool {
        self.outpos < self.outbuf.len()
    }

    pub(crate) fn is_dispatched(&self) -> bool {
        matches!(self.state, ConnState::Dispatched)
    }

    /// Should the reactor reap this connection at `now`? Mid-request
    /// stalls hit the read deadline; quiet keep-alive connections hit the
    /// idle timeout. (The threads backend answers a mid-request stall
    /// with `400`; here the connection simply closes — the byte-identical
    /// contract covers well-formed request streams only.)
    pub(crate) fn timed_out(&self, state: &State, now: Instant) -> bool {
        if let Some(d) = self.read_deadline {
            if now >= d {
                return true;
            }
        }
        if matches!(self.state, ConnState::ReadHead)
            && self.inbuf.is_empty()
            && !self.wants_write()
        {
            if let Some(limit) = state.idle_timeout {
                return now.duration_since(self.last_activity) >= limit;
            }
        }
        false
    }

    /// EPOLLIN: drain the socket into `inbuf`, then pump the state machine.
    pub(crate) fn handle_readable(&mut self, state: &State, waker: &Arc<Waker>) -> Step {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.peer_eof = true;
                    break;
                }
                Ok(n) => {
                    self.inbuf.extend_from_slice(&chunk[..n]);
                    if n < chunk.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Step::Close,
            }
        }
        self.advance(state, waker)
    }

    /// EPOLLOUT: flush pending response bytes, then pump the state machine
    /// (a finished response may unblock a pipelined request in `inbuf`).
    pub(crate) fn handle_writable(&mut self, state: &State, waker: &Arc<Waker>) -> Step {
        self.advance(state, waker)
    }

    /// Eventfd/tick sweep: collect a ready batcher completion, render the
    /// response (or re-dispatch on a hot-swap race), and pump.
    pub(crate) fn poll_completion(&mut self, state: &State, waker: &Arc<Waker>) -> Step {
        if !matches!(self.state, ConnState::Dispatched) {
            return Step::Continue;
        }
        if !self.out.comp.try_take_into(&mut self.out.results) {
            return Step::Continue; // spurious wake; results still pending
        }
        let d = self.dispatch.take().expect("dispatched conn has dispatch state");
        self.resolve(state, waker, d);
        self.advance(state, waker)
    }

    /// The state-machine pump: loops until no further progress is possible
    /// without new readiness (or a batcher completion).
    fn advance(&mut self, state: &State, waker: &Arc<Waker>) -> Step {
        loop {
            match self.state {
                ConnState::ReadHead => match http::parse_head(&self.inbuf, &mut self.req) {
                    Ok(None) => {
                        if self.peer_eof {
                            // Clean close between requests, or EOF
                            // mid-head — either way nothing to answer.
                            return Step::Close;
                        }
                        if !self.inbuf.is_empty() && self.read_deadline.is_none() {
                            self.read_deadline =
                                state.read_timeout.map(|t| Instant::now() + t);
                        }
                        return Step::Continue;
                    }
                    Ok(Some(info)) => {
                        if self.read_deadline.is_none() {
                            self.read_deadline =
                                state.read_timeout.map(|t| Instant::now() + t);
                        }
                        self.state = ConnState::ReadBody {
                            head_len: info.head_len,
                            content_length: info.content_length,
                        };
                    }
                    Err(e) => {
                        self.queue_parse_error(state, &format!("{e:#}"));
                    }
                },
                ConnState::ReadBody { head_len, content_length } => {
                    let total = head_len + content_length;
                    if self.inbuf.len() < total {
                        if self.peer_eof {
                            return Step::Close; // body can never complete
                        }
                        return Step::Continue;
                    }
                    self.req.set_body(&self.inbuf[head_len..total]);
                    self.inbuf.drain(..total);
                    self.read_deadline = None;
                    self.begin_request(state, waker);
                }
                ConnState::Dispatched => return Step::Continue,
                ConnState::WriteResponse => match self.flush_out() {
                    Ok(true) => {
                        self.outbuf.clear();
                        self.outpos = 0;
                        if self.close_after_write || !self.keep_alive {
                            return Step::Close;
                        }
                        self.last_activity = Instant::now();
                        self.state = ConnState::ReadHead;
                        // Loop: a pipelined request may already be buffered.
                    }
                    Ok(false) => return Step::Continue, // socket full; EPOLLOUT
                    Err(_) => return Step::Close,
                },
            }
        }
    }

    /// One fully-framed request is in `self.req`; answer it inline or
    /// dispatch it to the batcher.
    fn begin_request(&mut self, state: &State, waker: &Arc<Waker>) {
        state.stats.requests.inc();
        self.t0 = Instant::now();
        self.ep = Endpoint::classify(self.req.method(), self.req.path());
        self.keep_alive = !self.req.wants_close();
        if !server::is_batched(self.req.method(), self.req.path()) {
            // Inline endpoints (healthz/stats/metrics/reload/404/405) go
            // through the exact routing the threads backend uses; none of
            // route's blocking predict arms can execute here.
            let status = server::route(state, &self.req, &mut self.out);
            self.queue_response(state, status);
            return;
        }
        self.out.body_kind = BodyKind::Json;
        self.out.retry_after = None;
        let is_text = self.req.path() == "/predict/text";
        let parsed = if is_text {
            protocol::parse_text_streamed(self.req.body(), &mut self.out.texts)
        } else {
            protocol::parse_predict_streamed(self.req.body(), &mut self.out.builder)
        };
        let seed = match parsed {
            Ok(s) => s.unwrap_or(state.default_seed),
            Err(e) => {
                self.queue_http_error(state, server::bad_request(format!("{e:#}")));
                return;
            }
        };
        self.dispatch =
            Some(Dispatch { seed, is_text, attempts: 0, want: None, arena: None });
        self.try_dispatch(state, waker);
    }

    /// One submission attempt for the current [`Dispatch`]. Text requests
    /// (re-)encode against the current vocabulary first.
    fn try_dispatch(&mut self, state: &State, waker: &Arc<Waker>) {
        let mut d = self.dispatch.take().expect("try_dispatch without dispatch state");
        if d.is_text {
            match server::encode_texts_against_current(state, &mut self.out) {
                Ok(v) => d.want = Some(v),
                Err(e) => {
                    self.queue_http_error(state, e);
                    return;
                }
            }
            d.arena = Some(Arc::new(self.out.builder.finish()));
        } else if d.arena.is_none() {
            d.arena = Some(Arc::new(self.out.builder.finish()));
        }
        let arena = Arc::clone(d.arena.as_ref().unwrap());
        if arena.num_docs() == 0 {
            // Same outcome as the threads backend: nothing to enqueue, the
            // (empty) result set renders immediately.
            self.out.results.clear();
            self.resolve(state, waker, d);
            return;
        }
        if !state.batcher.submit_streamed_notify(arena, d.seed, &self.out.comp, waker) {
            state.stats.shed.inc();
            self.reclaim(d.arena.take());
            self.queue_http_error(state, server::overloaded());
            return;
        }
        self.dispatch = Some(d);
        self.state = ConnState::Dispatched;
    }

    /// Results for one attempt are in `out.results`: render the response,
    /// or retry on a hot-swap race (same policy/limit as the threads
    /// backend's `SWAP_RACE_RETRIES` loop).
    fn resolve(&mut self, state: &State, waker: &Arc<Waker>, mut d: Dispatch) {
        match server::render_uniform(d.want, &mut self.out) {
            Ok(true) => {
                self.reclaim(d.arena.take());
                self.queue_response(state, 200);
            }
            Ok(false) => {
                d.attempts += 1;
                if d.attempts >= server::SWAP_RACE_RETRIES {
                    self.reclaim(d.arena.take());
                    self.queue_http_error(state, server::raced());
                    return;
                }
                if d.is_text {
                    // Stale-vocabulary encodings are useless; reclaim the
                    // buffers and re-encode in try_dispatch.
                    self.reclaim(d.arena.take());
                }
                self.dispatch = Some(d);
                self.try_dispatch(state, waker);
            }
            Err(e) => {
                self.reclaim(d.arena.take());
                self.queue_http_error(state, e);
            }
        }
    }

    /// Best-effort buffer recycling, mirroring the threads backend: if the
    /// batcher's clones are gone, the arena's buffers return to the
    /// builder; otherwise the next request simply reallocates.
    fn reclaim(&mut self, arena: Option<Arc<TokenArena>>) {
        if let Some(a) = arena {
            if let Ok(a) = Arc::try_unwrap(a) {
                self.out.builder.reclaim(a);
            }
        }
    }

    /// Unparseable request: `400` + close, byte-identical to the threads
    /// backend's parse-error path.
    fn queue_parse_error(&mut self, state: &State, msg: &str) {
        self.out.body_kind = BodyKind::Json;
        self.out.retry_after = None;
        protocol::error_response_into(&mut self.out.writer, msg);
        self.keep_alive = false;
        self.close_after_write = true;
        self.queue_response(state, 400);
    }

    fn queue_http_error(&mut self, state: &State, e: HttpError) {
        self.out.body_kind = BodyKind::Json;
        self.out.retry_after = e.retry_after;
        protocol::error_response_into(&mut self.out.writer, &e.msg);
        self.queue_response(state, e.status);
    }

    /// Frame the response currently in the scratch buffers into `outbuf`
    /// (via the shared `http` writers — `Vec<u8>: Write`, so the bytes are
    /// exactly the threads backend's) and switch to `WriteResponse`.
    fn queue_response(&mut self, state: &State, status: u16) {
        if status >= 400 {
            state.stats.errors.inc();
        }
        let (body, ctype): (&[u8], &str) = match self.out.body_kind {
            BodyKind::Json => (self.out.writer.as_str().as_bytes(), http::CT_JSON),
            BodyKind::Metrics => (self.out.metrics_buf.as_bytes(), http::CT_PROMETHEUS),
        };
        let keep_alive = self.keep_alive && !self.close_after_write;
        let framed = match self.out.retry_after {
            Some(secs) => http::write_response_retry_after(
                &mut self.outbuf,
                &mut self.out.head,
                status,
                body,
                keep_alive,
                secs,
            ),
            None => http::write_response_typed(
                &mut self.outbuf,
                &mut self.out.head,
                status,
                ctype,
                body,
                keep_alive,
            ),
        };
        debug_assert!(framed.is_ok(), "Vec<u8> writes are infallible");
        let _ = framed;
        if state.latency_hist {
            state.stats.latency_for(self.ep).observe(self.t0.elapsed().as_micros() as u64);
        }
        self.state = ConnState::WriteResponse;
    }

    /// Non-blocking drain of `outbuf`; `Ok(true)` = fully flushed.
    fn flush_out(&mut self) -> std::io::Result<bool> {
        while self.outpos < self.outbuf.len() {
            match self.stream.write(&self.outbuf[self.outpos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket write returned 0",
                    ))
                }
                Ok(n) => self.outpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(true)
    }
}
