//! Micro-batching prediction queue.
//!
//! Connection handlers enqueue one work item per document and block on a
//! per-request [`Completion`] rendezvous; a pool of worker threads drains
//! the shared queue in batches of up to `max_batch`, waiting up to
//! `max_wait_us` for concurrent requests to coalesce (the
//! pipelined/batched inference idea of Yan et al.'s *Towards Big Topic
//! Modeling*, applied to serving). Each worker owns a reusable
//! [`DocInfer`] scratch, so the hot path allocates nothing beyond the
//! zbar row.
//!
//! Request documents are assembled into one flat [`TokenArena`] per request
//! (the same CSR layout the training corpus uses — DESIGN.md §Memory
//! layout): every per-document work item holds an `Arc` of the request's
//! arena plus a doc index, so enqueueing N documents costs one token
//! allocation, not N.
//!
//! **Allocation discipline.** The [`Completion`] replaces the old
//! per-request `mpsc::channel` + results `Vec`: connections keep one
//! `Arc<Completion>` and one results `Vec` in their scratch and recycle
//! both across requests ([`Batcher::submit_streamed_into`]), so the
//! warmed end-to-end `/predict` path enqueues, waits, and collects with
//! zero heap allocations. Metrics land in preregistered
//! [`ServeMetrics`](crate::obs::ServeMetrics) cells (relaxed atomics),
//! which keeps that property.
//!
//! **Determinism.** Every document draws from a private RNG stream seeded
//! by `doc_stream_seed(seed, token_hash(doc))` against an immutable
//! [`ModelEntry`]. Predictions therefore depend only on
//! (model version, seed, document content) — never on batch composition,
//! queue order, worker count, or cache state. Repeating a request returns
//! byte-identical responses.

use crate::config::schema::{KernelKind, TrainConfig};
use crate::data::corpus::TokenArena;
use crate::obs::ServeMetrics;
use crate::sampler::gibbs_predict::{doc_stream_seed, token_hash, DocInfer};
use crate::serve::registry::{ModelEntry, Registry};
use crate::util::rng::Pcg64;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batcher knobs (a resolved subset of `config::schema::ServeConfig`).
#[derive(Clone)]
pub struct BatcherConfig {
    /// Worker thread count (>= 1, already resolved from `workers = 0`).
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait_us: u64,
    /// Admission bound: a submit whose documents would push the queue past
    /// this depth is refused (the HTTP layer sheds it with `503
    /// Retry-After`). 0 = unbounded.
    pub queue_depth_max: usize,
    pub kernel: KernelKind,
    pub train: TrainConfig,
    /// Test-only failpoint: a document containing this token id panics the
    /// worker mid-dispatch, exercising the per-item panic isolation
    /// (`worker_loop`'s `catch_unwind`). Always `None` in production.
    pub panic_token: Option<u32>,
}

/// One document's prediction outcome.
#[derive(Clone, Debug)]
pub struct DocOut {
    pub yhat: f64,
    pub model_version: u64,
    pub cached: bool,
}

/// Reusable rendezvous between one submitting request and the workers
/// resolving its documents. Holds a slot per document; workers fill slots
/// and wake the submitter when the last one lands. Connections pool one
/// of these (plus its slots `Vec`) across requests, so a warmed submit
/// performs no heap allocation where the old per-request
/// `mpsc::channel()` + results `Vec` allocated every time.
#[derive(Default)]
pub struct Completion {
    inner: Mutex<CompletionInner>,
    cv: Condvar,
}

#[derive(Default)]
struct CompletionInner {
    slots: Vec<Option<anyhow::Result<DocOut>>>,
    remaining: usize,
    /// Event-loop rendezvous: when armed with a [`Waker`], the last fill
    /// also signals the reactor's (coalesced) eventfd so the epoll loop
    /// wakes without any thread parked on the condvar.
    notify: Option<Arc<Waker>>,
}

/// Coalesced eventfd wakeup shared between batcher workers and the epoll
/// reactor. Under load many completions resolve between two reactor
/// iterations; without coalescing each one pays a `write(2)` on the
/// eventfd. The `pending` flag collapses such bursts: only the first
/// [`Waker::signal`] since the last [`Waker::clear_pending`] performs the
/// syscall, every later one is a lone atomic swap.
///
/// The fd is borrowed, not owned (the reactor closes its eventfd itself);
/// a signal after close is a harmless failed write.
pub struct Waker {
    fd: i32,
    pending: AtomicBool,
}

impl Waker {
    pub fn new(fd: i32) -> Waker {
        Waker { fd, pending: AtomicBool::new(false) }
    }

    /// Worker side: request a reactor wakeup. Best-effort — a failed
    /// write is ignored, because the reactor also sweeps completions on
    /// its timeout tick, so a lost wakeup degrades latency, not
    /// correctness.
    pub fn signal(&self) {
        if !self.pending.swap(true, Ordering::AcqRel) {
            let one: u64 = 1;
            unsafe {
                libc::write(self.fd, &one as *const u64 as *const libc::c_void, 8);
            }
        }
    }

    /// Reactor side: re-open the coalescing window. Must be called
    /// *after* draining the eventfd and *before* sweeping completions.
    /// Clearing before the drain could leave the flag sticky-true with
    /// the counter already empty (a concurrent signal sets the flag and
    /// writes, the drain then swallows that write), suppressing every
    /// future wakeup; clearing after the drain only risks one spurious
    /// extra write, and any signal coalesced away between drain and clear
    /// had already published its completion, which the sweep collects.
    pub fn clear_pending(&self) {
        self.pending.store(false, Ordering::Release);
    }
}

impl Completion {
    pub fn new() -> Completion {
        Completion::default()
    }

    /// Reset for a request of `n` documents, keeping slot capacity.
    fn arm(&self, n: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.slots.clear();
        inner.slots.resize_with(n, || None);
        inner.remaining = n;
        inner.notify = None;
    }

    /// [`Completion::arm`] for the event-loop path: the last fill signals
    /// the reactor's [`Waker`] instead of relying on a parked submitter
    /// thread.
    fn arm_notify(&self, n: usize, waker: &Arc<Waker>) {
        let mut inner = self.inner.lock().unwrap();
        inner.slots.clear();
        inner.slots.resize_with(n, || None);
        inner.remaining = n;
        inner.notify = Some(Arc::clone(waker));
    }

    /// Deliver one document's result. First write wins; the last write
    /// standing wakes the submitter (condvar and, when armed with one,
    /// the reactor's eventfd).
    fn fill(&self, slot: usize, res: anyhow::Result<DocOut>) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(s) = inner.slots.get_mut(slot) {
            if s.is_none() {
                *s = Some(res);
                inner.remaining -= 1;
                if inner.remaining == 0 {
                    self.cv.notify_all();
                    if let Some(w) = &inner.notify {
                        w.signal();
                    }
                }
            }
        }
    }

    /// Block until every slot is filled, then move the results into `out`
    /// (cleared first), preserving slot order.
    fn wait_into(&self, out: &mut Vec<anyhow::Result<DocOut>>) {
        let mut inner = self.inner.lock().unwrap();
        while inner.remaining > 0 {
            inner = self.cv.wait(inner).unwrap();
        }
        out.clear();
        out.extend(
            inner
                .slots
                .drain(..)
                .map(|o| o.unwrap_or_else(|| Err(anyhow::anyhow!("server shutting down")))),
        );
    }

    /// Non-blocking collect for the event-loop path: if every slot is
    /// filled, move the results into `out` (cleared first, slot order)
    /// and return `true`; otherwise leave `out` untouched and return
    /// `false` (spurious eventfd wakeups are fine — poll again later).
    pub fn try_take_into(&self, out: &mut Vec<anyhow::Result<DocOut>>) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.remaining > 0 || inner.slots.is_empty() {
            return false;
        }
        out.clear();
        out.extend(inner.slots.drain(..).map(|o| {
            o.unwrap_or_else(|| Err(anyhow::anyhow!("server shutting down")))
        }));
        true
    }
}

struct WorkItem {
    /// The owning request's flat token arena, shared across its items.
    docs: Arc<TokenArena>,
    /// This item's document index within the arena.
    doc: usize,
    seed: u64,
    slot: usize,
    comp: Arc<Completion>,
    done: bool,
}

impl WorkItem {
    #[inline]
    fn tokens(&self) -> &[u32] {
        self.docs.doc(self.doc)
    }

    fn complete(&mut self, res: anyhow::Result<DocOut>) {
        self.done = true;
        self.comp.fill(self.slot, res);
    }
}

impl Drop for WorkItem {
    /// An item dropped unresolved (worker panic, queue torn down) still
    /// releases its submitter instead of leaving it parked forever.
    fn drop(&mut self) {
        if !self.done {
            self.comp.fill(self.slot, Err(anyhow::anyhow!("server shutting down")));
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<WorkItem>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Incremental [`TokenArena`] assembly for the streaming request codec:
/// the protocol layer pushes token ids straight off the wire (no
/// per-document `Vec<Vec<u32>>` staging), and the buffers recycle across
/// requests via [`ArenaBuilder::reclaim`], so a warmed keep-alive
/// connection builds its request arena with zero heap allocations.
#[derive(Default)]
pub struct ArenaBuilder {
    tokens: Vec<u32>,
    /// CSR offsets; maintained as `[0, end_0, end_1, ...]`.
    offsets: Vec<u32>,
}

impl ArenaBuilder {
    pub fn new() -> ArenaBuilder {
        ArenaBuilder { tokens: Vec::new(), offsets: vec![0] }
    }

    /// Drop any partially-assembled request, keeping buffer capacity.
    pub fn clear(&mut self) {
        self.tokens.clear();
        self.offsets.clear();
        self.offsets.push(0);
    }

    #[inline]
    pub fn push_token(&mut self, t: u32) {
        self.tokens.push(t);
    }

    /// Close the current document. Errors only if the arena would exceed
    /// u32::MAX tokens (unreachable under the HTTP layer's 64 MiB body
    /// cap, but the offsets must never silently wrap).
    pub fn end_doc(&mut self) -> anyhow::Result<()> {
        let end = u32::try_from(self.tokens.len())
            .map_err(|_| anyhow::anyhow!("request arena exceeds u32::MAX tokens"))?;
        self.offsets.push(end);
        Ok(())
    }

    /// Completed documents so far.
    pub fn num_docs(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Tokens pushed since the last [`ArenaBuilder::end_doc`].
    pub fn cur_doc_len(&self) -> usize {
        self.tokens.len() - *self.offsets.last().unwrap() as usize
    }

    /// Move the assembled documents out as a [`TokenArena`], leaving the
    /// builder empty (and without its buffers — pair with `reclaim`).
    pub fn finish(&mut self) -> TokenArena {
        let arena = TokenArena {
            tokens: std::mem::take(&mut self.tokens),
            offsets: std::mem::take(&mut self.offsets),
        };
        self.offsets.push(0);
        arena
    }

    /// Take an arena's buffers back for the next request (best-effort:
    /// callers skip this when other `Arc` holders still exist).
    pub fn reclaim(&mut self, arena: TokenArena) {
        self.tokens = arena.tokens;
        self.offsets = arena.offsets;
        self.clear();
    }
}

/// The worker pool + queue handle. Dropping it drains and joins cleanly.
pub struct Batcher {
    shared: Arc<Shared>,
    stats: Arc<ServeMetrics>,
    queue_depth_max: usize,
    workers: Vec<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(
        cfg: BatcherConfig,
        registry: Arc<Registry>,
        stats: Arc<ServeMetrics>,
    ) -> Batcher {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let queue_depth_max = cfg.queue_depth_max;
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                let registry = Arc::clone(&registry);
                let stats = Arc::clone(&stats);
                let cfg = cfg.clone();
                std::thread::spawn(move || worker_loop(&shared, &registry, &stats, &cfg))
            })
            .collect();
        Batcher { shared, stats, queue_depth_max, workers }
    }

    /// Enqueue a request's documents and block until every one resolves.
    /// Per-document errors (e.g. a token id outside the current model's
    /// vocabulary) come back as `Err` in that document's slot. The request
    /// is flattened into one shared [`TokenArena`] up front — per-document
    /// work items borrow it through an `Arc` instead of owning a `Vec`.
    pub fn submit(&self, docs: &[Vec<u32>], seed: u64) -> Vec<anyhow::Result<DocOut>> {
        self.submit_streamed(Arc::new(TokenArena::from_docs(docs)), seed)
    }

    /// [`Batcher::submit`] for a pre-assembled arena — the streaming codec
    /// path. Convenience wrapper that allocates a fresh [`Completion`] and
    /// results `Vec` per call; the serve layer uses
    /// [`Batcher::submit_streamed_into`] with pooled ones instead.
    pub fn submit_streamed(
        &self,
        arena: Arc<TokenArena>,
        seed: u64,
    ) -> Vec<anyhow::Result<DocOut>> {
        let comp = Arc::new(Completion::new());
        let mut out = Vec::new();
        self.submit_streamed_into(arena, seed, &comp, &mut out);
        out
    }

    /// Enqueue a pre-assembled arena and collect results through
    /// caller-pooled buffers: `comp` is re-armed for this request and
    /// `out` is cleared and filled in document order. With a warmed
    /// `comp`/`out` (capacity from earlier requests) this path performs
    /// no heap allocation beyond queue growth.
    ///
    /// `comp` must not be shared with a concurrently submitting request.
    pub fn submit_streamed_into(
        &self,
        arena: Arc<TokenArena>,
        seed: u64,
        comp: &Arc<Completion>,
        out: &mut Vec<anyhow::Result<DocOut>>,
    ) {
        let n = arena.num_docs();
        out.clear();
        if n == 0 {
            return;
        }
        comp.arm(n);
        self.enqueue(&arena, seed, comp, n);
        // Workers drain the queue even during shutdown, and dropped items
        // fill their slot with an error, so every armed slot resolves.
        comp.wait_into(out);
    }

    /// Admission-controlled [`Batcher::submit_streamed_into`]: refuses the
    /// whole request (returning `false`, enqueueing nothing, leaving `out`
    /// cleared) when its documents would push the queue past
    /// `queue_depth_max`. The HTTP layer turns a refusal into `503
    /// Retry-After`.
    pub fn try_submit_streamed_into(
        &self,
        arena: Arc<TokenArena>,
        seed: u64,
        comp: &Arc<Completion>,
        out: &mut Vec<anyhow::Result<DocOut>>,
    ) -> bool {
        let n = arena.num_docs();
        out.clear();
        if n == 0 {
            return true;
        }
        comp.arm(n);
        if !self.enqueue_bounded(&arena, seed, comp, n) {
            return false;
        }
        comp.wait_into(out);
        true
    }

    /// Non-blocking, admission-controlled submit for the epoll reactor:
    /// arms `comp` so the *last* worker fill signals `waker` (the
    /// reactor's coalesced eventfd), enqueues, and returns immediately.
    /// Returns `false` (nothing enqueued) when the queue bound would be
    /// exceeded — the caller sheds the request. Collect results later
    /// with [`Completion::try_take_into`].
    pub fn submit_streamed_notify(
        &self,
        arena: Arc<TokenArena>,
        seed: u64,
        comp: &Arc<Completion>,
        waker: &Arc<Waker>,
    ) -> bool {
        let n = arena.num_docs();
        if n == 0 {
            // Arm zero slots so try_take_into reports not-ready; callers
            // handle the empty request inline without dispatching.
            comp.arm(0);
            return true;
        }
        comp.arm_notify(n, waker);
        self.enqueue_bounded(&arena, seed, comp, n)
    }

    fn enqueue(&self, arena: &Arc<TokenArena>, seed: u64, comp: &Arc<Completion>, n: usize) {
        self.enqueue_inner(arena, seed, comp, n, 0);
    }

    /// [`Batcher::enqueue`] with the admission bound applied atomically
    /// under the queue lock: all-or-nothing, so a shed request never
    /// leaves partial work behind.
    fn enqueue_bounded(
        &self,
        arena: &Arc<TokenArena>,
        seed: u64,
        comp: &Arc<Completion>,
        n: usize,
    ) -> bool {
        self.enqueue_inner(arena, seed, comp, n, self.queue_depth_max)
    }

    fn enqueue_inner(
        &self,
        arena: &Arc<TokenArena>,
        seed: u64,
        comp: &Arc<Completion>,
        n: usize,
        bound: usize,
    ) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        if bound > 0 && q.len() + n > bound {
            return false;
        }
        for slot in 0..n {
            q.push_back(WorkItem {
                docs: Arc::clone(arena),
                doc: slot,
                seed,
                slot,
                comp: Arc::clone(comp),
                done: false,
            });
        }
        self.stats.queue_depth.set(q.len() as u64);
        drop(q);
        self.shared.cv.notify_all();
        true
    }

    /// Queue depth right now (stats surface).
    pub fn backlog(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// The configured admission bound (0 = unbounded).
    pub fn queue_bound(&self) -> usize {
        self.queue_depth_max
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    shared: &Shared,
    registry: &Registry,
    stats: &ServeMetrics,
    cfg: &BatcherConfig,
) {
    let mut scratch: Option<DocInfer> = None;
    let mut zrow: Vec<f32> = Vec::new();
    loop {
        let mut waited_us = 0u64;
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && q.is_empty() {
                    return;
                }
                if !q.is_empty() {
                    break;
                }
                q = shared.cv.wait(q).unwrap();
            }
            // Coalesce: hold the batch open briefly so concurrent requests
            // ride along, up to the batch ceiling.
            if cfg.max_wait_us > 0 && q.len() < cfg.max_batch {
                let start = Instant::now();
                let deadline = start + Duration::from_micros(cfg.max_wait_us);
                while q.len() < cfg.max_batch && !shared.shutdown.load(Ordering::SeqCst) {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) = shared.cv.wait_timeout(q, deadline - now).unwrap();
                    q = guard;
                    if timeout.timed_out() {
                        break;
                    }
                }
                waited_us = start.elapsed().as_micros() as u64;
            }
            let take = q.len().min(cfg.max_batch);
            let batch = q.drain(..take).collect::<Vec<WorkItem>>();
            stats.queue_depth.set(q.len() as u64);
            batch
        };
        if batch.is_empty() {
            continue;
        }
        stats.batch_wait.observe(waited_us);
        // One entry per batch: a hot-swap between batches is picked up
        // here; within a batch the model is immutable.
        let entry = registry.current();
        let t = entry.model.t;
        if scratch.as_ref().map(|s| s.topics()) != Some(t) {
            scratch = Some(DocInfer::new(cfg.kernel, t));
            zrow = vec![0.0f32; t];
        }
        stats.batches.inc();
        stats.predict_docs.add(batch.len() as u64);
        for mut item in batch {
            // Per-doc *failures* (empty doc, out-of-vocab token) surface as
            // the request's 4xx and are counted once there (the HTTP
            // layer), not per document. Per-doc *panics* are isolated
            // here: a poisoned document takes down its own slot (a 500 for
            // that document), never the worker thread or the sibling
            // documents parked on other completions.
            let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let infer = scratch.as_mut().unwrap();
                predict_one(&entry, infer, &mut zrow, cfg, registry, stats, &item)
            }));
            let res = match unwound {
                Ok(res) => res,
                Err(payload) => {
                    // The scratch may hold arbitrary partial state after
                    // an unwound kernel; rebuild it so the next document
                    // starts clean.
                    scratch = Some(DocInfer::new(cfg.kernel, t));
                    zrow = vec![0.0f32; t];
                    stats.errors.inc();
                    Err(anyhow::anyhow!(
                        "prediction panicked on this document: {}",
                        panic_message(payload.as_ref())
                    ))
                }
            };
            item.complete(res);
        }
    }
}

/// Best-effort text of a caught panic payload (`&str` / `String` panics;
/// anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
        .unwrap_or("<non-string panic payload>")
}

fn predict_one(
    entry: &Arc<ModelEntry>,
    infer: &mut DocInfer,
    zrow: &mut [f32],
    cfg: &BatcherConfig,
    registry: &Registry,
    stats: &ServeMetrics,
    item: &WorkItem,
) -> anyhow::Result<DocOut> {
    let model = &entry.model;
    let tokens = item.tokens();
    if let Some(poison) = cfg.panic_token {
        // Stands in for a kernel panic on a pathological document; checked
        // before validation so the poison token needn't be in-vocab.
        assert!(!tokens.contains(&poison), "deliberate failpoint panic: poisoned document");
    }
    anyhow::ensure!(!tokens.is_empty(), "empty document");
    if let Some(&w) = tokens.iter().find(|&&w| w as usize >= model.w) {
        anyhow::bail!("token id {w} >= model vocab size {}", model.w);
    }
    let hash = token_hash(tokens);
    let key = (entry.version, item.seed, hash);
    if let Some(yhat) = registry.cache_get(key) {
        stats.cache_hits.inc();
        return Ok(DocOut { yhat, model_version: entry.version, cached: true });
    }
    stats.cache_misses.inc();
    let mut rng = Pcg64::seed_from_u64(doc_stream_seed(item.seed, hash));
    // The frozen-phi alias tables ride the entry Arc: built once at
    // load/hot-swap, shared by every worker (present whenever the
    // configured kernel may resolve to alias, ignored otherwise).
    infer.infer_doc(
        model,
        &entry.phi_cum,
        entry.phi_alias.as_ref(),
        &cfg.train,
        tokens,
        &mut rng,
        zrow,
    );
    let yhat = model.predict_zbar(zrow);
    registry.cache_put(key, yhat);
    Ok(DocOut { yhat, model_version: entry.version, cached: false })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::persist::save_model_with_vocab;
    use crate::model::slda::SldaModel;
    use crate::util::pool::scoped_map;

    fn tiny_model(seed: u64) -> SldaModel {
        let mut rng = Pcg64::seed_from_u64(seed);
        let (t, w) = (6usize, 40usize);
        // positive phi rows so every token has mass somewhere
        SldaModel {
            t,
            w,
            eta: (0..t).map(|_| rng.next_gaussian()).collect(),
            phi: (0..w * t).map(|_| 0.01 + rng.next_f32()).collect(),
            rho: 0.5,
            alpha: 0.4,
            train_mse: 0.2,
            train_acc: 0.8,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_batcher_{}_{name}", std::process::id()));
        p
    }

    fn quick_train() -> TrainConfig {
        TrainConfig {
            sweeps: 5,
            burnin: 1,
            eta_every: 1,
            predict_sweeps: 6,
            predict_burnin: 2,
            ..TrainConfig::default()
        }
    }

    fn start(
        name: &str,
        workers: usize,
        max_batch: usize,
        cache: usize,
    ) -> (Batcher, Arc<Registry>, Arc<ServeMetrics>, std::path::PathBuf) {
        let p = tmp(name);
        save_model_with_vocab(&tiny_model(5), None, &p).unwrap();
        let registry = Arc::new(Registry::open(&p, cache, true).unwrap());
        let stats = Arc::new(ServeMetrics::new());
        let cfg = BatcherConfig {
            workers,
            max_batch,
            max_wait_us: 200,
            queue_depth_max: 0,
            kernel: KernelKind::Auto,
            train: quick_train(),
            panic_token: None,
        };
        let b = Batcher::start(cfg, Arc::clone(&registry), Arc::clone(&stats));
        (b, registry, stats, p)
    }

    fn docs(n: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..n).map(|_| (0..12).map(|_| rng.gen_range(40) as u32).collect()).collect()
    }

    #[test]
    fn submit_resolves_every_doc_deterministically() {
        let (b, _reg, stats, p) = start("det", 3, 4, 0);
        let d = docs(17, 1);
        let r1: Vec<f64> =
            b.submit(&d, 9).into_iter().map(|r| r.unwrap().yhat).collect();
        let r2: Vec<f64> =
            b.submit(&d, 9).into_iter().map(|r| r.unwrap().yhat).collect();
        assert_eq!(r1.len(), 17);
        assert!(r1.iter().all(|y| y.is_finite()));
        assert_eq!(r1, r2, "same (model, seed, docs) must repeat exactly");
        // a different seed changes the draw
        let r3: Vec<f64> =
            b.submit(&d, 10).into_iter().map(|r| r.unwrap().yhat).collect();
        assert_ne!(r1, r3);
        assert_eq!(stats.predict_docs.get(), 17 * 3);
        assert!(stats.batches.get() >= 3 * 5); // ceil(17/4) each
        assert_eq!(stats.batch_wait.snapshot().count(), stats.batches.get());
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn concurrent_submissions_coalesce_and_stay_deterministic() {
        let (b, _reg, stats, p) = start("conc", 4, 8, 0);
        let base = docs(6, 2);
        let solo: Vec<Vec<f64>> = base
            .iter()
            .map(|d| {
                b.submit(std::slice::from_ref(d), 3).into_iter().map(|r| r.unwrap().yhat).collect()
            })
            .collect();
        // hammer from 8 threads concurrently; every thread sends the same
        // docs and must get the same answers back in its own slots
        let ids: Vec<usize> = (0..8).collect();
        let all = scoped_map(&ids, 8, |_, _| {
            b.submit(&base, 3)
                .into_iter()
                .map(|r| r.unwrap().yhat)
                .collect::<Vec<f64>>()
        });
        for got in &all {
            for (i, y) in got.iter().enumerate() {
                assert_eq!(*y, solo[i][0], "doc {i} drifted under concurrency");
            }
        }
        assert!(stats.errors.get() == 0);
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn cache_serves_repeats_and_batch_errors_are_per_doc() {
        let (b, _reg, stats, p) = start("cache", 2, 8, 64);
        let d = docs(3, 3);
        let first: Vec<DocOut> = b.submit(&d, 1).into_iter().map(|r| r.unwrap()).collect();
        assert!(first.iter().all(|o| !o.cached));
        let second: Vec<DocOut> = b.submit(&d, 1).into_iter().map(|r| r.unwrap()).collect();
        assert!(second.iter().all(|o| o.cached));
        assert_eq!(
            first.iter().map(|o| o.yhat).collect::<Vec<_>>(),
            second.iter().map(|o| o.yhat).collect::<Vec<_>>()
        );
        assert_eq!(stats.cache_hits.get(), 3);

        // one bad doc (token out of vocab) fails alone; empty doc too
        let mixed = vec![d[0].clone(), vec![9999], Vec::new(), d[1].clone()];
        let res = b.submit(&mixed, 1);
        assert!(res[0].is_ok());
        assert!(res[1].is_err());
        assert!(res[2].is_err());
        assert!(res[3].is_ok());
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn hot_swap_between_batches_changes_version_not_liveness() {
        let (b, reg, _stats, p) = start("swap", 2, 4, 16);
        let p2 = tmp("swap2");
        save_model_with_vocab(&tiny_model(77), None, &p2).unwrap();
        let d = docs(4, 4);
        let v1: Vec<DocOut> = b.submit(&d, 2).into_iter().map(|r| r.unwrap()).collect();
        assert!(v1.iter().all(|o| o.model_version == 1));
        reg.reload(Some(&p2)).unwrap();
        let v2: Vec<DocOut> = b.submit(&d, 2).into_iter().map(|r| r.unwrap()).collect();
        assert!(v2.iter().all(|o| o.model_version == 2));
        assert!(v2.iter().all(|o| !o.cached), "cache must not leak across versions");
        drop(b);
        std::fs::remove_file(p).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn arena_builder_assembles_and_recycles() {
        let mut b = ArenaBuilder::new();
        for &t in &[1u32, 2, 2] {
            b.push_token(t);
        }
        assert_eq!(b.cur_doc_len(), 3);
        b.end_doc().unwrap();
        b.push_token(7);
        b.end_doc().unwrap();
        assert_eq!(b.num_docs(), 2);
        let arena = b.finish();
        assert_eq!(arena, TokenArena::from_docs(&[vec![1, 2, 2], vec![7]]));
        assert_eq!(b.num_docs(), 0);
        // Reclaimed buffers come back cleared but with capacity.
        let cap = arena.tokens.capacity();
        b.reclaim(arena);
        assert_eq!(b.num_docs(), 0);
        assert_eq!(b.cur_doc_len(), 0);
        b.push_token(9);
        b.end_doc().unwrap();
        let again = b.finish();
        assert_eq!(again.doc(0), &[9]);
        assert!(again.tokens.capacity() >= cap.min(1));
    }

    #[test]
    fn submit_streamed_matches_submit() {
        let (b, _reg, _stats, p) = start("streamed", 2, 4, 0);
        let d = docs(5, 7);
        let via_vecs: Vec<f64> =
            b.submit(&d, 11).into_iter().map(|r| r.unwrap().yhat).collect();
        let mut builder = ArenaBuilder::new();
        for row in &d {
            for &t in row {
                builder.push_token(t);
            }
            builder.end_doc().unwrap();
        }
        let arena = Arc::new(builder.finish());
        let via_arena: Vec<f64> = b
            .submit_streamed(Arc::clone(&arena), 11)
            .into_iter()
            .map(|r| r.unwrap().yhat)
            .collect();
        assert_eq!(via_vecs, via_arena, "codec path must not change predictions");
        // Zero-doc arenas resolve immediately.
        assert!(b.submit_streamed(Arc::new(TokenArena::from_docs(&[])), 1).is_empty());
        drop(b);
        std::fs::remove_file(p).ok();
    }

    /// The admission bound is all-or-nothing at `len + n > bound`. Checked
    /// against an empty queue so the decisions are deterministic under any
    /// worker scheduling: a request larger than the bound always sheds, a
    /// request exactly at the bound always admits.
    #[test]
    fn bounded_queue_sheds_all_or_nothing_at_the_boundary() {
        let p = tmp("bound");
        save_model_with_vocab(&tiny_model(5), None, &p).unwrap();
        let registry = Arc::new(Registry::open(&p, 0, true).unwrap());
        let stats = Arc::new(ServeMetrics::new());
        let cfg = BatcherConfig {
            workers: 1,
            max_batch: 8,
            max_wait_us: 0,
            queue_depth_max: 4,
            kernel: KernelKind::Auto,
            train: quick_train(),
            panic_token: None,
        };
        let b = Batcher::start(cfg, Arc::clone(&registry), Arc::clone(&stats));
        assert_eq!(b.queue_bound(), 4);
        let mut out = Vec::new();

        // 5 docs > bound 4: shed even into an empty queue, nothing
        // enqueued, the completion never resolves.
        let idle_waker = Arc::new(Waker::new(-1));
        let five = Arc::new(TokenArena::from_docs(&docs(5, 9)));
        let shed_comp = Arc::new(Completion::new());
        assert!(!b.submit_streamed_notify(Arc::clone(&five), 1, &shed_comp, &idle_waker));
        assert!(!shed_comp.try_take_into(&mut out));
        // ... and the blocking admission wrapper sheds identically.
        assert!(!b.try_submit_streamed_into(Arc::clone(&five), 1, &shed_comp, &mut out));
        assert!(out.is_empty());

        // Exactly the bound (0 + 4 = 4): admitted and resolved.
        let four = Arc::new(TokenArena::from_docs(&docs(4, 9)));
        let comp = Arc::new(Completion::new());
        assert!(b.submit_streamed_notify(Arc::clone(&four), 1, &comp, &idle_waker));
        let deadline = Instant::now() + Duration::from_secs(30);
        while !comp.try_take_into(&mut out) {
            assert!(Instant::now() < deadline, "admitted request never resolved");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|r| r.as_ref().unwrap().yhat.is_finite()));

        // The blocking wrapper admits at the boundary too, with results
        // matching the unbounded reference path.
        assert!(b.try_submit_streamed_into(Arc::clone(&four), 1, &comp, &mut out));
        let bounded: Vec<f64> = out.drain(..).map(|r| r.unwrap().yhat).collect();
        let reference: Vec<f64> = b
            .submit_streamed(Arc::clone(&four), 1)
            .into_iter()
            .map(|r| r.unwrap().yhat)
            .collect();
        assert_eq!(bounded, reference);
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn try_submit_blocking_path_sheds_and_admits() {
        let (b, _reg, _stats, p) = start("tryblock", 2, 8, 0);
        // Unbounded (queue_depth_max = 0): always admitted.
        let d = docs(4, 12);
        let arena = Arc::new(TokenArena::from_docs(&d));
        let comp = Arc::new(Completion::new());
        let mut out = Vec::new();
        assert!(b.try_submit_streamed_into(Arc::clone(&arena), 2, &comp, &mut out));
        assert_eq!(out.len(), 4);
        let blocking: Vec<f64> = out.drain(..).map(|r| r.unwrap().yhat).collect();
        let plain: Vec<f64> =
            b.submit(&d, 2).into_iter().map(|r| r.unwrap().yhat).collect();
        assert_eq!(blocking, plain, "admission wrapper must not change predictions");
        // Zero-doc requests are trivially admitted.
        assert!(b.try_submit_streamed_into(
            Arc::new(TokenArena::from_docs(&[])),
            2,
            &comp,
            &mut out
        ));
        assert!(out.is_empty());
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn notify_submit_signals_eventfd_and_collects() {
        let (b, _reg, _stats, p) = start("notify", 2, 4, 0);
        let efd = unsafe { libc::eventfd(0, libc::EFD_NONBLOCK | libc::EFD_CLOEXEC) };
        assert!(efd >= 0);
        let d = docs(5, 21);
        let arena = Arc::new(TokenArena::from_docs(&d));
        let comp = Arc::new(Completion::new());
        let waker = Arc::new(Waker::new(efd));
        assert!(b.submit_streamed_notify(Arc::clone(&arena), 6, &comp, &waker));
        // Wait for the eventfd to fire (the last fill writes 1).
        let mut val: u64 = 0;
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let n = unsafe {
                libc::read(efd, &mut val as *mut u64 as *mut libc::c_void, 8)
            };
            if n == 8 {
                break;
            }
            assert!(Instant::now() < deadline, "eventfd never signaled");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(val >= 1);
        let mut out = Vec::new();
        assert!(comp.try_take_into(&mut out), "signaled completion must be ready");
        assert_eq!(out.len(), 5);
        let notified: Vec<f64> = out.drain(..).map(|r| r.unwrap().yhat).collect();
        let plain: Vec<f64> =
            b.submit(&d, 6).into_iter().map(|r| r.unwrap().yhat).collect();
        assert_eq!(notified, plain, "notify path must not change predictions");
        // A drained completion reports not-ready until re-armed.
        assert!(!comp.try_take_into(&mut out));
        unsafe { libc::close(efd) };
        drop(b);
        std::fs::remove_file(p).ok();
    }

    /// The waker's coalescing protocol: a burst of signals performs one
    /// eventfd write; the window stays closed (no further writes) until
    /// the reactor drains the counter *and then* clears the flag, after
    /// which the next signal writes again.
    #[test]
    fn waker_coalesces_signal_bursts_until_cleared() {
        let efd = unsafe { libc::eventfd(0, libc::EFD_NONBLOCK | libc::EFD_CLOEXEC) };
        assert!(efd >= 0);
        // Drains the counter; -1 = nothing to read (EAGAIN).
        let drain = |efd: i32| -> i64 {
            let mut v: u64 = 0;
            let n = unsafe { libc::read(efd, &mut v as *mut u64 as *mut libc::c_void, 8) };
            if n == 8 {
                v as i64
            } else {
                -1
            }
        };
        let w = Waker::new(efd);
        w.signal();
        w.signal();
        w.signal();
        assert_eq!(drain(efd), 1, "a signal burst must collapse to one write");
        // Drained but not yet cleared: signals stay coalesced.
        w.signal();
        assert_eq!(drain(efd), -1, "pre-clear signal must not write");
        // Reactor protocol: drain (above), clear, sweep — after which the
        // next burst opens with exactly one fresh write.
        w.clear_pending();
        w.signal();
        w.signal();
        assert_eq!(drain(efd), 1, "post-clear signal must write once");
        unsafe { libc::close(efd) };
    }

    /// A document that panics the worker mid-dispatch must fail only its
    /// own slot; sibling documents, the worker threads, and later requests
    /// all survive (serve-path panic isolation).
    #[test]
    fn panicking_document_fails_its_slot_not_the_server() {
        let p = tmp("panic");
        save_model_with_vocab(&tiny_model(5), None, &p).unwrap();
        let registry = Arc::new(Registry::open(&p, 0, true).unwrap());
        let stats = Arc::new(ServeMetrics::new());
        const POISON: u32 = 31_337;
        let cfg = BatcherConfig {
            workers: 2,
            max_batch: 8,
            max_wait_us: 200,
            queue_depth_max: 0,
            kernel: KernelKind::Auto,
            train: quick_train(),
            panic_token: Some(POISON),
        };
        let b = Batcher::start(cfg, Arc::clone(&registry), Arc::clone(&stats));
        let good = docs(4, 13);
        let clean: Vec<f64> =
            b.submit(&good, 5).into_iter().map(|r| r.unwrap().yhat).collect();

        // Poisoned document sandwiched between healthy ones.
        let mixed =
            vec![good[0].clone(), vec![1, POISON, 2], good[1].clone(), good[2].clone()];
        let res = b.submit(&mixed, 5);
        assert_eq!(res[0].as_ref().unwrap().yhat, clean[0]);
        let err = res[1].as_ref().unwrap_err().to_string();
        assert!(err.contains("panicked"), "got: {err}");
        assert!(err.contains("poisoned document"), "panic payload lost: {err}");
        assert_eq!(res[2].as_ref().unwrap().yhat, clean[1]);
        assert_eq!(res[3].as_ref().unwrap().yhat, clean[2]);
        assert_eq!(stats.errors.get(), 1, "each panic counts once into errors_total");

        // The pool is still healthy and deterministic afterwards.
        let again: Vec<f64> =
            b.submit(&good, 5).into_iter().map(|r| r.unwrap().yhat).collect();
        assert_eq!(again, clean, "post-panic predictions must not drift");
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn pooled_completion_recycles_across_requests() {
        let (b, _reg, stats, p) = start("pooled", 2, 4, 0);
        let d = docs(5, 8);
        let arena = Arc::new(TokenArena::from_docs(&d));
        let comp = Arc::new(Completion::new());
        let mut out = Vec::new();
        let baseline: Vec<f64> =
            b.submit(&d, 4).into_iter().map(|r| r.unwrap().yhat).collect();
        for _ in 0..3 {
            b.submit_streamed_into(Arc::clone(&arena), 4, &comp, &mut out);
            let got: Vec<f64> = out.drain(..).map(|r| r.unwrap().yhat).collect();
            assert_eq!(got, baseline, "pooled path must match the plain path");
        }
        assert!(stats.predict_docs.get() >= 20);
        // Zero-doc submits leave out empty without arming anything.
        b.submit_streamed_into(Arc::new(TokenArena::from_docs(&[])), 4, &comp, &mut out);
        assert!(out.is_empty());
        drop(b);
        std::fs::remove_file(p).ok();
    }
}
