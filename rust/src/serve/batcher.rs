//! Micro-batching prediction queue.
//!
//! Connection handlers enqueue one work item per document and block on a
//! per-request channel; a pool of worker threads drains the shared queue in
//! batches of up to `max_batch`, waiting up to `max_wait_us` for
//! concurrent requests to coalesce (the pipelined/batched inference idea of
//! Yan et al.'s *Towards Big Topic Modeling*, applied to serving). Each
//! worker owns a reusable [`DocInfer`] scratch, so the hot path allocates
//! nothing beyond the zbar row.
//!
//! Request documents are assembled into one flat [`TokenArena`] per request
//! (the same CSR layout the training corpus uses — DESIGN.md §Memory
//! layout): every per-document work item holds an `Arc` of the request's
//! arena plus a doc index, so enqueueing N documents costs one token
//! allocation, not N.
//!
//! **Determinism.** Every document draws from a private RNG stream seeded
//! by `doc_stream_seed(seed, token_hash(doc))` against an immutable
//! [`ModelEntry`]. Predictions therefore depend only on
//! (model version, seed, document content) — never on batch composition,
//! queue order, worker count, or cache state. Repeating a request returns
//! byte-identical responses.

use crate::config::schema::{KernelKind, TrainConfig};
use crate::data::corpus::TokenArena;
use crate::sampler::gibbs_predict::{doc_stream_seed, token_hash, DocInfer};
use crate::serve::registry::{ModelEntry, Registry};
use crate::util::rng::Pcg64;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving counters, shared by the batcher and the HTTP layer
/// (`GET /stats` renders them).
#[derive(Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub predict_docs: AtomicU64,
    pub batches: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub errors: AtomicU64,
    pub reloads: AtomicU64,
}

impl ServeStats {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Batcher knobs (a resolved subset of `config::schema::ServeConfig`).
#[derive(Clone)]
pub struct BatcherConfig {
    /// Worker thread count (>= 1, already resolved from `workers = 0`).
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub kernel: KernelKind,
    pub train: TrainConfig,
}

/// One document's prediction outcome.
#[derive(Clone, Debug)]
pub struct DocOut {
    pub yhat: f64,
    pub model_version: u64,
    pub cached: bool,
}

struct WorkItem {
    /// The owning request's flat token arena, shared across its items.
    docs: Arc<TokenArena>,
    /// This item's document index within the arena.
    doc: usize,
    seed: u64,
    slot: usize,
    tx: mpsc::Sender<(usize, anyhow::Result<DocOut>)>,
}

impl WorkItem {
    #[inline]
    fn tokens(&self) -> &[u32] {
        self.docs.doc(self.doc)
    }
}

struct Shared {
    queue: Mutex<VecDeque<WorkItem>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Incremental [`TokenArena`] assembly for the streaming request codec:
/// the protocol layer pushes token ids straight off the wire (no
/// per-document `Vec<Vec<u32>>` staging), and the buffers recycle across
/// requests via [`ArenaBuilder::reclaim`], so a warmed keep-alive
/// connection builds its request arena with zero heap allocations.
#[derive(Default)]
pub struct ArenaBuilder {
    tokens: Vec<u32>,
    /// CSR offsets; maintained as `[0, end_0, end_1, ...]`.
    offsets: Vec<u32>,
}

impl ArenaBuilder {
    pub fn new() -> ArenaBuilder {
        ArenaBuilder { tokens: Vec::new(), offsets: vec![0] }
    }

    /// Drop any partially-assembled request, keeping buffer capacity.
    pub fn clear(&mut self) {
        self.tokens.clear();
        self.offsets.clear();
        self.offsets.push(0);
    }

    #[inline]
    pub fn push_token(&mut self, t: u32) {
        self.tokens.push(t);
    }

    /// Close the current document. Errors only if the arena would exceed
    /// u32::MAX tokens (unreachable under the HTTP layer's 64 MiB body
    /// cap, but the offsets must never silently wrap).
    pub fn end_doc(&mut self) -> anyhow::Result<()> {
        let end = u32::try_from(self.tokens.len())
            .map_err(|_| anyhow::anyhow!("request arena exceeds u32::MAX tokens"))?;
        self.offsets.push(end);
        Ok(())
    }

    /// Completed documents so far.
    pub fn num_docs(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Tokens pushed since the last [`ArenaBuilder::end_doc`].
    pub fn cur_doc_len(&self) -> usize {
        self.tokens.len() - *self.offsets.last().unwrap() as usize
    }

    /// Move the assembled documents out as a [`TokenArena`], leaving the
    /// builder empty (and without its buffers — pair with `reclaim`).
    pub fn finish(&mut self) -> TokenArena {
        let arena = TokenArena {
            tokens: std::mem::take(&mut self.tokens),
            offsets: std::mem::take(&mut self.offsets),
        };
        self.offsets.push(0);
        arena
    }

    /// Take an arena's buffers back for the next request (best-effort:
    /// callers skip this when other `Arc` holders still exist).
    pub fn reclaim(&mut self, arena: TokenArena) {
        self.tokens = arena.tokens;
        self.offsets = arena.offsets;
        self.clear();
    }
}

/// The worker pool + queue handle. Dropping it drains and joins cleanly.
pub struct Batcher {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(
        cfg: BatcherConfig,
        registry: Arc<Registry>,
        stats: Arc<ServeStats>,
    ) -> Batcher {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                let registry = Arc::clone(&registry);
                let stats = Arc::clone(&stats);
                let cfg = cfg.clone();
                std::thread::spawn(move || worker_loop(&shared, &registry, &stats, &cfg))
            })
            .collect();
        Batcher { shared, workers }
    }

    /// Enqueue a request's documents and block until every one resolves.
    /// Per-document errors (e.g. a token id outside the current model's
    /// vocabulary) come back as `Err` in that document's slot. The request
    /// is flattened into one shared [`TokenArena`] up front — per-document
    /// work items borrow it through an `Arc` instead of owning a `Vec`.
    pub fn submit(&self, docs: &[Vec<u32>], seed: u64) -> Vec<anyhow::Result<DocOut>> {
        self.submit_streamed(Arc::new(TokenArena::from_docs(docs)), seed)
    }

    /// [`Batcher::submit`] for a pre-assembled arena — the streaming codec
    /// path: `protocol::parse_predict_streamed` fills an [`ArenaBuilder`]
    /// straight from the wire and hands the result here without ever
    /// staging per-document `Vec`s. The caller keeps (a clone of) the
    /// `Arc` and can attempt [`Arc::try_unwrap`] afterwards to recycle the
    /// buffers through [`ArenaBuilder::reclaim`].
    pub fn submit_streamed(
        &self,
        arena: Arc<TokenArena>,
        seed: u64,
    ) -> Vec<anyhow::Result<DocOut>> {
        let n = arena.num_docs();
        if n == 0 {
            return Vec::new();
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            for slot in 0..n {
                q.push_back(WorkItem {
                    docs: Arc::clone(&arena),
                    doc: slot,
                    seed,
                    slot,
                    tx: tx.clone(),
                });
            }
        }
        self.shared.cv.notify_all();
        drop(tx);
        let mut out: Vec<Option<anyhow::Result<DocOut>>> = (0..n).map(|_| None).collect();
        let mut got = 0usize;
        while got < n {
            match rx.recv() {
                Ok((slot, res)) => {
                    if out[slot].replace(res).is_none() {
                        got += 1;
                    }
                }
                Err(_) => break, // workers gone: shutdown mid-request
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow::anyhow!("server shutting down"))))
            .collect()
    }

    /// Queue depth right now (stats surface).
    pub fn backlog(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    shared: &Shared,
    registry: &Registry,
    stats: &ServeStats,
    cfg: &BatcherConfig,
) {
    let mut scratch: Option<DocInfer> = None;
    let mut zrow: Vec<f32> = Vec::new();
    loop {
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && q.is_empty() {
                    return;
                }
                if !q.is_empty() {
                    break;
                }
                q = shared.cv.wait(q).unwrap();
            }
            // Coalesce: hold the batch open briefly so concurrent requests
            // ride along, up to the batch ceiling.
            if cfg.max_wait_us > 0 && q.len() < cfg.max_batch {
                let deadline = Instant::now() + Duration::from_micros(cfg.max_wait_us);
                while q.len() < cfg.max_batch && !shared.shutdown.load(Ordering::SeqCst) {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) = shared.cv.wait_timeout(q, deadline - now).unwrap();
                    q = guard;
                    if timeout.timed_out() {
                        break;
                    }
                }
            }
            let take = q.len().min(cfg.max_batch);
            q.drain(..take).collect::<Vec<WorkItem>>()
        };
        if batch.is_empty() {
            continue;
        }
        // One entry per batch: a hot-swap between batches is picked up
        // here; within a batch the model is immutable.
        let entry = registry.current();
        let t = entry.model.t;
        if scratch.as_ref().map(|s| s.topics()) != Some(t) {
            scratch = Some(DocInfer::new(cfg.kernel, t));
            zrow = vec![0.0f32; t];
        }
        let infer = scratch.as_mut().unwrap();
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.predict_docs.fetch_add(batch.len() as u64, Ordering::Relaxed);
        for item in batch {
            // Per-doc failures surface as the request's 4xx and are
            // counted once there (the HTTP layer), not per document.
            let res = predict_one(&entry, infer, &mut zrow, cfg, registry, stats, &item);
            // Receiver may have given up (client disconnect): ignore.
            let _ = item.tx.send((item.slot, res));
        }
    }
}

fn predict_one(
    entry: &Arc<ModelEntry>,
    infer: &mut DocInfer,
    zrow: &mut [f32],
    cfg: &BatcherConfig,
    registry: &Registry,
    stats: &ServeStats,
    item: &WorkItem,
) -> anyhow::Result<DocOut> {
    let model = &entry.model;
    let tokens = item.tokens();
    anyhow::ensure!(!tokens.is_empty(), "empty document");
    if let Some(&w) = tokens.iter().find(|&&w| w as usize >= model.w) {
        anyhow::bail!("token id {w} >= model vocab size {}", model.w);
    }
    let hash = token_hash(tokens);
    let key = (entry.version, item.seed, hash);
    if let Some(yhat) = registry.cache_get(key) {
        stats.cache_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(DocOut { yhat, model_version: entry.version, cached: true });
    }
    stats.cache_misses.fetch_add(1, Ordering::Relaxed);
    let mut rng = Pcg64::seed_from_u64(doc_stream_seed(item.seed, hash));
    // The frozen-phi alias tables ride the entry Arc: built once at
    // load/hot-swap, shared by every worker (present whenever the
    // configured kernel may resolve to alias, ignored otherwise).
    infer.infer_doc(
        model,
        &entry.phi_cum,
        entry.phi_alias.as_ref(),
        &cfg.train,
        tokens,
        &mut rng,
        zrow,
    );
    let yhat = model.predict_zbar(zrow);
    registry.cache_put(key, yhat);
    Ok(DocOut { yhat, model_version: entry.version, cached: false })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::persist::save_model_with_vocab;
    use crate::model::slda::SldaModel;
    use crate::util::pool::scoped_map;

    fn tiny_model(seed: u64) -> SldaModel {
        let mut rng = Pcg64::seed_from_u64(seed);
        let (t, w) = (6usize, 40usize);
        // positive phi rows so every token has mass somewhere
        SldaModel {
            t,
            w,
            eta: (0..t).map(|_| rng.next_gaussian()).collect(),
            phi: (0..w * t).map(|_| 0.01 + rng.next_f32()).collect(),
            rho: 0.5,
            alpha: 0.4,
            train_mse: 0.2,
            train_acc: 0.8,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_batcher_{}_{name}", std::process::id()));
        p
    }

    fn quick_train() -> TrainConfig {
        TrainConfig { sweeps: 5, burnin: 1, eta_every: 1, predict_sweeps: 6, predict_burnin: 2 }
    }

    fn start(
        name: &str,
        workers: usize,
        max_batch: usize,
        cache: usize,
    ) -> (Batcher, Arc<Registry>, Arc<ServeStats>, std::path::PathBuf) {
        let p = tmp(name);
        save_model_with_vocab(&tiny_model(5), None, &p).unwrap();
        let registry = Arc::new(Registry::open(&p, cache, true).unwrap());
        let stats = Arc::new(ServeStats::new());
        let cfg = BatcherConfig {
            workers,
            max_batch,
            max_wait_us: 200,
            kernel: KernelKind::Auto,
            train: quick_train(),
        };
        let b = Batcher::start(cfg, Arc::clone(&registry), Arc::clone(&stats));
        (b, registry, stats, p)
    }

    fn docs(n: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..n).map(|_| (0..12).map(|_| rng.gen_range(40) as u32).collect()).collect()
    }

    #[test]
    fn submit_resolves_every_doc_deterministically() {
        let (b, _reg, stats, p) = start("det", 3, 4, 0);
        let d = docs(17, 1);
        let r1: Vec<f64> =
            b.submit(&d, 9).into_iter().map(|r| r.unwrap().yhat).collect();
        let r2: Vec<f64> =
            b.submit(&d, 9).into_iter().map(|r| r.unwrap().yhat).collect();
        assert_eq!(r1.len(), 17);
        assert!(r1.iter().all(|y| y.is_finite()));
        assert_eq!(r1, r2, "same (model, seed, docs) must repeat exactly");
        // a different seed changes the draw
        let r3: Vec<f64> =
            b.submit(&d, 10).into_iter().map(|r| r.unwrap().yhat).collect();
        assert_ne!(r1, r3);
        assert_eq!(stats.predict_docs.load(Ordering::Relaxed), 17 * 3);
        assert!(stats.batches.load(Ordering::Relaxed) >= 3 * 5); // ceil(17/4) each
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn concurrent_submissions_coalesce_and_stay_deterministic() {
        let (b, _reg, stats, p) = start("conc", 4, 8, 0);
        let base = docs(6, 2);
        let solo: Vec<Vec<f64>> = base
            .iter()
            .map(|d| {
                b.submit(std::slice::from_ref(d), 3).into_iter().map(|r| r.unwrap().yhat).collect()
            })
            .collect();
        // hammer from 8 threads concurrently; every thread sends the same
        // docs and must get the same answers back in its own slots
        let ids: Vec<usize> = (0..8).collect();
        let all = scoped_map(&ids, 8, |_, _| {
            b.submit(&base, 3)
                .into_iter()
                .map(|r| r.unwrap().yhat)
                .collect::<Vec<f64>>()
        });
        for got in &all {
            for (i, y) in got.iter().enumerate() {
                assert_eq!(*y, solo[i][0], "doc {i} drifted under concurrency");
            }
        }
        assert!(stats.errors.load(Ordering::Relaxed) == 0);
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn cache_serves_repeats_and_batch_errors_are_per_doc() {
        let (b, _reg, stats, p) = start("cache", 2, 8, 64);
        let d = docs(3, 3);
        let first: Vec<DocOut> = b.submit(&d, 1).into_iter().map(|r| r.unwrap()).collect();
        assert!(first.iter().all(|o| !o.cached));
        let second: Vec<DocOut> = b.submit(&d, 1).into_iter().map(|r| r.unwrap()).collect();
        assert!(second.iter().all(|o| o.cached));
        assert_eq!(
            first.iter().map(|o| o.yhat).collect::<Vec<_>>(),
            second.iter().map(|o| o.yhat).collect::<Vec<_>>()
        );
        assert_eq!(stats.cache_hits.load(Ordering::Relaxed), 3);

        // one bad doc (token out of vocab) fails alone; empty doc too
        let mixed = vec![d[0].clone(), vec![9999], Vec::new(), d[1].clone()];
        let res = b.submit(&mixed, 1);
        assert!(res[0].is_ok());
        assert!(res[1].is_err());
        assert!(res[2].is_err());
        assert!(res[3].is_ok());
        drop(b);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn hot_swap_between_batches_changes_version_not_liveness() {
        let (b, reg, _stats, p) = start("swap", 2, 4, 16);
        let p2 = tmp("swap2");
        save_model_with_vocab(&tiny_model(77), None, &p2).unwrap();
        let d = docs(4, 4);
        let v1: Vec<DocOut> = b.submit(&d, 2).into_iter().map(|r| r.unwrap()).collect();
        assert!(v1.iter().all(|o| o.model_version == 1));
        reg.reload(Some(&p2)).unwrap();
        let v2: Vec<DocOut> = b.submit(&d, 2).into_iter().map(|r| r.unwrap()).collect();
        assert!(v2.iter().all(|o| o.model_version == 2));
        assert!(v2.iter().all(|o| !o.cached), "cache must not leak across versions");
        drop(b);
        std::fs::remove_file(p).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn arena_builder_assembles_and_recycles() {
        let mut b = ArenaBuilder::new();
        for &t in &[1u32, 2, 2] {
            b.push_token(t);
        }
        assert_eq!(b.cur_doc_len(), 3);
        b.end_doc().unwrap();
        b.push_token(7);
        b.end_doc().unwrap();
        assert_eq!(b.num_docs(), 2);
        let arena = b.finish();
        assert_eq!(arena, TokenArena::from_docs(&[vec![1, 2, 2], vec![7]]));
        assert_eq!(b.num_docs(), 0);
        // Reclaimed buffers come back cleared but with capacity.
        let cap = arena.tokens.capacity();
        b.reclaim(arena);
        assert_eq!(b.num_docs(), 0);
        assert_eq!(b.cur_doc_len(), 0);
        b.push_token(9);
        b.end_doc().unwrap();
        let again = b.finish();
        assert_eq!(again.doc(0), &[9]);
        assert!(again.tokens.capacity() >= cap.min(1));
    }

    #[test]
    fn submit_streamed_matches_submit() {
        let (b, _reg, _stats, p) = start("streamed", 2, 4, 0);
        let d = docs(5, 7);
        let via_vecs: Vec<f64> =
            b.submit(&d, 11).into_iter().map(|r| r.unwrap().yhat).collect();
        let mut builder = ArenaBuilder::new();
        for row in &d {
            for &t in row {
                builder.push_token(t);
            }
            builder.end_doc().unwrap();
        }
        let arena = Arc::new(builder.finish());
        let via_arena: Vec<f64> = b
            .submit_streamed(Arc::clone(&arena), 11)
            .into_iter()
            .map(|r| r.unwrap().yhat)
            .collect();
        assert_eq!(via_vecs, via_arena, "codec path must not change predictions");
        // Zero-doc arenas resolve immediately.
        assert!(b.submit_streamed(Arc::new(TokenArena::from_docs(&[])), 1).is_empty());
        drop(b);
        std::fs::remove_file(p).ok();
    }
}
