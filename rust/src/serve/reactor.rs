//! The epoll backend: one non-blocking readiness loop for every
//! connection (`[serve] backend = "epoll"`, DESIGN.md §Serving
//! "Event-loop architecture").
//!
//! A single reactor thread owns an `epoll` instance with three kinds of
//! registrations, distinguished by the event cookie:
//!
//! * cookie `0` — the listening socket; readiness drains an `accept4`
//!   loop (`SOCK_NONBLOCK | SOCK_CLOEXEC`, one syscall per connection)
//!   behind the `max_conns` admission gate (`503 Retry-After` + close
//!   past it).
//! * cookie `1` — an `eventfd`. Batcher workers signal it through a
//!   coalescing [`Waker`] when the last document of a dispatched predict
//!   request resolves ([`Completion`]'s notify arm), replacing the
//!   blocking condvar rendezvous of the threads backend: the reactor
//!   wakes, drains the counter, re-opens the waker's coalescing window
//!   (drain first, *then* clear — see [`Waker::clear_pending`]), and
//!   sweeps dispatched connections with the non-blocking
//!   [`Conn::poll_completion`]. Coalescing means a burst of completions
//!   between two reactor iterations costs one `write(2)` syscall total,
//!   not one per completion.
//! * cookie `slot + 2` — connections, stored in a slab (`Vec<Option>` +
//!   free list) so cookies stay dense and stable. Write interest
//!   (`EPOLLOUT`) is toggled with `EPOLL_CTL_MOD` only while a response
//!   is partially written.
//!
//! The wait runs with a 50ms tick: each tick (and each eventfd wake)
//! sweeps dispatched completions — a lost wakeup degrades latency by at
//! most one tick, never correctness — and reaps idle / stalled
//! connections against `idle_timeout_ms` / `read_timeout_ms`. Time spent
//! *processing* each non-empty `epoll_wait` batch is recorded in the
//! `cfslda_event_loop_iteration_seconds` histogram.
//!
//! [`Completion`]: crate::serve::batcher::Completion
//! [`Conn::poll_completion`]: crate::serve::conn::Conn::poll_completion

use crate::serve::batcher::Waker;
use crate::serve::conn::{Conn, Step};
use crate::serve::server::{self, ConnScratch, OpenConnGuard, State};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, FromRawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const LISTENER_COOKIE: u64 = 0;
const EVENTFD_COOKIE: u64 = 1;
/// Connection slot `s` registers with cookie `s + CONN_BASE`.
const CONN_BASE: u64 = 2;
/// Events collected per `epoll_wait` call.
const MAX_EVENTS: usize = 256;
/// Wait timeout: the cadence of completion sweeps, timeout reaps, and
/// shutdown-flag polls when the loop is otherwise quiet.
const TICK_MS: i32 = 50;

/// Run the event loop until `shutdown` is set. Consumes the listening
/// socket; connections still open at shutdown are dropped (the same
/// contract as the threads backend, whose handlers exit at their next
/// poll tick).
pub fn run(
    listener: TcpListener,
    state: Arc<State>,
    shutdown: Arc<AtomicBool>,
) -> anyhow::Result<()> {
    Reactor::new(listener, state, shutdown)?.run_loop()
}

fn ep_ctl(epfd: i32, op: i32, fd: i32, events: u32, cookie: u64) -> std::io::Result<()> {
    let mut ev = libc::epoll_event { events, u64: cookie };
    let rc = unsafe { libc::epoll_ctl(epfd, op, fd, &mut ev) };
    if rc < 0 {
        Err(std::io::Error::last_os_error())
    } else {
        Ok(())
    }
}

struct Reactor {
    epfd: i32,
    /// Completion-notify eventfd, shared with batcher workers.
    efd: i32,
    /// Coalescing wrapper around `efd`, handed to every dispatch so
    /// worker signal bursts collapse to one eventfd write per reactor
    /// iteration.
    waker: Arc<Waker>,
    listener: TcpListener,
    state: Arc<State>,
    shutdown: Arc<AtomicBool>,
    /// Connection slab; index = cookie - CONN_BASE.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// Currently-registered epoll interest per slot (skips no-op MODs).
    interest: Vec<u32>,
    /// Scratch for admission-shed responses written inline at accept.
    shed_out: ConnScratch,
}

impl Reactor {
    fn new(
        listener: TcpListener,
        state: Arc<State>,
        shutdown: Arc<AtomicBool>,
    ) -> anyhow::Result<Reactor> {
        // The accept4 flags only affect the *accepted* socket; the listener
        // itself must be non-blocking or a connection that resets between
        // readiness and accept would block the whole reactor.
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow::anyhow!("listener set_nonblocking: {e}"))?;
        let epfd = unsafe { libc::epoll_create1(libc::EPOLL_CLOEXEC) };
        anyhow::ensure!(epfd >= 0, "epoll_create1: {}", std::io::Error::last_os_error());
        let efd = unsafe { libc::eventfd(0, libc::EFD_NONBLOCK | libc::EFD_CLOEXEC) };
        if efd < 0 {
            let e = std::io::Error::last_os_error();
            unsafe { libc::close(epfd) };
            anyhow::bail!("eventfd: {e}");
        }
        let r = Reactor {
            epfd,
            efd,
            waker: Arc::new(Waker::new(efd)),
            listener,
            state,
            shutdown,
            conns: Vec::new(),
            free: Vec::new(),
            interest: Vec::new(),
            shed_out: ConnScratch::new(),
        };
        ep_ctl(epfd, libc::EPOLL_CTL_ADD, r.listener.as_raw_fd(), libc::EPOLLIN, LISTENER_COOKIE)
            .map_err(|e| anyhow::anyhow!("registering listener: {e}"))?;
        ep_ctl(epfd, libc::EPOLL_CTL_ADD, efd, libc::EPOLLIN, EVENTFD_COOKIE)
            .map_err(|e| anyhow::anyhow!("registering eventfd: {e}"))?;
        Ok(r)
    }

    fn run_loop(&mut self) -> anyhow::Result<()> {
        let mut events = [libc::epoll_event { events: 0, u64: 0 }; MAX_EVENTS];
        let mut last_reap = Instant::now();
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return Ok(());
            }
            let n = unsafe {
                libc::epoll_wait(self.epfd, events.as_mut_ptr(), MAX_EVENTS as i32, TICK_MS)
            };
            if n < 0 {
                let e = std::io::Error::last_os_error();
                if e.kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                anyhow::bail!("epoll_wait: {e}");
            }
            let t0 = Instant::now();
            let mut sweep = n == 0; // quiet tick: safety-net sweep
            for ev in events.iter().take(n as usize) {
                // Braced reads: the x86_64 struct is packed.
                let cookie = { ev.u64 };
                let mask = { ev.events };
                match cookie {
                    LISTENER_COOKIE => self.accept_ready(),
                    EVENTFD_COOKIE => {
                        // Drain first, then clear: clearing before the
                        // drain could swallow a concurrent signal's write
                        // and leave the flag sticky-true, suppressing
                        // every future wakeup (50ms-tick latency forever).
                        self.drain_eventfd();
                        self.waker.clear_pending();
                        sweep = true;
                    }
                    c => self.conn_ready((c - CONN_BASE) as usize, mask),
                }
            }
            if sweep {
                self.sweep_dispatched();
            }
            if last_reap.elapsed() >= Duration::from_millis(TICK_MS as u64) {
                last_reap = Instant::now();
                self.reap_timeouts();
            }
            if n > 0 {
                self.state.stats.loop_iteration.observe(t0.elapsed().as_micros() as u64);
            }
        }
    }

    /// Drain the accept backlog: one `accept4` per connection, admission
    /// gate applied before registration.
    fn accept_ready(&mut self) {
        loop {
            let fd = unsafe {
                libc::accept4(
                    self.listener.as_raw_fd(),
                    std::ptr::null_mut(),
                    std::ptr::null_mut(),
                    libc::SOCK_NONBLOCK | libc::SOCK_CLOEXEC,
                )
            };
            if fd < 0 {
                let e = std::io::Error::last_os_error();
                match e.kind() {
                    std::io::ErrorKind::WouldBlock => return,
                    std::io::ErrorKind::Interrupted => continue,
                    _ => {
                        log::warn!("accept error: {e}");
                        return;
                    }
                }
            }
            let mut stream = unsafe { TcpStream::from_raw_fd(fd) };
            self.state.stats.accepted.inc();
            if self.state.max_conns > 0
                && self.state.stats.open_connections.get() >= self.state.max_conns as u64
            {
                self.state.stats.shed.inc();
                server::write_shed_response(&mut stream, &mut self.shed_out);
                continue; // drop closes the socket
            }
            let open = OpenConnGuard::new(&self.state.stats);
            let conn = Conn::new(stream, open);
            let slot = self.free.pop().unwrap_or_else(|| {
                self.conns.push(None);
                self.interest.push(0);
                self.conns.len() - 1
            });
            let want = libc::EPOLLIN | libc::EPOLLRDHUP;
            if let Err(e) =
                ep_ctl(self.epfd, libc::EPOLL_CTL_ADD, conn.raw_fd(), want, CONN_BASE + slot as u64)
            {
                log::warn!("registering connection: {e}");
                self.free.push(slot);
                continue; // conn drops, guard decrements
            }
            self.interest[slot] = want;
            self.conns[slot] = Some(conn);
        }
    }

    fn drain_eventfd(&mut self) {
        // Non-semaphore eventfd: one read returns the whole counter.
        let mut v: u64 = 0;
        unsafe {
            libc::read(self.efd, &mut v as *mut u64 as *mut libc::c_void, 8);
        }
    }

    fn conn_ready(&mut self, slot: usize, mask: u32) {
        let step = {
            let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut) else {
                return; // already closed this iteration
            };
            if mask & libc::EPOLLERR != 0 {
                Step::Close
            } else {
                // RDHUP/HUP surface through read() (EOF), which still
                // lets a final buffered request be answered first.
                let mut step = Step::Continue;
                if mask & (libc::EPOLLIN | libc::EPOLLRDHUP | libc::EPOLLHUP) != 0 {
                    step = conn.handle_readable(&self.state, &self.waker);
                }
                if step == Step::Continue && mask & libc::EPOLLOUT != 0 {
                    step = conn.handle_writable(&self.state, &self.waker);
                }
                step
            }
        };
        self.finish_step(slot, step);
    }

    /// Collect any ready completions on dispatched connections.
    fn sweep_dispatched(&mut self) {
        for slot in 0..self.conns.len() {
            let dispatched =
                matches!(self.conns[slot].as_ref(), Some(c) if c.is_dispatched());
            if !dispatched {
                continue;
            }
            let step = self.conns[slot]
                .as_mut()
                .unwrap()
                .poll_completion(&self.state, &self.waker);
            self.finish_step(slot, step);
        }
    }

    fn reap_timeouts(&mut self) {
        let now = Instant::now();
        for slot in 0..self.conns.len() {
            let expired =
                matches!(self.conns[slot].as_ref(), Some(c) if c.timed_out(&self.state, now));
            if expired {
                self.close_conn(slot);
            }
        }
    }

    fn finish_step(&mut self, slot: usize, step: Step) {
        match step {
            Step::Close => self.close_conn(slot),
            Step::Continue => self.update_interest(slot),
        }
    }

    /// Re-derive the slot's epoll interest (write interest only while a
    /// response is partially flushed); no-op unless it changed.
    fn update_interest(&mut self, slot: usize) {
        let Some(conn) = self.conns[slot].as_ref() else { return };
        let mut want = libc::EPOLLIN | libc::EPOLLRDHUP;
        if conn.wants_write() {
            want |= libc::EPOLLOUT;
        }
        if want != self.interest[slot] {
            match ep_ctl(self.epfd, libc::EPOLL_CTL_MOD, conn.raw_fd(), want, CONN_BASE + slot as u64)
            {
                Ok(()) => self.interest[slot] = want,
                Err(e) => {
                    log::warn!("epoll_ctl MOD: {e}");
                    self.close_conn(slot);
                }
            }
        }
    }

    fn close_conn(&mut self, slot: usize) {
        if let Some(conn) = self.conns[slot].take() {
            // Kernels before 2.6.9 required a non-null event for DEL; ours
            // don't, but passing one costs nothing.
            let _ = ep_ctl(self.epfd, libc::EPOLL_CTL_DEL, conn.raw_fd(), 0, 0);
            self.interest[slot] = 0;
            self.free.push(slot);
            // Dropping the conn closes the socket and decrements the
            // open-connections gauge; any still-running batcher work for
            // it resolves into a completion nobody collects — harmless.
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        unsafe {
            libc::close(self.efd);
            libc::close(self.epfd);
        }
    }
}
