//! Minimal HTTP/1.1 framing over `std::net` (no hyper/tokio in the
//! vendored-offline build).
//!
//! Exactly what the serving subsystem needs and nothing more: request
//! parsing with `Content-Length` bodies, keep-alive by default, JSON
//! responses, and a tiny keep-alive client used by `cfslda serve-bench`
//! and the integration tests. Chunked transfer encoding, pipelining and
//! TLS are intentionally out of scope — the server sits behind loopback
//! or an internal load balancer.
//!
//! The server side frames requests into a per-connection
//! [`RequestScratch`]: the raw head accumulates in one reused buffer with
//! method/path/header *spans* into it (no per-line or per-header `String`s)
//! and the body lands in a second reused buffer, so a warmed keep-alive
//! connection parses requests without heap allocation.

use anyhow::Context;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Cap on the total request-head size (request line + headers).
pub const MAX_HEAD_BYTES: usize = 64 * 1024;
/// Cap on a request body; prediction batches are JSON token-id arrays, so
/// 64 MiB is far beyond any sane batch.
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// Byte range into [`RequestScratch::head`].
type Span = (usize, usize);

/// One parsed HTTP request with owned fields (cold paths and tests; the
/// connection loop uses [`RequestScratch`] + [`read_request_into`]).
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Lower-cased header names, trimmed values.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Did the client ask to drop the connection after this exchange?
    pub fn wants_close(&self) -> bool {
        self.header("connection").map(|v| v.eq_ignore_ascii_case("close")).unwrap_or(false)
    }

    pub fn body_str(&self) -> anyhow::Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not valid utf-8")
    }
}

/// Per-connection request framing buffers, reused across keep-alive
/// requests: the head is one flat byte buffer with spans pointing at the
/// method, path and (lower-cased in place) header names/values; the body
/// is a second reusable buffer.
#[derive(Default)]
pub struct RequestScratch {
    head: Vec<u8>,
    headers: Vec<(Span, Span)>,
    method: Span,
    path: Span,
    body: Vec<u8>,
}

impl RequestScratch {
    pub fn new() -> RequestScratch {
        RequestScratch::default()
    }

    fn str_at(&self, sp: Span) -> &str {
        // Every span lies inside head bytes that were UTF-8 validated at
        // read time, trimmed/split only at ASCII boundaries.
        std::str::from_utf8(&self.head[sp.0..sp.1]).unwrap_or("")
    }

    pub fn method(&self) -> &str {
        self.str_at(self.method)
    }

    pub fn path(&self) -> &str {
        self.str_at(self.path)
    }

    /// Look up a header by lower-cased name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| &self.head[k.0..k.1] == name.as_bytes())
            .map(|&(_, v)| self.str_at(v))
    }

    /// (name, value) pairs in arrival order, names lower-cased.
    pub fn headers(&self) -> impl Iterator<Item = (&str, &str)> {
        self.headers.iter().map(|&(k, v)| (self.str_at(k), self.str_at(v)))
    }

    pub fn wants_close(&self) -> bool {
        self.header("connection").map(|v| v.eq_ignore_ascii_case("close")).unwrap_or(false)
    }

    pub fn body(&self) -> &[u8] {
        &self.body
    }

    pub fn body_str(&self) -> anyhow::Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not valid utf-8")
    }

    /// Install the request body (event-loop path: the reactor slices it
    /// out of the connection's receive buffer once `Content-Length` bytes
    /// have arrived). Reuses the body buffer's capacity.
    pub fn set_body(&mut self, bytes: &[u8]) {
        self.body.clear();
        self.body.extend_from_slice(bytes);
    }

    fn reset(&mut self) {
        self.head.clear();
        self.headers.clear();
        self.method = (0, 0);
        self.path = (0, 0);
        self.body.clear();
    }
}

/// Outcome of a successful [`parse_head`]: how many bytes of the input
/// the head consumed, and the declared body length still to arrive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeadInfo {
    pub head_len: usize,
    pub content_length: usize,
}

/// Incremental request-head parse over an accumulated receive buffer (the
/// epoll path's counterpart to [`read_request_into`]). Returns
/// `Ok(None)` while the head is still incomplete — call again once more
/// bytes arrive; the caps ([`MAX_HEAD_BYTES`], [`MAX_BODY_BYTES`]) and
/// every parse error match the blocking parser, so both backends reject
/// identical requests identically. On success the scratch holds the
/// parsed head (method/path/headers); the body is *not* consumed here —
/// once `content_length` more bytes follow `head_len`, hand them to
/// [`RequestScratch::set_body`].
pub fn parse_head(raw: &[u8], s: &mut RequestScratch) -> anyhow::Result<Option<HeadInfo>> {
    s.reset();
    // Locate the end of head: the first line *after* the request line
    // that is empty once trimmed.
    let mut line_start = 0usize;
    let mut first_line_end = None;
    let mut head_end = None;
    for (pos, &b) in raw.iter().enumerate() {
        if b != b'\n' {
            continue;
        }
        if first_line_end.is_none() {
            first_line_end = Some(pos + 1);
        } else if raw[line_start..=pos].iter().all(|c| c.is_ascii_whitespace()) {
            head_end = Some(pos + 1);
            break;
        }
        line_start = pos + 1;
    }
    let Some(head_end) = head_end else {
        anyhow::ensure!(raw.len() <= MAX_HEAD_BYTES, "request head too large");
        return Ok(None);
    };
    anyhow::ensure!(head_end <= MAX_HEAD_BYTES, "request head too large");
    let first_line_end = first_line_end.unwrap();

    s.head.extend_from_slice(&raw[..head_end]);
    std::str::from_utf8(&s.head).context("request head is not valid utf-8")?;

    // Request line: method SP path SP version, whitespace-tolerant
    // (same grammar as the blocking parser).
    let mut cursor = (0usize, first_line_end);
    let mut next_word = |buf: &[u8]| -> Span {
        let mut a = cursor.0;
        while a < cursor.1 && buf[a].is_ascii_whitespace() {
            a += 1;
        }
        let mut b = a;
        while b < cursor.1 && !buf[b].is_ascii_whitespace() {
            b += 1;
        }
        cursor.0 = b;
        (a, b)
    };
    let method = next_word(&s.head);
    anyhow::ensure!(method.0 < method.1, "empty request line");
    let path = next_word(&s.head);
    anyhow::ensure!(path.0 < path.1, "request line missing path");
    let version = next_word(&s.head);
    anyhow::ensure!(
        version.0 == version.1 || s.head[version.0..version.1].starts_with(b"HTTP/1."),
        "unsupported protocol '{}'",
        String::from_utf8_lossy(&s.head[version.0..version.1])
    );
    s.method = method;
    s.path = path;

    // Header lines between the request line and the blank terminator.
    let mut start = first_line_end;
    while start < head_end {
        let end = s.head[start..head_end]
            .iter()
            .position(|&b| b == b'\n')
            .map(|p| start + p + 1)
            .unwrap_or(head_end);
        let t = trim_span(&s.head, (start, end));
        if t.0 < t.1 {
            if let Some(ci) = s.head[t.0..t.1].iter().position(|&b| b == b':') {
                let name = trim_span(&s.head, (t.0, t.0 + ci));
                let value = trim_span(&s.head, (t.0 + ci + 1, t.1));
                s.head[name.0..name.1].make_ascii_lowercase();
                s.headers.push((name, value));
            }
        }
        start = end;
    }

    let clen = s
        .header("content-length")
        .map(|v| v.parse::<usize>())
        .transpose()
        .context("bad content-length header")?
        .unwrap_or(0);
    anyhow::ensure!(clen <= MAX_BODY_BYTES, "request body too large ({clen} bytes)");
    Ok(Some(HeadInfo { head_len: head_end, content_length: clen }))
}

/// Append one `\n`-terminated line to `buf`, enforcing `limit` on the
/// line's length *before* buffering — a multi-gigabyte line errors out
/// instead of being accumulated into memory first. Returns the new line's
/// span, or `None` on clean EOF before any byte.
fn read_line_into<R: BufRead>(
    r: &mut R,
    limit: usize,
    buf: &mut Vec<u8>,
) -> anyhow::Result<Option<Span>> {
    let start = buf.len();
    loop {
        let used = {
            let available = r.fill_buf()?;
            if available.is_empty() {
                if buf.len() == start {
                    return Ok(None);
                }
                anyhow::bail!("connection closed mid-line");
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    anyhow::ensure!(
                        buf.len() - start + pos + 1 <= limit,
                        "request head too large"
                    );
                    buf.extend_from_slice(&available[..=pos]);
                    pos + 1
                }
                None => {
                    anyhow::ensure!(
                        buf.len() - start + available.len() <= limit,
                        "request head too large"
                    );
                    buf.extend_from_slice(available);
                    available.len()
                }
            }
        };
        r.consume(used);
        if buf.last() == Some(&b'\n') {
            return Ok(Some((start, buf.len())));
        }
    }
}

fn trim_span(buf: &[u8], mut sp: Span) -> Span {
    while sp.0 < sp.1 && buf[sp.0].is_ascii_whitespace() {
        sp.0 += 1;
    }
    while sp.1 > sp.0 && buf[sp.1 - 1].is_ascii_whitespace() {
        sp.1 -= 1;
    }
    sp
}

/// Read one request off the stream into the connection's scratch buffers.
/// `Ok(false)` means the peer closed cleanly between requests; timeouts
/// surface as `Err` carrying an [`std::io::Error`] (see [`is_timeout_io`]).
pub fn read_request_into<R: BufRead>(
    r: &mut R,
    s: &mut RequestScratch,
) -> anyhow::Result<bool> {
    s.reset();
    let line = match read_line_into(r, MAX_HEAD_BYTES, &mut s.head)? {
        None => return Ok(false),
        Some(sp) => sp,
    };
    std::str::from_utf8(&s.head[line.0..line.1]).context("request head is not valid utf-8")?;
    let mut head_bytes = line.1 - line.0;

    // Request line: method SP path SP version, whitespace-tolerant.
    let mut cursor = line;
    let mut next_word = |buf: &[u8]| -> Span {
        let mut a = cursor.0;
        while a < cursor.1 && buf[a].is_ascii_whitespace() {
            a += 1;
        }
        let mut b = a;
        while b < cursor.1 && !buf[b].is_ascii_whitespace() {
            b += 1;
        }
        cursor.0 = b;
        (a, b)
    };
    let method = next_word(&s.head);
    anyhow::ensure!(method.0 < method.1, "empty request line");
    let path = next_word(&s.head);
    anyhow::ensure!(path.0 < path.1, "request line missing path");
    let version = next_word(&s.head);
    anyhow::ensure!(
        version.0 == version.1 || s.head[version.0..version.1].starts_with(b"HTTP/1."),
        "unsupported protocol '{}'",
        String::from_utf8_lossy(&s.head[version.0..version.1])
    );
    s.method = method;
    s.path = path;

    loop {
        let sp = read_line_into(r, MAX_HEAD_BYTES - head_bytes, &mut s.head)?
            .context("connection closed mid-headers")?;
        head_bytes += sp.1 - sp.0;
        std::str::from_utf8(&s.head[sp.0..sp.1])
            .context("request head is not valid utf-8")?;
        let t = trim_span(&s.head, sp);
        if t.0 == t.1 {
            break;
        }
        if let Some(ci) = s.head[t.0..t.1].iter().position(|&b| b == b':') {
            let name = trim_span(&s.head, (t.0, t.0 + ci));
            let value = trim_span(&s.head, (t.0 + ci + 1, t.1));
            s.head[name.0..name.1].make_ascii_lowercase();
            s.headers.push((name, value));
        }
    }

    let clen = s
        .header("content-length")
        .map(|v| v.parse::<usize>())
        .transpose()
        .context("bad content-length header")?
        .unwrap_or(0);
    anyhow::ensure!(clen <= MAX_BODY_BYTES, "request body too large ({clen} bytes)");
    s.body.resize(clen, 0);
    r.read_exact(&mut s.body).context("reading request body")?;
    Ok(true)
}

/// Read one request off the stream with owned fields. `Ok(None)` means the
/// peer closed cleanly between requests. Thin wrapper over
/// [`read_request_into`] for cold paths and tests.
pub fn read_request<R: BufRead>(r: &mut R) -> anyhow::Result<Option<Request>> {
    let mut s = RequestScratch::new();
    if !read_request_into(r, &mut s)? {
        return Ok(None);
    }
    Ok(Some(Request {
        method: s.method().to_string(),
        path: s.path().to_string(),
        headers: s.headers().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
        body: std::mem::take(&mut s.body),
    }))
}

/// Is this a read-timeout? The connection handler's idle peek treats
/// those as "keep-alive, poll again", not as failures.
pub fn is_timeout_io(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock)
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// `Content-Type` for JSON responses (everything except `/metrics`).
pub const CT_JSON: &str = "application/json";
/// `Content-Type` for Prometheus text exposition (`GET /metrics`).
pub const CT_PROMETHEUS: &str = "text/plain; version=0.0.4";

/// Write a response with an explicit content type, assembling the head in
/// a reusable scratch buffer first: one allocation-free format pass, then
/// two `write_all` calls.
pub fn write_response_typed<W: Write>(
    w: &mut W,
    head: &mut Vec<u8>,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    head.clear();
    write!(
        head,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    w.write_all(head)?;
    w.write_all(body)?;
    w.flush()
}

/// The admission-control shed response: [`write_response_typed`] framing
/// plus a `Retry-After: {secs}` header, so load balancers and well-behaved
/// clients back off instead of hammering an overloaded server.
pub fn write_response_retry_after<W: Write>(
    w: &mut W,
    head: &mut Vec<u8>,
    status: u16,
    body: &[u8],
    keep_alive: bool,
    retry_after_secs: u64,
) -> std::io::Result<()> {
    head.clear();
    write!(
        head,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nRetry-After: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        CT_JSON,
        body.len(),
        retry_after_secs,
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    w.write_all(head)?;
    w.write_all(body)?;
    w.flush()
}

/// [`write_response_typed`] pinned to JSON — byte-identical framing to
/// every release before `/metrics` existed.
pub fn write_response_buffered<W: Write>(
    w: &mut W,
    head: &mut Vec<u8>,
    status: u16,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_typed(w, head, status, CT_JSON, body, keep_alive)
}

/// Write a JSON response (one-shot convenience; the connection loop uses
/// [`write_response_buffered`]).
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = Vec::with_capacity(128);
    write_response_buffered(w, &mut head, status, body.as_bytes(), keep_alive)
}

/// Tiny keep-alive HTTP client (serve-bench load generator + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream) })
    }

    /// Issue one request and read the full response. Returns (status, body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> anyhow::Result<(u16, String)> {
        {
            let s = self.reader.get_mut();
            write!(
                s,
                "{method} {path} HTTP/1.1\r\nHost: cfslda\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
                body.len()
            )?;
            s.write_all(body.as_bytes())?;
            s.flush()?;
        }
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed connection before responding");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .with_context(|| format!("bad status line '{}'", line.trim_end()))?
            .parse()
            .context("non-numeric status code")?;
        let mut clen = 0usize;
        loop {
            let mut h = String::new();
            let n = self.reader.read_line(&mut h)?;
            anyhow::ensure!(n > 0, "server closed connection mid-headers");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    clen = v.trim().parse().context("bad response content-length")?;
                }
            }
        }
        let mut body = vec![0u8; clen];
        self.reader.read_exact(&mut body)?;
        Ok((status, String::from_utf8(body).context("response body not utf-8")?))
    }
}

/// One-shot convenience: connect, request, return (status, body).
pub fn request_once(addr: &str, method: &str, path: &str, body: &str) -> anyhow::Result<(u16, String)> {
    Client::connect(addr)?.request(method, path, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> anyhow::Result<Option<Request>> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\n{\"docs\":[]}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert_eq!(req.body_str().unwrap(), "{\"docs\":[]}");
        assert!(!req.wants_close());
    }

    #[test]
    fn parses_get_without_body_and_close() {
        let req = parse("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.body.len(), 0);
        assert!(req.wants_close());
        assert_eq!(req.header("connection"), Some("close"));
    }

    #[test]
    fn eof_between_requests_is_none() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn scratch_reuses_across_keep_alive_requests() {
        let raw = "POST /predict HTTP/1.1\r\nHost: x\r\nX-Mixed-CASE: Keep\r\n\
                   Content-Length: 5\r\n\r\nhello\
                   GET /stats HTTP/1.1\r\n\r\n";
        let mut r = Cursor::new(raw.as_bytes().to_vec());
        let mut s = RequestScratch::new();
        assert!(read_request_into(&mut r, &mut s).unwrap());
        assert_eq!(s.method(), "POST");
        assert_eq!(s.path(), "/predict");
        assert_eq!(s.header("x-mixed-case"), Some("Keep"));
        assert_eq!(s.body(), b"hello");
        let head_cap = { s.head.capacity() };
        assert!(read_request_into(&mut r, &mut s).unwrap());
        assert_eq!(s.method(), "GET");
        assert_eq!(s.path(), "/stats");
        assert_eq!(s.header("x-mixed-case"), None, "stale headers must not leak");
        assert!(s.body().is_empty());
        assert!(s.head.capacity() >= head_cap.min(1), "buffers must be retained");
        assert!(!read_request_into(&mut r, &mut s).unwrap(), "clean EOF");
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(parse("GARBAGE\r\n\r\n").is_err()); // no path
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err()); // bad protocol
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: zebra\r\n\r\n").is_err());
        // body shorter than content-length
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 50\r\n\r\nxx").is_err());
        // truncated mid-headers
        assert!(parse("GET / HTTP/1.1\r\nHost: x\r\n").is_err());
    }

    #[test]
    fn oversized_head_rejected() {
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..9000 {
            raw.push_str(&format!("X-Pad-{i}: aaaaaaaa\r\n"));
        }
        raw.push_str("\r\n");
        let err = parse(&raw).unwrap_err().to_string();
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn response_framing() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "{\"ok\":true}", true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("Content-Length: 11\r\n"));
        assert!(s.contains("Connection: keep-alive\r\n"));
        assert!(s.ends_with("{\"ok\":true}"));
        let mut out = Vec::new();
        write_response(&mut out, 404, "{}", false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        // The buffered form emits identical bytes and reuses its head.
        let mut out = Vec::new();
        let mut head = Vec::new();
        write_response_buffered(&mut out, &mut head, 200, b"{\"ok\":true}", true).unwrap();
        let mut out2 = Vec::new();
        write_response(&mut out2, 200, "{\"ok\":true}", true).unwrap();
        assert_eq!(out, out2);
    }

    #[test]
    fn typed_response_framing() {
        let mut out = Vec::new();
        let mut head = Vec::new();
        write_response_typed(&mut out, &mut head, 200, CT_PROMETHEUS, b"m 1\n", true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("Content-Type: text/plain; version=0.0.4\r\n"), "{s}");
        assert!(s.contains("Content-Length: 4\r\n"));
        assert!(s.ends_with("m 1\n"));
    }

    #[test]
    fn incremental_head_parse_matches_blocking_parser() {
        let raw = "POST /predict HTTP/1.1\r\nHost: x\r\nX-Mixed-CASE: Keep\r\n\
                   Content-Length: 5\r\n\r\nhello";
        let bytes = raw.as_bytes();
        let mut s = RequestScratch::new();
        // Every prefix that ends before the blank line is incomplete.
        let head_len = raw.find("\r\n\r\n").unwrap() + 4;
        for cut in 0..head_len {
            assert!(
                parse_head(&bytes[..cut], &mut s).unwrap().is_none(),
                "cut at {cut} should be incomplete"
            );
        }
        // From the blank line on, the head parses; the body is untouched.
        let info = parse_head(bytes, &mut s).unwrap().unwrap();
        assert_eq!(info, HeadInfo { head_len, content_length: 5 });
        assert_eq!(s.method(), "POST");
        assert_eq!(s.path(), "/predict");
        assert_eq!(s.header("x-mixed-case"), Some("Keep"));
        assert_eq!(s.header("content-length"), Some("5"));
        s.set_body(&bytes[info.head_len..info.head_len + info.content_length]);
        assert_eq!(s.body(), b"hello");
        assert!(!s.wants_close());

        // Field-for-field agreement with the blocking parser.
        let mut blocking = RequestScratch::new();
        let mut r = Cursor::new(bytes.to_vec());
        assert!(read_request_into(&mut r, &mut blocking).unwrap());
        assert_eq!(s.method(), blocking.method());
        assert_eq!(s.path(), blocking.path());
        assert_eq!(s.body(), blocking.body());
        let a: Vec<(String, String)> =
            s.headers().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        let b: Vec<(String, String)> =
            blocking.headers().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn incremental_head_parse_rejects_like_blocking() {
        let mut s = RequestScratch::new();
        // Same malformed heads the blocking parser rejects.
        assert!(parse_head(b"GARBAGE\r\n\r\n", &mut s).is_err());
        assert!(parse_head(b"GET / SPDY/3\r\n\r\n", &mut s).is_err());
        assert!(
            parse_head(b"POST / HTTP/1.1\r\nContent-Length: zebra\r\n\r\n", &mut s).is_err()
        );
        // Oversized head: rejected both complete and still-accumulating.
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..9000 {
            raw.push_str(&format!("X-Pad-{i}: aaaaaaaa\r\n"));
        }
        let err = parse_head(raw.as_bytes(), &mut s).unwrap_err().to_string();
        assert!(err.contains("too large"), "{err}");
        raw.push_str("\r\n");
        let err = parse_head(raw.as_bytes(), &mut s).unwrap_err().to_string();
        assert!(err.contains("too large"), "{err}");
        // Oversized declared body.
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let err = parse_head(huge.as_bytes(), &mut s).unwrap_err().to_string();
        assert!(err.contains("body too large"), "{err}");
        // GET with no headers at all parses fine.
        let info = parse_head(b"GET /healthz HTTP/1.1\r\n\r\n", &mut s).unwrap().unwrap();
        assert_eq!(info.content_length, 0);
        assert_eq!(s.path(), "/healthz");
    }

    #[test]
    fn incremental_parse_supports_pipelined_requests() {
        let raw = b"POST /predict HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi\
                    GET /stats HTTP/1.1\r\n\r\ntrailing";
        let mut s = RequestScratch::new();
        let info = parse_head(raw, &mut s).unwrap().unwrap();
        s.set_body(&raw[info.head_len..info.head_len + info.content_length]);
        assert_eq!(s.method(), "POST");
        assert_eq!(s.body(), b"hi");
        let rest = &raw[info.head_len + info.content_length..];
        let info2 = parse_head(rest, &mut s).unwrap().unwrap();
        assert_eq!(s.method(), "GET");
        assert_eq!(s.path(), "/stats");
        assert_eq!(info2.content_length, 0);
        assert_eq!(&rest[info2.head_len..], b"trailing");
    }

    #[test]
    fn retry_after_response_framing() {
        let mut out = Vec::new();
        let mut head = Vec::new();
        write_response_retry_after(&mut out, &mut head, 503, b"{\"error\":\"x\"}", true, 2)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{s}");
        assert!(s.contains("Retry-After: 2\r\n"), "{s}");
        assert!(s.contains("Content-Length: 13\r\n"));
        assert!(s.contains("Connection: keep-alive\r\n"));
        assert!(s.ends_with("{\"error\":\"x\"}"));
    }

    #[test]
    fn timeout_detection() {
        assert!(is_timeout_io(&std::io::Error::new(std::io::ErrorKind::WouldBlock, "poll")));
        assert!(is_timeout_io(&std::io::Error::new(std::io::ErrorKind::TimedOut, "slow")));
        assert!(!is_timeout_io(&std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof")));
    }
}
