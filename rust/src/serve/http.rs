//! Minimal HTTP/1.1 framing over `std::net` (no hyper/tokio in the
//! vendored-offline build).
//!
//! Exactly what the serving subsystem needs and nothing more: request
//! parsing with `Content-Length` bodies, keep-alive by default, JSON
//! responses, and a tiny keep-alive client used by `cfslda serve-bench`
//! and the integration tests. Chunked transfer encoding, pipelining and
//! TLS are intentionally out of scope — the server sits behind loopback
//! or an internal load balancer.

use anyhow::Context;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Cap on the total request-head size (request line + headers).
pub const MAX_HEAD_BYTES: usize = 64 * 1024;
/// Cap on a request body; prediction batches are JSON token-id arrays, so
/// 64 MiB is far beyond any sane batch.
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Lower-cased header names, trimmed values.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Did the client ask to drop the connection after this exchange?
    pub fn wants_close(&self) -> bool {
        self.header("connection").map(|v| v.eq_ignore_ascii_case("close")).unwrap_or(false)
    }

    pub fn body_str(&self) -> anyhow::Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not valid utf-8")
    }
}

/// Read one `\n`-terminated line, enforcing `limit` *before* buffering —
/// unlike `read_line`, a multi-gigabyte line errors out instead of being
/// accumulated into memory first. `Ok(None)` = clean EOF before any byte.
fn read_line_limited<R: BufRead>(r: &mut R, limit: usize) -> anyhow::Result<Option<String>> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let used = {
            let available = r.fill_buf()?;
            if available.is_empty() {
                if buf.is_empty() {
                    return Ok(None);
                }
                anyhow::bail!("connection closed mid-line");
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    anyhow::ensure!(buf.len() + pos + 1 <= limit, "request head too large");
                    buf.extend_from_slice(&available[..=pos]);
                    pos + 1
                }
                None => {
                    anyhow::ensure!(buf.len() + available.len() <= limit, "request head too large");
                    buf.extend_from_slice(available);
                    available.len()
                }
            }
        };
        r.consume(used);
        if buf.last() == Some(&b'\n') {
            let s = String::from_utf8(buf).context("request head is not valid utf-8")?;
            return Ok(Some(s));
        }
    }
}

/// Read one request off the stream. `Ok(None)` means the peer closed the
/// connection cleanly between requests; timeouts surface as `Err` carrying
/// an [`std::io::Error`] (see [`is_timeout_io`]).
pub fn read_request<R: BufRead>(r: &mut R) -> anyhow::Result<Option<Request>> {
    let line = match read_line_limited(r, MAX_HEAD_BYTES)? {
        None => return Ok(None),
        Some(l) => l,
    };
    let mut head_bytes = line.len();
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_string();
    let path = parts.next().context("request line missing path")?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    anyhow::ensure!(version.starts_with("HTTP/1."), "unsupported protocol '{version}'");

    let mut headers = Vec::new();
    loop {
        let h = read_line_limited(r, MAX_HEAD_BYTES - head_bytes)?
            .context("connection closed mid-headers")?;
        head_bytes += h.len();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }

    let clen = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse::<usize>())
        .transpose()
        .context("bad content-length header")?
        .unwrap_or(0);
    anyhow::ensure!(clen <= MAX_BODY_BYTES, "request body too large ({clen} bytes)");
    let mut body = vec![0u8; clen];
    r.read_exact(&mut body).context("reading request body")?;
    Ok(Some(Request { method, path, headers, body }))
}

/// Is this a read-timeout? The connection handler's idle peek treats
/// those as "keep-alive, poll again", not as failures.
pub fn is_timeout_io(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock)
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a JSON response.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Tiny keep-alive HTTP client (serve-bench load generator + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream) })
    }

    /// Issue one request and read the full response. Returns (status, body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> anyhow::Result<(u16, String)> {
        {
            let s = self.reader.get_mut();
            write!(
                s,
                "{method} {path} HTTP/1.1\r\nHost: cfslda\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
                body.len()
            )?;
            s.write_all(body.as_bytes())?;
            s.flush()?;
        }
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed connection before responding");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .with_context(|| format!("bad status line '{}'", line.trim_end()))?
            .parse()
            .context("non-numeric status code")?;
        let mut clen = 0usize;
        loop {
            let mut h = String::new();
            let n = self.reader.read_line(&mut h)?;
            anyhow::ensure!(n > 0, "server closed connection mid-headers");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    clen = v.trim().parse().context("bad response content-length")?;
                }
            }
        }
        let mut body = vec![0u8; clen];
        self.reader.read_exact(&mut body)?;
        Ok((status, String::from_utf8(body).context("response body not utf-8")?))
    }
}

/// One-shot convenience: connect, request, return (status, body).
pub fn request_once(addr: &str, method: &str, path: &str, body: &str) -> anyhow::Result<(u16, String)> {
    Client::connect(addr)?.request(method, path, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> anyhow::Result<Option<Request>> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\n{\"docs\":[]}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert_eq!(req.body_str().unwrap(), "{\"docs\":[]}");
        assert!(!req.wants_close());
    }

    #[test]
    fn parses_get_without_body_and_close() {
        let req = parse("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.body.len(), 0);
        assert!(req.wants_close());
        assert_eq!(req.header("connection"), Some("close"));
    }

    #[test]
    fn eof_between_requests_is_none() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(parse("GARBAGE\r\n\r\n").is_err()); // no path
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err()); // bad protocol
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: zebra\r\n\r\n").is_err());
        // body shorter than content-length
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 50\r\n\r\nxx").is_err());
        // truncated mid-headers
        assert!(parse("GET / HTTP/1.1\r\nHost: x\r\n").is_err());
    }

    #[test]
    fn oversized_head_rejected() {
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..9000 {
            raw.push_str(&format!("X-Pad-{i}: aaaaaaaa\r\n"));
        }
        raw.push_str("\r\n");
        let err = parse(&raw).unwrap_err().to_string();
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn response_framing() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "{\"ok\":true}", true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("Content-Length: 11\r\n"));
        assert!(s.contains("Connection: keep-alive\r\n"));
        assert!(s.ends_with("{\"ok\":true}"));
        let mut out = Vec::new();
        write_response(&mut out, 404, "{}", false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(s.contains("Connection: close\r\n"));
    }

    #[test]
    fn timeout_detection() {
        assert!(is_timeout_io(&std::io::Error::new(std::io::ErrorKind::WouldBlock, "poll")));
        assert!(is_timeout_io(&std::io::Error::new(std::io::ErrorKind::TimedOut, "slow")));
        assert!(!is_timeout_io(&std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof")));
    }
}
