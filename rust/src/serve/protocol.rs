//! Wire types for the serving endpoints: JSON parsing/rendering on top of
//! `config::json` (no serde in the vendored-offline build).
//!
//! Request shapes:
//!
//! * `POST /predict`      — `{"docs": [[1, 4, 4], [7]], "seed": 42}`
//!   (token-id bag-of-words rows; `seed` optional).
//! * `POST /predict/text` — `{"texts": ["strong revenue growth", ...],
//!   "seed": 42}` (requires a model persisted with its vocabulary).
//! * `POST /reload`       — `{"path": "new_model.bin"}` or `{}` to reload
//!   the currently-served path.
//!
//! Responses are JSON objects; errors are `{"error": "..."}` with a 4xx/5xx
//! status.

use crate::config::json::{self, Value};
use anyhow::Context;

/// Ceiling on documents per request: keeps one request from monopolizing
/// the batcher queue; split larger workloads across requests.
pub const MAX_DOCS_PER_REQUEST: usize = 4096;
/// Ceiling on tokens per document.
pub const MAX_TOKENS_PER_DOC: usize = 1 << 20;

/// Parsed body of `POST /predict`.
#[derive(Clone, Debug, PartialEq)]
pub struct PredictRequest {
    pub docs: Vec<Vec<u32>>,
    pub seed: Option<u64>,
}

/// Parsed body of `POST /predict/text`.
#[derive(Clone, Debug, PartialEq)]
pub struct TextRequest {
    pub texts: Vec<String>,
    pub seed: Option<u64>,
}

fn parse_seed(v: &Value) -> anyhow::Result<Option<u64>> {
    match v.get("seed") {
        None => Ok(None),
        Some(s) => {
            let n = s.as_i64().context("'seed' must be an integer")?;
            anyhow::ensure!(n >= 0, "'seed' must be non-negative");
            Ok(Some(n as u64))
        }
    }
}

/// Parse and validate a `POST /predict` body.
pub fn parse_predict(body: &str) -> anyhow::Result<PredictRequest> {
    let v = json::parse(body).context("invalid json")?;
    let docs_v = v
        .get("docs")
        .and_then(|d| d.as_array())
        .context("body must be an object with a 'docs' array")?;
    anyhow::ensure!(!docs_v.is_empty(), "'docs' must not be empty");
    anyhow::ensure!(
        docs_v.len() <= MAX_DOCS_PER_REQUEST,
        "'docs' has {} rows; max {MAX_DOCS_PER_REQUEST} per request",
        docs_v.len()
    );
    let mut docs = Vec::with_capacity(docs_v.len());
    for (i, row) in docs_v.iter().enumerate() {
        let row = row.as_array().with_context(|| format!("doc {i} must be a token array"))?;
        anyhow::ensure!(!row.is_empty(), "doc {i} is empty");
        anyhow::ensure!(
            row.len() <= MAX_TOKENS_PER_DOC,
            "doc {i} has {} tokens; max {MAX_TOKENS_PER_DOC}",
            row.len()
        );
        let tokens: Option<Vec<u32>> = row
            .iter()
            .map(|t| t.as_usize().and_then(|u| u32::try_from(u).ok()))
            .collect();
        let tokens =
            tokens.with_context(|| format!("doc {i} has a non-integer or oversized token id"))?;
        docs.push(tokens);
    }
    Ok(PredictRequest { docs, seed: parse_seed(&v)? })
}

/// Parse and validate a `POST /predict/text` body.
pub fn parse_text(body: &str) -> anyhow::Result<TextRequest> {
    let v = json::parse(body).context("invalid json")?;
    let texts_v = v
        .get("texts")
        .and_then(|t| t.as_array())
        .context("body must be an object with a 'texts' array")?;
    anyhow::ensure!(!texts_v.is_empty(), "'texts' must not be empty");
    anyhow::ensure!(
        texts_v.len() <= MAX_DOCS_PER_REQUEST,
        "'texts' has {} rows; max {MAX_DOCS_PER_REQUEST} per request",
        texts_v.len()
    );
    let mut texts = Vec::with_capacity(texts_v.len());
    for (i, t) in texts_v.iter().enumerate() {
        texts.push(
            t.as_str().with_context(|| format!("text {i} must be a string"))?.to_string(),
        );
    }
    Ok(TextRequest { texts, seed: parse_seed(&v)? })
}

/// Parse a `POST /reload` body; `None` means "reload the current path".
/// An empty body is allowed and means the same as `{}`.
pub fn parse_reload(body: &str) -> anyhow::Result<Option<String>> {
    if body.trim().is_empty() {
        return Ok(None);
    }
    let v = json::parse(body).context("invalid json")?;
    match v.get("path") {
        None => Ok(None),
        Some(p) => Ok(Some(p.as_str().context("'path' must be a string")?.to_string())),
    }
}

/// Render a prediction response.
pub fn predict_response(yhat: &[f64], model_version: u64, cached: usize) -> String {
    let v = Value::object(vec![
        ("yhat", Value::from_f64_slice(yhat)),
        ("model_version", Value::Number(model_version as f64)),
        ("count", Value::Number(yhat.len() as f64)),
        ("cached", Value::Number(cached as f64)),
    ]);
    json::to_string(&v)
}

/// Render an error body.
pub fn error_response(msg: &str) -> String {
    json::to_string(&Value::object(vec![("error", Value::String(msg.to_string()))]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_parse_roundtrip() {
        let r = parse_predict(r#"{"docs": [[1, 2, 2], [7]], "seed": 9}"#).unwrap();
        assert_eq!(r.docs, vec![vec![1, 2, 2], vec![7]]);
        assert_eq!(r.seed, Some(9));
        let r = parse_predict(r#"{"docs": [[0]]}"#).unwrap();
        assert_eq!(r.seed, None);
    }

    #[test]
    fn predict_parse_rejects_bad_shapes() {
        assert!(parse_predict("not json").is_err());
        assert!(parse_predict(r#"{"docs": []}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[]]}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[1.5]]}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[-3]]}"#).is_err());
        assert!(parse_predict(r#"{"docs": "x"}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[1]], "seed": -4}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[1]], "seed": 1.5}"#).is_err());
    }

    #[test]
    fn text_parse() {
        let r = parse_text(r#"{"texts": ["strong growth", "weak outlook"]}"#).unwrap();
        assert_eq!(r.texts.len(), 2);
        assert!(parse_text(r#"{"texts": []}"#).is_err());
        assert!(parse_text(r#"{"texts": [5]}"#).is_err());
        assert!(parse_text(r#"{}"#).is_err());
    }

    #[test]
    fn reload_parse() {
        assert_eq!(parse_reload("").unwrap(), None);
        assert_eq!(parse_reload("{}").unwrap(), None);
        assert_eq!(parse_reload(r#"{"path": "m.bin"}"#).unwrap(), Some("m.bin".into()));
        assert!(parse_reload(r#"{"path": 5}"#).is_err());
        assert!(parse_reload("][").is_err());
    }

    #[test]
    fn response_rendering() {
        let s = predict_response(&[0.5, -1.25], 3, 1);
        let v = crate::config::json::parse(&s).unwrap();
        assert_eq!(v.get("model_version").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("count").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("cached").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("yhat").unwrap().as_array().unwrap().len(), 2);
        let e = error_response("boom \"quoted\"");
        let v = crate::config::json::parse(&e).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("boom \"quoted\""));
    }
}
