//! Wire types for the serving endpoints: JSON parsing/rendering on top of
//! `config::json` (no serde in the vendored-offline build).
//!
//! Request shapes:
//!
//! * `POST /predict`      — `{"docs": [[1, 4, 4], [7]], "seed": 42}`
//!   (token-id bag-of-words rows; `seed` optional).
//! * `POST /predict/text` — `{"texts": ["strong revenue growth", ...],
//!   "seed": 42}` (requires a model persisted with its vocabulary).
//! * `POST /reload`       — `{"path": "new_model.bin"}` or `{}` to reload
//!   the currently-served path.
//!
//! Responses are JSON objects; errors are `{"error": "..."}` with a 4xx/5xx
//! status.
//!
//! Two codecs cover each shape:
//!
//! * **Streaming** (`*_streamed`, `*_into`) — the serve hot path. Bodies
//!   lex event-by-event: `/predict` token ids land directly in the
//!   caller's [`ArenaBuilder`] (the batcher's flat CSR arena, recycled
//!   across requests), limits are enforced *while* scanning (oversized
//!   requests are rejected before their tokens are buffered), and
//!   responses render through a reusable [`JsonWriter`]. With warmed
//!   buffers this path performs zero heap allocations per request.
//!   Integer seeds lex exactly (full u64 range — no f64 round-trip).
//! * **Tree** (`parse_predict`, `predict_response`, ...) — the original
//!   `Value`-based implementations, kept as the cold-path/reference codec.
//!   The differential suite (`tests/json_streaming.rs`) pins the two to
//!   identical accept/reject decisions and values, with one documented
//!   asymmetry: integer literals in `(2^53, u64::MAX]` parse exactly when
//!   streamed but are *rejected* by the tree parser (which stores f64 and
//!   refuses to round).

use crate::config::json::{self, Event, JsonWriter, Lexer, Value};
use crate::serve::batcher::ArenaBuilder;
use anyhow::Context;

/// Ceiling on documents per request: keeps one request from monopolizing
/// the batcher queue; split larger workloads across requests.
pub const MAX_DOCS_PER_REQUEST: usize = 4096;
/// Ceiling on tokens per document.
pub const MAX_TOKENS_PER_DOC: usize = 1 << 20;

/// Parsed body of `POST /predict`.
#[derive(Clone, Debug, PartialEq)]
pub struct PredictRequest {
    pub docs: Vec<Vec<u32>>,
    pub seed: Option<u64>,
}

/// Parsed body of `POST /predict/text`.
#[derive(Clone, Debug, PartialEq)]
pub struct TextRequest {
    pub texts: Vec<String>,
    pub seed: Option<u64>,
}

fn invalid_json(e: json::ParseError) -> anyhow::Error {
    anyhow::Error::new(e).context("invalid json")
}

// ---- streaming codec (hot path) ----------------------------------------

/// Streaming `POST /predict` parser: token ids go straight from the wire
/// into `builder` (cleared first; on error it may hold a partial request —
/// `clear()` before reuse). Returns the optional seed.
pub fn parse_predict_streamed(
    body: &[u8],
    builder: &mut ArenaBuilder,
) -> anyhow::Result<Option<u64>> {
    builder.clear();
    let mut lex = Lexer::new(body);
    match lex.next().map_err(invalid_json)? {
        Event::ObjectStart => {}
        _ => anyhow::bail!("body must be an object with a 'docs' array"),
    }
    let mut seed = None;
    let mut saw_docs = false;
    loop {
        enum Field {
            Docs,
            Seed,
            Other,
        }
        let field = match lex.next().map_err(invalid_json)? {
            Event::ObjectEnd => break,
            Event::Key("docs") => Field::Docs,
            Event::Key("seed") => Field::Seed,
            Event::Key(_) => Field::Other,
            _ => anyhow::bail!("invalid json"),
        };
        match field {
            Field::Docs => {
                saw_docs = true;
                // Duplicate keys: last one wins, like the tree's BTreeMap.
                builder.clear();
                parse_docs_into(&mut lex, builder)?;
            }
            Field::Seed => seed = Some(parse_seed_streamed(&mut lex)?),
            Field::Other => lex.skip_value().map_err(invalid_json)?,
        }
    }
    match lex.next().map_err(invalid_json)? {
        Event::Eof => {}
        _ => anyhow::bail!("invalid json"),
    }
    anyhow::ensure!(saw_docs, "body must be an object with a 'docs' array");
    anyhow::ensure!(builder.num_docs() > 0, "'docs' must not be empty");
    Ok(seed)
}

fn parse_docs_into(lex: &mut Lexer<'_>, builder: &mut ArenaBuilder) -> anyhow::Result<()> {
    match lex.next().map_err(invalid_json)? {
        Event::ArrayStart => {}
        _ => anyhow::bail!("body must be an object with a 'docs' array"),
    }
    loop {
        match lex.next().map_err(invalid_json)? {
            Event::ArrayEnd => return Ok(()),
            Event::ArrayStart => {}
            _ => anyhow::bail!("doc {} must be a token array", builder.num_docs()),
        }
        let i = builder.num_docs();
        // Enforced mid-scan: row 4097's opening bracket is enough to
        // reject — its tokens are never buffered.
        anyhow::ensure!(
            i < MAX_DOCS_PER_REQUEST,
            "'docs' has more than {MAX_DOCS_PER_REQUEST} rows; max {MAX_DOCS_PER_REQUEST} \
             per request"
        );
        loop {
            let n = match lex.next().map_err(invalid_json)? {
                Event::ArrayEnd => break,
                Event::Number(n) => n,
                _ => anyhow::bail!("doc {i} has a non-integer or oversized token id"),
            };
            let t = n
                .as_u32_exact()
                .with_context(|| format!("doc {i} has a non-integer or oversized token id"))?;
            anyhow::ensure!(
                builder.cur_doc_len() < MAX_TOKENS_PER_DOC,
                "doc {i} has more than {MAX_TOKENS_PER_DOC} tokens"
            );
            builder.push_token(t);
        }
        anyhow::ensure!(builder.cur_doc_len() > 0, "doc {i} is empty");
        builder.end_doc()?;
    }
}

/// Streaming seed value: exact u64 (integral floats like `1e3` accepted,
/// matching the tree path; negatives and fractions rejected).
fn parse_seed_streamed(lex: &mut Lexer<'_>) -> anyhow::Result<u64> {
    let n = match lex.next().map_err(invalid_json)? {
        Event::Number(n) => n,
        _ => anyhow::bail!("'seed' must be an integer"),
    };
    if let Some(u) = n.as_u64_exact() {
        return Ok(u);
    }
    let f = n.as_f64();
    anyhow::ensure!(f >= 0.0 || f.fract() != 0.0, "'seed' must be non-negative");
    anyhow::bail!("'seed' must be an integer")
}

/// Streaming `POST /predict/text` parser: texts accumulate into the
/// caller's reused `Vec` (the `String`s themselves are the only copies).
pub fn parse_text_streamed(
    body: &[u8],
    texts: &mut Vec<String>,
) -> anyhow::Result<Option<u64>> {
    texts.clear();
    let mut lex = Lexer::new(body);
    match lex.next().map_err(invalid_json)? {
        Event::ObjectStart => {}
        _ => anyhow::bail!("body must be an object with a 'texts' array"),
    }
    let mut seed = None;
    let mut saw_texts = false;
    loop {
        enum Field {
            Texts,
            Seed,
            Other,
        }
        let field = match lex.next().map_err(invalid_json)? {
            Event::ObjectEnd => break,
            Event::Key("texts") => Field::Texts,
            Event::Key("seed") => Field::Seed,
            Event::Key(_) => Field::Other,
            _ => anyhow::bail!("invalid json"),
        };
        match field {
            Field::Texts => {
                saw_texts = true;
                texts.clear();
                match lex.next().map_err(invalid_json)? {
                    Event::ArrayStart => {}
                    _ => anyhow::bail!("body must be an object with a 'texts' array"),
                }
                loop {
                    match lex.next().map_err(invalid_json)? {
                        Event::ArrayEnd => break,
                        Event::String(s) => {
                            anyhow::ensure!(
                                texts.len() < MAX_DOCS_PER_REQUEST,
                                "'texts' has more than {MAX_DOCS_PER_REQUEST} rows; \
                                 max {MAX_DOCS_PER_REQUEST} per request"
                            );
                            texts.push(s.to_string());
                        }
                        _ => anyhow::bail!("text {} must be a string", texts.len()),
                    }
                }
            }
            Field::Seed => seed = Some(parse_seed_streamed(&mut lex)?),
            Field::Other => lex.skip_value().map_err(invalid_json)?,
        }
    }
    match lex.next().map_err(invalid_json)? {
        Event::Eof => {}
        _ => anyhow::bail!("invalid json"),
    }
    anyhow::ensure!(saw_texts, "body must be an object with a 'texts' array");
    anyhow::ensure!(!texts.is_empty(), "'texts' must not be empty");
    Ok(seed)
}

/// Streaming `POST /reload` parser; `None` means "reload the current
/// path". Matches the tree semantics: empty body and non-object (but
/// valid) JSON both mean `None`.
pub fn parse_reload_streamed(body: &[u8]) -> anyhow::Result<Option<String>> {
    if body.iter().all(|b| b.is_ascii_whitespace()) {
        return Ok(None);
    }
    let mut lex = Lexer::new(body);
    let mut path: Option<String> = None;
    match lex.next().map_err(invalid_json)? {
        Event::ObjectStart => loop {
            enum Field {
                Path,
                Other,
            }
            let field = match lex.next().map_err(invalid_json)? {
                Event::ObjectEnd => break,
                Event::Key("path") => Field::Path,
                Event::Key(_) => Field::Other,
                _ => anyhow::bail!("invalid json"),
            };
            match field {
                Field::Path => {
                    path = Some(match lex.next().map_err(invalid_json)? {
                        Event::String(s) => s.to_string(),
                        _ => anyhow::bail!("'path' must be a string"),
                    });
                }
                Field::Other => lex.skip_value().map_err(invalid_json)?,
            }
        },
        // Non-object document: no path, but the body must still be valid
        // JSON end to end (the tree path parses it fully).
        Event::ArrayStart => {
            let mut depth = 1usize;
            while depth > 0 {
                match lex.next().map_err(invalid_json)? {
                    Event::ObjectStart | Event::ArrayStart => depth += 1,
                    Event::ObjectEnd | Event::ArrayEnd => depth -= 1,
                    Event::Eof => anyhow::bail!("invalid json"),
                    _ => {}
                }
            }
        }
        _ => {}
    }
    match lex.next().map_err(invalid_json)? {
        Event::Eof => Ok(path),
        _ => anyhow::bail!("invalid json"),
    }
}

/// Render a prediction response into a reusable writer. Byte-identical to
/// [`predict_response`]: keys in sorted order (the tree path serializes a
/// `BTreeMap`) and the same integer/float formatting.
pub fn predict_response_into(
    w: &mut JsonWriter,
    yhat: &[f64],
    model_version: u64,
    cached: usize,
) {
    w.clear();
    w.begin_object();
    w.key("cached");
    w.number_u64(cached as u64);
    w.key("count");
    w.number_u64(yhat.len() as u64);
    w.key("model_version");
    w.number_u64(model_version);
    w.key("yhat");
    w.begin_array();
    for &y in yhat {
        w.number_f64(y);
    }
    w.end_array();
    w.end_object();
}

/// Render an error body into a reusable writer (byte-identical to
/// [`error_response`]).
pub fn error_response_into(w: &mut JsonWriter, msg: &str) {
    w.clear();
    w.begin_object();
    w.key("error");
    w.string(msg);
    w.end_object();
}

// ---- tree codec (cold path / differential reference) --------------------

fn parse_seed(v: &Value) -> anyhow::Result<Option<u64>> {
    match v.get("seed") {
        None => Ok(None),
        Some(s) => {
            let n = s.as_i64().context("'seed' must be an integer")?;
            anyhow::ensure!(n >= 0, "'seed' must be non-negative");
            Ok(Some(n as u64))
        }
    }
}

/// Parse and validate a `POST /predict` body through the tree codec.
/// Serving uses [`parse_predict_streamed`]; this stays as the reference
/// implementation the differential suite checks the streaming path
/// against (and rejects — never rounds — integer seeds above 2^53).
pub fn parse_predict(body: &str) -> anyhow::Result<PredictRequest> {
    let v = json::parse(body).context("invalid json")?;
    let docs_v = v
        .get("docs")
        .and_then(|d| d.as_array())
        .context("body must be an object with a 'docs' array")?;
    anyhow::ensure!(!docs_v.is_empty(), "'docs' must not be empty");
    anyhow::ensure!(
        docs_v.len() <= MAX_DOCS_PER_REQUEST,
        "'docs' has {} rows; max {MAX_DOCS_PER_REQUEST} per request",
        docs_v.len()
    );
    let mut docs = Vec::with_capacity(docs_v.len());
    for (i, row) in docs_v.iter().enumerate() {
        let row = row.as_array().with_context(|| format!("doc {i} must be a token array"))?;
        anyhow::ensure!(!row.is_empty(), "doc {i} is empty");
        anyhow::ensure!(
            row.len() <= MAX_TOKENS_PER_DOC,
            "doc {i} has {} tokens; max {MAX_TOKENS_PER_DOC}",
            row.len()
        );
        let tokens: Option<Vec<u32>> = row
            .iter()
            .map(|t| t.as_usize().and_then(|u| u32::try_from(u).ok()))
            .collect();
        let tokens =
            tokens.with_context(|| format!("doc {i} has a non-integer or oversized token id"))?;
        docs.push(tokens);
    }
    Ok(PredictRequest { docs, seed: parse_seed(&v)? })
}

/// Parse and validate a `POST /predict/text` body (tree codec; serving
/// uses [`parse_text_streamed`]).
pub fn parse_text(body: &str) -> anyhow::Result<TextRequest> {
    let v = json::parse(body).context("invalid json")?;
    let texts_v = v
        .get("texts")
        .and_then(|t| t.as_array())
        .context("body must be an object with a 'texts' array")?;
    anyhow::ensure!(!texts_v.is_empty(), "'texts' must not be empty");
    anyhow::ensure!(
        texts_v.len() <= MAX_DOCS_PER_REQUEST,
        "'texts' has {} rows; max {MAX_DOCS_PER_REQUEST} per request",
        texts_v.len()
    );
    let mut texts = Vec::with_capacity(texts_v.len());
    for (i, t) in texts_v.iter().enumerate() {
        texts.push(
            t.as_str().with_context(|| format!("text {i} must be a string"))?.to_string(),
        );
    }
    Ok(TextRequest { texts, seed: parse_seed(&v)? })
}

/// Parse a `POST /reload` body; `None` means "reload the current path".
/// An empty body is allowed and means the same as `{}` (tree codec;
/// serving uses [`parse_reload_streamed`]).
pub fn parse_reload(body: &str) -> anyhow::Result<Option<String>> {
    if body.trim().is_empty() {
        return Ok(None);
    }
    let v = json::parse(body).context("invalid json")?;
    match v.get("path") {
        None => Ok(None),
        Some(p) => Ok(Some(p.as_str().context("'path' must be a string")?.to_string())),
    }
}

/// Render a prediction response (tree codec; serving renders through
/// [`predict_response_into`], which this must stay byte-identical to).
pub fn predict_response(yhat: &[f64], model_version: u64, cached: usize) -> String {
    let v = Value::object(vec![
        ("yhat", Value::from_f64_slice(yhat)),
        ("model_version", Value::Number(model_version as f64)),
        ("count", Value::Number(yhat.len() as f64)),
        ("cached", Value::Number(cached as f64)),
    ]);
    json::to_string(&v)
}

/// Render an error body.
pub fn error_response(msg: &str) -> String {
    json::to_string(&Value::object(vec![("error", Value::String(msg.to_string()))]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_parse_roundtrip() {
        let r = parse_predict(r#"{"docs": [[1, 2, 2], [7]], "seed": 9}"#).unwrap();
        assert_eq!(r.docs, vec![vec![1, 2, 2], vec![7]]);
        assert_eq!(r.seed, Some(9));
        let r = parse_predict(r#"{"docs": [[0]]}"#).unwrap();
        assert_eq!(r.seed, None);
    }

    #[test]
    fn predict_parse_rejects_bad_shapes() {
        assert!(parse_predict("not json").is_err());
        assert!(parse_predict(r#"{"docs": []}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[]]}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[1.5]]}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[-3]]}"#).is_err());
        assert!(parse_predict(r#"{"docs": "x"}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[1]], "seed": -4}"#).is_err());
        assert!(parse_predict(r#"{"docs": [[1]], "seed": 1.5}"#).is_err());
    }

    #[test]
    fn text_parse() {
        let r = parse_text(r#"{"texts": ["strong growth", "weak outlook"]}"#).unwrap();
        assert_eq!(r.texts.len(), 2);
        assert!(parse_text(r#"{"texts": []}"#).is_err());
        assert!(parse_text(r#"{"texts": [5]}"#).is_err());
        assert!(parse_text(r#"{}"#).is_err());
    }

    #[test]
    fn reload_parse() {
        assert_eq!(parse_reload("").unwrap(), None);
        assert_eq!(parse_reload("{}").unwrap(), None);
        assert_eq!(parse_reload(r#"{"path": "m.bin"}"#).unwrap(), Some("m.bin".into()));
        assert!(parse_reload(r#"{"path": 5}"#).is_err());
        assert!(parse_reload("][").is_err());
    }

    #[test]
    fn response_rendering() {
        let s = predict_response(&[0.5, -1.25], 3, 1);
        let v = crate::config::json::parse(&s).unwrap();
        assert_eq!(v.get("model_version").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("count").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("cached").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("yhat").unwrap().as_array().unwrap().len(), 2);
        let e = error_response("boom \"quoted\"");
        let v = crate::config::json::parse(&e).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("boom \"quoted\""));
    }

    // ---- streaming codec ------------------------------------------------

    fn streamed_docs(body: &str) -> anyhow::Result<(Vec<Vec<u32>>, Option<u64>)> {
        let mut b = ArenaBuilder::new();
        let seed = parse_predict_streamed(body.as_bytes(), &mut b)?;
        let arena = b.finish();
        let docs = (0..arena.num_docs()).map(|i| arena.doc(i).to_vec()).collect();
        Ok((docs, seed))
    }

    #[test]
    fn streamed_predict_matches_tree() {
        for body in [
            r#"{"docs": [[1, 2, 2], [7]], "seed": 9}"#,
            r#"{"docs": [[0]]}"#,
            r#"{"seed": 3, "docs": [[5, 5]], "extra": {"ignored": [1, {"x": null}]}}"#,
            r#"{"docs": [[1]], "docs": [[2, 3]]}"#,
            r#"{"docs": [[1e2, 4.0]], "seed": 1e3}"#,
        ] {
            let tree = parse_predict(body).unwrap();
            let (docs, seed) = streamed_docs(body).unwrap();
            assert_eq!(docs, tree.docs, "{body}");
            assert_eq!(seed, tree.seed, "{body}");
        }
    }

    #[test]
    fn streamed_predict_rejects_bad_shapes() {
        for body in [
            "not json",
            r#"{"docs": []}"#,
            r#"{"docs": [[]]}"#,
            r#"{"docs": [[1.5]]}"#,
            r#"{"docs": [[-3]]}"#,
            r#"{"docs": "x"}"#,
            r#"{"docs": [[1]], "seed": -4}"#,
            r#"{"docs": [[1]], "seed": 1.5}"#,
            r#"{"docs": [[1]]} trailing"#,
            r#"{"docs": [[4294967296]]}"#,
            r#"[1, 2]"#,
            r#"{}"#,
        ] {
            assert!(streamed_docs(body).is_err(), "{body}");
            assert!(parse_predict(body).is_err(), "{body}");
        }
    }

    #[test]
    fn streamed_seed_keeps_full_u64_precision() {
        // Satellite regression: seeds above 2^53 must not round. The
        // streaming codec accepts them exactly; the tree codec rejects.
        let max = r#"{"docs": [[1]], "seed": 18446744073709551615}"#;
        let (_, seed) = streamed_docs(max).unwrap();
        assert_eq!(seed, Some(u64::MAX));
        assert!(parse_predict(max).is_err(), "tree must reject, not round");
        let above53 = r#"{"docs": [[1]], "seed": 9007199254740993}"#;
        let (_, seed) = streamed_docs(above53).unwrap();
        assert_eq!(seed, Some(9007199254740993));
        assert!(parse_predict(above53).is_err());
        // At the boundary both agree.
        let at53 = r#"{"docs": [[1]], "seed": 9007199254740992}"#;
        assert_eq!(streamed_docs(at53).unwrap().1, Some(1u64 << 53));
        assert_eq!(parse_predict(at53).unwrap().seed, Some(1u64 << 53));
    }

    #[test]
    fn streamed_limits_enforced_mid_scan() {
        // 4097 rows: rejected at row 4097's bracket, before its tokens.
        let mut body = String::from(r#"{"docs": ["#);
        for i in 0..(MAX_DOCS_PER_REQUEST + 1) {
            if i > 0 {
                body.push(',');
            }
            body.push_str("[1]");
        }
        body.push_str("]}");
        let mut b = ArenaBuilder::new();
        let e = parse_predict_streamed(body.as_bytes(), &mut b).unwrap_err();
        assert!(e.to_string().contains("rows"), "{e}");
        // ... and the tree agrees on reject.
        assert!(parse_predict(&body).is_err());
    }

    #[test]
    fn streamed_text_and_reload_match_tree() {
        let body = r#"{"texts": ["strong growth", "weak outlook"], "seed": 2}"#;
        let tree = parse_text(body).unwrap();
        let mut texts = Vec::new();
        let seed = parse_text_streamed(body.as_bytes(), &mut texts).unwrap();
        assert_eq!(texts, tree.texts);
        assert_eq!(seed, tree.seed);
        for bad in [r#"{"texts": []}"#, r#"{"texts": [5]}"#, r#"{}"#, "nope"] {
            assert!(parse_text_streamed(bad.as_bytes(), &mut texts).is_err(), "{bad}");
            assert!(parse_text(bad).is_err(), "{bad}");
        }
        for (body, want) in [
            ("", None),
            ("{}", None),
            (r#"{"path": "m.bin"}"#, Some("m.bin".to_string())),
            (r#"[1, {"path": "x"}]"#, None),
        ] {
            assert_eq!(parse_reload_streamed(body.as_bytes()).unwrap(), want, "{body}");
            assert_eq!(parse_reload(body).unwrap(), want, "{body}");
        }
        for bad in [r#"{"path": 5}"#, "][", r#"[1"#] {
            assert!(parse_reload_streamed(bad.as_bytes()).is_err(), "{bad}");
            assert!(parse_reload(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn writer_responses_match_tree_bytes() {
        let mut w = JsonWriter::new();
        predict_response_into(&mut w, &[0.5, -1.25, 3.0], 7, 2);
        assert_eq!(w.as_str(), predict_response(&[0.5, -1.25, 3.0], 7, 2));
        error_response_into(&mut w, "boom \"quoted\"\n");
        assert_eq!(w.as_str(), error_response("boom \"quoted\"\n"));
        // Reuse after clear stays identical.
        predict_response_into(&mut w, &[1.0], 1, 0);
        assert_eq!(w.as_str(), predict_response(&[1.0], 1, 0));
    }
}
