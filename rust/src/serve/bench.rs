//! `cfslda serve-bench`: self-driving loopback load harness.
//!
//! For every (sampler kernel × server workers × request batch size) cell
//! it boots a fresh in-process [`Server`] on an ephemeral port, hammers it
//! from a pool of keep-alive clients, and records throughput (docs/s) plus
//! request latency quantiles. The kernel axis defaults to
//! `sparse,alias` so every run lands a before/after pair — the alias
//! kernel's serving speedup is read straight out of `BENCH_serve.json`,
//! which is written at the invocation directory (the repo root in CI),
//! next to `BENCH_gibbs_hotpath.json`.
//!
//! A second sweep scales *open connections* instead of throughput: for
//! every (serve backend × `--conns-list` count) cell it holds that many
//! keep-alive connections open simultaneously, round-robins single-doc
//! predicts across them, and records latency quantiles plus the
//! admission counters (`accepted`, `shed`, `shed_rate`) into the
//! top-level `conns` array of the same JSON. This is the epoll backend's
//! headline measurement — the threads backend pays one OS thread per
//! open connection; the reactor pays one registered fd.

use crate::config::json::{self, Value};
use crate::config::schema::{ExperimentConfig, KernelKind, ServeBackend};
use crate::model::persist::load_model_full;
use crate::serve::http::Client;
use crate::serve::server::Server;
use crate::util::pool::scoped_map;
use crate::util::rng::Pcg64;
use crate::util::stats::quantile;
use crate::util::timer::Stopwatch;
use std::path::{Path, PathBuf};

/// One sweep cell's knobs.
#[derive(Clone, Debug)]
pub struct BenchOptions {
    pub model_path: PathBuf,
    /// Sampler kernels to sweep (the before/after axis; default
    /// sparse -> alias so the speedup lands in one JSON).
    pub kernel_list: Vec<KernelKind>,
    /// Server worker-pool sizes to sweep (the scaling axis).
    pub workers_list: Vec<usize>,
    /// Documents per request to sweep (the batching axis).
    pub batch_list: Vec<usize>,
    /// Concurrent client connections per cell.
    pub clients: usize,
    /// Requests each client issues per cell.
    pub requests_per_client: usize,
    /// Tokens per synthetic document.
    pub doc_len: usize,
    /// Open-connection counts for the connection-scaling sweep (each cell
    /// holds this many keep-alive connections open simultaneously and
    /// round-robins single-doc predicts across them).
    pub conns_list: Vec<usize>,
    /// Serve backends swept on the connection-scaling axis.
    pub backend_list: Vec<ServeBackend>,
    pub seed: u64,
    pub out_json: PathBuf,
}

impl BenchOptions {
    pub fn new(model_path: PathBuf, quick: bool) -> Self {
        BenchOptions {
            model_path,
            kernel_list: vec![KernelKind::Sparse, KernelKind::Alias],
            workers_list: if quick { vec![1, 2] } else { vec![1, 2, 4] },
            batch_list: vec![1, 8],
            clients: 4,
            requests_per_client: if quick { 12 } else { 100 },
            doc_len: 48,
            conns_list: if quick { vec![8, 32] } else { vec![64, 1024, 4096] },
            backend_list: vec![ServeBackend::Threads, ServeBackend::Epoll],
            seed: 20170710,
            out_json: PathBuf::from("BENCH_serve.json"),
        }
    }
}

/// One cell's measurements.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub kernel: &'static str,
    pub workers: usize,
    pub batch: usize,
    pub requests: usize,
    pub docs: usize,
    pub wall_secs: f64,
    pub docs_per_sec: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Server-side request-duration percentiles for `POST /predict`, read
    /// from the server's own latency histogram (`cfslda_request_duration_
    /// seconds{endpoint="predict"}`) after the load run. Client-side
    /// `p*_ms` include loopback + client scheduling; these do not.
    pub server_p50_ms: f64,
    pub server_p95_ms: f64,
    pub server_p99_ms: f64,
    /// Steady-state heap allocations per request in the codec path
    /// (parse into arena + render response), measured by the counting
    /// allocator; `-1` when built without `--features bench-alloc`.
    pub allocs_per_request: f64,
    /// Bytes allocated per request in the same loop; `-1` when
    /// uninstrumented.
    pub bytes_per_request: f64,
}

/// One connection-scaling cell: `conns` keep-alive connections held open
/// against one backend, latency quantiles over round-robin predicts, and
/// the server's own admission counters.
#[derive(Clone, Debug)]
pub struct ConnsCellResult {
    pub backend: &'static str,
    /// Connections attempted.
    pub conns: usize,
    /// Connections that survived admission and completed every round
    /// (the rest were shed with `503 Retry-After` or reset).
    pub connected: usize,
    /// Successful (200) requests measured.
    pub requests: usize,
    pub wall_secs: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// `cfslda_accepted_total` / `cfslda_shed_total` from the cell's own
    /// server, read after the load run.
    pub accepted: u64,
    pub shed: u64,
    /// shed / accepted (0 when nothing was accepted).
    pub shed_rate: f64,
}

/// Measure steady-state codec allocations for one request body: warmed
/// parse-into-arena + response render, no server or batcher threads in
/// the picture (counters are process-global, so this runs before the
/// first cell boots). The response render uses a synthetic yhat of the
/// right length; its cost is identical to the served one.
#[cfg(feature = "bench-alloc")]
fn codec_allocs_per_request(body: &str, iters: usize) -> (f64, f64) {
    use crate::config::json::JsonWriter;
    use crate::serve::batcher::ArenaBuilder;
    use crate::serve::protocol;
    use crate::util::alloc_count;

    let bytes = body.as_bytes();
    let mut builder = ArenaBuilder::new();
    let mut w = JsonWriter::with_capacity(1024);
    let mut yhat: Vec<f64> = Vec::new();
    let mut run_once = |builder: &mut ArenaBuilder, w: &mut JsonWriter, yhat: &mut Vec<f64>| {
        let seed = protocol::parse_predict_streamed(bytes, builder)
            .expect("bench body parses")
            .unwrap_or(0);
        let arena = builder.finish();
        yhat.clear();
        for d in 0..arena.num_docs() {
            yhat.push(arena.doc(d).len() as f64 * 0.25);
        }
        protocol::predict_response_into(w, yhat, seed, 0);
        builder.reclaim(arena);
    };
    // Warmup grows every reusable buffer to its steady-state capacity.
    for _ in 0..8 {
        run_once(&mut builder, &mut w, &mut yhat);
    }
    let before = alloc_count::snapshot();
    for _ in 0..iters {
        run_once(&mut builder, &mut w, &mut yhat);
    }
    let (da, db) = alloc_count::delta(before);
    (da as f64 / iters as f64, db as f64 / iters as f64)
}

#[cfg(not(feature = "bench-alloc"))]
fn codec_allocs_per_request(_body: &str, _iters: usize) -> (f64, f64) {
    (-1.0, -1.0)
}

/// Measure steady-state allocations for the **whole warmed request
/// pipeline**: parse into the pooled arena, submit through the batcher
/// with a pooled [`Completion`] + results `Vec`, render the response, and
/// reclaim the arena. Unlike [`codec_allocs_per_request`] this includes
/// the batcher queue hop and the worker's prediction (which allocates its
/// sampling state), so it bounds the serve hot path from above. Runs with
/// one worker before any cell's server boots (the counters are
/// process-global).
#[cfg(feature = "bench-alloc")]
fn pipeline_allocs_per_request(
    cfg: &ExperimentConfig,
    model_path: &Path,
    body: &str,
    iters: usize,
) -> anyhow::Result<(f64, f64)> {
    use crate::config::json::JsonWriter;
    use crate::serve::batcher::{ArenaBuilder, Batcher, BatcherConfig, Completion, DocOut};
    use crate::serve::protocol;
    use crate::serve::registry::Registry;
    use crate::util::alloc_count;
    use std::sync::Arc;

    let registry = Arc::new(Registry::open(model_path, 0, true)?);
    let stats = Arc::new(crate::obs::ServeMetrics::new());
    let batcher = Batcher::start(
        BatcherConfig {
            workers: 1,
            max_batch: cfg.serve.max_batch.max(1),
            max_wait_us: 0,
            queue_depth_max: 0,
            kernel: cfg.sampler.kernel,
            train: cfg.train.clone(),
            panic_token: None,
        },
        registry,
        stats,
    );

    let bytes = body.as_bytes();
    let mut builder = ArenaBuilder::new();
    let mut w = JsonWriter::with_capacity(1024);
    let mut results: Vec<anyhow::Result<DocOut>> = Vec::new();
    let mut yhat: Vec<f64> = Vec::new();
    let comp = Arc::new(Completion::new());
    let mut run_once = |builder: &mut ArenaBuilder,
                        w: &mut JsonWriter,
                        results: &mut Vec<anyhow::Result<DocOut>>,
                        yhat: &mut Vec<f64>| {
        let seed = protocol::parse_predict_streamed(bytes, builder)
            .expect("bench body parses")
            .unwrap_or(0);
        let mut arena = Arc::new(builder.finish());
        batcher.submit_streamed_into(Arc::clone(&arena), seed, &comp, results);
        yhat.clear();
        let mut version = 0;
        for r in results.iter() {
            let d = r.as_ref().expect("bench prediction succeeds");
            yhat.push(d.yhat);
            version = d.model_version;
        }
        protocol::predict_response_into(w, yhat, version, 0);
        // The worker may still hold its (already-completed) item's arena
        // Arc for an instant after waking us; spin briefly to reclaim.
        for _ in 0..1000 {
            match Arc::try_unwrap(arena) {
                Ok(a) => {
                    builder.reclaim(a);
                    return;
                }
                Err(back) => {
                    arena = back;
                    std::thread::yield_now();
                }
            }
        }
    };
    for _ in 0..8 {
        run_once(&mut builder, &mut w, &mut results, &mut yhat);
    }
    let before = alloc_count::snapshot();
    for _ in 0..iters {
        run_once(&mut builder, &mut w, &mut results, &mut yhat);
    }
    let (da, db) = alloc_count::delta(before);
    drop(batcher);
    Ok((da as f64 / iters as f64, db as f64 / iters as f64))
}

#[cfg(not(feature = "bench-alloc"))]
fn pipeline_allocs_per_request(
    _cfg: &ExperimentConfig,
    _model_path: &Path,
    _body: &str,
    _iters: usize,
) -> anyhow::Result<(f64, f64)> {
    Ok((-1.0, -1.0))
}

fn gen_docs(rng: &mut Pcg64, n: usize, len: usize, vocab: usize) -> Vec<Vec<u32>> {
    (0..n).map(|_| (0..len).map(|_| rng.gen_range(vocab) as u32).collect()).collect()
}

fn docs_body(docs: &[Vec<u32>], seed: u64) -> String {
    let rows: Vec<Value> = docs
        .iter()
        .map(|d| Value::Array(d.iter().map(|&t| Value::Number(t as f64)).collect()))
        .collect();
    json::to_string(&Value::object(vec![
        ("docs", Value::Array(rows)),
        ("seed", Value::Number(seed as f64)),
    ]))
}

fn run_cell(
    cfg_base: &ExperimentConfig,
    opts: &BenchOptions,
    vocab: usize,
    kernel: KernelKind,
    workers: usize,
    batch: usize,
) -> anyhow::Result<CellResult> {
    let mut cfg = cfg_base.clone();
    cfg.serve.addr = "127.0.0.1:0".to_string();
    cfg.serve.workers = workers;
    cfg.sampler.kernel = kernel;
    // measure sampler throughput, not cache hits: distinct docs + no cache
    cfg.serve.cache_capacity = 0;
    let server = Server::start(&opts.model_path, &cfg)?;
    let addr = server.local_addr().to_string();

    // Pre-render one request body per (client, request): distinct docs so
    // every prediction does real sampling work.
    let bodies: Vec<Vec<String>> = (0..opts.clients)
        .map(|c| {
            let mut rng = Pcg64::seed_from_u64(
                opts.seed ^ (c as u64) << 32 ^ (workers as u64) << 8 ^ batch as u64,
            );
            (0..opts.requests_per_client)
                .map(|_| {
                    let docs = gen_docs(&mut rng, batch, opts.doc_len, vocab);
                    docs_body(&docs, opts.seed)
                })
                .collect()
        })
        .collect();

    let sw = Stopwatch::new();
    let per_client: Vec<anyhow::Result<Vec<f64>>> =
        scoped_map(&bodies, opts.clients.max(1), |_, reqs| {
            let mut client = Client::connect(&addr)?;
            let mut lats = Vec::with_capacity(reqs.len());
            for body in reqs {
                let t = Stopwatch::new();
                let (status, resp) = client.request("POST", "/predict", body)?;
                anyhow::ensure!(status == 200, "predict returned {status}: {resp}");
                lats.push(t.elapsed_secs());
            }
            Ok(lats)
        });
    let wall_secs = sw.elapsed_secs();
    // Server-side latency distribution: this cell booted its own Server,
    // so its metrics cover exactly this cell's traffic.
    let hist = server.metrics().latency_for(crate::obs::Endpoint::Predict).snapshot();
    server.stop();

    let mut lats = Vec::new();
    for r in per_client {
        lats.extend(r?);
    }
    let requests = lats.len();
    let docs = requests * batch;
    Ok(CellResult {
        kernel: kernel.name(),
        workers,
        batch,
        requests,
        docs,
        wall_secs,
        docs_per_sec: docs as f64 / wall_secs.max(1e-9),
        p50_ms: quantile(&lats, 0.50) * 1e3,
        p95_ms: quantile(&lats, 0.95) * 1e3,
        p99_ms: quantile(&lats, 0.99) * 1e3,
        server_p50_ms: hist.quantile(0.50) as f64 * 1e-3,
        server_p95_ms: hist.quantile(0.95) as f64 * 1e-3,
        server_p99_ms: hist.quantile(0.99) as f64 * 1e-3,
        // Filled in by run_bench from the per-batch codec measurement.
        allocs_per_request: -1.0,
        bytes_per_request: -1.0,
    })
}

/// Predict rounds each surviving connection issues in the
/// connection-scaling sweep (round one doubles as the admission probe:
/// a shed connection 503s or resets on its first request).
const CONNS_ROUNDS: usize = 2;

fn run_conns_cell(
    cfg_base: &ExperimentConfig,
    opts: &BenchOptions,
    vocab: usize,
    backend: ServeBackend,
    conns: usize,
) -> anyhow::Result<ConnsCellResult> {
    let mut cfg = cfg_base.clone();
    cfg.serve.addr = "127.0.0.1:0".to_string();
    cfg.serve.backend = backend;
    cfg.serve.cache_capacity = 0;
    let server = Server::start(&opts.model_path, &cfg)?;
    let addr = server.local_addr().to_string();

    // Driver threads each own a shard of connections. Every shard connects
    // its whole shard first, so the full `conns` population is open
    // simultaneously, then round-robins single-doc predicts across the
    // connections that survived admission.
    let threads = conns.clamp(1, 16);
    let shards: Vec<Vec<String>> = (0..threads)
        .map(|s| {
            let mut rng = Pcg64::seed_from_u64(
                opts.seed ^ 0xc0a5 ^ (s as u64) << 20 ^ conns as u64,
            );
            let count = conns / threads + usize::from(s < conns % threads);
            (0..count)
                .map(|_| {
                    docs_body(&gen_docs(&mut rng, 1, opts.doc_len.min(16), vocab), opts.seed)
                })
                .collect()
        })
        .collect();
    let sw = Stopwatch::new();
    let per_shard: Vec<anyhow::Result<(Vec<f64>, usize)>> =
        scoped_map(&shards, threads, |_, bodies| {
            let mut clients: Vec<Option<Client>> =
                bodies.iter().map(|_| Client::connect(&addr).ok()).collect();
            let mut lats = Vec::new();
            for _ in 0..CONNS_ROUNDS {
                for (i, slot) in clients.iter_mut().enumerate() {
                    let Some(client) = slot.as_mut() else { continue };
                    let t = Stopwatch::new();
                    match client.request("POST", "/predict", &bodies[i]) {
                        Ok((200, _)) => lats.push(t.elapsed_secs()),
                        // Shed (503 + close) or reset: drop the connection
                        // from later rounds; the server's counters record it.
                        _ => *slot = None,
                    }
                }
            }
            let connected = clients.iter().filter(|c| c.is_some()).count();
            Ok((lats, connected))
        });
    let wall_secs = sw.elapsed_secs();
    let accepted = server.metrics().accepted.get();
    let shed = server.metrics().shed.get();
    server.stop();

    let mut lats = Vec::new();
    let mut connected = 0;
    for r in per_shard {
        let (l, c) = r?;
        lats.extend(l);
        connected += c;
    }
    let q = |p: f64| if lats.is_empty() { 0.0 } else { quantile(&lats, p) * 1e3 };
    Ok(ConnsCellResult {
        backend: backend.name(),
        conns,
        connected,
        requests: lats.len(),
        wall_secs,
        p50_ms: q(0.50),
        p95_ms: q(0.95),
        p99_ms: q(0.99),
        accepted,
        shed,
        shed_rate: if accepted > 0 { shed as f64 / accepted as f64 } else { 0.0 },
    })
}

fn render_table(results: &[CellResult]) -> String {
    let mut s = String::from("== bench: serve (loopback) ==\n");
    s.push_str(&format!(
        "{:<8} {:<8} {:>6} {:>9} {:>8} {:>12} {:>9} {:>9} {:>9} {:>9} {:>11} {:>11}\n",
        "kernel", "workers", "batch", "requests", "docs", "docs/s", "p50(ms)", "p95(ms)",
        "p99(ms)", "sp95(ms)", "allocs/req", "bytes/req"
    ));
    for r in results {
        s.push_str(&format!(
            "{:<8} {:<8} {:>6} {:>9} {:>8} {:>12.1} {:>9.2} {:>9.2} {:>9.2} {:>9.2} \
             {:>11.2} {:>11.0}\n",
            r.kernel, r.workers, r.batch, r.requests, r.docs, r.docs_per_sec, r.p50_ms,
            r.p95_ms, r.p99_ms, r.server_p95_ms, r.allocs_per_request, r.bytes_per_request
        ));
    }
    s
}

fn render_conns_table(cells: &[ConnsCellResult]) -> String {
    let mut s = String::from("== bench: serve connection scaling ==\n");
    s.push_str(&format!(
        "{:<8} {:>7} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7} {:>10}\n",
        "backend", "conns", "connected", "requests", "p50(ms)", "p95(ms)", "p99(ms)",
        "accepted", "shed", "shed_rate"
    ));
    for r in cells {
        s.push_str(&format!(
            "{:<8} {:>7} {:>9} {:>9} {:>9.2} {:>9.2} {:>9.2} {:>9} {:>7} {:>10.4}\n",
            r.backend, r.conns, r.connected, r.requests, r.p50_ms, r.p95_ms, r.p99_ms,
            r.accepted, r.shed, r.shed_rate
        ));
    }
    s
}

fn results_json(
    opts: &BenchOptions,
    t: usize,
    w: usize,
    backend: &str,
    results: &[CellResult],
    conns: &[ConnsCellResult],
    pipeline_allocs: &[(usize, (f64, f64))],
) -> Value {
    let cells: Vec<Value> = results
        .iter()
        .map(|r| {
            Value::object(vec![
                ("kernel", Value::String(r.kernel.to_string())),
                ("workers", Value::Number(r.workers as f64)),
                ("batch", Value::Number(r.batch as f64)),
                ("requests", Value::Number(r.requests as f64)),
                ("docs", Value::Number(r.docs as f64)),
                ("wall_secs", Value::Number(r.wall_secs)),
                ("docs_per_sec", Value::Number(r.docs_per_sec)),
                ("p50_ms", Value::Number(r.p50_ms)),
                ("p95_ms", Value::Number(r.p95_ms)),
                ("p99_ms", Value::Number(r.p99_ms)),
                ("server_p50_ms", Value::Number(r.server_p50_ms)),
                ("server_p95_ms", Value::Number(r.server_p95_ms)),
                ("server_p99_ms", Value::Number(r.server_p99_ms)),
                ("allocs_per_request", Value::Number(r.allocs_per_request)),
                ("bytes_per_request", Value::Number(r.bytes_per_request)),
            ])
        })
        .collect();
    let conns_cells: Vec<Value> = conns
        .iter()
        .map(|r| {
            Value::object(vec![
                ("backend", Value::String(r.backend.to_string())),
                ("conns", Value::Number(r.conns as f64)),
                ("connected", Value::Number(r.connected as f64)),
                ("requests", Value::Number(r.requests as f64)),
                ("wall_secs", Value::Number(r.wall_secs)),
                ("p50_ms", Value::Number(r.p50_ms)),
                ("p95_ms", Value::Number(r.p95_ms)),
                ("p99_ms", Value::Number(r.p99_ms)),
                ("accepted", Value::Number(r.accepted as f64)),
                ("shed", Value::Number(r.shed as f64)),
                ("shed_rate", Value::Number(r.shed_rate)),
            ])
        })
        .collect();
    let pipeline: Vec<Value> = pipeline_allocs
        .iter()
        .map(|&(batch, (a, b))| {
            Value::object(vec![
                ("batch", Value::Number(batch as f64)),
                ("allocs_per_request", Value::Number(a)),
                ("bytes_per_request", Value::Number(b)),
            ])
        })
        .collect();
    Value::object(vec![
        ("bench", Value::String("serve".into())),
        ("model", Value::object(vec![
            ("path", Value::String(opts.model_path.display().to_string())),
            ("topics", Value::Number(t as f64)),
            ("vocab", Value::Number(w as f64)),
        ])),
        ("clients", Value::Number(opts.clients as f64)),
        ("requests_per_client", Value::Number(opts.requests_per_client as f64)),
        ("doc_len", Value::Number(opts.doc_len as f64)),
        ("seed", Value::Number(opts.seed as f64)),
        ("alloc_instrumented", Value::Bool(cfg!(feature = "bench-alloc"))),
        // Backend serving the kernel/workers/batch sweep in `results`;
        // `conns` carries its own per-cell backend axis.
        ("backend", Value::String(backend.to_string())),
        ("results", Value::Array(cells)),
        ("conns", Value::Array(conns_cells)),
        ("pipeline", Value::Array(pipeline)),
    ])
}

/// Run the full sweep; prints the table, writes `opts.out_json`, and
/// returns the parsed results for programmatic use.
pub fn run_bench(
    cfg_base: &ExperimentConfig,
    opts: &BenchOptions,
) -> anyhow::Result<Vec<CellResult>> {
    anyhow::ensure!(opts.clients > 0, "need at least one client");
    anyhow::ensure!(opts.requests_per_client > 0, "need at least one request per client");
    anyhow::ensure!(!opts.workers_list.is_empty() && !opts.batch_list.is_empty(), "empty sweep");
    anyhow::ensure!(opts.batch_list.iter().all(|&b| b >= 1), "batch sizes must be >= 1");
    let (model, _) = load_model_full(Path::new(&opts.model_path))?;
    let (t, w) = (model.t, model.w);
    drop(model);
    anyhow::ensure!(!opts.kernel_list.is_empty(), "empty kernel sweep");
    // Codec allocation profile per batch size, measured while the process
    // is still quiet (the counting allocator's totals are process-global,
    // so this must run before the first cell's server threads spin up).
    let codec_allocs: Vec<(usize, (f64, f64))> = opts
        .batch_list
        .iter()
        .map(|&batch| {
            let mut rng = Pcg64::seed_from_u64(opts.seed ^ batch as u64);
            let docs = gen_docs(&mut rng, batch, opts.doc_len, w);
            (batch, codec_allocs_per_request(&docs_body(&docs, opts.seed), 64))
        })
        .collect();
    // End-to-end pipeline allocation profile (codec + batcher hop with the
    // pooled Completion + worker prediction), per batch size.
    let pipeline_allocs: Vec<(usize, (f64, f64))> = opts
        .batch_list
        .iter()
        .map(|&batch| {
            let mut rng = Pcg64::seed_from_u64(opts.seed ^ 0x5eed ^ batch as u64);
            let docs = gen_docs(&mut rng, batch, opts.doc_len, w);
            let body = docs_body(&docs, opts.seed);
            let (a, b) = pipeline_allocs_per_request(cfg_base, &opts.model_path, &body, 32)?;
            Ok((batch, (a, b)))
        })
        .collect::<anyhow::Result<_>>()?;
    for &(batch, (a, b)) in &pipeline_allocs {
        if a >= 0.0 {
            log::info!(
                "pipeline allocs batch={batch}: {a:.2} allocs/req, {b:.0} bytes/req"
            );
        }
    }
    let mut results = Vec::new();
    for &kernel in &opts.kernel_list {
        for &workers in &opts.workers_list {
            for &batch in &opts.batch_list {
                let mut cell = run_cell(cfg_base, opts, w, kernel, workers, batch)?;
                if let Some(&(_, (a, b))) = codec_allocs.iter().find(|(x, _)| *x == batch) {
                    cell.allocs_per_request = a;
                    cell.bytes_per_request = b;
                }
                log::info!(
                    "serve-bench kernel={} workers={} batch={}: {:.1} docs/s p95={:.2}ms",
                    cell.kernel, cell.workers, cell.batch, cell.docs_per_sec, cell.p95_ms
                );
                results.push(cell);
            }
        }
    }
    // Connection-scaling sweep: per backend, hold `conns` keep-alive
    // connections open simultaneously and measure latency quantiles plus
    // the admission counters (shed_rate stays 0 until `--max-conns` /
    // `--queue-depth-max` bites).
    let mut conns_cells = Vec::new();
    for &backend in &opts.backend_list {
        for &conns in &opts.conns_list {
            let cell = run_conns_cell(cfg_base, opts, w, backend, conns)?;
            log::info!(
                "serve-bench backend={} conns={}: connected={} p95={:.2}ms shed_rate={:.4}",
                cell.backend,
                cell.conns,
                cell.connected,
                cell.p95_ms,
                cell.shed_rate
            );
            conns_cells.push(cell);
        }
    }
    println!("{}", render_table(&results));
    if !conns_cells.is_empty() {
        println!("{}", render_conns_table(&conns_cells));
    }
    // Before/after headline: alias speedup over the first non-alias kernel
    // at matching (workers, batch) cells.
    for a in results.iter().filter(|r| r.kernel == "alias") {
        if let Some(b) = results
            .iter()
            .find(|r| r.kernel != "alias" && r.workers == a.workers && r.batch == a.batch)
        {
            if b.docs_per_sec > 0.0 {
                println!(
                    "speedup workers={} batch={}: alias/{} = {:.2}x",
                    a.workers,
                    a.batch,
                    b.kernel,
                    a.docs_per_sec / b.docs_per_sec
                );
            }
        }
    }
    let v = results_json(
        opts,
        t,
        w,
        cfg_base.serve.backend.name(),
        &results,
        &conns_cells,
        &pipeline_allocs,
    );
    std::fs::write(&opts.out_json, json::to_string_pretty(&v))?;
    println!("wrote {}", opts.out_json.display());
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn docs_body_is_valid_protocol_json() {
        let mut rng = Pcg64::seed_from_u64(1);
        let docs = gen_docs(&mut rng, 3, 5, 100);
        let body = docs_body(&docs, 42);
        let parsed = crate::serve::protocol::parse_predict(&body).unwrap();
        assert_eq!(parsed.docs, docs);
        assert_eq!(parsed.seed, Some(42));
    }

    #[test]
    fn table_and_json_render() {
        let cell = CellResult {
            kernel: "alias",
            workers: 2,
            batch: 8,
            requests: 10,
            docs: 80,
            wall_secs: 0.5,
            docs_per_sec: 160.0,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
            server_p50_ms: 0.5,
            server_p95_ms: 1.5,
            server_p99_ms: 2.5,
            allocs_per_request: 0.0,
            bytes_per_request: 0.0,
        };
        let conns_cell = ConnsCellResult {
            backend: "epoll",
            conns: 1024,
            connected: 1000,
            requests: 2000,
            wall_secs: 1.5,
            p50_ms: 0.8,
            p95_ms: 2.2,
            p99_ms: 4.0,
            accepted: 1024,
            shed: 24,
            shed_rate: 24.0 / 1024.0,
        };
        let table = render_table(&[cell.clone()]);
        assert!(table.contains("docs/s"));
        assert!(table.contains("160.0"));
        assert!(table.contains("sp95(ms)"));
        let conns_table = render_conns_table(&[conns_cell.clone()]);
        assert!(conns_table.contains("shed_rate"));
        assert!(conns_table.contains("epoll"));
        let opts = BenchOptions::new(PathBuf::from("m.bin"), true);
        let v = results_json(
            &opts,
            8,
            100,
            "threads",
            &[cell],
            &[conns_cell],
            &[(8, (3.0, 512.0))],
        );
        let parsed = json::parse(&json::to_string_pretty(&v)).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("serve"));
        assert_eq!(
            parsed.get("results").unwrap().as_array().unwrap()[0]
                .get("kernel")
                .unwrap()
                .as_str(),
            Some("alias")
        );
        assert_eq!(
            parsed.get("results").unwrap().as_array().unwrap()[0]
                .get("docs")
                .unwrap()
                .as_usize(),
            Some(80)
        );
        // The CI serve-smoke job greps for these; keep them present even
        // when the build is uninstrumented.
        assert_eq!(
            parsed.get("alloc_instrumented").unwrap().as_bool(),
            Some(cfg!(feature = "bench-alloc"))
        );
        assert_eq!(
            parsed.get("results").unwrap().as_array().unwrap()[0]
                .get("allocs_per_request")
                .unwrap()
                .as_f64(),
            Some(0.0)
        );
        assert!(
            parsed.get("results").unwrap().as_array().unwrap()[0]
                .get("bytes_per_request")
                .is_some()
        );
        assert_eq!(
            parsed.get("results").unwrap().as_array().unwrap()[0]
                .get("server_p95_ms")
                .unwrap()
                .as_f64(),
            Some(1.5)
        );
        let pipe = parsed.get("pipeline").unwrap().as_array().unwrap();
        assert_eq!(pipe[0].get("batch").unwrap().as_usize(), Some(8));
        assert_eq!(pipe[0].get("allocs_per_request").unwrap().as_f64(), Some(3.0));
        assert_eq!(parsed.get("backend").unwrap().as_str(), Some("threads"));
        let conns = parsed.get("conns").unwrap().as_array().unwrap();
        assert_eq!(conns[0].get("backend").unwrap().as_str(), Some("epoll"));
        assert_eq!(conns[0].get("conns").unwrap().as_usize(), Some(1024));
        let rate = conns[0].get("shed_rate").unwrap().as_f64().unwrap();
        assert!(rate.is_finite() && (rate - 24.0 / 1024.0).abs() < 1e-12);
        assert!(conns[0].get("p99_ms").unwrap().as_f64().unwrap().is_finite());
    }

    #[cfg(feature = "bench-alloc")]
    #[test]
    fn codec_measurement_runs_and_is_finite() {
        let mut rng = Pcg64::seed_from_u64(7);
        let docs = gen_docs(&mut rng, 4, 16, 50);
        let body = docs_body(&docs, 9);
        let (allocs, bytes) = codec_allocs_per_request(&body, 16);
        assert!(allocs.is_finite() && allocs >= 0.0, "allocs/req = {allocs}");
        assert!(bytes.is_finite() && bytes >= 0.0, "bytes/req = {bytes}");
    }
}
