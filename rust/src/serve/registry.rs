//! Model registry: versioned slots, atomic hot-swap, and the doc-level
//! LRU prediction cache.
//!
//! A [`ModelEntry`] bundles everything the prediction workers need to stay
//! allocation-free on the request path: the model, its optional persisted
//! vocabulary, the precomputed per-word sparse smoothing table (`phi_cum`,
//! see [`kernel::build_phi_cum`]) and the frozen-phi Walker alias tables
//! ([`PhiAliasTables`] — the alias kernel's exact O(1) word proposal) that
//! `cfslda predict` would otherwise rebuild on every invocation. The tables
//! are built at load/`POST /reload`, so a hot swap pays the build cost once
//! and every batcher worker shares them through the pinned entry `Arc`;
//! `GET /stats` reports the build time and resident bytes per version.
//!
//! Hot-swap protocol: `/reload` loads the new file into a fresh entry,
//! then atomically replaces the `current` pointer. In-flight batches keep
//! their `Arc<ModelEntry>` alive until they finish, so **zero requests are
//! dropped** during a swap; the old entry is retained in the version ring
//! until the last reference drains. The prediction cache is keyed by
//! (model version, seed, token hash), so stale entries can never serve a
//! new model's traffic; it is additionally cleared on swap to hand the
//! memory to the new version immediately.

use crate::model::persist::load_model_full;
use crate::model::slda::SldaModel;
use crate::data::vocab::Vocab;
use crate::sampler::gibbs_predict::token_hash;
use crate::sampler::kernel::{self, PhiAliasTables};
use crate::util::timer::Stopwatch;
use anyhow::Context;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::sync::Arc;

/// How many superseded versions the registry remembers (for `/stats`
/// introspection; the `Arc`s themselves free as soon as workers drain).
const RETAINED_VERSIONS: usize = 4;

/// Everything the workers need for one model version, resident in memory.
pub struct ModelEntry {
    pub version: u64,
    pub path: PathBuf,
    pub model: SldaModel,
    pub vocab: Option<Vocab>,
    /// Precomputed per-word cumulative smoothing masses `Σ α·phi` — the
    /// sparse prediction kernel's lookup table, built once per load.
    pub phi_cum: Vec<f64>,
    /// Frozen-phi Walker alias tables — the alias kernel's exact O(1) word
    /// proposal, built once per load/hot-swap and shared by every batcher
    /// worker via this entry's `Arc`. `None` when the registry was opened
    /// with a kernel that can never resolve to alias (dense/sparse), so
    /// those deployments pay neither the O(W·T) build nor the residency.
    pub phi_alias: Option<PhiAliasTables>,
    /// Wall-clock seconds spent building `phi_alias` (0 when not built;
    /// surfaced by `/stats`).
    pub alias_build_secs: f64,
}

/// One row of the registry's bounded version history (`/stats`).
#[derive(Clone, Debug)]
pub struct VersionInfo {
    pub version: u64,
    pub path: PathBuf,
    pub alias_build_secs: f64,
    pub alias_resident_bytes: usize,
}

/// Cache key: (model version, request seed, document token hash).
pub type CacheKey = (u64, u64, u64);

/// Versioned model slots + prediction cache.
pub struct Registry {
    current: RwLock<Arc<ModelEntry>>,
    retained: Mutex<Vec<VersionInfo>>,
    /// Whether loads build the frozen-phi alias tables (the serving kernel
    /// is alias or may resolve to it). Fixed at open time, applied to every
    /// reload.
    build_alias: bool,
    next_version: AtomicU64,
    cache: Mutex<Lru>,
    /// Serializes whole reload operations (version take → load → swap) so
    /// concurrent `/reload`s cannot publish an older version after a newer
    /// one — versions observed by clients only ever move forward.
    reload_lock: Mutex<()>,
}

impl Registry {
    fn load_entry(path: &Path, version: u64, build_alias: bool) -> anyhow::Result<ModelEntry> {
        let (model, vocab) =
            load_model_full(path).with_context(|| format!("loading model {path:?}"))?;
        let phi_cum = kernel::build_phi_cum(&model.phi, model.t, model.alpha);
        let sw = Stopwatch::new();
        let phi_alias =
            build_alias.then(|| PhiAliasTables::build(&model.phi, model.t));
        let alias_build_secs = if phi_alias.is_some() { sw.elapsed_secs() } else { 0.0 };
        Ok(ModelEntry {
            version,
            path: path.to_path_buf(),
            model,
            vocab,
            phi_cum,
            phi_alias,
            alias_build_secs,
        })
    }

    fn info_of(entry: &ModelEntry) -> VersionInfo {
        VersionInfo {
            version: entry.version,
            path: entry.path.clone(),
            alias_build_secs: entry.alias_build_secs,
            alias_resident_bytes: entry
                .phi_alias
                .as_ref()
                .map_or(0, |t| t.resident_bytes()),
        }
    }

    /// Open the registry with the initial model (version 1). `build_alias`
    /// controls whether loads prebuild the frozen-phi alias tables (pass
    /// true unless the serving kernel is pinned to dense/sparse).
    pub fn open(
        path: &Path,
        cache_capacity: usize,
        build_alias: bool,
    ) -> anyhow::Result<Registry> {
        let entry = Arc::new(Self::load_entry(path, 1, build_alias)?);
        Ok(Registry {
            retained: Mutex::new(vec![Self::info_of(&entry)]),
            current: RwLock::new(entry),
            build_alias,
            next_version: AtomicU64::new(1),
            cache: Mutex::new(Lru::new(cache_capacity)),
            reload_lock: Mutex::new(()),
        })
    }

    /// The entry serving traffic right now. Callers hold the `Arc` for the
    /// whole batch, so a concurrent swap never invalidates their model.
    pub fn current(&self) -> Arc<ModelEntry> {
        self.current.read().unwrap().clone()
    }

    /// Load `path` (or the current path when `None`) into a new versioned
    /// slot and atomically make it current. On any load error the previous
    /// model keeps serving — reload is all-or-nothing.
    pub fn reload(&self, path: Option<&Path>) -> anyhow::Result<Arc<ModelEntry>> {
        let _serialize = self.reload_lock.lock().unwrap();
        let path = match path {
            Some(p) => p.to_path_buf(),
            None => self.current().path.clone(),
        };
        let version = self.next_version.fetch_add(1, Ordering::SeqCst) + 1;
        let entry = Arc::new(Self::load_entry(&path, version, self.build_alias)?);
        {
            let mut retained = self.retained.lock().unwrap();
            retained.push(Self::info_of(&entry));
            let excess = retained.len().saturating_sub(RETAINED_VERSIONS);
            retained.drain(..excess);
        }
        *self.current.write().unwrap() = entry.clone();
        self.cache.lock().unwrap().clear();
        log::info!(
            "model hot-swap: now serving version {} from {:?} (alias build {:.3}s)",
            entry.version,
            entry.path,
            entry.alias_build_secs
        );
        Ok(entry)
    }

    /// Version history (with alias-table build cost/footprint), oldest
    /// first (bounded ring).
    pub fn versions(&self) -> Vec<VersionInfo> {
        self.retained.lock().unwrap().clone()
    }

    pub fn cache_get(&self, key: CacheKey) -> Option<f64> {
        self.cache.lock().unwrap().get(key)
    }

    pub fn cache_put(&self, key: CacheKey, yhat: f64) {
        self.cache.lock().unwrap().put(key, yhat);
    }

    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Cache key for a document under this entry/seed.
    pub fn cache_key(entry: &ModelEntry, seed: u64, tokens: &[u32]) -> CacheKey {
        (entry.version, seed, token_hash(tokens))
    }
}

const NIL: usize = usize::MAX;

struct Node {
    key: CacheKey,
    val: f64,
    prev: usize,
    next: usize,
}

/// Fixed-capacity LRU map (slab + intrusive doubly-linked recency list;
/// no hashing crates offline). Capacity 0 disables it entirely.
pub struct Lru {
    cap: usize,
    map: HashMap<CacheKey, usize>,
    nodes: Vec<Node>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl Lru {
    pub fn new(cap: usize) -> Lru {
        Lru { cap, map: HashMap::new(), nodes: Vec::new(), free: Vec::new(), head: NIL, tail: NIL }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn unlink(&mut self, idx: usize) {
        let (p, n) = (self.nodes[idx].prev, self.nodes[idx].next);
        if p != NIL {
            self.nodes[p].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.nodes[n].prev = p;
        } else {
            self.tail = p;
        }
        self.nodes[idx].prev = NIL;
        self.nodes[idx].next = NIL;
    }

    fn push_front(&mut self, idx: usize) {
        self.nodes[idx].prev = NIL;
        self.nodes[idx].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = idx;
        } else {
            self.tail = idx;
        }
        self.head = idx;
    }

    pub fn get(&mut self, key: CacheKey) -> Option<f64> {
        let idx = *self.map.get(&key)?;
        self.unlink(idx);
        self.push_front(idx);
        Some(self.nodes[idx].val)
    }

    pub fn put(&mut self, key: CacheKey, val: f64) {
        if self.cap == 0 {
            return;
        }
        if let Some(&idx) = self.map.get(&key) {
            self.nodes[idx].val = val;
            self.unlink(idx);
            self.push_front(idx);
            return;
        }
        if self.map.len() >= self.cap {
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            self.unlink(victim);
            self.map.remove(&self.nodes[victim].key);
            self.free.push(victim);
        }
        let idx = match self.free.pop() {
            Some(i) => {
                self.nodes[i] = Node { key, val, prev: NIL, next: NIL };
                i
            }
            None => {
                self.nodes.push(Node { key, val, prev: NIL, next: NIL });
                self.nodes.len() - 1
            }
        };
        self.push_front(idx);
        self.map.insert(key, idx);
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::persist::save_model_with_vocab;
    use crate::util::rng::Pcg64;

    fn k(i: u64) -> CacheKey {
        (1, 0, i)
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut lru = Lru::new(2);
        lru.put(k(1), 1.0);
        lru.put(k(2), 2.0);
        assert_eq!(lru.get(k(1)), Some(1.0)); // 1 becomes MRU
        lru.put(k(3), 3.0); // evicts 2
        assert_eq!(lru.get(k(2)), None);
        assert_eq!(lru.get(k(1)), Some(1.0));
        assert_eq!(lru.get(k(3)), Some(3.0));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn lru_update_moves_to_front() {
        let mut lru = Lru::new(2);
        lru.put(k(1), 1.0);
        lru.put(k(2), 2.0);
        lru.put(k(1), 10.0); // update, 1 is MRU
        lru.put(k(3), 3.0); // evicts 2
        assert_eq!(lru.get(k(1)), Some(10.0));
        assert_eq!(lru.get(k(2)), None);
    }

    #[test]
    fn lru_zero_capacity_is_disabled() {
        let mut lru = Lru::new(0);
        lru.put(k(1), 1.0);
        assert_eq!(lru.get(k(1)), None);
        assert!(lru.is_empty());
    }

    #[test]
    fn lru_single_slot_and_clear() {
        let mut lru = Lru::new(1);
        for i in 0..100 {
            lru.put(k(i), i as f64);
            assert_eq!(lru.len(), 1);
            assert_eq!(lru.get(k(i)), Some(i as f64));
        }
        lru.clear();
        assert_eq!(lru.get(k(99)), None);
        lru.put(k(7), 7.0);
        assert_eq!(lru.get(k(7)), Some(7.0));
    }

    #[test]
    fn lru_randomized_against_naive_model() {
        // Cross-check against a straightforward Vec-based LRU.
        let mut lru = Lru::new(8);
        let mut naive: Vec<(CacheKey, f64)> = Vec::new(); // MRU at end
        let mut rng = Pcg64::seed_from_u64(99);
        for step in 0..5000 {
            let key = k(rng.gen_range(24) as u64);
            if rng.next_f64() < 0.5 {
                let val = step as f64;
                lru.put(key, val);
                if let Some(pos) = naive.iter().position(|(kk, _)| *kk == key) {
                    naive.remove(pos);
                } else if naive.len() == 8 {
                    naive.remove(0);
                }
                naive.push((key, val));
            } else {
                let got = lru.get(key);
                let want = naive.iter().position(|(kk, _)| *kk == key).map(|pos| {
                    let (kk, vv) = naive.remove(pos);
                    naive.push((kk, vv));
                    vv
                });
                assert_eq!(got, want, "step {step}");
            }
            assert_eq!(lru.len(), naive.len());
        }
    }

    fn tiny_model(seed: u64) -> SldaModel {
        let mut rng = Pcg64::seed_from_u64(seed);
        let (t, w) = (4usize, 12usize);
        SldaModel {
            t,
            w,
            eta: (0..t).map(|_| rng.next_gaussian()).collect(),
            phi: (0..w * t).map(|_| 0.01 + rng.next_f32()).collect(),
            rho: 0.5,
            alpha: 0.4,
            train_mse: 0.2,
            train_acc: 0.8,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_registry_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn registry_open_swap_and_rollback() {
        let p1 = tmp("r1.bin");
        let p2 = tmp("r2.bin");
        save_model_with_vocab(&tiny_model(1), None, &p1).unwrap();
        save_model_with_vocab(&tiny_model(2), None, &p2).unwrap();

        let reg = Registry::open(&p1, 16, true).unwrap();
        let e1 = reg.current();
        assert_eq!(e1.version, 1);
        assert_eq!(e1.phi_cum.len(), e1.model.phi.len());
        // phi_cum rows end at alpha (phi rows sum to ~1 for real models;
        // here just check monotone non-decreasing per row)
        for w in 0..e1.model.w {
            let row = &e1.phi_cum[w * e1.model.t..(w + 1) * e1.model.t];
            assert!(row.windows(2).all(|ab| ab[0] <= ab[1]));
        }
        // frozen-phi alias tables are prebuilt and accounted for
        let tables = e1.phi_alias.as_ref().expect("open(build_alias=true) must build");
        assert_eq!(tables.topics(), e1.model.t);
        assert_eq!(tables.words(), e1.model.w);
        assert!(tables.resident_bytes() >= e1.model.phi.len() * 20);
        assert!(e1.alias_build_secs >= 0.0);
        let infos = reg.versions();
        assert_eq!(infos[0].version, 1);
        assert_eq!(infos[0].alias_resident_bytes, tables.resident_bytes());
        // a dense/sparse-pinned registry skips the build entirely
        let no_alias = Registry::open(&p1, 4, false).unwrap();
        assert!(no_alias.current().phi_alias.is_none());
        assert_eq!(no_alias.current().alias_build_secs, 0.0);
        assert_eq!(no_alias.versions()[0].alias_resident_bytes, 0);

        reg.cache_put(Registry::cache_key(&e1, 0, &[1, 2]), 0.5);
        assert_eq!(reg.cache_get(Registry::cache_key(&e1, 0, &[1, 2])), Some(0.5));

        // hot swap: version bumps, cache cleared, old Arc still usable
        let e2 = reg.reload(Some(&p2)).unwrap();
        assert_eq!(e2.version, 2);
        assert_eq!(reg.current().version, 2);
        assert_eq!(reg.cache_len(), 0);
        assert_eq!(e1.version, 1); // in-flight handle unaffected
        assert_ne!(e1.model.eta, e2.model.eta);

        // failed reload leaves the current model serving
        let missing = tmp("missing.bin");
        assert!(reg.reload(Some(&missing)).is_err());
        assert_eq!(reg.current().version, 2);

        // reload with None re-reads the current path as a new version
        let e3 = reg.reload(None).unwrap();
        assert_eq!(e3.version, 4); // version 3 was burned by the failed attempt
        assert_eq!(e3.path, p2);
        let versions = reg.versions();
        assert_eq!(versions.last().unwrap().version, 4);

        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }
}
