//! Long-lived prediction serving (`cfslda serve`, DESIGN.md §Serving).
//!
//! The batch `cfslda predict` command reloads the model and rebuilds its
//! sparse smoothing tables on every invocation; this subsystem keeps them
//! resident behind a tiny HTTP/1.1 server and turns prediction into a
//! steady-state service:
//!
//! * [`http`] — request/response framing over `std::net` (no async
//!   runtime in the vendored-offline build) plus the keep-alive client
//!   used by the bench harness and tests.
//! * [`protocol`] — JSON wire types for the JSON endpoints (`/predict`,
//!   `/predict/text`, `/reload`, `/healthz`, `/stats`); `GET /metrics`
//!   serves Prometheus text format straight from the preregistered
//!   [`crate::obs`] cells (DESIGN.md §Observability).
//! * [`registry`] — versioned model slots, atomic hot-swap on `/reload`
//!   (in-flight requests drain on the old `Arc`), and the doc-level LRU
//!   prediction cache.
//! * [`batcher`] — the micro-batching queue: concurrent requests coalesce
//!   into prediction batches (`max_batch` / `max_wait_us`) executed by a
//!   worker pool with per-document seeded RNG streams, so responses are
//!   deterministic for a given (model, seed, doc).
//! * [`server`] — routing, endpoint handlers, admission control, and the
//!   `threads` backend (one handler thread per connection — the portable
//!   fallback and behavioral reference).
//! * [`conn`] — per-connection non-blocking state machine (ReadHead →
//!   ReadBody → Dispatched → WriteResponse → KeepAlive) used by the epoll
//!   backend; keep-alive pipelining, buffer reuse.
//! * [`reactor`] — the `epoll` backend: a single readiness event loop
//!   driving [`conn`] state machines for 10k+ concurrent connections,
//!   with batcher completions delivered via `eventfd`.
//! * [`bench`] — the `serve-bench` loopback load harness
//!   (`BENCH_serve.json`).

pub mod batcher;
pub mod bench;
pub mod conn;
pub mod http;
pub mod protocol;
pub mod reactor;
pub mod registry;
pub mod server;
