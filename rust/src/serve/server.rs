//! The `cfslda serve` HTTP server: accept loop, routing, endpoints.
//!
//! Endpoints (DESIGN.md §Serving):
//!
//! * `GET  /healthz`      — liveness + current model version.
//! * `GET  /stats`        — serving counters, cache + batcher state.
//! * `GET  /metrics`      — Prometheus text exposition (DESIGN.md
//!   §Observability): per-endpoint latency histograms, batcher/cache
//!   counters, training telemetry.
//! * `POST /predict`      — BoW batches through the micro-batcher.
//! * `POST /predict/text` — raw text, tokenized against the persisted
//!   vocabulary (400 when the model was saved without one).
//! * `POST /reload`       — atomic hot-swap to a new (or re-read) model
//!   file; in-flight requests finish on the old version.
//!
//! Connection handling is backend-selectable (`[serve] backend`,
//! DESIGN.md §Serving "Event-loop architecture"):
//!
//! * `threads` — one detached handler thread per connection (keep-alive),
//!   the portable fallback and the behavioral reference.
//! * `epoll` — a single non-blocking readiness loop
//!   ([`crate::serve::reactor`]) driving per-connection state machines
//!   ([`crate::serve::conn`]) for 10k+ concurrent connections.
//!
//! Both funnel prediction work through the shared [`Batcher`] pool (so
//! connection count does not multiply sampler threads), share every
//! endpoint handler below, and return byte-identical responses for the
//! same (model, seed, doc) request stream. Admission control is shared
//! too: beyond `max_conns` open connections or `queue_depth_max` queued
//! documents, requests are shed with `503 Retry-After`.
//!
//! Allocation discipline (DESIGN.md §Serving, "Streaming codec"): each
//! connection owns a [`ConnScratch`] — request-head/body buffers, a
//! [`JsonWriter`], an [`ArenaBuilder`], a pooled batcher [`Completion`]
//! and the results/yhat staging vectors — so a warmed keep-alive
//! connection parses `/predict` bodies straight into the arena, rides the
//! batcher, and serializes responses without touching the heap. Metric
//! recording is relaxed atomics on preregistered cells and keeps that
//! property.

use crate::config::json::JsonWriter;
use crate::config::schema::{ExperimentConfig, ServeBackend};
use crate::data::corpus::TokenArena;
use crate::data::tokenizer::{tokenize, TokenizerConfig};
use crate::obs::{Endpoint, ServeMetrics};
use crate::serve::batcher::{ArenaBuilder, Batcher, BatcherConfig, Completion, DocOut};
use crate::serve::http::{self, RequestScratch};
use crate::serve::protocol;
use crate::serve::registry::Registry;
use crate::util::pool::num_cpus;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shared per-server state, one `Arc` per connection thread (threads
/// backend) or one for the whole reactor (epoll backend).
pub(crate) struct State {
    pub(crate) registry: Arc<Registry>,
    pub(crate) batcher: Batcher,
    pub(crate) stats: Arc<ServeMetrics>,
    pub(crate) started: Instant,
    pub(crate) default_seed: u64,
    pub(crate) workers: usize,
    pub(crate) tok_cfg: TokenizerConfig,
    /// `[obs] latency_histograms` — record per-endpoint latency when set.
    pub(crate) latency_hist: bool,
    /// Admission limit on concurrently open connections (0 = unlimited).
    pub(crate) max_conns: usize,
    /// Idle keep-alive reap timeout (`None` = never).
    pub(crate) idle_timeout: Option<Duration>,
    /// Mid-request stall timeout (`None` = never).
    pub(crate) read_timeout: Option<Duration>,
    /// Graceful-shutdown flag: `/healthz` reports `draining` while set.
    pub(crate) draining: AtomicBool,
}

/// `Retry-After` seconds carried on every admission-control shed.
pub(crate) const RETRY_AFTER_SECS: u64 = 1;

/// Which scratch buffer holds the response body for the current request.
pub(crate) enum BodyKind {
    /// `out.writer` (JSON, the default).
    Json,
    /// `out.metrics_buf` (Prometheus text exposition).
    Metrics,
}

/// Per-connection reusable buffers. Everything the hot path writes into
/// lives here and is recycled across keep-alive requests; only the cold
/// paths (errors, `/stats`, `/predict/text` tokenization) allocate per
/// request.
pub(crate) struct ConnScratch {
    /// Response body under construction (also reused for error bodies).
    pub(crate) writer: JsonWriter,
    /// Response head bytes (status line + headers).
    pub(crate) head: Vec<u8>,
    /// CSR staging area for `/predict` docs; recycled via
    /// [`ArenaBuilder::reclaim`] when the batcher drops its handle in time.
    pub(crate) builder: ArenaBuilder,
    /// `/predict/text` rows.
    pub(crate) texts: Vec<String>,
    /// Pooled batcher rendezvous, re-armed per request.
    pub(crate) comp: Arc<Completion>,
    /// Per-document batcher results, drained into `yhat` per request.
    pub(crate) results: Vec<anyhow::Result<DocOut>>,
    /// Per-request responses collected from the batcher before rendering.
    pub(crate) yhat: Vec<f64>,
    /// `GET /metrics` exposition body (reused across scrapes).
    pub(crate) metrics_buf: String,
    /// Selects the body buffer when writing the response.
    pub(crate) body_kind: BodyKind,
    /// `Some(secs)` when the last routed request was shed by admission
    /// control; selects the `Retry-After` response framing.
    pub(crate) retry_after: Option<u64>,
}

impl ConnScratch {
    pub(crate) fn new() -> ConnScratch {
        ConnScratch {
            writer: JsonWriter::with_capacity(256),
            head: Vec::with_capacity(128),
            builder: ArenaBuilder::new(),
            texts: Vec::new(),
            comp: Arc::new(Completion::new()),
            results: Vec::new(),
            yhat: Vec::new(),
            metrics_buf: String::new(),
            body_kind: BodyKind::Json,
            retry_after: None,
        }
    }
}

/// A running server; dropping (or [`Server::stop`]) shuts the accept loop
/// down and joins the batcher workers.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    state: Arc<State>,
}

impl Server {
    /// Bind `cfg.serve.addr`, load the model, spin up the worker pool and
    /// the accept loop. Returns once the socket is listening.
    pub fn start(model_path: &Path, cfg: &ExperimentConfig) -> anyhow::Result<Server> {
        crate::config::validate::validate(cfg)?;
        // Prebuild the frozen-phi alias tables unless the kernel is pinned
        // to dense/sparse and can never resolve to alias (DESIGN.md
        // §Serving): dense/sparse deployments skip the O(W·T) build and
        // its residency entirely.
        let build_alias = !matches!(
            cfg.sampler.kernel,
            crate::config::schema::KernelKind::Dense
                | crate::config::schema::KernelKind::Sparse
        );
        let registry =
            Arc::new(Registry::open(model_path, cfg.serve.cache_capacity, build_alias)?);
        let stats = Arc::new(ServeMetrics::new());
        let workers = if cfg.serve.workers == 0 { num_cpus() } else { cfg.serve.workers };
        let batcher = Batcher::start(
            BatcherConfig {
                workers,
                max_batch: cfg.serve.max_batch,
                max_wait_us: cfg.serve.max_wait_us,
                queue_depth_max: cfg.serve.queue_depth_max,
                kernel: cfg.sampler.kernel,
                train: cfg.train.clone(),
                panic_token: None,
            },
            Arc::clone(&registry),
            Arc::clone(&stats),
        );
        let ms = |v: u64| (v > 0).then(|| Duration::from_millis(v));
        let state = Arc::new(State {
            registry,
            batcher,
            stats,
            started: Instant::now(),
            default_seed: cfg.seed,
            workers,
            tok_cfg: TokenizerConfig::default(),
            latency_hist: cfg.obs.latency_histograms,
            max_conns: cfg.serve.max_conns,
            idle_timeout: ms(cfg.serve.idle_timeout_ms),
            read_timeout: ms(cfg.serve.read_timeout_ms),
            draining: AtomicBool::new(false),
        });

        let listener = TcpListener::bind(&cfg.serve.addr)
            .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.serve.addr))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept = {
            let state = Arc::clone(&state);
            let shutdown = Arc::clone(&shutdown);
            match cfg.serve.backend {
                ServeBackend::Threads => {
                    std::thread::spawn(move || accept_loop(listener, state, shutdown))
                }
                ServeBackend::Epoll => std::thread::spawn(move || {
                    if let Err(e) = crate::serve::reactor::run(listener, state, shutdown) {
                        log::error!("epoll reactor exited: {e:#}");
                    }
                }),
            }
        };
        Ok(Server { addr, shutdown, accept: Some(accept), state })
    }

    /// The actually-bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current model version (diagnostics).
    pub fn model_version(&self) -> u64 {
        self.state.registry.current().version
    }

    /// This server's metric cells (benches read histograms from here).
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.state.stats)
    }

    /// Graceful-shutdown step 1: flip `/healthz` to `"draining"` so load
    /// balancers stop routing here while existing connections keep being
    /// served. [`Server::stop`] calls this first.
    pub fn begin_drain(&self) {
        self.state.draining.store(true, Ordering::SeqCst);
    }

    /// Stop accepting and join the accept loop. Existing keep-alive
    /// connections drop at their next poll tick.
    pub fn stop(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.begin_drain();
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// RAII decrement for `cfslda_open_connections`; one per live connection
/// in either backend.
pub(crate) struct OpenConnGuard(Arc<ServeMetrics>);

impl OpenConnGuard {
    pub(crate) fn new(stats: &Arc<ServeMetrics>) -> OpenConnGuard {
        stats.open_connections.add(1);
        OpenConnGuard(Arc::clone(stats))
    }
}

impl Drop for OpenConnGuard {
    fn drop(&mut self) {
        self.0.open_connections.sub(1);
    }
}

/// Shed a connection at the accept gate: best-effort `503 Retry-After`
/// so the client learns to back off, then close. Shared by both backends.
pub(crate) fn write_shed_response<W: Write>(w: &mut W, scratch: &mut ConnScratch) {
    let e = overloaded();
    protocol::error_response_into(&mut scratch.writer, &e.msg);
    let _ = http::write_response_retry_after(
        w,
        &mut scratch.head,
        e.status,
        scratch.writer.as_str().as_bytes(),
        false,
        RETRY_AFTER_SECS,
    );
}

fn accept_loop(listener: TcpListener, state: Arc<State>, shutdown: Arc<AtomicBool>) {
    // Scratch for shed responses written inline on the accept thread.
    let mut shed_out = ConnScratch::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                state.stats.accepted.inc();
                // Admission gate: past `max_conns` open connections, shed
                // instead of spawning an unbounded number of handler
                // threads (the whole point of the limit).
                if state.max_conns > 0
                    && state.stats.open_connections.get() >= state.max_conns as u64
                {
                    state.stats.shed.inc();
                    write_shed_response(&mut stream, &mut shed_out);
                    continue;
                }
                let state = Arc::clone(&state);
                let shutdown = Arc::clone(&shutdown);
                std::thread::spawn(move || handle_conn(stream, state, shutdown));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                log::warn!("accept error: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// `BufRead` adapter enforcing a *total* per-request deadline on top of
/// the socket's short poll timeout. The socket timeout alone cannot stop
/// a slow-loris client that trickles one byte per 200ms — every syscall
/// succeeds in time while the request never completes. Here each
/// `fill_buf` retries through poll timeouts until the deadline, then
/// surfaces `TimedOut` (which the caller turns into 400 + close).
struct TimedReader<'a> {
    inner: &'a mut BufReader<TcpStream>,
    deadline: Option<Instant>,
}

impl Read for TimedReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let available = self.fill_buf()?;
        let n = available.len().min(buf.len());
        buf[..n].copy_from_slice(&available[..n]);
        self.consume(n);
        Ok(n)
    }
}

impl BufRead for TimedReader<'_> {
    fn fill_buf(&mut self) -> std::io::Result<&[u8]> {
        loop {
            match self.inner.fill_buf() {
                Ok(_) => break,
                Err(e) if http::is_timeout_io(&e) => {
                    if let Some(d) = self.deadline {
                        if Instant::now() >= d {
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::TimedOut,
                                "request read deadline exceeded",
                            ));
                        }
                    }
                }
                Err(e) => return Err(e),
            }
        }
        // The data (or hard error) is now buffered; re-borrow to return it.
        self.inner.fill_buf()
    }

    fn consume(&mut self, amt: usize) {
        self.inner.consume(amt);
    }
}

fn handle_conn(stream: TcpStream, state: Arc<State>, shutdown: Arc<AtomicBool>) {
    let _open = OpenConnGuard::new(&state.stats);
    // Short read timeout => idle keep-alive connections poll the shutdown
    // flag a few times per second instead of pinning a thread forever.
    stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
    stream.set_nodelay(true).ok();
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = write_half;
    let mut reader = BufReader::new(stream);
    let mut req = RequestScratch::new();
    let mut out = ConnScratch::new();
    let mut idle_since = Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Idle wait happens *here*, on the buffered peek: a read timeout
        // between requests just re-polls the shutdown flag (and the idle
        // reap deadline). Once the first byte of a request has arrived,
        // the per-request read deadline below takes over — we never
        // resync a half-read stream.
        {
            match reader.fill_buf() {
                Ok(buf) if buf.is_empty() => return, // peer closed
                Ok(_) => {}
                Err(e) if http::is_timeout_io(&e) => {
                    if let Some(limit) = state.idle_timeout {
                        if idle_since.elapsed() >= limit {
                            return; // idle keep-alive reaped
                        }
                    }
                    continue;
                }
                Err(_) => return,
            }
        }
        let mut timed = TimedReader {
            inner: &mut reader,
            deadline: state.read_timeout.map(|t| Instant::now() + t),
        };
        match http::read_request_into(&mut timed, &mut req) {
            Ok(false) => return, // peer closed
            Ok(true) => {
                state.stats.requests.inc();
                let keep_alive = !req.wants_close();
                // Latency covers handler + response write: the span a
                // client actually waits once its request is parsed.
                let t0 = Instant::now();
                let ep = Endpoint::classify(req.method(), req.path());
                let status = route(&state, &req, &mut out);
                if status >= 400 {
                    state.stats.errors.inc();
                }
                let (body, ctype): (&[u8], &str) = match out.body_kind {
                    BodyKind::Json => (out.writer.as_str().as_bytes(), http::CT_JSON),
                    BodyKind::Metrics => (out.metrics_buf.as_bytes(), http::CT_PROMETHEUS),
                };
                let write_ok = match out.retry_after {
                    Some(secs) => http::write_response_retry_after(
                        &mut writer,
                        &mut out.head,
                        status,
                        body,
                        keep_alive,
                        secs,
                    ),
                    None => http::write_response_typed(
                        &mut writer,
                        &mut out.head,
                        status,
                        ctype,
                        body,
                        keep_alive,
                    ),
                };
                if state.latency_hist {
                    state.stats.latency_for(ep).observe(t0.elapsed().as_micros() as u64);
                }
                if write_ok.is_err() || !keep_alive {
                    return;
                }
                idle_since = Instant::now();
            }
            Err(e) => {
                state.stats.errors.inc();
                protocol::error_response_into(&mut out.writer, &format!("{e:#}"));
                let _ = http::write_response_buffered(
                    &mut writer,
                    &mut out.head,
                    400,
                    out.writer.as_str().as_bytes(),
                    false,
                );
                return;
            }
        }
    }
}

/// Dispatch one parsed request. The response body is left in the scratch
/// buffer selected by `out.body_kind`; the returned status selects the
/// head line. `out.retry_after` is set iff admission control shed the
/// request.
pub(crate) fn route(state: &State, req: &RequestScratch, out: &mut ConnScratch) -> u16 {
    out.body_kind = BodyKind::Json;
    out.retry_after = None;
    let res = match (req.method(), req.path()) {
        ("GET", "/healthz") => handle_healthz(state, &mut out.writer),
        ("GET", "/stats") => handle_stats(state, &mut out.writer),
        ("GET", "/metrics") => handle_metrics(state, out),
        ("POST", "/predict") => handle_predict(state, req, out),
        ("POST", "/predict/text") => handle_predict_text(state, req, out),
        ("POST", "/reload") => handle_reload(state, req, &mut out.writer),
        ("GET", _) | ("POST", _) => Err(HttpError {
            status: 404,
            msg: "no such endpoint".into(),
            retry_after: None,
        }),
        _ => Err(HttpError { status: 405, msg: "method not allowed".into(), retry_after: None }),
    };
    match res {
        Ok(()) => 200,
        Err(e) => {
            out.body_kind = BodyKind::Json;
            out.retry_after = e.retry_after;
            protocol::error_response_into(&mut out.writer, &e.msg);
            e.status
        }
    }
}

/// Whether a request rides the micro-batcher (and must therefore never be
/// handled inline on the epoll reactor thread).
pub(crate) fn is_batched(method: &str, path: &str) -> bool {
    matches!((method, path), ("POST", "/predict") | ("POST", "/predict/text"))
}

/// Handler error carrying the HTTP status to respond with.
pub(crate) struct HttpError {
    pub(crate) status: u16,
    pub(crate) msg: String,
    /// `Some(secs)` on admission-control sheds (adds `Retry-After`).
    pub(crate) retry_after: Option<u64>,
}

pub(crate) fn bad_request(e: impl std::fmt::Display) -> HttpError {
    HttpError { status: 400, msg: format!("{e}"), retry_after: None }
}

fn server_error(e: impl std::fmt::Display) -> HttpError {
    HttpError { status: 500, msg: format!("{e}"), retry_after: None }
}

pub(crate) fn raced() -> HttpError {
    HttpError {
        status: 503,
        msg: "model reloads raced this request; retry".into(),
        retry_after: None,
    }
}

pub(crate) fn overloaded() -> HttpError {
    HttpError {
        status: 503,
        msg: "server overloaded; prediction queue is full".into(),
        retry_after: Some(RETRY_AFTER_SECS),
    }
}

// Response keys are emitted in sorted order on purpose: the tree codec
// serialized `BTreeMap` objects, and the streamed writers must stay
// byte-identical to it (pinned by protocol + integration tests).

fn handle_healthz(state: &State, w: &mut JsonWriter) -> Result<(), HttpError> {
    let entry = state.registry.current();
    w.clear();
    w.begin_object();
    w.key("has_vocab_terms");
    w.boolean(entry.vocab.is_some());
    w.key("model_version");
    w.number_f64(entry.version as f64);
    w.key("status");
    w.string(if state.draining.load(Ordering::SeqCst) { "draining" } else { "ok" });
    w.key("topics");
    w.number_f64(entry.model.t as f64);
    w.key("vocab");
    w.number_f64(entry.model.w as f64);
    w.end_object();
    Ok(())
}

fn handle_stats(state: &State, w: &mut JsonWriter) -> Result<(), HttpError> {
    let s = &state.stats;
    let entry = state.registry.current();
    let batches = s.batches.get();
    let docs = s.predict_docs.get();
    let mean_batch =
        if batches > 0 { docs as f64 / batches as f64 } else { 0.0 };
    w.clear();
    w.begin_object();
    w.key("alias_build_secs");
    w.number_f64(entry.alias_build_secs);
    w.key("alias_resident_bytes");
    w.number_f64(entry.phi_alias.as_ref().map_or(0, |t| t.resident_bytes()) as f64);
    w.key("backlog");
    w.number_f64(state.batcher.backlog() as f64);
    w.key("batches");
    w.number_f64(batches as f64);
    w.key("cache_entries");
    w.number_f64(state.registry.cache_len() as f64);
    w.key("cache_hits");
    w.number_f64(s.cache_hits.get() as f64);
    w.key("cache_misses");
    w.number_f64(s.cache_misses.get() as f64);
    w.key("errors");
    w.number_f64(s.errors.get() as f64);
    w.key("mean_batch");
    w.number_f64(mean_batch);
    w.key("model_version");
    w.number_f64(entry.version as f64);
    w.key("predict_docs");
    w.number_f64(docs as f64);
    w.key("reloads");
    w.number_f64(s.reloads.get() as f64);
    w.key("requests");
    w.number_f64(s.requests.get() as f64);
    w.key("uptime_secs");
    w.number_f64(state.started.elapsed().as_secs_f64());
    w.key("versions");
    w.begin_array();
    for v in state.registry.versions() {
        w.begin_object();
        w.key("alias_build_secs");
        w.number_f64(v.alias_build_secs);
        w.key("alias_resident_bytes");
        w.number_f64(v.alias_resident_bytes as f64);
        w.key("path");
        w.string(&v.path.display().to_string());
        w.key("version");
        w.number_f64(v.version as f64);
        w.end_object();
    }
    w.end_array();
    w.key("workers");
    w.number_f64(state.workers as f64);
    w.end_object();
    Ok(())
}

fn handle_metrics(state: &State, out: &mut ConnScratch) -> Result<(), HttpError> {
    crate::obs::render_prometheus(&state.stats, &mut out.metrics_buf);
    out.body_kind = BodyKind::Metrics;
    Ok(())
}

/// Attempts per request when a hot-swap races the batcher: predictions
/// are deterministic and cached, so a retry is cheap and converges as
/// soon as one full pass runs against a single model version.
pub(crate) const SWAP_RACE_RETRIES: usize = 3;

/// Submit an arena through the connection's pooled completion (shedding
/// with 503 `Retry-After` when the batcher queue is at its bound) and
/// render the response via [`render_uniform`].
fn submit_uniform(
    state: &State,
    arena: &Arc<TokenArena>,
    seed: u64,
    want: Option<u64>,
    out: &mut ConnScratch,
) -> Result<bool, HttpError> {
    if !state.batcher.try_submit_streamed_into(
        Arc::clone(arena),
        seed,
        &out.comp,
        &mut out.results,
    ) {
        state.stats.shed.inc();
        return Err(overloaded());
    }
    render_uniform(want, out)
}

/// Render a predict response from `out.results` (drained) **if** every
/// document resolved under the same model version; `want` additionally
/// pins which one (the text path's token ids are only meaningful under
/// the vocabulary they were encoded with). `Ok(false)` = a hot swap
/// landed mid-request; the caller re-submits. Shared with the epoll
/// backend, which fills `out.results` via `Completion::try_take_into`.
pub(crate) fn render_uniform(
    want: Option<u64>,
    out: &mut ConnScratch,
) -> Result<bool, HttpError> {
    out.yhat.clear();
    let mut version: Option<u64> = None;
    let mut cached = 0usize;
    for (i, r) in out.results.drain(..).enumerate() {
        match r {
            Ok(d) => {
                match version {
                    None => version = Some(d.model_version),
                    Some(v) if v != d.model_version => return Ok(false),
                    Some(_) => {}
                }
                out.yhat.push(d.yhat);
                cached += d.cached as usize;
            }
            Err(e) => return Err(bad_request(format!("doc {i}: {e:#}"))),
        }
    }
    let version = version.unwrap_or(0);
    if let Some(wv) = want {
        if wv != version {
            return Ok(false);
        }
    }
    protocol::predict_response_into(&mut out.writer, &out.yhat, version, cached);
    Ok(true)
}

fn handle_predict(
    state: &State,
    req: &RequestScratch,
    out: &mut ConnScratch,
) -> Result<(), HttpError> {
    let seed = protocol::parse_predict_streamed(req.body(), &mut out.builder)
        .map_err(|e| bad_request(format!("{e:#}")))?
        .unwrap_or(state.default_seed);
    let arena = Arc::new(out.builder.finish());
    let mut outcome: Result<bool, HttpError> = Ok(false);
    for _ in 0..SWAP_RACE_RETRIES {
        outcome = submit_uniform(state, &arena, seed, None, out);
        if !matches!(outcome, Ok(false)) {
            break;
        }
    }
    // Best-effort buffer recycling: the batcher's clones are normally gone
    // by the time all results are in; if a worker still holds one, the
    // builder simply reallocates on the next request.
    if let Ok(arena) = Arc::try_unwrap(arena) {
        out.builder.reclaim(arena);
    }
    match outcome {
        Ok(true) => Ok(()),
        Ok(false) => Err(raced()),
        Err(e) => Err(e),
    }
}

/// Tokenize `out.texts` into the connection's arena builder against the
/// *current* registry entry; returns the model version the ids were
/// encoded under (each `/predict/text` attempt must run under exactly
/// that version). Shared with the epoll backend.
pub(crate) fn encode_texts_against_current(
    state: &State,
    out: &mut ConnScratch,
) -> Result<u64, HttpError> {
    let entry = state.registry.current();
    let vocab = entry.vocab.as_ref().ok_or_else(|| {
        bad_request(
            "model was saved without a vocabulary; re-train with `cfslda train` \
             on a raw-text corpus (or pass --vocab) to enable /predict/text",
        )
    })?;
    // Encode straight into the connection's arena builder — no
    // per-document `Vec<Vec<u32>>` staging; out-of-vocabulary tokens
    // drop exactly as `Vocab::encode` drops them.
    out.builder.clear();
    for (i, text) in out.texts.iter().enumerate() {
        for tok in tokenize(text, &state.tok_cfg) {
            if let Some(id) = vocab.id(&tok) {
                out.builder.push_token(id);
            }
        }
        if out.builder.cur_doc_len() == 0 {
            out.builder.clear();
            return Err(bad_request(format!(
                "text {i} has no in-vocabulary tokens after tokenization"
            )));
        }
        out.builder.end_doc().map_err(|e| bad_request(format!("{e:#}")))?;
    }
    Ok(entry.version)
}

fn handle_predict_text(
    state: &State,
    req: &RequestScratch,
    out: &mut ConnScratch,
) -> Result<(), HttpError> {
    let seed = protocol::parse_text_streamed(req.body(), &mut out.texts)
        .map_err(|e| bad_request(format!("{e:#}")))?
        .unwrap_or(state.default_seed);
    // Token ids are only meaningful under the vocabulary that produced
    // them, so each attempt re-encodes against the *current* entry and
    // requires the batch to run under exactly that version.
    for _ in 0..SWAP_RACE_RETRIES {
        let version = encode_texts_against_current(state, out)?;
        let arena = Arc::new(out.builder.finish());
        let done = submit_uniform(state, &arena, seed, Some(version), out)?;
        if let Ok(a) = Arc::try_unwrap(arena) {
            out.builder.reclaim(a);
        }
        if done {
            return Ok(());
        }
    }
    Err(raced())
}

fn handle_reload(
    state: &State,
    req: &RequestScratch,
    w: &mut JsonWriter,
) -> Result<(), HttpError> {
    let path = protocol::parse_reload_streamed(req.body())
        .map_err(|e| bad_request(format!("{e:#}")))?;
    let entry = state
        .registry
        .reload(path.as_deref().map(Path::new))
        .map_err(|e| server_error(format!("{e:#}")))?;
    state.stats.reloads.inc();
    w.clear();
    w.begin_object();
    w.key("model_version");
    w.number_f64(entry.version as f64);
    w.key("path");
    w.string(&entry.path.display().to_string());
    w.key("status");
    w.string("reloaded");
    w.key("topics");
    w.number_f64(entry.model.t as f64);
    w.key("vocab");
    w.number_f64(entry.model.w as f64);
    w.end_object();
    Ok(())
}

/// Resolved options for [`run_blocking`] (the CLI entry point).
pub struct RunOptions {
    pub model_path: PathBuf,
    pub cfg: ExperimentConfig,
    /// Optional file to write the bound address into (CI / scripts
    /// discovering an ephemeral port).
    pub port_file: Option<PathBuf>,
}

/// Start the server and block forever (the `cfslda serve` command).
pub fn run_blocking(opts: RunOptions) -> anyhow::Result<()> {
    let server = Server::start(&opts.model_path, &opts.cfg)?;
    let entry = server.state.registry.current();
    println!(
        "serving on http://{} (model v{} T={} W={} vocab_terms={} backend={} workers={} max_batch={} max_wait_us={})",
        server.local_addr(),
        entry.version,
        entry.model.t,
        entry.model.w,
        entry.vocab.is_some(),
        opts.cfg.serve.backend.name(),
        server.state.workers,
        opts.cfg.serve.max_batch,
        opts.cfg.serve.max_wait_us,
    );
    if let Some(pf) = &opts.port_file {
        let mut f = std::fs::File::create(pf)?;
        writeln!(f, "{}", server.local_addr())?;
    }
    log::info!("endpoints: POST /predict /predict/text /reload; GET /healthz /stats /metrics");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}
