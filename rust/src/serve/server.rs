//! The `cfslda serve` HTTP server: accept loop, routing, endpoints.
//!
//! Endpoints (DESIGN.md §Serving):
//!
//! * `GET  /healthz`      — liveness + current model version.
//! * `GET  /stats`        — serving counters, cache + batcher state.
//! * `POST /predict`      — BoW batches through the micro-batcher.
//! * `POST /predict/text` — raw text, tokenized against the persisted
//!   vocabulary (400 when the model was saved without one).
//! * `POST /reload`       — atomic hot-swap to a new (or re-read) model
//!   file; in-flight requests finish on the old version.
//!
//! Threading: one detached handler thread per connection (keep-alive), all
//! prediction work funneled through the shared [`Batcher`] pool, so
//! connection count does not multiply sampler threads.

use crate::config::schema::ExperimentConfig;
use crate::config::json::{self, Value};
use crate::data::tokenizer::{tokenize, TokenizerConfig};
use crate::serve::batcher::{Batcher, BatcherConfig, DocOut, ServeStats};
use crate::serve::http::{self, Request};
use crate::serve::protocol;
use crate::serve::registry::Registry;
use crate::util::pool::num_cpus;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shared per-server state, one `Arc` per connection thread.
struct State {
    registry: Arc<Registry>,
    batcher: Batcher,
    stats: Arc<ServeStats>,
    started: Instant,
    default_seed: u64,
    workers: usize,
    tok_cfg: TokenizerConfig,
}

/// A running server; dropping (or [`Server::stop`]) shuts the accept loop
/// down and joins the batcher workers.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    state: Arc<State>,
}

impl Server {
    /// Bind `cfg.serve.addr`, load the model, spin up the worker pool and
    /// the accept loop. Returns once the socket is listening.
    pub fn start(model_path: &Path, cfg: &ExperimentConfig) -> anyhow::Result<Server> {
        crate::config::validate::validate(cfg)?;
        // Prebuild the frozen-phi alias tables unless the kernel is pinned
        // to dense/sparse and can never resolve to alias (DESIGN.md
        // §Serving): dense/sparse deployments skip the O(W·T) build and
        // its residency entirely.
        let build_alias = !matches!(
            cfg.sampler.kernel,
            crate::config::schema::KernelKind::Dense
                | crate::config::schema::KernelKind::Sparse
        );
        let registry =
            Arc::new(Registry::open(model_path, cfg.serve.cache_capacity, build_alias)?);
        let stats = Arc::new(ServeStats::new());
        let workers = if cfg.serve.workers == 0 { num_cpus() } else { cfg.serve.workers };
        let batcher = Batcher::start(
            BatcherConfig {
                workers,
                max_batch: cfg.serve.max_batch,
                max_wait_us: cfg.serve.max_wait_us,
                kernel: cfg.sampler.kernel,
                train: cfg.train.clone(),
            },
            Arc::clone(&registry),
            Arc::clone(&stats),
        );
        let state = Arc::new(State {
            registry,
            batcher,
            stats,
            started: Instant::now(),
            default_seed: cfg.seed,
            workers,
            tok_cfg: TokenizerConfig::default(),
        });

        let listener = TcpListener::bind(&cfg.serve.addr)
            .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.serve.addr))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept = {
            let state = Arc::clone(&state);
            let shutdown = Arc::clone(&shutdown);
            std::thread::spawn(move || accept_loop(listener, state, shutdown))
        };
        Ok(Server { addr, shutdown, accept: Some(accept), state })
    }

    /// The actually-bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current model version (diagnostics).
    pub fn model_version(&self) -> u64 {
        self.state.registry.current().version
    }

    /// Stop accepting and join the accept loop. Existing keep-alive
    /// connections drop at their next poll tick.
    pub fn stop(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<State>, shutdown: Arc<AtomicBool>) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let state = Arc::clone(&state);
                let shutdown = Arc::clone(&shutdown);
                std::thread::spawn(move || handle_conn(stream, state, shutdown));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                log::warn!("accept error: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

fn handle_conn(stream: TcpStream, state: Arc<State>, shutdown: Arc<AtomicBool>) {
    // Short read timeout => idle keep-alive connections poll the shutdown
    // flag a few times per second instead of pinning a thread forever.
    stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
    stream.set_nodelay(true).ok();
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = write_half;
    let mut reader = BufReader::new(stream);
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Idle wait happens *here*, on the buffered peek: a read timeout
        // between requests just re-polls the shutdown flag. Once the first
        // byte of a request has arrived, a timeout inside read_request is
        // a protocol error (we never resync a half-read stream).
        {
            use std::io::BufRead;
            match reader.fill_buf() {
                Ok(buf) if buf.is_empty() => return, // peer closed
                Ok(_) => {}
                Err(e) if http::is_timeout_io(&e) => continue,
                Err(_) => return,
            }
        }
        match http::read_request(&mut reader) {
            Ok(None) => return, // peer closed
            Ok(Some(req)) => {
                state.stats.requests.fetch_add(1, Ordering::Relaxed);
                let keep_alive = !req.wants_close();
                let (status, body) = route(&state, &req);
                if status >= 400 {
                    state.stats.errors.fetch_add(1, Ordering::Relaxed);
                }
                if http::write_response(&mut writer, status, &body, keep_alive).is_err() {
                    return;
                }
                if !keep_alive {
                    return;
                }
            }
            Err(e) => {
                state.stats.errors.fetch_add(1, Ordering::Relaxed);
                let _ = http::write_response(
                    &mut writer,
                    400,
                    &protocol::error_response(&format!("{e:#}")),
                    false,
                );
                return;
            }
        }
    }
}

fn route(state: &State, req: &Request) -> (u16, String) {
    let res = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => handle_healthz(state),
        ("GET", "/stats") => handle_stats(state),
        ("POST", "/predict") => handle_predict(state, req),
        ("POST", "/predict/text") => handle_predict_text(state, req),
        ("POST", "/reload") => handle_reload(state, req),
        ("GET", _) | ("POST", _) => {
            return (404, protocol::error_response("no such endpoint"))
        }
        _ => return (405, protocol::error_response("method not allowed")),
    };
    match res {
        Ok(body) => (200, body),
        Err(e) => (e.status, protocol::error_response(&e.msg)),
    }
}

/// Handler error carrying the HTTP status to respond with.
struct HttpError {
    status: u16,
    msg: String,
}

fn bad_request(e: impl std::fmt::Display) -> HttpError {
    HttpError { status: 400, msg: format!("{e}") }
}

fn server_error(e: impl std::fmt::Display) -> HttpError {
    HttpError { status: 500, msg: format!("{e}") }
}

fn handle_healthz(state: &State) -> Result<String, HttpError> {
    let entry = state.registry.current();
    let v = Value::object(vec![
        ("status", Value::String("ok".into())),
        ("model_version", Value::Number(entry.version as f64)),
        ("topics", Value::Number(entry.model.t as f64)),
        ("vocab", Value::Number(entry.model.w as f64)),
        ("has_vocab_terms", Value::Bool(entry.vocab.is_some())),
    ]);
    Ok(json::to_string(&v))
}

fn handle_stats(state: &State) -> Result<String, HttpError> {
    let s = &state.stats;
    let entry = state.registry.current();
    let batches = s.batches.load(Ordering::Relaxed);
    let docs = s.predict_docs.load(Ordering::Relaxed);
    let mean_batch =
        if batches > 0 { docs as f64 / batches as f64 } else { 0.0 };
    let versions: Vec<Value> = state
        .registry
        .versions()
        .into_iter()
        .map(|v| {
            Value::object(vec![
                ("version", Value::Number(v.version as f64)),
                ("path", Value::String(v.path.display().to_string())),
                ("alias_build_secs", Value::Number(v.alias_build_secs)),
                ("alias_resident_bytes", Value::Number(v.alias_resident_bytes as f64)),
            ])
        })
        .collect();
    let v = Value::object(vec![
        ("uptime_secs", Value::Number(state.started.elapsed().as_secs_f64())),
        ("model_version", Value::Number(entry.version as f64)),
        ("workers", Value::Number(state.workers as f64)),
        ("requests", Value::Number(s.requests.load(Ordering::Relaxed) as f64)),
        ("predict_docs", Value::Number(docs as f64)),
        ("batches", Value::Number(batches as f64)),
        ("mean_batch", Value::Number(mean_batch)),
        ("cache_hits", Value::Number(s.cache_hits.load(Ordering::Relaxed) as f64)),
        ("cache_misses", Value::Number(s.cache_misses.load(Ordering::Relaxed) as f64)),
        ("cache_entries", Value::Number(state.registry.cache_len() as f64)),
        ("alias_build_secs", Value::Number(entry.alias_build_secs)),
        (
            "alias_resident_bytes",
            Value::Number(
                entry.phi_alias.as_ref().map_or(0, |t| t.resident_bytes()) as f64,
            ),
        ),
        ("backlog", Value::Number(state.batcher.backlog() as f64)),
        ("errors", Value::Number(s.errors.load(Ordering::Relaxed) as f64)),
        ("reloads", Value::Number(s.reloads.load(Ordering::Relaxed) as f64)),
        ("versions", Value::Array(versions)),
    ]);
    Ok(json::to_string(&v))
}

/// Attempts per request when a hot-swap races the batcher: predictions
/// are deterministic and cached, so a retry is cheap and converges as
/// soon as one full pass runs against a single model version.
const SWAP_RACE_RETRIES: usize = 3;

/// Submit the docs and render a response **if** every document resolved
/// under the same model version (`want` additionally pins which one, for
/// the text path whose token ids are only meaningful under the vocabulary
/// they were encoded with). `Ok(None)` = a hot swap landed mid-request;
/// the caller re-submits.
fn submit_uniform(
    state: &State,
    docs: &[Vec<u32>],
    seed: u64,
    want: Option<u64>,
) -> Result<Option<String>, HttpError> {
    let results = state.batcher.submit(docs, seed);
    let mut yhat = Vec::with_capacity(results.len());
    let mut version: Option<u64> = None;
    let mut cached = 0usize;
    for (i, r) in results.into_iter().enumerate() {
        match r {
            Ok(out) => {
                match version {
                    None => version = Some(out.model_version),
                    Some(v) if v != out.model_version => return Ok(None),
                    Some(_) => {}
                }
                yhat.push(out.yhat);
                cached += out.cached as usize;
            }
            Err(e) => return Err(bad_request(format!("doc {i}: {e:#}"))),
        }
    }
    let version = version.unwrap_or(0);
    if let Some(w) = want {
        if w != version {
            return Ok(None);
        }
    }
    Ok(Some(protocol::predict_response(&yhat, version, cached)))
}

fn handle_predict(state: &State, req: &Request) -> Result<String, HttpError> {
    let body = req.body_str().map_err(bad_request)?;
    let preq = protocol::parse_predict(body).map_err(|e| bad_request(format!("{e:#}")))?;
    let seed = preq.seed.unwrap_or(state.default_seed);
    for _ in 0..SWAP_RACE_RETRIES {
        if let Some(body) = submit_uniform(state, &preq.docs, seed, None)? {
            return Ok(body);
        }
    }
    Err(HttpError { status: 503, msg: "model reloads raced this request; retry".into() })
}

fn handle_predict_text(state: &State, req: &Request) -> Result<String, HttpError> {
    let body = req.body_str().map_err(bad_request)?;
    let treq = protocol::parse_text(body).map_err(|e| bad_request(format!("{e:#}")))?;
    let seed = treq.seed.unwrap_or(state.default_seed);
    // Token ids are only meaningful under the vocabulary that produced
    // them, so each attempt re-encodes against the *current* entry and
    // requires the batch to run under exactly that version.
    for _ in 0..SWAP_RACE_RETRIES {
        let entry = state.registry.current();
        let vocab = entry.vocab.as_ref().ok_or_else(|| bad_request(
            "model was saved without a vocabulary; re-train with `cfslda train` \
             on a raw-text corpus (or pass --vocab) to enable /predict/text",
        ))?;
        let mut docs = Vec::with_capacity(treq.texts.len());
        for (i, text) in treq.texts.iter().enumerate() {
            let toks = tokenize(text, &state.tok_cfg);
            let enc = vocab.encode(&toks);
            if enc.is_empty() {
                return Err(bad_request(format!(
                    "text {i} has no in-vocabulary tokens after tokenization"
                )));
            }
            docs.push(enc);
        }
        if let Some(body) = submit_uniform(state, &docs, seed, Some(entry.version))? {
            return Ok(body);
        }
    }
    Err(HttpError { status: 503, msg: "model reloads raced this request; retry".into() })
}

fn handle_reload(state: &State, req: &Request) -> Result<String, HttpError> {
    let body = req.body_str().map_err(bad_request)?;
    let path = protocol::parse_reload(body).map_err(|e| bad_request(format!("{e:#}")))?;
    let entry = state
        .registry
        .reload(path.as_deref().map(Path::new))
        .map_err(|e| server_error(format!("{e:#}")))?;
    state.stats.reloads.fetch_add(1, Ordering::Relaxed);
    let v = Value::object(vec![
        ("status", Value::String("reloaded".into())),
        ("model_version", Value::Number(entry.version as f64)),
        ("path", Value::String(entry.path.display().to_string())),
        ("topics", Value::Number(entry.model.t as f64)),
        ("vocab", Value::Number(entry.model.w as f64)),
    ]);
    Ok(json::to_string(&v))
}

/// Resolved options for [`run_blocking`] (the CLI entry point).
pub struct RunOptions {
    pub model_path: PathBuf,
    pub cfg: ExperimentConfig,
    /// Optional file to write the bound address into (CI / scripts
    /// discovering an ephemeral port).
    pub port_file: Option<PathBuf>,
}

/// Start the server and block forever (the `cfslda serve` command).
pub fn run_blocking(opts: RunOptions) -> anyhow::Result<()> {
    let server = Server::start(&opts.model_path, &opts.cfg)?;
    let entry = server.state.registry.current();
    println!(
        "serving on http://{} (model v{} T={} W={} vocab_terms={} workers={} max_batch={} max_wait_us={})",
        server.local_addr(),
        entry.version,
        entry.model.t,
        entry.model.w,
        entry.vocab.is_some(),
        server.state.workers,
        opts.cfg.serve.max_batch,
        opts.cfg.serve.max_wait_us,
    );
    if let Some(pf) = &opts.port_file {
        let mut f = std::fs::File::create(pf)?;
        writeln!(f, "{}", server.local_addr())?;
    }
    log::info!("endpoints: POST /predict /predict/text /reload; GET /healthz /stats");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}
