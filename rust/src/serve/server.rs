//! The `cfslda serve` HTTP server: accept loop, routing, endpoints.
//!
//! Endpoints (DESIGN.md §Serving):
//!
//! * `GET  /healthz`      — liveness + current model version.
//! * `GET  /stats`        — serving counters, cache + batcher state.
//! * `GET  /metrics`      — Prometheus text exposition (DESIGN.md
//!   §Observability): per-endpoint latency histograms, batcher/cache
//!   counters, training telemetry.
//! * `POST /predict`      — BoW batches through the micro-batcher.
//! * `POST /predict/text` — raw text, tokenized against the persisted
//!   vocabulary (400 when the model was saved without one).
//! * `POST /reload`       — atomic hot-swap to a new (or re-read) model
//!   file; in-flight requests finish on the old version.
//!
//! Threading: one detached handler thread per connection (keep-alive), all
//! prediction work funneled through the shared [`Batcher`] pool, so
//! connection count does not multiply sampler threads.
//!
//! Allocation discipline (DESIGN.md §Serving, "Streaming codec"): each
//! connection owns a [`ConnScratch`] — request-head/body buffers, a
//! [`JsonWriter`], an [`ArenaBuilder`], a pooled batcher [`Completion`]
//! and the results/yhat staging vectors — so a warmed keep-alive
//! connection parses `/predict` bodies straight into the arena, rides the
//! batcher, and serializes responses without touching the heap. Metric
//! recording is relaxed atomics on preregistered cells and keeps that
//! property.

use crate::config::json::JsonWriter;
use crate::config::schema::ExperimentConfig;
use crate::data::corpus::TokenArena;
use crate::data::tokenizer::{tokenize, TokenizerConfig};
use crate::obs::{Endpoint, ServeMetrics};
use crate::serve::batcher::{ArenaBuilder, Batcher, BatcherConfig, Completion, DocOut};
use crate::serve::http::{self, RequestScratch};
use crate::serve::protocol;
use crate::serve::registry::Registry;
use crate::util::pool::num_cpus;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shared per-server state, one `Arc` per connection thread.
struct State {
    registry: Arc<Registry>,
    batcher: Batcher,
    stats: Arc<ServeMetrics>,
    started: Instant,
    default_seed: u64,
    workers: usize,
    tok_cfg: TokenizerConfig,
    /// `[obs] latency_histograms` — record per-endpoint latency when set.
    latency_hist: bool,
}

/// Which scratch buffer holds the response body for the current request.
enum BodyKind {
    /// `out.writer` (JSON, the default).
    Json,
    /// `out.metrics_buf` (Prometheus text exposition).
    Metrics,
}

/// Per-connection reusable buffers. Everything the hot path writes into
/// lives here and is recycled across keep-alive requests; only the cold
/// paths (errors, `/stats`, `/predict/text` tokenization) allocate per
/// request.
struct ConnScratch {
    /// Response body under construction (also reused for error bodies).
    writer: JsonWriter,
    /// Response head bytes (status line + headers).
    head: Vec<u8>,
    /// CSR staging area for `/predict` docs; recycled via
    /// [`ArenaBuilder::reclaim`] when the batcher drops its handle in time.
    builder: ArenaBuilder,
    /// `/predict/text` rows.
    texts: Vec<String>,
    /// Pooled batcher rendezvous, re-armed per request.
    comp: Arc<Completion>,
    /// Per-document batcher results, drained into `yhat` per request.
    results: Vec<anyhow::Result<DocOut>>,
    /// Per-request responses collected from the batcher before rendering.
    yhat: Vec<f64>,
    /// `GET /metrics` exposition body (reused across scrapes).
    metrics_buf: String,
    /// Selects the body buffer when writing the response.
    body_kind: BodyKind,
}

impl ConnScratch {
    fn new() -> ConnScratch {
        ConnScratch {
            writer: JsonWriter::with_capacity(256),
            head: Vec::with_capacity(128),
            builder: ArenaBuilder::new(),
            texts: Vec::new(),
            comp: Arc::new(Completion::new()),
            results: Vec::new(),
            yhat: Vec::new(),
            metrics_buf: String::new(),
            body_kind: BodyKind::Json,
        }
    }
}

/// A running server; dropping (or [`Server::stop`]) shuts the accept loop
/// down and joins the batcher workers.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    state: Arc<State>,
}

impl Server {
    /// Bind `cfg.serve.addr`, load the model, spin up the worker pool and
    /// the accept loop. Returns once the socket is listening.
    pub fn start(model_path: &Path, cfg: &ExperimentConfig) -> anyhow::Result<Server> {
        crate::config::validate::validate(cfg)?;
        // Prebuild the frozen-phi alias tables unless the kernel is pinned
        // to dense/sparse and can never resolve to alias (DESIGN.md
        // §Serving): dense/sparse deployments skip the O(W·T) build and
        // its residency entirely.
        let build_alias = !matches!(
            cfg.sampler.kernel,
            crate::config::schema::KernelKind::Dense
                | crate::config::schema::KernelKind::Sparse
        );
        let registry =
            Arc::new(Registry::open(model_path, cfg.serve.cache_capacity, build_alias)?);
        let stats = Arc::new(ServeMetrics::new());
        let workers = if cfg.serve.workers == 0 { num_cpus() } else { cfg.serve.workers };
        let batcher = Batcher::start(
            BatcherConfig {
                workers,
                max_batch: cfg.serve.max_batch,
                max_wait_us: cfg.serve.max_wait_us,
                kernel: cfg.sampler.kernel,
                train: cfg.train.clone(),
            },
            Arc::clone(&registry),
            Arc::clone(&stats),
        );
        let state = Arc::new(State {
            registry,
            batcher,
            stats,
            started: Instant::now(),
            default_seed: cfg.seed,
            workers,
            tok_cfg: TokenizerConfig::default(),
            latency_hist: cfg.obs.latency_histograms,
        });

        let listener = TcpListener::bind(&cfg.serve.addr)
            .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.serve.addr))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept = {
            let state = Arc::clone(&state);
            let shutdown = Arc::clone(&shutdown);
            std::thread::spawn(move || accept_loop(listener, state, shutdown))
        };
        Ok(Server { addr, shutdown, accept: Some(accept), state })
    }

    /// The actually-bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current model version (diagnostics).
    pub fn model_version(&self) -> u64 {
        self.state.registry.current().version
    }

    /// This server's metric cells (benches read histograms from here).
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.state.stats)
    }

    /// Stop accepting and join the accept loop. Existing keep-alive
    /// connections drop at their next poll tick.
    pub fn stop(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<State>, shutdown: Arc<AtomicBool>) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let state = Arc::clone(&state);
                let shutdown = Arc::clone(&shutdown);
                std::thread::spawn(move || handle_conn(stream, state, shutdown));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                log::warn!("accept error: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

fn handle_conn(stream: TcpStream, state: Arc<State>, shutdown: Arc<AtomicBool>) {
    // Short read timeout => idle keep-alive connections poll the shutdown
    // flag a few times per second instead of pinning a thread forever.
    stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
    stream.set_nodelay(true).ok();
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = write_half;
    let mut reader = BufReader::new(stream);
    let mut req = RequestScratch::new();
    let mut out = ConnScratch::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Idle wait happens *here*, on the buffered peek: a read timeout
        // between requests just re-polls the shutdown flag. Once the first
        // byte of a request has arrived, a timeout inside read_request_into
        // is a protocol error (we never resync a half-read stream).
        {
            use std::io::BufRead;
            match reader.fill_buf() {
                Ok(buf) if buf.is_empty() => return, // peer closed
                Ok(_) => {}
                Err(e) if http::is_timeout_io(&e) => continue,
                Err(_) => return,
            }
        }
        match http::read_request_into(&mut reader, &mut req) {
            Ok(false) => return, // peer closed
            Ok(true) => {
                state.stats.requests.inc();
                let keep_alive = !req.wants_close();
                // Latency covers handler + response write: the span a
                // client actually waits once its request is parsed.
                let t0 = Instant::now();
                let ep = Endpoint::classify(req.method(), req.path());
                let status = route(&state, &req, &mut out);
                if status >= 400 {
                    state.stats.errors.inc();
                }
                let (body, ctype): (&[u8], &str) = match out.body_kind {
                    BodyKind::Json => (out.writer.as_str().as_bytes(), http::CT_JSON),
                    BodyKind::Metrics => (out.metrics_buf.as_bytes(), http::CT_PROMETHEUS),
                };
                let write_ok = http::write_response_typed(
                    &mut writer,
                    &mut out.head,
                    status,
                    ctype,
                    body,
                    keep_alive,
                );
                if state.latency_hist {
                    state.stats.latency_for(ep).observe(t0.elapsed().as_micros() as u64);
                }
                if write_ok.is_err() || !keep_alive {
                    return;
                }
            }
            Err(e) => {
                state.stats.errors.inc();
                protocol::error_response_into(&mut out.writer, &format!("{e:#}"));
                let _ = http::write_response_buffered(
                    &mut writer,
                    &mut out.head,
                    400,
                    out.writer.as_str().as_bytes(),
                    false,
                );
                return;
            }
        }
    }
}

/// Dispatch one parsed request. The response body is left in the scratch
/// buffer selected by `out.body_kind`; the returned status selects the
/// head line.
fn route(state: &State, req: &RequestScratch, out: &mut ConnScratch) -> u16 {
    out.body_kind = BodyKind::Json;
    let res = match (req.method(), req.path()) {
        ("GET", "/healthz") => handle_healthz(state, &mut out.writer),
        ("GET", "/stats") => handle_stats(state, &mut out.writer),
        ("GET", "/metrics") => handle_metrics(state, out),
        ("POST", "/predict") => handle_predict(state, req, out),
        ("POST", "/predict/text") => handle_predict_text(state, req, out),
        ("POST", "/reload") => handle_reload(state, req, &mut out.writer),
        ("GET", _) | ("POST", _) => {
            Err(HttpError { status: 404, msg: "no such endpoint".into() })
        }
        _ => Err(HttpError { status: 405, msg: "method not allowed".into() }),
    };
    match res {
        Ok(()) => 200,
        Err(e) => {
            out.body_kind = BodyKind::Json;
            protocol::error_response_into(&mut out.writer, &e.msg);
            e.status
        }
    }
}

/// Handler error carrying the HTTP status to respond with.
struct HttpError {
    status: u16,
    msg: String,
}

fn bad_request(e: impl std::fmt::Display) -> HttpError {
    HttpError { status: 400, msg: format!("{e}") }
}

fn server_error(e: impl std::fmt::Display) -> HttpError {
    HttpError { status: 500, msg: format!("{e}") }
}

fn raced() -> HttpError {
    HttpError { status: 503, msg: "model reloads raced this request; retry".into() }
}

// Response keys are emitted in sorted order on purpose: the tree codec
// serialized `BTreeMap` objects, and the streamed writers must stay
// byte-identical to it (pinned by protocol + integration tests).

fn handle_healthz(state: &State, w: &mut JsonWriter) -> Result<(), HttpError> {
    let entry = state.registry.current();
    w.clear();
    w.begin_object();
    w.key("has_vocab_terms");
    w.boolean(entry.vocab.is_some());
    w.key("model_version");
    w.number_f64(entry.version as f64);
    w.key("status");
    w.string("ok");
    w.key("topics");
    w.number_f64(entry.model.t as f64);
    w.key("vocab");
    w.number_f64(entry.model.w as f64);
    w.end_object();
    Ok(())
}

fn handle_stats(state: &State, w: &mut JsonWriter) -> Result<(), HttpError> {
    let s = &state.stats;
    let entry = state.registry.current();
    let batches = s.batches.get();
    let docs = s.predict_docs.get();
    let mean_batch =
        if batches > 0 { docs as f64 / batches as f64 } else { 0.0 };
    w.clear();
    w.begin_object();
    w.key("alias_build_secs");
    w.number_f64(entry.alias_build_secs);
    w.key("alias_resident_bytes");
    w.number_f64(entry.phi_alias.as_ref().map_or(0, |t| t.resident_bytes()) as f64);
    w.key("backlog");
    w.number_f64(state.batcher.backlog() as f64);
    w.key("batches");
    w.number_f64(batches as f64);
    w.key("cache_entries");
    w.number_f64(state.registry.cache_len() as f64);
    w.key("cache_hits");
    w.number_f64(s.cache_hits.get() as f64);
    w.key("cache_misses");
    w.number_f64(s.cache_misses.get() as f64);
    w.key("errors");
    w.number_f64(s.errors.get() as f64);
    w.key("mean_batch");
    w.number_f64(mean_batch);
    w.key("model_version");
    w.number_f64(entry.version as f64);
    w.key("predict_docs");
    w.number_f64(docs as f64);
    w.key("reloads");
    w.number_f64(s.reloads.get() as f64);
    w.key("requests");
    w.number_f64(s.requests.get() as f64);
    w.key("uptime_secs");
    w.number_f64(state.started.elapsed().as_secs_f64());
    w.key("versions");
    w.begin_array();
    for v in state.registry.versions() {
        w.begin_object();
        w.key("alias_build_secs");
        w.number_f64(v.alias_build_secs);
        w.key("alias_resident_bytes");
        w.number_f64(v.alias_resident_bytes as f64);
        w.key("path");
        w.string(&v.path.display().to_string());
        w.key("version");
        w.number_f64(v.version as f64);
        w.end_object();
    }
    w.end_array();
    w.key("workers");
    w.number_f64(state.workers as f64);
    w.end_object();
    Ok(())
}

fn handle_metrics(state: &State, out: &mut ConnScratch) -> Result<(), HttpError> {
    crate::obs::render_prometheus(&state.stats, &mut out.metrics_buf);
    out.body_kind = BodyKind::Metrics;
    Ok(())
}

/// Attempts per request when a hot-swap races the batcher: predictions
/// are deterministic and cached, so a retry is cheap and converges as
/// soon as one full pass runs against a single model version.
const SWAP_RACE_RETRIES: usize = 3;

/// Submit an arena through the connection's pooled completion and render
/// a response into `out.writer` **if** every document resolved under the
/// same model version (`want` additionally pins which one, for the text
/// path whose token ids are only meaningful under the vocabulary they
/// were encoded with). `Ok(false)` = a hot swap landed mid-request; the
/// caller re-submits.
fn submit_uniform(
    state: &State,
    arena: &Arc<TokenArena>,
    seed: u64,
    want: Option<u64>,
    out: &mut ConnScratch,
) -> Result<bool, HttpError> {
    state.batcher.submit_streamed_into(Arc::clone(arena), seed, &out.comp, &mut out.results);
    out.yhat.clear();
    let mut version: Option<u64> = None;
    let mut cached = 0usize;
    for (i, r) in out.results.drain(..).enumerate() {
        match r {
            Ok(d) => {
                match version {
                    None => version = Some(d.model_version),
                    Some(v) if v != d.model_version => return Ok(false),
                    Some(_) => {}
                }
                out.yhat.push(d.yhat);
                cached += d.cached as usize;
            }
            Err(e) => return Err(bad_request(format!("doc {i}: {e:#}"))),
        }
    }
    let version = version.unwrap_or(0);
    if let Some(wv) = want {
        if wv != version {
            return Ok(false);
        }
    }
    protocol::predict_response_into(&mut out.writer, &out.yhat, version, cached);
    Ok(true)
}

fn handle_predict(
    state: &State,
    req: &RequestScratch,
    out: &mut ConnScratch,
) -> Result<(), HttpError> {
    let seed = protocol::parse_predict_streamed(req.body(), &mut out.builder)
        .map_err(|e| bad_request(format!("{e:#}")))?
        .unwrap_or(state.default_seed);
    let arena = Arc::new(out.builder.finish());
    let mut outcome: Result<bool, HttpError> = Ok(false);
    for _ in 0..SWAP_RACE_RETRIES {
        outcome = submit_uniform(state, &arena, seed, None, out);
        if !matches!(outcome, Ok(false)) {
            break;
        }
    }
    // Best-effort buffer recycling: the batcher's clones are normally gone
    // by the time all results are in; if a worker still holds one, the
    // builder simply reallocates on the next request.
    if let Ok(arena) = Arc::try_unwrap(arena) {
        out.builder.reclaim(arena);
    }
    match outcome {
        Ok(true) => Ok(()),
        Ok(false) => Err(raced()),
        Err(e) => Err(e),
    }
}

fn handle_predict_text(
    state: &State,
    req: &RequestScratch,
    out: &mut ConnScratch,
) -> Result<(), HttpError> {
    let seed = protocol::parse_text_streamed(req.body(), &mut out.texts)
        .map_err(|e| bad_request(format!("{e:#}")))?
        .unwrap_or(state.default_seed);
    // Token ids are only meaningful under the vocabulary that produced
    // them, so each attempt re-encodes against the *current* entry and
    // requires the batch to run under exactly that version.
    for _ in 0..SWAP_RACE_RETRIES {
        let entry = state.registry.current();
        let vocab = entry.vocab.as_ref().ok_or_else(|| bad_request(
            "model was saved without a vocabulary; re-train with `cfslda train` \
             on a raw-text corpus (or pass --vocab) to enable /predict/text",
        ))?;
        // Encode straight into the connection's arena builder — no
        // per-document `Vec<Vec<u32>>` staging; out-of-vocabulary tokens
        // drop exactly as `Vocab::encode` drops them.
        out.builder.clear();
        for (i, text) in out.texts.iter().enumerate() {
            for tok in tokenize(text, &state.tok_cfg) {
                if let Some(id) = vocab.id(&tok) {
                    out.builder.push_token(id);
                }
            }
            if out.builder.cur_doc_len() == 0 {
                out.builder.clear();
                return Err(bad_request(format!(
                    "text {i} has no in-vocabulary tokens after tokenization"
                )));
            }
            out.builder.end_doc().map_err(|e| bad_request(format!("{e:#}")))?;
        }
        let arena = Arc::new(out.builder.finish());
        let done = submit_uniform(state, &arena, seed, Some(entry.version), out)?;
        if let Ok(a) = Arc::try_unwrap(arena) {
            out.builder.reclaim(a);
        }
        if done {
            return Ok(());
        }
    }
    Err(raced())
}

fn handle_reload(
    state: &State,
    req: &RequestScratch,
    w: &mut JsonWriter,
) -> Result<(), HttpError> {
    let path = protocol::parse_reload_streamed(req.body())
        .map_err(|e| bad_request(format!("{e:#}")))?;
    let entry = state
        .registry
        .reload(path.as_deref().map(Path::new))
        .map_err(|e| server_error(format!("{e:#}")))?;
    state.stats.reloads.inc();
    w.clear();
    w.begin_object();
    w.key("model_version");
    w.number_f64(entry.version as f64);
    w.key("path");
    w.string(&entry.path.display().to_string());
    w.key("status");
    w.string("reloaded");
    w.key("topics");
    w.number_f64(entry.model.t as f64);
    w.key("vocab");
    w.number_f64(entry.model.w as f64);
    w.end_object();
    Ok(())
}

/// Resolved options for [`run_blocking`] (the CLI entry point).
pub struct RunOptions {
    pub model_path: PathBuf,
    pub cfg: ExperimentConfig,
    /// Optional file to write the bound address into (CI / scripts
    /// discovering an ephemeral port).
    pub port_file: Option<PathBuf>,
}

/// Start the server and block forever (the `cfslda serve` command).
pub fn run_blocking(opts: RunOptions) -> anyhow::Result<()> {
    let server = Server::start(&opts.model_path, &opts.cfg)?;
    let entry = server.state.registry.current();
    println!(
        "serving on http://{} (model v{} T={} W={} vocab_terms={} workers={} max_batch={} max_wait_us={})",
        server.local_addr(),
        entry.version,
        entry.model.t,
        entry.model.w,
        entry.vocab.is_some(),
        server.state.workers,
        opts.cfg.serve.max_batch,
        opts.cfg.serve.max_wait_us,
    );
    if let Some(pf) = &opts.port_file {
        let mut f = std::fs::File::create(pf)?;
        writeln!(f, "{}", server.local_addr())?;
    }
    log::info!("endpoints: POST /predict /predict/text /reload; GET /healthz /stats /metrics");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}
