//! Config validation: fail fast with actionable messages before a run.

use super::schema::{EngineKind, ExperimentConfig, KernelKind, RespMode};
use anyhow::bail;

/// Hard topic ceiling: token assignments are stored as `u16` and the
/// sparse-kernel index keeps `u16` topic ids; 4096 is far above any
/// configuration that samples in reasonable time.
const MAX_TOPICS_NATIVE: usize = 4096;

/// Validate an experiment config against the model/sampler invariants and
/// the AOT artifact shape buckets.
pub fn validate(c: &ExperimentConfig) -> anyhow::Result<()> {
    let m = &c.model;
    if m.topics < 2 {
        bail!("model.topics must be >= 2 (got {})", m.topics);
    }
    // The AOT artifacts are compiled at fixed topic buckets (largest: 64).
    // The native engine has no such limit — large-T runs are exactly where
    // the sparse kernel shines — so the bucket cap only applies when the
    // XLA path can be taken.
    if m.topics > 64 && c.engine != EngineKind::Native {
        bail!(
            "model.topics = {} exceeds the largest AOT topic bucket (64); \
             re-run `make artifacts` with --topics including a larger bucket \
             or use engine=native",
            m.topics
        );
    }
    if m.topics > MAX_TOPICS_NATIVE {
        bail!(
            "model.topics = {} exceeds the supported maximum {MAX_TOPICS_NATIVE}",
            m.topics
        );
    }
    for (name, v) in [("alpha", m.alpha), ("beta", m.beta), ("rho", m.rho), ("sigma", m.sigma)] {
        if !(v.is_finite() && v > 0.0) {
            bail!("model.{name} must be finite and > 0 (got {v})");
        }
    }
    if !m.mu.is_finite() {
        bail!("model.mu must be finite");
    }
    let t = &c.train;
    if t.sweeps == 0 {
        bail!("train.sweeps must be >= 1");
    }
    if t.burnin >= t.sweeps {
        bail!("train.burnin ({}) must be < train.sweeps ({})", t.burnin, t.sweeps);
    }
    if t.eta_every == 0 {
        bail!("train.eta_every must be >= 1");
    }
    if t.predict_sweeps == 0 {
        bail!("train.predict_sweeps must be >= 1");
    }
    if t.predict_burnin >= t.predict_sweeps {
        bail!(
            "train.predict_burnin ({}) must be < train.predict_sweeps ({})",
            t.predict_burnin, t.predict_sweeps
        );
    }
    if t.checkpoint_every > 1 << 20 {
        bail!(
            "train.checkpoint_every must be <= {} (0 = off), got {}",
            1usize << 20,
            t.checkpoint_every
        );
    }
    if t.checkpoint_every > 0 && t.checkpoint_dir.is_empty() {
        bail!(
            "train.checkpoint_every = {} but train.checkpoint_dir is empty; \
             set a checkpoint directory (or pass --checkpoint-dir)",
            t.checkpoint_every
        );
    }
    let sp = &c.sampler;
    if sp.alias_staleness > 0
        && matches!(sp.kernel, KernelKind::Dense | KernelKind::Sparse)
    {
        bail!(
            "sampler.alias_staleness ({}) only applies to the alias kernel, \
             but sampler.kernel = {}; drop the knob or set kernel = alias|auto",
            sp.alias_staleness,
            sp.kernel.name()
        );
    }
    if sp.resp_mode == RespMode::Mh && sp.kernel == KernelKind::Dense {
        bail!(
            "sampler.resp_mode = mh requires a kernel with an MH supervised \
             path, but sampler.kernel = dense; set kernel = sparse|alias|auto \
             or resp_mode = exact|auto"
        );
    }
    if sp.alias_staleness > 1 << 20 {
        bail!(
            "sampler.alias_staleness must be <= {} (0 = auto), got {}",
            1usize << 20,
            sp.alias_staleness
        );
    }
    let p = &c.parallel;
    if p.shards == 0 || p.shards > 16 {
        bail!("parallel.shards must be in 1..=16 (AOT shard bucket), got {}", p.shards);
    }
    if p.threads == 0 {
        bail!("parallel.threads must be >= 1");
    }
    let s = &c.serve;
    if s.addr.is_empty() {
        bail!("serve.addr must not be empty (e.g. 127.0.0.1:7878)");
    }
    if s.max_batch == 0 || s.max_batch > 4096 {
        bail!("serve.max_batch must be in 1..=4096, got {}", s.max_batch);
    }
    if s.max_wait_us > 5_000_000 {
        bail!(
            "serve.max_wait_us must be <= 5000000 (5s); a longer coalescing \
             window than that is a latency bug, got {}",
            s.max_wait_us
        );
    }
    if s.workers > 1024 {
        bail!("serve.workers must be <= 1024 (0 = one per CPU), got {}", s.workers);
    }
    if s.cache_capacity > 1 << 24 {
        bail!("serve.cache_capacity must be <= {} entries, got {}", 1usize << 24, s.cache_capacity);
    }
    if s.max_conns > 1 << 20 {
        bail!(
            "serve.max_conns must be <= {} (0 = unlimited), got {}",
            1usize << 20,
            s.max_conns
        );
    }
    if s.queue_depth_max > 1 << 20 {
        bail!(
            "serve.queue_depth_max must be <= {} (0 = unbounded), got {}",
            1usize << 20,
            s.queue_depth_max
        );
    }
    for (name, v) in [("idle_timeout_ms", s.idle_timeout_ms), ("read_timeout_ms", s.read_timeout_ms)]
    {
        if v > 3_600_000 {
            bail!("serve.{name} must be <= 3600000 (1h; 0 = off), got {v}");
        }
    }
    let o = &c.obs;
    if !o.heartbeat_secs.is_finite() || o.heartbeat_secs < 0.0 {
        bail!(
            "obs.heartbeat_secs must be finite and >= 0 (0 = off), got {}",
            o.heartbeat_secs
        );
    }
    if o.heartbeat_secs > 0.0 && o.heartbeat_secs < 0.01 {
        bail!(
            "obs.heartbeat_secs must be >= 0.01 when enabled (a sub-10ms \
             heartbeat floods the log), got {}",
            o.heartbeat_secs
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ExperimentConfig;

    #[test]
    fn default_configs_valid() {
        validate(&ExperimentConfig::default()).unwrap();
        validate(&ExperimentConfig::quick()).unwrap();
        validate(&ExperimentConfig::fig6()).unwrap();
        validate(&ExperimentConfig::fig7()).unwrap();
    }

    #[test]
    fn rejects_bad_topics() {
        let mut c = ExperimentConfig::quick();
        c.model.topics = 1;
        assert!(validate(&c).is_err());
        c.model.topics = 100;
        let err = validate(&c).unwrap_err().to_string();
        assert!(err.contains("bucket"), "{err}");
    }

    #[test]
    fn native_engine_allows_large_topic_counts() {
        let mut c = ExperimentConfig::quick();
        c.engine = crate::config::schema::EngineKind::Native;
        c.model.topics = 256; // sparse-kernel regime
        validate(&c).unwrap();
        c.model.topics = 5000; // beyond the u16-backed ceiling
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_bad_hypers() {
        for f in [
            |c: &mut ExperimentConfig| c.model.alpha = 0.0,
            |c: &mut ExperimentConfig| c.model.beta = -1.0,
            |c: &mut ExperimentConfig| c.model.rho = f64::NAN,
            |c: &mut ExperimentConfig| c.model.sigma = f64::INFINITY,
        ] {
            let mut c = ExperimentConfig::quick();
            f(&mut c);
            assert!(validate(&c).is_err());
        }
    }

    #[test]
    fn rejects_bad_schedule() {
        let mut c = ExperimentConfig::quick();
        c.train.burnin = c.train.sweeps;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.train.eta_every = 0;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.train.predict_burnin = c.train.predict_sweeps;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_bad_checkpoint_settings() {
        // cadence without a directory is a misconfiguration
        let mut c = ExperimentConfig::quick();
        c.train.checkpoint_every = 10;
        let err = validate(&c).unwrap_err().to_string();
        assert!(err.contains("checkpoint_dir"), "{err}");
        // absurd cadence rejected
        let mut c = ExperimentConfig::quick();
        c.train.checkpoint_every = (1 << 20) + 1;
        c.train.checkpoint_dir = "ckpts".to_string();
        assert!(validate(&c).is_err());
        // cadence + directory is valid; directory alone (cadence 0) is too
        let mut c = ExperimentConfig::quick();
        c.train.checkpoint_every = 10;
        c.train.checkpoint_dir = "ckpts".to_string();
        validate(&c).unwrap();
        let mut c = ExperimentConfig::quick();
        c.train.checkpoint_dir = "ckpts".to_string();
        validate(&c).unwrap();
    }

    #[test]
    fn rejects_bad_serve_settings() {
        let mut c = ExperimentConfig::quick();
        c.serve.max_batch = 0;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.serve.max_batch = 5000;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.serve.addr = String::new();
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.serve.max_wait_us = 10_000_000;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.serve.workers = 4096;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.serve.max_conns = (1 << 20) + 1;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.serve.queue_depth_max = (1 << 20) + 1;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.serve.idle_timeout_ms = 3_600_001;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.serve.read_timeout_ms = 3_600_001;
        assert!(validate(&c).is_err());
        // 0 sentinels (unlimited / unbounded / no timeout) are valid
        let mut c = ExperimentConfig::quick();
        c.serve.max_conns = 0;
        c.serve.queue_depth_max = 0;
        c.serve.idle_timeout_ms = 0;
        c.serve.read_timeout_ms = 0;
        validate(&c).unwrap();
    }

    #[test]
    fn rejects_alias_staleness_on_non_alias_kernels() {
        use crate::config::schema::KernelKind;
        // staleness knob with a kernel that can never resolve to alias
        for k in [KernelKind::Dense, KernelKind::Sparse] {
            let mut c = ExperimentConfig::quick();
            c.sampler.kernel = k;
            c.sampler.alias_staleness = 64;
            let err = validate(&c).unwrap_err().to_string();
            assert!(err.contains("alias_staleness"), "{err}");
        }
        // fine with alias and with auto (which may resolve to alias)
        for k in [KernelKind::Alias, KernelKind::Auto] {
            let mut c = ExperimentConfig::quick();
            c.sampler.kernel = k;
            c.sampler.alias_staleness = 64;
            validate(&c).unwrap();
        }
        // 0 = auto is always valid
        let mut c = ExperimentConfig::quick();
        c.sampler.kernel = KernelKind::Dense;
        c.sampler.alias_staleness = 0;
        validate(&c).unwrap();
        // absurd budgets are rejected
        let mut c = ExperimentConfig::quick();
        c.sampler.kernel = KernelKind::Alias;
        c.sampler.alias_staleness = (1 << 20) + 1;
        assert!(validate(&c).is_err());
    }

    #[test]
    fn rejects_mh_resp_mode_on_the_dense_kernel() {
        use crate::config::schema::{KernelKind, RespMode};
        let mut c = ExperimentConfig::quick();
        c.sampler.kernel = KernelKind::Dense;
        c.sampler.resp_mode = RespMode::Mh;
        let err = validate(&c).unwrap_err().to_string();
        assert!(err.contains("resp_mode"), "{err}");
        // exact and auto are fine on dense
        for m in [RespMode::Exact, RespMode::Auto] {
            let mut c = ExperimentConfig::quick();
            c.sampler.kernel = KernelKind::Dense;
            c.sampler.resp_mode = m;
            validate(&c).unwrap();
        }
        // mh pairs with every kernel that has (or may resolve to) an MH
        // supervised path
        for k in [KernelKind::Sparse, KernelKind::Alias, KernelKind::Auto] {
            let mut c = ExperimentConfig::quick();
            c.sampler.kernel = k;
            c.sampler.resp_mode = RespMode::Mh;
            validate(&c).unwrap();
        }
    }

    #[test]
    fn rejects_bad_obs_settings() {
        let mut c = ExperimentConfig::quick();
        c.obs.heartbeat_secs = -1.0;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.obs.heartbeat_secs = f64::NAN;
        assert!(validate(&c).is_err());
        let mut c = ExperimentConfig::quick();
        c.obs.heartbeat_secs = 0.001;
        let err = validate(&c).unwrap_err().to_string();
        assert!(err.contains("heartbeat"), "{err}");
        // 0 (off) and sane intervals are fine
        let mut c = ExperimentConfig::quick();
        c.obs.heartbeat_secs = 0.0;
        validate(&c).unwrap();
        c.obs.heartbeat_secs = 5.0;
        validate(&c).unwrap();
    }

    #[test]
    fn rejects_bad_topology() {
        let mut c = ExperimentConfig::quick();
        c.parallel.shards = 0;
        assert!(validate(&c).is_err());
        c.parallel.shards = 17;
        assert!(validate(&c).is_err());
    }
}
