//! Typed configuration schema + JSON (de)serialization.
//!
//! One [`ExperimentConfig`] drives everything: model hyperparameters (the
//! paper's alpha, beta, rho, sigma, mu), sampler schedule, parallel topology
//! (M shards — the paper uses 4), engine selection (AOT XLA artifacts vs the
//! native fallback), and the RNG seed. `ExperimentConfig::quick()` is tuned
//! for tests/examples; `fig6()`/`fig7()` match the paper's two experiments.

use super::json::{self, Value};
use anyhow::{bail, Context};

/// Which numerical engine executes the dense sLDA algebra.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT-compiled HLO artifacts through PJRT (the production path).
    Xla,
    /// Pure-rust reference implementation (fallback + test oracle).
    Native,
    /// Xla when `artifacts/manifest.json` exists, else Native.
    Auto,
}

impl EngineKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "xla" => EngineKind::Xla,
            "native" => EngineKind::Native,
            "auto" => EngineKind::Auto,
            other => bail!("unknown engine '{other}' (expected xla|native|auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::Native => "native",
            EngineKind::Auto => "auto",
        }
    }
}

/// Which Gibbs token-update kernel the sampler uses (DESIGN.md §Perf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Classic O(T)-per-token conditional.
    Dense,
    /// SparseLDA-style bucket decomposition iterating only non-zero counts.
    Sparse,
    /// Walker alias tables + Metropolis-Hastings correction: amortized O(1)
    /// per token (LightLDA-style). Statistically equivalent to dense/sparse
    /// but a *different* (still seed-deterministic) chain — exempt from the
    /// byte-identical contract (DESIGN.md §Perf).
    Alias,
    /// Path-dependent resolution: see [`KernelKind::resolve_train`] and
    /// [`KernelKind::resolve_predict`].
    Auto,
}

/// `auto` train-kernel threshold: below this topic count the dense kernel's
/// branch-free loops win; at and above it sparsity pays (DESIGN.md §Perf).
pub const SPARSE_AUTO_TOPICS: usize = 64;

/// `auto` train-kernel threshold for the alias-MH kernel: at and above this
/// topic count the amortized O(1) alias draw beats even the sparse bucket
/// walk on burn-in sweeps (DESIGN.md §Perf).
pub const ALIAS_AUTO_TOPICS: usize = 256;

impl KernelKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "dense" => KernelKind::Dense,
            "sparse" => KernelKind::Sparse,
            "alias" => KernelKind::Alias,
            "auto" => KernelKind::Auto,
            other => bail!("unknown sampler kernel '{other}' (expected dense|sparse|alias|auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Dense => "dense",
            KernelKind::Sparse => "sparse",
            KernelKind::Alias => "alias",
            KernelKind::Auto => "auto",
        }
    }

    /// Resolve `Auto` for the training path by topic count: alias-MH at
    /// T >= [`ALIAS_AUTO_TOPICS`], sparse at T >= [`SPARSE_AUTO_TOPICS`],
    /// dense below. Explicit kinds pass through; the result is never `Auto`.
    pub fn resolve_train(self, topics: usize) -> KernelKind {
        match self {
            KernelKind::Auto => {
                if topics >= ALIAS_AUTO_TOPICS {
                    KernelKind::Alias
                } else if topics >= SPARSE_AUTO_TOPICS {
                    KernelKind::Sparse
                } else {
                    KernelKind::Dense
                }
            }
            k => k,
        }
    }

    /// Resolve `Auto` for the prediction path: phi is frozen there, so the
    /// per-word alias tables are exact (never stale) and the amortized O(1)
    /// MH draw wins at every T. Explicit kinds pass through; the result is
    /// never `Auto`.
    pub fn resolve_predict(self, _topics: usize) -> KernelKind {
        match self {
            KernelKind::Auto => KernelKind::Alias,
            k => k,
        }
    }
}

/// How the *supervised* (eta-active) training sweep draws topics
/// (DESIGN.md §Perf "Supervised MH decomposition").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RespMode {
    /// Exact supervised conditional: the dense O(T)-per-token Gaussian
    /// margin sweep (`sweep_doc_gauss`) — the reference path.
    Exact,
    /// Metropolis-Hastings: propose from the kernel's unsupervised
    /// machinery (sparse buckets / alias tables) and correct with the O(1)
    /// Gaussian response ratio. Requires `kernel = sparse|alias` (or
    /// `auto`); the dense kernel has no MH supervised path.
    Mh,
    /// Per-kernel resolution: exact for dense, MH for sparse/alias (see
    /// [`RespMode::resolve`]).
    Auto,
}

impl RespMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "exact" => RespMode::Exact,
            "mh" => RespMode::Mh,
            "auto" => RespMode::Auto,
            other => bail!("unknown sampler resp_mode '{other}' (expected exact|mh|auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RespMode::Exact => "exact",
            RespMode::Mh => "mh",
            RespMode::Auto => "auto",
        }
    }

    /// Resolve against a *resolved* (never `Auto`) train kernel: the dense
    /// kernel always runs the exact sweep (its MH machinery does not
    /// exist — validation rejects an explicit `mh` + `dense` pairing, and
    /// an `auto` kernel that resolves to dense degrades `mh` to exact);
    /// sparse/alias resolve `Auto` to MH. The result is never `Auto`.
    pub fn resolve(self, kernel: KernelKind) -> RespMode {
        match kernel {
            KernelKind::Dense => RespMode::Exact,
            _ => match self {
                RespMode::Exact => RespMode::Exact,
                _ => RespMode::Mh,
            },
        }
    }
}

/// Sampler implementation knobs (orthogonal to the model/schedule).
#[derive(Clone, Debug, PartialEq)]
pub struct SamplerConfig {
    /// Token-update kernel. Dense and sparse are draw-for-draw identical
    /// under a fixed seed (burn-in, prediction and `resp_mode = exact`
    /// supervised sweeps); alias is statistically equivalent (and still
    /// seed-deterministic) but a different chain.
    pub kernel: KernelKind,
    /// Alias-kernel staleness budget (training path only): how many count
    /// updates a word's table may absorb before the next touch rebuilds it.
    /// 0 = auto (resolves to `max(T, 16)` — amortized O(1) rebuild cost).
    /// Only meaningful for `kernel = alias` (or `auto`); prediction tables
    /// are built once against frozen phi and are always exact.
    pub alias_staleness: usize,
    /// Supervised-sweep mode: `exact` keeps every kernel on the dense
    /// Gaussian-margin conditional once eta activates; `mh` runs the
    /// kernel's own proposals with the O(1) response-ratio MH correction;
    /// `auto` resolves per kernel (exact for dense, MH for sparse/alias).
    pub resp_mode: RespMode,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            kernel: KernelKind::Auto,
            alias_staleness: 0,
            resp_mode: RespMode::Auto,
        }
    }
}

/// Response type of the supervised signal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseKind {
    /// Gaussian response (paper Experiment I: earnings per share). Metric: MSE.
    Continuous,
    /// Binary response via the Gaussian linear-probability reading of the
    /// paper's logit-normal note (Experiment II: sentiment). Metric: accuracy
    /// at the 0.5 threshold; Weighted Average weights use train accuracy.
    Binary,
}

impl ResponseKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "continuous" => ResponseKind::Continuous,
            "binary" => ResponseKind::Binary,
            other => bail!("unknown response kind '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ResponseKind::Continuous => "continuous",
            ResponseKind::Binary => "binary",
        }
    }
}

/// sLDA hyperparameters (paper §III-B notation).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Number of topics T.
    pub topics: usize,
    /// Symmetric Dirichlet prior on per-document topic proportions.
    pub alpha: f64,
    /// Symmetric Dirichlet prior on per-topic word distributions.
    pub beta: f64,
    /// Response variance rho (fixed unless `learn_rho`).
    pub rho: f64,
    /// Re-estimate rho from residuals at each eta step.
    pub learn_rho: bool,
    /// Gaussian prior variance sigma on eta coefficients.
    pub sigma: f64,
    /// Gaussian prior mean mu on eta coefficients.
    pub mu: f64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            topics: 16,
            alpha: 0.5,
            beta: 0.1,
            rho: 0.5,
            learn_rho: true,
            sigma: 10.0,
            mu: 0.0,
        }
    }
}

impl ModelConfig {
    /// Ridge strength implied by the priors: lambda = rho / sigma (eq. 2).
    pub fn lambda(&self, rho: f64) -> f64 {
        rho / self.sigma
    }
}

/// Gibbs/stochastic-EM schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// Total training Gibbs sweeps over the shard's tokens.
    pub sweeps: usize,
    /// Sweeps before the first eta update.
    pub burnin: usize,
    /// Re-optimize eta every this many sweeps after burn-in.
    pub eta_every: usize,
    /// Gibbs sweeps per test document at prediction time.
    pub predict_sweeps: usize,
    /// Prediction burn-in sweeps (samples before this are discarded when
    /// averaging the empirical topic distribution — Nguyen et al. 2014).
    pub predict_burnin: usize,
    /// Durability cadence: write a crash-recovery checkpoint every this many
    /// sweeps (0 = off). The value is **chain-defining** (DESIGN.md
    /// §Durability): each checkpoint boundary deterministically re-derives
    /// the kernel state from the counts, so a resumed run and an
    /// uninterrupted run with the same `checkpoint_every` are byte-identical
    /// — but a run with a different cadence is a different (equally valid)
    /// chain. Part of the checkpoint config fingerprint.
    pub checkpoint_every: usize,
    /// Checkpoint directory ("" = none). Not part of the config fingerprint
    /// — moving a checkpoint directory does not invalidate it.
    pub checkpoint_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            sweeps: 100,
            burnin: 10,
            eta_every: 5,
            predict_sweeps: 20,
            predict_burnin: 5,
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
        }
    }
}

/// Which connection-handling backend `cfslda serve` runs
/// (DESIGN.md §Serving "Event-loop architecture").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeBackend {
    /// Thread-per-connection over blocking `std::net` — the portable
    /// fallback and the behavioral reference for the byte-identical
    /// response contract.
    Threads,
    /// Single-threaded epoll readiness loop with per-connection state
    /// machines (Linux only): keep-alive pipelining, idle/read timeouts,
    /// and admission control at 10k+ concurrent connections.
    Epoll,
}

impl ServeBackend {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "threads" => ServeBackend::Threads,
            "epoll" => ServeBackend::Epoll,
            other => bail!("unknown serve backend '{other}' (expected threads|epoll)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServeBackend::Threads => "threads",
            ServeBackend::Epoll => "epoll",
        }
    }
}

/// Prediction-serving knobs (`cfslda serve`, DESIGN.md §Serving).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (printed at startup).
    pub addr: String,
    /// Connection-handling backend: portable blocking threads or the
    /// Linux epoll readiness loop. Both return byte-identical responses
    /// for the same (model, seed, doc) request stream.
    pub backend: ServeBackend,
    /// Prediction worker threads; 0 means one per available CPU.
    pub workers: usize,
    /// Micro-batch ceiling: a worker drains at most this many queued
    /// documents into one prediction batch.
    pub max_batch: usize,
    /// How long a worker waits (microseconds) for more documents to
    /// coalesce into a batch before predicting what it has. 0 disables
    /// coalescing (every dequeue predicts immediately).
    pub max_wait_us: u64,
    /// Capacity of the doc-level LRU prediction cache (entries, keyed by
    /// (model version, seed, token hash)). 0 disables the cache.
    pub cache_capacity: usize,
    /// Admission control: maximum concurrently open client connections.
    /// Connections beyond the limit are shed with `503 Retry-After`
    /// before any request parsing. 0 = unlimited.
    pub max_conns: usize,
    /// Admission control: maximum queued documents in the batcher before
    /// new requests are shed with `503 Retry-After`. 0 = unbounded.
    pub queue_depth_max: usize,
    /// Idle keep-alive timeout (milliseconds): a connection with no
    /// in-flight request is reaped after this long without bytes.
    /// 0 = never reaped.
    pub idle_timeout_ms: u64,
    /// Mid-request read timeout (milliseconds): a connection that has
    /// started a request head/body but stalls for this long is dropped
    /// (slow-loris defense). 0 = never dropped.
    pub read_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            backend: ServeBackend::Threads,
            workers: 0,
            max_batch: 32,
            max_wait_us: 500,
            cache_capacity: 4096,
            max_conns: 8192,
            queue_depth_max: 4096,
            idle_timeout_ms: 30_000,
            read_timeout_ms: 10_000,
        }
    }
}

/// Observability knobs (DESIGN.md §Observability).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Interval (seconds) between structured JSON heartbeat lines emitted
    /// by the parallel-training leader. 0 disables the heartbeat.
    pub heartbeat_secs: f64,
    /// Record per-endpoint request latency histograms in the server.
    pub latency_histograms: bool,
    /// Record per-sweep training telemetry (tokens/s, MH acceptance,
    /// alias rebuilds) into the process-global registry.
    pub train_telemetry: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { heartbeat_secs: 0.0, latency_histograms: true, train_telemetry: true }
    }
}

/// Parallel topology.
#[derive(Clone, Debug, PartialEq)]
pub struct ParallelConfig {
    /// Number of training shards M (the paper uses 4).
    pub shards: usize,
    /// Worker threads (defaults to `shards`).
    pub threads: usize,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig { shards: 4, threads: 4 }
    }
}

/// Top-level experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub model: ModelConfig,
    pub train: TrainConfig,
    pub sampler: SamplerConfig,
    pub parallel: ParallelConfig,
    pub serve: ServeConfig,
    pub obs: ObsConfig,
    pub engine: EngineKind,
    pub response: ResponseKind,
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: ModelConfig::default(),
            train: TrainConfig::default(),
            sampler: SamplerConfig::default(),
            parallel: ParallelConfig::default(),
            serve: ServeConfig::default(),
            obs: ObsConfig::default(),
            engine: EngineKind::Auto,
            response: ResponseKind::Continuous,
            seed: 20170710,
        }
    }
}

impl ExperimentConfig {
    /// Small, fast configuration for tests and the quickstart example.
    pub fn quick() -> Self {
        let mut c = Self::default();
        c.model.topics = 8;
        c.train = TrainConfig {
            sweeps: 30,
            burnin: 5,
            eta_every: 5,
            predict_sweeps: 10,
            predict_burnin: 3,
            ..TrainConfig::default()
        };
        c
    }

    /// Paper Experiment I (MD&A -> EPS) shape: continuous response, M=4.
    pub fn fig6() -> Self {
        let mut c = Self::default();
        c.model.topics = 16;
        c.response = ResponseKind::Continuous;
        c.train = TrainConfig {
            sweeps: 100,
            burnin: 10,
            eta_every: 5,
            predict_sweeps: 20,
            predict_burnin: 5,
            ..TrainConfig::default()
        };
        c
    }

    /// Paper Experiment II (reviews -> sentiment) shape: binary response, M=4.
    pub fn fig7() -> Self {
        let mut c = Self::fig6();
        c.response = ResponseKind::Binary;
        c
    }

    // ---- JSON mapping (manual: no serde in the vendor set) ----

    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("model", Value::object(vec![
                ("topics", Value::Number(self.model.topics as f64)),
                ("alpha", Value::Number(self.model.alpha)),
                ("beta", Value::Number(self.model.beta)),
                ("rho", Value::Number(self.model.rho)),
                ("learn_rho", Value::Bool(self.model.learn_rho)),
                ("sigma", Value::Number(self.model.sigma)),
                ("mu", Value::Number(self.model.mu)),
            ])),
            ("train", Value::object(vec![
                ("sweeps", Value::Number(self.train.sweeps as f64)),
                ("burnin", Value::Number(self.train.burnin as f64)),
                ("eta_every", Value::Number(self.train.eta_every as f64)),
                ("predict_sweeps", Value::Number(self.train.predict_sweeps as f64)),
                ("predict_burnin", Value::Number(self.train.predict_burnin as f64)),
                ("checkpoint_every", Value::Number(self.train.checkpoint_every as f64)),
                ("checkpoint_dir", Value::String(self.train.checkpoint_dir.clone())),
            ])),
            ("sampler", Value::object(vec![
                ("kernel", Value::String(self.sampler.kernel.name().to_string())),
                ("alias_staleness", Value::Number(self.sampler.alias_staleness as f64)),
                ("resp_mode", Value::String(self.sampler.resp_mode.name().to_string())),
            ])),
            ("parallel", Value::object(vec![
                ("shards", Value::Number(self.parallel.shards as f64)),
                ("threads", Value::Number(self.parallel.threads as f64)),
            ])),
            ("serve", Value::object(vec![
                ("addr", Value::String(self.serve.addr.clone())),
                ("backend", Value::String(self.serve.backend.name().to_string())),
                ("workers", Value::Number(self.serve.workers as f64)),
                ("max_batch", Value::Number(self.serve.max_batch as f64)),
                ("max_wait_us", Value::Number(self.serve.max_wait_us as f64)),
                ("cache_capacity", Value::Number(self.serve.cache_capacity as f64)),
                ("max_conns", Value::Number(self.serve.max_conns as f64)),
                ("queue_depth_max", Value::Number(self.serve.queue_depth_max as f64)),
                ("idle_timeout_ms", Value::Number(self.serve.idle_timeout_ms as f64)),
                ("read_timeout_ms", Value::Number(self.serve.read_timeout_ms as f64)),
            ])),
            ("obs", Value::object(vec![
                ("heartbeat_secs", Value::Number(self.obs.heartbeat_secs)),
                ("latency_histograms", Value::Bool(self.obs.latency_histograms)),
                ("train_telemetry", Value::Bool(self.obs.train_telemetry)),
            ])),
            ("engine", Value::String(self.engine.name().to_string())),
            ("response", Value::String(self.response.name().to_string())),
            ("seed", Value::Number(self.seed as f64)),
        ])
    }

    pub fn from_value(v: &Value) -> anyhow::Result<Self> {
        let mut c = ExperimentConfig::default();
        if let Some(m) = v.get("model") {
            read_usize(m, "topics", &mut c.model.topics)?;
            read_f64(m, "alpha", &mut c.model.alpha)?;
            read_f64(m, "beta", &mut c.model.beta)?;
            read_f64(m, "rho", &mut c.model.rho)?;
            read_bool(m, "learn_rho", &mut c.model.learn_rho)?;
            read_f64(m, "sigma", &mut c.model.sigma)?;
            read_f64(m, "mu", &mut c.model.mu)?;
        }
        if let Some(t) = v.get("train") {
            read_usize(t, "sweeps", &mut c.train.sweeps)?;
            read_usize(t, "burnin", &mut c.train.burnin)?;
            read_usize(t, "eta_every", &mut c.train.eta_every)?;
            read_usize(t, "predict_sweeps", &mut c.train.predict_sweeps)?;
            read_usize(t, "predict_burnin", &mut c.train.predict_burnin)?;
            read_usize(t, "checkpoint_every", &mut c.train.checkpoint_every)?;
            if let Some(d) = t.get("checkpoint_dir") {
                c.train.checkpoint_dir =
                    d.as_str().context("train.checkpoint_dir must be a string")?.to_string();
            }
        }
        if let Some(s) = v.get("sampler") {
            if let Some(k) = s.get("kernel") {
                c.sampler.kernel =
                    KernelKind::parse(k.as_str().context("sampler.kernel must be a string")?)?;
            }
            read_usize(s, "alias_staleness", &mut c.sampler.alias_staleness)?;
            if let Some(r) = s.get("resp_mode") {
                c.sampler.resp_mode =
                    RespMode::parse(r.as_str().context("sampler.resp_mode must be a string")?)?;
            }
        }
        if let Some(p) = v.get("parallel") {
            read_usize(p, "shards", &mut c.parallel.shards)?;
            read_usize(p, "threads", &mut c.parallel.threads)?;
        }
        if let Some(s) = v.get("serve") {
            if let Some(a) = s.get("addr") {
                c.serve.addr =
                    a.as_str().context("serve.addr must be a string")?.to_string();
            }
            if let Some(b) = s.get("backend") {
                c.serve.backend =
                    ServeBackend::parse(b.as_str().context("serve.backend must be a string")?)?;
            }
            read_usize(s, "workers", &mut c.serve.workers)?;
            read_usize(s, "max_batch", &mut c.serve.max_batch)?;
            let mut wait = c.serve.max_wait_us as usize;
            read_usize(s, "max_wait_us", &mut wait)?;
            c.serve.max_wait_us = wait as u64;
            read_usize(s, "cache_capacity", &mut c.serve.cache_capacity)?;
            read_usize(s, "max_conns", &mut c.serve.max_conns)?;
            read_usize(s, "queue_depth_max", &mut c.serve.queue_depth_max)?;
            let mut idle = c.serve.idle_timeout_ms as usize;
            read_usize(s, "idle_timeout_ms", &mut idle)?;
            c.serve.idle_timeout_ms = idle as u64;
            let mut rt = c.serve.read_timeout_ms as usize;
            read_usize(s, "read_timeout_ms", &mut rt)?;
            c.serve.read_timeout_ms = rt as u64;
        }
        if let Some(o) = v.get("obs") {
            read_f64(o, "heartbeat_secs", &mut c.obs.heartbeat_secs)?;
            read_bool(o, "latency_histograms", &mut c.obs.latency_histograms)?;
            read_bool(o, "train_telemetry", &mut c.obs.train_telemetry)?;
        }
        if let Some(e) = v.get("engine") {
            c.engine = EngineKind::parse(e.as_str().context("engine must be a string")?)?;
        }
        if let Some(r) = v.get("response") {
            c.response = ResponseKind::parse(r.as_str().context("response must be a string")?)?;
        }
        if let Some(s) = v.get("seed") {
            c.seed = s.as_i64().context("seed must be an integer")? as u64;
        }
        Ok(c)
    }

    pub fn to_json(&self) -> String {
        json::to_string_pretty(&self.to_value())
    }

    pub fn from_json(s: &str) -> anyhow::Result<Self> {
        let v = json::parse(s).context("parsing experiment config")?;
        Self::from_value(&v)
    }

    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_json(&text)
    }
}

fn read_usize(v: &Value, key: &str, dst: &mut usize) -> anyhow::Result<()> {
    if let Some(x) = v.get(key) {
        *dst = x.as_usize().with_context(|| format!("'{key}' must be a non-negative integer"))?;
    }
    Ok(())
}

fn read_f64(v: &Value, key: &str, dst: &mut f64) -> anyhow::Result<()> {
    if let Some(x) = v.get(key) {
        *dst = x.as_f64().with_context(|| format!("'{key}' must be a number"))?;
    }
    Ok(())
}

fn read_bool(v: &Value, key: &str, dst: &mut bool) -> anyhow::Result<()> {
    if let Some(x) = v.get(key) {
        *dst = x.as_bool().with_context(|| format!("'{key}' must be a bool"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::fig7();
        c.model.topics = 24;
        c.seed = 99;
        c.engine = EngineKind::Native;
        c.train.checkpoint_every = 10;
        c.train.checkpoint_dir = "/tmp/ckpt".to_string();
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let c = ExperimentConfig::from_json(r#"{"model": {"topics": 5}}"#).unwrap();
        assert_eq!(c.model.topics, 5);
        assert_eq!(c.model.alpha, ModelConfig::default().alpha);
        assert_eq!(c.parallel.shards, 4);
        assert_eq!(c.train.checkpoint_every, 0);
        assert!(c.train.checkpoint_dir.is_empty());
    }

    #[test]
    fn checkpoint_knobs_roundtrip_and_validate_types() {
        let c = ExperimentConfig::from_json(
            r#"{"train": {"checkpoint_every": 25, "checkpoint_dir": "ckpts"}}"#,
        )
        .unwrap();
        assert_eq!(c.train.checkpoint_every, 25);
        assert_eq!(c.train.checkpoint_dir, "ckpts");
        assert!(ExperimentConfig::from_json(
            r#"{"train": {"checkpoint_every": -1}}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            r#"{"train": {"checkpoint_dir": 5}}"#
        )
        .is_err());
    }

    #[test]
    fn bad_values_rejected() {
        assert!(ExperimentConfig::from_json(r#"{"model": {"topics": -2}}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"engine": "gpu"}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"response": 7}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"sampler": {"kernel": "turbo"}}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"sampler": {"resp_mode": "sorta"}}"#).is_err());
    }

    #[test]
    fn kernel_knob_roundtrips_and_resolves() {
        let mut c = ExperimentConfig::quick();
        c.sampler.kernel = KernelKind::Sparse;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.sampler.kernel, KernelKind::Sparse);
        let c3 = ExperimentConfig::from_json("{}").unwrap();
        assert_eq!(c3.sampler.kernel, KernelKind::Auto);
        assert_eq!(c3.sampler.alias_staleness, 0);

        // auto train resolution: dense -> sparse -> alias by topic count
        assert_eq!(KernelKind::Auto.resolve_train(SPARSE_AUTO_TOPICS - 1), KernelKind::Dense);
        assert_eq!(KernelKind::Auto.resolve_train(SPARSE_AUTO_TOPICS), KernelKind::Sparse);
        assert_eq!(KernelKind::Auto.resolve_train(ALIAS_AUTO_TOPICS - 1), KernelKind::Sparse);
        assert_eq!(KernelKind::Auto.resolve_train(ALIAS_AUTO_TOPICS), KernelKind::Alias);
        // auto predict resolution: alias at every T (frozen phi => exact tables)
        assert_eq!(KernelKind::Auto.resolve_predict(2), KernelKind::Alias);
        assert_eq!(KernelKind::Auto.resolve_predict(4096), KernelKind::Alias);
        // explicit kinds pass through on both paths
        assert_eq!(KernelKind::Dense.resolve_train(1024), KernelKind::Dense);
        assert_eq!(KernelKind::Sparse.resolve_train(2), KernelKind::Sparse);
        assert_eq!(KernelKind::Alias.resolve_train(2), KernelKind::Alias);
        assert_eq!(KernelKind::Dense.resolve_predict(1024), KernelKind::Dense);
        assert_eq!(KernelKind::Sparse.resolve_predict(1024), KernelKind::Sparse);
        for k in [KernelKind::Dense, KernelKind::Sparse, KernelKind::Alias, KernelKind::Auto] {
            assert_eq!(KernelKind::parse(k.name()).unwrap(), k);
        }
        assert!(KernelKind::parse("bogus").is_err());
    }

    #[test]
    fn resp_mode_roundtrips_and_resolves() {
        let mut c = ExperimentConfig::quick();
        c.sampler.kernel = KernelKind::Sparse;
        c.sampler.resp_mode = RespMode::Mh;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.sampler.resp_mode, RespMode::Mh);
        let c3 = ExperimentConfig::from_json("{}").unwrap();
        assert_eq!(c3.sampler.resp_mode, RespMode::Auto);

        // per-kernel resolution: dense is always exact, sparse/alias
        // resolve auto (and explicit mh) to MH; the result is never Auto.
        for m in [RespMode::Exact, RespMode::Mh, RespMode::Auto] {
            assert_eq!(m.resolve(KernelKind::Dense), RespMode::Exact);
        }
        for k in [KernelKind::Sparse, KernelKind::Alias] {
            assert_eq!(RespMode::Auto.resolve(k), RespMode::Mh);
            assert_eq!(RespMode::Mh.resolve(k), RespMode::Mh);
            assert_eq!(RespMode::Exact.resolve(k), RespMode::Exact);
        }
        for m in [RespMode::Exact, RespMode::Mh, RespMode::Auto] {
            assert_eq!(RespMode::parse(m.name()).unwrap(), m);
        }
        assert!(RespMode::parse("bogus").is_err());
    }

    #[test]
    fn alias_staleness_roundtrips() {
        let mut c = ExperimentConfig::quick();
        c.sampler.kernel = KernelKind::Alias;
        c.sampler.alias_staleness = 128;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.sampler.alias_staleness, 128);
        assert_eq!(c2.sampler.kernel, KernelKind::Alias);
        assert!(ExperimentConfig::from_json(
            r#"{"sampler": {"alias_staleness": -4}}"#
        )
        .is_err());
    }

    #[test]
    fn serve_section_roundtrips_and_defaults() {
        let mut c = ExperimentConfig::default();
        c.serve.addr = "0.0.0.0:9000".to_string();
        c.serve.backend = ServeBackend::Epoll;
        c.serve.workers = 8;
        c.serve.max_batch = 64;
        c.serve.max_wait_us = 250;
        c.serve.cache_capacity = 0;
        c.serve.max_conns = 10_000;
        c.serve.queue_depth_max = 512;
        c.serve.idle_timeout_ms = 1_500;
        c.serve.read_timeout_ms = 750;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
        // partial json keeps the rest of the defaults
        let c3 = ExperimentConfig::from_json(r#"{"serve": {"max_batch": 7}}"#).unwrap();
        assert_eq!(c3.serve.max_batch, 7);
        assert_eq!(c3.serve.addr, ServeConfig::default().addr);
        assert_eq!(c3.serve.backend, ServeBackend::Threads);
        assert_eq!(c3.serve.max_conns, ServeConfig::default().max_conns);
        assert_eq!(c3.serve.queue_depth_max, ServeConfig::default().queue_depth_max);
        assert_eq!(c3.serve.idle_timeout_ms, ServeConfig::default().idle_timeout_ms);
        assert_eq!(c3.serve.read_timeout_ms, ServeConfig::default().read_timeout_ms);
        assert!(ExperimentConfig::from_json(r#"{"serve": {"addr": 5}}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"serve": {"workers": -1}}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"serve": {"backend": "uring"}}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"serve": {"backend": 3}}"#).is_err());
    }

    #[test]
    fn serve_backend_parse_name_roundtrip() {
        for b in [ServeBackend::Threads, ServeBackend::Epoll] {
            assert_eq!(ServeBackend::parse(b.name()).unwrap(), b);
        }
        assert!(ServeBackend::parse("bogus").is_err());
    }

    #[test]
    fn obs_section_roundtrips_and_defaults() {
        let mut c = ExperimentConfig::default();
        c.obs.heartbeat_secs = 2.5;
        c.obs.latency_histograms = false;
        c.obs.train_telemetry = false;
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
        // partial json keeps the rest of the defaults
        let c3 = ExperimentConfig::from_json(r#"{"obs": {"heartbeat_secs": 1.0}}"#).unwrap();
        assert_eq!(c3.obs.heartbeat_secs, 1.0);
        assert!(c3.obs.latency_histograms);
        assert!(c3.obs.train_telemetry);
        let c4 = ExperimentConfig::from_json("{}").unwrap();
        assert_eq!(c4.obs, ObsConfig::default());
        assert!(ExperimentConfig::from_json(r#"{"obs": {"latency_histograms": 3}}"#).is_err());
    }

    #[test]
    fn lambda_is_rho_over_sigma() {
        let m = ModelConfig { rho: 2.0, sigma: 4.0, ..Default::default() };
        assert!((m.lambda(2.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn presets_are_sane() {
        assert_eq!(ExperimentConfig::fig6().response, ResponseKind::Continuous);
        assert_eq!(ExperimentConfig::fig7().response, ResponseKind::Binary);
        assert!(ExperimentConfig::quick().train.sweeps < 50);
    }
}
