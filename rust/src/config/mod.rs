//! Configuration system: a hand-rolled JSON parser/serializer (`json`), the
//! typed config schema (`schema`), and validation (`validate`).
//!
//! JSON is the single interchange format of the project: artifact manifests
//! written by `python/compile/aot.py`, experiment configs, and the metrics
//! dumps emitted by the experiment runners. serde is not available in the
//! offline vendor set, so `json::Value` + explicit `from_value`/`to_value`
//! mappings play its role.

pub mod json;
pub mod schema;
pub mod validate;
