//! JSON parsing and serialization (RFC 8259 subset, no serde offline).
//!
//! Two layers share one tokenizer:
//!
//! * **Streaming** — [`Lexer`] pulls [`Event`]s straight off a `&[u8]` with
//!   no intermediate tree. Strings borrow from the input when they contain
//!   no escapes; integers lex exactly as [`Number::U64`]/[`Number::I64`]
//!   (full 64-bit range, no f64 round-trip). This is the serve hot path:
//!   the protocol codec feeds token ids from the wire directly into the
//!   batcher's arena.
//! * **Tree** — [`parse`] builds the classic [`Value`] model on top of the
//!   lexer for cold paths (configs, manifests, tests). Numbers are stored
//!   as f64; integer literals that cannot round-trip through f64 exactly
//!   (magnitude above 2^53) are *rejected*, never silently rounded.
//!
//! Both layers keep precise line:col error positions, support `\uXXXX`
//! escapes incl. surrogate pairs, and cap nesting at [`MAX_DEPTH`] so
//! malicious documents cannot overflow the stack. Serialization goes
//! through [`to_string`]/[`to_string_pretty`] for trees and the reusable
//! [`JsonWriter`] for allocation-free rendering into a recycled buffer.

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

/// Maximum container nesting depth accepted by the lexer (and therefore by
/// the tree parser, whose recursion is bounded by it) and supported by
/// [`JsonWriter`]. Both track container state in fixed bitsets, so depth
/// costs no allocation.
pub const MAX_DEPTH: usize = 128;

/// Words in the fixed bitsets that track per-level container state.
const DEPTH_WORDS: usize = MAX_DEPTH / 64;

#[inline]
fn bit_get(bits: &[u64; DEPTH_WORDS], i: usize) -> bool {
    bits[i >> 6] & (1u64 << (i & 63)) != 0
}

#[inline]
fn bit_set(bits: &mut [u64; DEPTH_WORDS], i: usize, v: bool) {
    let mask = 1u64 << (i & 63);
    if v {
        bits[i >> 6] |= mask;
    } else {
        bits[i >> 6] &= !mask;
    }
}

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        if let Value::Bool(b) = self { Some(*b) } else { None }
    }

    pub fn as_f64(&self) -> Option<f64> {
        if let Value::Number(n) = self { Some(*n) } else { None }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53) { Some(n as usize) } else { None }
        })
    }

    /// Integer access, `None` when the stored f64 is fractional or its
    /// magnitude exceeds 2^53 (beyond which f64 cannot represent every
    /// integer — use the streaming [`Lexer`] for full 64-bit range).
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) { Some(n as i64) } else { None }
        })
    }

    /// Non-negative integer access with the same 2^53 exactness bound as
    /// [`Value::as_i64`].
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53) { Some(n as u64) } else { None }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        if let Value::String(s) = self { Some(s) } else { None }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        if let Value::Array(a) = self { Some(a) } else { None }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        if let Value::Object(o) = self { Some(o) } else { None }
    }

    /// Object field access; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Build an object from pairs (convenience for serialization sites).
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x)).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x as f64)).collect())
    }
}

/// Parse error with position info.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// An exactly-lexed JSON number. Integer literals that fit 64 bits keep
/// their exact value (`U64` for non-negative, `I64` for negative);
/// everything else (fractions, exponents, magnitudes beyond 64 bits)
/// falls back to `F64`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Number {
    U64(u64),
    I64(i64),
    F64(f64),
}

impl Number {
    /// Lossy f64 view (what the tree model stores).
    pub fn as_f64(self) -> f64 {
        match self {
            Number::U64(n) => n as f64,
            Number::I64(n) => n as f64,
            Number::F64(f) => f,
        }
    }

    /// The f64 value if the conversion is exact; `None` when an integer
    /// literal would lose precision (magnitude above 2^53 with low bits
    /// set). `F64` is returned as-is: the literal already went through
    /// float parsing, so f64 *is* its value.
    pub fn as_exact_f64(self) -> Option<f64> {
        match self {
            Number::U64(n) => {
                let f = n as f64;
                // The cast rounds; accept only when it round-trips. Guard
                // against f == 2^64 (u64::MAX rounds up), where the
                // saturating cast back would falsely "round-trip".
                if f < 18_446_744_073_709_551_616.0 && f as u64 == n { Some(f) } else { None }
            }
            Number::I64(n) => {
                let f = n as f64;
                if f >= -9_223_372_036_854_775_808.0 && f as i64 == n { Some(f) } else { None }
            }
            Number::F64(f) => Some(f),
        }
    }

    /// Exact u64 view: integer literals in `[0, u64::MAX]`, including
    /// integral floats (e.g. `7.0`, `1e3`) below 2^64.
    pub fn as_u64_exact(self) -> Option<u64> {
        match self {
            Number::U64(n) => Some(n),
            Number::I64(_) => None,
            Number::F64(f) => {
                if f >= 0.0 && f.fract() == 0.0 && f < 18_446_744_073_709_551_616.0 {
                    Some(f as u64)
                } else {
                    None
                }
            }
        }
    }

    /// Exact u32 view (token ids); accepts integral floats like the tree
    /// path's `as_usize` did, so the two codecs agree on what a token is.
    pub fn as_u32_exact(self) -> Option<u32> {
        match self {
            Number::U64(n) => u32::try_from(n).ok(),
            Number::I64(_) => None,
            Number::F64(f) => {
                if f >= 0.0 && f.fract() == 0.0 && f <= u32::MAX as f64 {
                    Some(f as u32)
                } else {
                    None
                }
            }
        }
    }
}

/// One streaming parse event. String payloads borrow from the lexer (and
/// from the input directly when escape-free), so consuming them before the
/// next `next()` call is copy-free.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event<'a> {
    ObjectStart,
    ObjectEnd,
    ArrayStart,
    ArrayEnd,
    /// An object key (the following event is its value).
    Key(&'a str),
    String(&'a str),
    Number(Number),
    Bool(bool),
    Null,
    /// Document complete (emitted once, after the top-level value).
    Eof,
}

/// Pull-based JSON tokenizer over raw bytes. Allocation-free in the steady
/// state: container bookkeeping lives in fixed bitsets, and the only
/// buffer (`scratch`, for strings with escapes) is recycled across calls.
///
/// ```text
/// {"docs": [[1, 2]]}  ->  ObjectStart, Key("docs"), ArrayStart,
///                         ArrayStart, Number(U64(1)), Number(U64(2)),
///                         ArrayEnd, ArrayEnd, ObjectEnd, Eof
/// ```
pub struct Lexer<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
    /// Bit set => the container at that level is an object.
    is_obj: [u64; DEPTH_WORDS],
    /// Bit set => the container at that level already emitted an element.
    has_elem: [u64; DEPTH_WORDS],
    /// A key was just emitted; the next event is its value.
    after_key: bool,
    /// The top-level value has been fully consumed.
    done: bool,
    /// Decode buffer for strings containing escapes (reused).
    scratch: String,
}

impl<'a> Lexer<'a> {
    pub fn new(bytes: &'a [u8]) -> Lexer<'a> {
        Lexer {
            bytes,
            pos: 0,
            depth: 0,
            is_obj: [0; DEPTH_WORDS],
            has_elem: [0; DEPTH_WORDS],
            after_key: false,
            done: false,
            scratch: String::new(),
        }
    }

    /// Current byte offset (for diagnostics).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Build a positioned error at the current offset (public so typed
    /// codecs layered on the lexer can report schema errors with the same
    /// line:col precision as syntax errors).
    pub fn error(&self, msg: impl Into<String>) -> ParseError {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        ParseError { line, col, msg: msg.into() }
    }

    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(self.error(msg))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            Some(x) => self.err(format!("expected '{}', found '{}'", b as char, x as char)),
            None => self.err(format!("expected '{}', found EOF", b as char)),
        }
    }

    fn push(&mut self, obj: bool) -> Result<(), ParseError> {
        if self.depth >= MAX_DEPTH {
            return self.err(format!("nesting deeper than {MAX_DEPTH} levels"));
        }
        bit_set(&mut self.is_obj, self.depth, obj);
        bit_set(&mut self.has_elem, self.depth, false);
        self.depth += 1;
        Ok(())
    }

    /// Pull the next event. After [`Event::Eof`] further calls keep
    /// returning `Eof`.
    pub fn next(&mut self) -> Result<Event<'_>, ParseError> {
        self.skip_ws();
        if self.depth == 0 {
            if self.done {
                return if self.pos == self.bytes.len() {
                    Ok(Event::Eof)
                } else {
                    self.err("trailing characters after document")
                };
            }
            self.done = true;
            return self.lex_value();
        }
        if self.after_key {
            self.after_key = false;
            self.expect(b':')?;
            self.skip_ws();
            return self.lex_value();
        }
        let top = self.depth - 1;
        let first = !bit_get(&self.has_elem, top);
        if bit_get(&self.is_obj, top) {
            if first {
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Event::ObjectEnd);
                }
            } else {
                match self.bump() {
                    Some(b',') => self.skip_ws(),
                    Some(b'}') => {
                        self.depth -= 1;
                        return Ok(Event::ObjectEnd);
                    }
                    _ => return self.err("expected ',' or '}'"),
                }
            }
            bit_set(&mut self.has_elem, top, true);
            self.after_key = true;
            let s = self.lex_string()?;
            Ok(Event::Key(s))
        } else {
            if first {
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Event::ArrayEnd);
                }
            } else {
                match self.bump() {
                    Some(b',') => self.skip_ws(),
                    Some(b']') => {
                        self.depth -= 1;
                        return Ok(Event::ArrayEnd);
                    }
                    _ => return self.err("expected ',' or ']'"),
                }
            }
            bit_set(&mut self.has_elem, top, true);
            self.lex_value()
        }
    }

    /// Consume one complete value (scalar or whole container). Call where
    /// a value is expected — e.g. right after an unrecognized [`Event::Key`]
    /// — to skip fields a typed codec does not care about.
    pub fn skip_value(&mut self) -> Result<(), ParseError> {
        let mut depth = 0usize;
        loop {
            match self.next()? {
                Event::ObjectStart | Event::ArrayStart => depth += 1,
                Event::ObjectEnd | Event::ArrayEnd => {
                    depth -= 1;
                    if depth == 0 {
                        return Ok(());
                    }
                }
                Event::Key(_) => {}
                Event::String(_) | Event::Number(_) | Event::Bool(_) | Event::Null => {
                    if depth == 0 {
                        return Ok(());
                    }
                }
                Event::Eof => return self.err("unexpected EOF"),
            }
        }
    }

    fn lex_value(&mut self) -> Result<Event<'_>, ParseError> {
        match self.peek() {
            Some(b'{') => {
                self.pos += 1;
                self.push(true)?;
                Ok(Event::ObjectStart)
            }
            Some(b'[') => {
                self.pos += 1;
                self.push(false)?;
                Ok(Event::ArrayStart)
            }
            Some(b'"') => {
                let s = self.lex_string()?;
                Ok(Event::String(s))
            }
            Some(b't') => {
                self.lex_lit("true")?;
                Ok(Event::Bool(true))
            }
            Some(b'f') => {
                self.lex_lit("false")?;
                Ok(Event::Bool(false))
            }
            Some(b'n') => {
                self.lex_lit("null")?;
                Ok(Event::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => Ok(Event::Number(self.lex_number()?)),
            Some(c) => self.err(format!("unexpected character '{}'", c as char)),
            None => self.err("unexpected EOF"),
        }
    }

    fn lex_lit(&mut self, lit: &str) -> Result<(), ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            self.err(format!("invalid literal, expected '{lit}'"))
        }
    }

    fn lex_number(&mut self) -> Result<Number, ParseError> {
        let start = self.pos;
        let neg = self.peek() == Some(b'-');
        if neg {
            self.pos += 1;
        }
        // Accumulate the integral magnitude exactly; on u64 overflow keep
        // consuming digits and fall back to the float path below.
        let mut mag = 0u64;
        let mut digits = 0usize;
        let mut overflow = false;
        while let Some(c) = self.peek() {
            if !c.is_ascii_digit() {
                break;
            }
            self.pos += 1;
            digits += 1;
            match mag.checked_mul(10).and_then(|m| m.checked_add(u64::from(c - b'0'))) {
                Some(m) => mag = m,
                None => overflow = true,
            }
        }
        let mut integral = digits > 0;
        if self.peek() == Some(b'.') {
            integral = false;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            integral = false;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if integral && !overflow {
            if !neg {
                return Ok(Number::U64(mag));
            }
            if mag <= 1u64 << 63 {
                // mag == 2^63 wraps to exactly i64::MIN, which is -2^63.
                return Ok(Number::I64((mag as i64).wrapping_neg()));
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Number::F64(n)),
            _ => self.err(format!("invalid number '{s}'")),
        }
    }

    /// Lex a string. Escape-free strings are returned as a borrow of the
    /// input (zero-copy); strings with escapes decode into the reused
    /// scratch buffer.
    fn lex_string(&mut self) -> Result<&str, ParseError> {
        self.expect(b'"')?;
        let bytes = self.bytes;
        let mut i = self.pos;
        while i < bytes.len() && bytes[i] != b'"' && bytes[i] != b'\\' && bytes[i] >= 0x20 {
            i += 1;
        }
        if i < bytes.len() && bytes[i] == b'"' {
            let raw = &bytes[self.pos..i];
            match std::str::from_utf8(raw) {
                Ok(s) => {
                    self.pos = i + 1;
                    Ok(s)
                }
                Err(_) => self.err("invalid utf-8"),
            }
        } else if i < bytes.len() && bytes[i] < 0x20 {
            self.pos = i + 1;
            self.err("control character in string")
        } else if i >= bytes.len() {
            self.pos = i;
            self.err("unterminated string")
        } else {
            // Hit a backslash: copy the clean prefix into scratch and
            // finish with the escape-decoding loop.
            let mut out = std::mem::take(&mut self.scratch);
            out.clear();
            match std::str::from_utf8(&bytes[self.pos..i]) {
                Ok(s) => out.push_str(s),
                Err(_) => {
                    self.scratch = out;
                    return self.err("invalid utf-8");
                }
            }
            self.pos = i;
            let r = self.string_tail(&mut out);
            self.scratch = out;
            r?;
            Ok(&self.scratch)
        }
    }

    /// Decode the remainder of a string (starting at an escape) into `out`,
    /// consuming the closing quote.
    fn string_tail(&mut self, out: &mut String) -> Result<(), ParseError> {
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(()),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.parse_hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("lone high surrogate");
                            }
                            let lo = self.parse_hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(cp) {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("control character in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let end = self.pos - 1 + len;
                        if end > self.bytes.len() {
                            return self.err("truncated utf-8");
                        }
                        match std::str::from_utf8(&self.bytes[self.pos - 1..end]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = end;
                            }
                            Err(_) => return self.err("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("truncated \\u escape"),
            };
            let d = match c {
                b'0'..=b'9' => u32::from(c - b'0'),
                b'a'..=b'f' => u32::from(c - b'a') + 10,
                b'A'..=b'F' => u32::from(c - b'A') + 10,
                _ => return self.err("invalid hex digit"),
            };
            v = (v << 4) | d;
        }
        Ok(v)
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// First event of a value, pre-classified so the array loop can tell "next
/// element" apart from "container closed" with a single `next()` call.
enum First {
    Obj,
    Arr,
    Num(Number),
    Val(Value),
    End,
}

fn classify(lex: &mut Lexer<'_>) -> Result<First, ParseError> {
    Ok(match lex.next()? {
        Event::ObjectStart => First::Obj,
        Event::ArrayStart => First::Arr,
        Event::ObjectEnd | Event::ArrayEnd => First::End,
        Event::String(s) => First::Val(Value::String(s.to_string())),
        Event::Number(n) => First::Num(n),
        Event::Bool(b) => First::Val(Value::Bool(b)),
        Event::Null => First::Val(Value::Null),
        // The lexer never yields these where a value can start.
        Event::Key(_) | Event::Eof => return Err(lex.error("unexpected token")),
    })
}

fn build_from(lex: &mut Lexer<'_>, first: First) -> Result<Value, ParseError> {
    match first {
        First::Val(v) => Ok(v),
        First::Num(n) => match n.as_exact_f64() {
            Some(f) => Ok(Value::Number(f)),
            // Refuse to round: callers that need full 64-bit integers
            // (e.g. RNG seeds) go through the streaming layer instead.
            None => Err(lex.error("integer literal not exactly representable as f64 (|n| > 2^53)")),
        },
        First::End => Err(lex.error("unexpected token")),
        First::Obj => {
            let mut map = BTreeMap::new();
            loop {
                let key = match lex.next()? {
                    Event::ObjectEnd => return Ok(Value::Object(map)),
                    Event::Key(k) => k.to_string(),
                    _ => return Err(lex.error("unexpected token in object")),
                };
                let f = classify(lex)?;
                if matches!(f, First::End) {
                    return Err(lex.error("unexpected token in object"));
                }
                let val = build_from(lex, f)?;
                map.insert(key, val);
            }
        }
        First::Arr => {
            let mut items = Vec::new();
            loop {
                match classify(lex)? {
                    First::End => return Ok(Value::Array(items)),
                    f => items.push(build_from(lex, f)?),
                }
            }
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed). Built on
/// the streaming [`Lexer`], so both layers share one tokenizer.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut lex = Lexer::new(input.as_bytes());
    let first = classify(&mut lex)?;
    let v = build_from(&mut lex, first)?;
    match lex.next()? {
        Event::Eof => Ok(v),
        _ => Err(lex.error("trailing characters after document")),
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_number(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

/// Streaming serializer into a reusable buffer. The serve hot path keeps
/// one per connection: `clear()` retains capacity, so a warmed writer
/// renders a response with zero heap allocations. Output bytes are
/// identical to [`to_string`] of the equivalent tree (same number and
/// string formatting) — emit object keys in sorted order to match the
/// `BTreeMap` iteration order of the tree path bit-for-bit.
pub struct JsonWriter {
    buf: String,
    depth: usize,
    has_elem: [u64; DEPTH_WORDS],
    pending_key: bool,
}

impl Default for JsonWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonWriter {
    pub fn new() -> JsonWriter {
        JsonWriter { buf: String::new(), depth: 0, has_elem: [0; DEPTH_WORDS], pending_key: false }
    }

    pub fn with_capacity(n: usize) -> JsonWriter {
        JsonWriter {
            buf: String::with_capacity(n),
            depth: 0,
            has_elem: [0; DEPTH_WORDS],
            pending_key: false,
        }
    }

    /// Reset for the next document, keeping the buffer's capacity.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.depth = 0;
        self.has_elem = [0; DEPTH_WORDS];
        self.pending_key = false;
    }

    pub fn as_str(&self) -> &str {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_string(self) -> String {
        self.buf
    }

    /// Comma/`:` bookkeeping shared by every emitter.
    fn sep(&mut self) {
        if self.pending_key {
            self.pending_key = false;
            return;
        }
        if self.depth > 0 {
            if bit_get(&self.has_elem, self.depth - 1) {
                self.buf.push(',');
            }
            bit_set(&mut self.has_elem, self.depth - 1, true);
        }
    }

    fn push_level(&mut self) {
        assert!(self.depth < MAX_DEPTH, "JsonWriter nesting deeper than {MAX_DEPTH}");
        bit_set(&mut self.has_elem, self.depth, false);
        self.depth += 1;
    }

    pub fn begin_object(&mut self) {
        self.sep();
        self.push_level();
        self.buf.push('{');
    }

    pub fn end_object(&mut self) {
        debug_assert!(self.depth > 0, "end_object at depth 0");
        self.depth -= 1;
        self.buf.push('}');
    }

    pub fn begin_array(&mut self) {
        self.sep();
        self.push_level();
        self.buf.push('[');
    }

    pub fn end_array(&mut self) {
        debug_assert!(self.depth > 0, "end_array at depth 0");
        self.depth -= 1;
        self.buf.push(']');
    }

    pub fn key(&mut self, k: &str) {
        self.sep();
        escape_into(k, &mut self.buf);
        self.buf.push(':');
        self.pending_key = true;
    }

    pub fn string(&mut self, s: &str) {
        self.sep();
        escape_into(s, &mut self.buf);
    }

    pub fn number_f64(&mut self, n: f64) {
        self.sep();
        write_number(n, &mut self.buf);
    }

    pub fn number_u64(&mut self, n: u64) {
        self.sep();
        let _ = write!(self.buf, "{n}");
    }

    pub fn boolean(&mut self, b: bool) {
        self.sep();
        self.buf.push_str(if b { "true" } else { "false" });
    }

    pub fn null(&mut self) {
        self.sep();
        self.buf.push_str("null");
    }
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => escape_into(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (level + 1)));
                }
                write_value(item, out, indent, level + 1);
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * level));
            }
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (level + 1)));
                }
                escape_into(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, level + 1);
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * level));
            }
            out.push('}');
        }
    }
}

/// Compact serialization.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Pretty serialization (2-space indent).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"naïve — ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "naïve — ☃");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\u12\"").is_err());
    }

    #[test]
    fn error_positions() {
        let e = parse("{\n  \"a\": x\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col >= 8, "col={}", e.col);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":-7}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(to_string(&Value::Number(42.0)), "42");
        assert_eq!(to_string(&Value::Number(2.5)), "2.5");
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "x": 1.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("x").unwrap().as_usize(), None);
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "version": 1, "row_bucket": 4096, "topic_buckets": [8, 16],
          "functions": [{"name": "gram_T8", "file": "gram_T8.hlo.txt",
                         "params": [{"shape": [4096, 8], "dtype": "float32"}]}]
        }"#;
        let v = parse(src).unwrap();
        let fns = v.get("functions").unwrap().as_array().unwrap();
        assert_eq!(fns[0].get("name").unwrap().as_str(), Some("gram_T8"));
        let shape = fns[0].get("params").unwrap().as_array().unwrap()[0]
            .get("shape").unwrap().as_array().unwrap();
        assert_eq!(shape[0].as_usize(), Some(4096));
    }

    // ---- streaming layer -------------------------------------------------

    #[test]
    fn lexer_event_stream() {
        let mut lex = Lexer::new(br#"{"docs": [[1, 2]], "s": "a\nb", "t": true}"#);
        assert_eq!(lex.next().unwrap(), Event::ObjectStart);
        assert_eq!(lex.next().unwrap(), Event::Key("docs"));
        assert_eq!(lex.next().unwrap(), Event::ArrayStart);
        assert_eq!(lex.next().unwrap(), Event::ArrayStart);
        assert_eq!(lex.next().unwrap(), Event::Number(Number::U64(1)));
        assert_eq!(lex.next().unwrap(), Event::Number(Number::U64(2)));
        assert_eq!(lex.next().unwrap(), Event::ArrayEnd);
        assert_eq!(lex.next().unwrap(), Event::ArrayEnd);
        assert_eq!(lex.next().unwrap(), Event::Key("s"));
        assert_eq!(lex.next().unwrap(), Event::String("a\nb"));
        assert_eq!(lex.next().unwrap(), Event::Key("t"));
        assert_eq!(lex.next().unwrap(), Event::Bool(true));
        assert_eq!(lex.next().unwrap(), Event::ObjectEnd);
        assert_eq!(lex.next().unwrap(), Event::Eof);
        assert_eq!(lex.next().unwrap(), Event::Eof);
    }

    #[test]
    fn lexer_numbers_exact() {
        let mut lex = Lexer::new(
            b"[18446744073709551615, -9223372036854775808, 9007199254740993, 2.5, 1e3]",
        );
        assert_eq!(lex.next().unwrap(), Event::ArrayStart);
        assert_eq!(lex.next().unwrap(), Event::Number(Number::U64(u64::MAX)));
        assert_eq!(lex.next().unwrap(), Event::Number(Number::I64(i64::MIN)));
        // 2^53 + 1: exact as u64, not representable as f64.
        let n = match lex.next().unwrap() {
            Event::Number(n) => n,
            e => panic!("{e:?}"),
        };
        assert_eq!(n, Number::U64(9007199254740993));
        assert_eq!(n.as_exact_f64(), None);
        assert_eq!(n.as_u64_exact(), Some(9007199254740993));
        assert_eq!(lex.next().unwrap(), Event::Number(Number::F64(2.5)));
        assert_eq!(lex.next().unwrap(), Event::Number(Number::F64(1e3)));
        assert_eq!(lex.next().unwrap(), Event::ArrayEnd);
        assert_eq!(lex.next().unwrap(), Event::Eof);
    }

    #[test]
    fn number_accessors_are_exact() {
        assert_eq!(Number::U64(u64::MAX).as_u64_exact(), Some(u64::MAX));
        assert_eq!(Number::U64(u64::MAX).as_exact_f64(), None);
        assert_eq!(Number::U64(1u64 << 53).as_exact_f64(), Some(9007199254740992.0));
        assert_eq!(Number::I64(-1).as_u64_exact(), None);
        assert_eq!(Number::F64(7.0).as_u64_exact(), Some(7));
        assert_eq!(Number::F64(7.5).as_u64_exact(), None);
        assert_eq!(Number::F64(-1.0).as_u64_exact(), None);
        assert_eq!(Number::U64(7).as_u32_exact(), Some(7));
        assert_eq!(Number::U64(u64::from(u32::MAX) + 1).as_u32_exact(), None);
        assert_eq!(Number::F64(1e2).as_u32_exact(), Some(100));
        assert_eq!(Number::I64(-3).as_u32_exact(), None);
    }

    #[test]
    fn tree_rejects_imprecise_integers() {
        // 2^53 is the last exactly-representable power; +1 must be refused,
        // not rounded (it used to come back as 9007199254740992.0).
        assert!(parse("9007199254740992").is_ok());
        assert!(parse("9007199254740993").is_err());
        assert!(parse("18446744073709551615").is_err());
        assert!(parse(r#"{"seed": 18446744073709551615}"#).is_err());
        // Floats keep their usual lossy semantics.
        assert_eq!(parse("1e300").unwrap(), Value::Number(1e300));
    }

    #[test]
    fn as_i64_no_longer_saturates() {
        assert_eq!(parse("1e300").unwrap().as_i64(), None);
        assert_eq!(parse("-42").unwrap().as_i64(), Some(-42));
        assert_eq!(parse("1e300").unwrap().as_u64(), None);
    }

    #[test]
    fn nesting_bombs_are_rejected_not_overflowed() {
        let deep_ok = format!("{}0{}", "[".repeat(100), "]".repeat(100));
        assert!(parse(&deep_ok).is_ok());
        let bomb = "[".repeat(100_000);
        let e = parse(&bomb).unwrap_err();
        assert!(e.msg.contains("nesting"), "{e}");
        let obj_bomb = r#"{"a":"#.repeat(10_000) + "1";
        assert!(parse(&obj_bomb).is_err());
    }

    #[test]
    fn skip_value_consumes_whole_subtree() {
        let mut lex = Lexer::new(br#"{"skip": {"a": [1, {"b": 2}], "c": "x"}, "keep": 7}"#);
        assert_eq!(lex.next().unwrap(), Event::ObjectStart);
        assert_eq!(lex.next().unwrap(), Event::Key("skip"));
        lex.skip_value().unwrap();
        assert_eq!(lex.next().unwrap(), Event::Key("keep"));
        assert_eq!(lex.next().unwrap(), Event::Number(Number::U64(7)));
        assert_eq!(lex.next().unwrap(), Event::ObjectEnd);
        assert_eq!(lex.next().unwrap(), Event::Eof);
    }

    #[test]
    fn lexer_borrowed_vs_decoded_strings() {
        // Escape-free: borrowed straight from the input slice.
        let input = br#""plain utf8: naive""#;
        let mut lex = Lexer::new(input);
        match lex.next().unwrap() {
            Event::String(s) => {
                let inside = &input[1..input.len() - 1];
                assert!(std::ptr::eq(s.as_bytes().as_ptr(), inside.as_ptr()));
            }
            e => panic!("{e:?}"),
        }
        // Escaped (incl. surrogate pair): decoded into scratch.
        let mut lex = Lexer::new(br#""pre\u0041post \ud83d\ude00""#);
        match lex.next().unwrap() {
            Event::String(s) => assert_eq!(s, "preApost 😀"),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn writer_matches_tree_serialization() {
        let v = parse(r#"{"a":[1,2.5,true,null,"s\n"],"b":{"k":-7},"z":"🦀"}"#).unwrap();
        let mut w = JsonWriter::new();
        w.begin_object();
        w.key("a");
        w.begin_array();
        w.number_f64(1.0);
        w.number_f64(2.5);
        w.boolean(true);
        w.null();
        w.string("s\n");
        w.end_array();
        w.key("b");
        w.begin_object();
        w.key("k");
        w.number_f64(-7.0);
        w.end_object();
        w.key("z");
        w.string("🦀");
        w.end_object();
        assert_eq!(w.as_str(), to_string(&v));
    }

    #[test]
    fn writer_reuse_and_empty_containers() {
        let mut w = JsonWriter::with_capacity(64);
        w.begin_array();
        w.end_array();
        assert_eq!(w.as_str(), "[]");
        w.clear();
        w.begin_object();
        w.key("u");
        w.number_u64(u64::MAX);
        w.key("e");
        w.begin_object();
        w.end_object();
        w.end_object();
        assert_eq!(w.as_str(), r#"{"u":18446744073709551615,"e":{}}"#);
    }
}
