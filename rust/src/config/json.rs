//! JSON parsing and serialization (RFC 8259 subset, no serde offline).
//!
//! Supports the full JSON value model with:
//! * numbers parsed as f64 (integers round-trip exactly up to 2^53, which
//!   covers every count this project serializes),
//! * `\uXXXX` escapes incl. surrogate pairs,
//! * precise error positions (line:col) for config debugging,
//! * pretty and compact serialization.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        if let Value::Bool(b) = self { Some(*b) } else { None }
    }

    pub fn as_f64(&self) -> Option<f64> {
        if let Value::Number(n) = self { Some(*n) } else { None }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53) { Some(n as usize) } else { None }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        if let Value::String(s) = self { Some(s) } else { None }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        if let Value::Array(a) = self { Some(a) } else { None }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        if let Value::Object(o) = self { Some(o) } else { None }
    }

    /// Object field access; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Build an object from pairs (convenience for serialization sites).
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x)).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x as f64)).collect())
    }
}

/// Parse error with position info.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Err(ParseError { line, col, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            Some(x) => self.err(format!("expected '{}', found '{}'", b as char, x as char)),
            None => self.err(format!("expected '{}', found EOF", b as char)),
        }
    }

    fn parse_value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_object(),
            Some(b'[') => self.parse_array(),
            Some(b'"') => Ok(Value::String(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Value::Bool(true)),
            Some(b'f') => self.parse_lit("false", Value::Bool(false)),
            Some(b'n') => self.parse_lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_number(),
            Some(c) => self.err(format!("unexpected character '{}'", c as char)),
            None => self.err("unexpected EOF"),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("invalid literal, expected '{lit}'"))
        }
    }

    fn parse_number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Value::Number(n)),
            _ => self.err(format!("invalid number '{s}'")),
        }
    }

    fn parse_string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.parse_hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("lone high surrogate");
                            }
                            let lo = self.parse_hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(cp) {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("control character in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let end = self.pos - 1 + len;
                        if end > self.bytes.len() {
                            return self.err("truncated utf-8");
                        }
                        match std::str::from_utf8(&self.bytes[self.pos - 1..end]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = end;
                            }
                            Err(_) => return self.err("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("truncated \\u escape"),
            };
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a') as u32 + 10,
                b'A'..=b'F' => (c - b'A') as u32 + 10,
                _ => return self.err("invalid hex digit"),
            };
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn parse_array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn parse_object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing characters after document");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_number(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => escape_into(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (level + 1)));
                }
                write_value(item, out, indent, level + 1);
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * level));
            }
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (level + 1)));
                }
                escape_into(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, level + 1);
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * level));
            }
            out.push('}');
        }
    }
}

/// Compact serialization.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Pretty serialization (2-space indent).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"naïve — ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "naïve — ☃");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\u12\"").is_err());
    }

    #[test]
    fn error_positions() {
        let e = parse("{\n  \"a\": x\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col >= 8, "col={}", e.col);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":-7}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(to_string(&Value::Number(42.0)), "42");
        assert_eq!(to_string(&Value::Number(2.5)), "2.5");
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "x": 1.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("x").unwrap().as_usize(), None);
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "version": 1, "row_bucket": 4096, "topic_buckets": [8, 16],
          "functions": [{"name": "gram_T8", "file": "gram_T8.hlo.txt",
                         "params": [{"shape": [4096, 8], "dtype": "float32"}]}]
        }"#;
        let v = parse(src).unwrap();
        let fns = v.get("functions").unwrap().as_array().unwrap();
        assert_eq!(fns[0].get("name").unwrap().as_str(), Some("gram_T8"));
        let shape = fns[0].get("params").unwrap().as_array().unwrap()[0]
            .get("shape").unwrap().as_array().unwrap();
        assert_eq!(shape[0].as_usize(), Some(4096));
    }
}
