//! Fig 5 reproduction: the histogram of the document label (earnings per
//! share in the paper) and its approximate normality — the premise behind
//! sLDA's Gaussian response assumption.

use crate::data::stats::{label_report, LabelReport};
use crate::data::synthetic::{generate_corpus, SyntheticSpec};
use crate::util::rng::Pcg64;

/// Generate the Experiment-I-scale corpus and report its label distribution.
pub fn fig5_labels(spec: &SyntheticSpec, bins: usize, seed: u64) -> LabelReport {
    let mut rng = Pcg64::seed_from_u64(seed);
    let corpus = generate_corpus(spec, &mut rng);
    label_report(&corpus, bins)
}

/// Render with the paper's framing attached.
pub fn render(report: &LabelReport, spec: &SyntheticSpec) -> String {
    let mut s = report.render(&format!(
        "Fig 5: label histogram, {} documents (EPS-like synthetic)",
        spec.docs
    ));
    s.push_str(&format!(
        "normality verdict: KS={:.4} |skew|={:.3} |ex.kurt|={:.3} -> {}\n",
        report.ks_normal,
        report.skewness.abs(),
        report.kurtosis.abs(),
        if report.ks_normal < 0.05 && report.skewness.abs() < 0.5 {
            "close to normal (supports the sLDA Gaussian response assumption)"
        } else {
            "deviates from normal"
        }
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_full_scale_labels_near_normal() {
        let spec = SyntheticSpec::mdna();
        let r = fig5_labels(&spec, 40, 20170710);
        assert_eq!(r.summary.n, spec.docs);
        assert!(r.ks_normal < 0.06, "ks={}", r.ks_normal);
        let text = render(&r, &spec);
        assert!(text.contains("close to normal"), "{text}");
    }
}
