//! The shared four-algorithm comparison harness (paper Figs. 6 and 7).
//!
//! One [`Comparison`] = one corpus + one config + R repeated runs of each
//! algorithm (the paper averages 100 runs; each run re-seeds the partition
//! and the samplers, corpus held fixed — matching the paper's protocol of
//! re-dividing the training set per run). Output is the figure's content as
//! a table: computation time and test MSE (Fig 6) / accuracy (Fig 7) per
//! algorithm, plus the extras the paper discusses in prose: phase
//! breakdowns, speedups vs Non-parallel, and communication volume.

use crate::config::schema::ExperimentConfig;
#[cfg(test)]
use crate::config::schema::ResponseKind;
use crate::data::corpus::Dataset;
use crate::data::partition::train_test_split;
use crate::data::synthetic::{generate_corpus, SyntheticSpec};
use crate::parallel::comm::CommStats;
use crate::parallel::leader::{
    has_checkpoint, run_with_engine_ckpt, Algorithm, CkptPlan, RunOutcome,
};
use crate::runtime::EngineHandle;
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;
use crate::util::timer::PhaseTimings;

/// Configuration of one comparison experiment.
#[derive(Clone, Debug)]
pub struct Comparison {
    /// Corpus spec (generated once per comparison).
    pub spec: SyntheticSpec,
    /// Training documents (rest become the test set) — paper: 3000/1216
    /// (Exp I), 20000/5000 (Exp II).
    pub n_train: usize,
    pub cfg: ExperimentConfig,
    /// Repeated runs per algorithm (paper: 100).
    pub runs: usize,
    pub algorithms: Vec<Algorithm>,
}

impl Comparison {
    /// Paper Experiment I shape, scaled by `scale` in (0, 1] for quick runs.
    pub fn fig6(scale: f64, runs: usize) -> Self {
        let mut spec = SyntheticSpec::mdna();
        spec.docs = ((spec.docs as f64 * scale) as usize).max(40);
        spec.vocab = ((spec.vocab as f64 * scale) as usize).max(60);
        let n_train = spec.docs * 3000 / 4216;
        let mut cfg = ExperimentConfig::fig6();
        cfg.model.topics = 16;
        Comparison { spec, n_train, cfg, runs, algorithms: Algorithm::ALL.to_vec() }
    }

    /// Paper Experiment II shape, scaled.
    pub fn fig7(scale: f64, runs: usize) -> Self {
        let mut spec = SyntheticSpec::imdb();
        spec.docs = ((spec.docs as f64 * scale) as usize).max(40);
        spec.vocab = ((spec.vocab as f64 * scale) as usize).max(60);
        let n_train = spec.docs * 20_000 / 25_000;
        let mut cfg = ExperimentConfig::fig7();
        cfg.model.topics = 16;
        Comparison { spec, n_train, cfg, runs, algorithms: Algorithm::ALL.to_vec() }
    }
}

/// Aggregated series for one algorithm across runs.
#[derive(Clone, Debug)]
pub struct AlgoSeries {
    pub algorithm: Algorithm,
    /// Real wall clock on this machine (1 core in the benchmark container).
    pub wall: Summary,
    /// Simulated M-core wall time (the paper's machine model; DESIGN.md §3).
    pub sim_wall: Summary,
    pub mse: Summary,
    pub acc: Summary,
    pub r2: Summary,
    /// Last run's phase breakdown (representative).
    pub timings: PhaseTimings,
    /// Last run's communication stats.
    pub comm: CommStats,
}

/// Checkpoint/resume controls for [`run_comparison_ckpt`], applied to each
/// (algorithm, run) leg. Every leg checkpoints under its own
/// `<algorithm>-seed<seed>` store, so an interrupted comparison resumes the
/// in-flight leg from its newest committed generation while legs that never
/// persisted state start fresh.
pub struct ComparisonCkpt<'p> {
    pub resume: bool,
    pub stop: Option<&'p std::sync::atomic::AtomicBool>,
}

/// Result of a checkpoint-aware comparison.
pub enum ComparisonRun {
    Done(Box<(Vec<AlgoSeries>, Dataset)>),
    /// Stopped cleanly at a checkpoint boundary inside one leg. Rerunning
    /// the same command with `--resume` replays completed legs from their
    /// retained final checkpoints (byte-identical, near-free) and continues
    /// this one where it stopped.
    Interrupted { algorithm: Algorithm, run: usize, next_sweep: u64 },
}

/// Run the full comparison. Returns one series per algorithm, in input
/// order, plus the dataset actually used (for downstream diagnostics).
pub fn run_comparison(
    c: &Comparison,
    engine: &EngineHandle,
) -> anyhow::Result<(Vec<AlgoSeries>, Dataset)> {
    match run_comparison_ckpt(c, engine, None)? {
        ComparisonRun::Done(both) => Ok(*both),
        // unreachable: without a plan there is no stop flag to interrupt on
        ComparisonRun::Interrupted { .. } => {
            anyhow::bail!("comparison interrupted without a checkpoint plan")
        }
    }
}

/// [`run_comparison`] with checkpoint/resume plumbing (see
/// [`ComparisonCkpt`]).
pub fn run_comparison_ckpt(
    c: &Comparison,
    engine: &EngineHandle,
    ckpt: Option<ComparisonCkpt<'_>>,
) -> anyhow::Result<ComparisonRun> {
    let mut corpus_rng = Pcg64::seed_from_u64(c.cfg.seed ^ 0xC0FFEE);
    let corpus = generate_corpus(&c.spec, &mut corpus_rng);
    let ds = train_test_split(&corpus, c.n_train, &mut corpus_rng);

    let mut series = Vec::new();
    for &algo in &c.algorithms {
        let mut wall = Summary::new();
        let mut sim_wall = Summary::new();
        let mut mse = Summary::new();
        let mut acc = Summary::new();
        let mut r2 = Summary::new();
        let mut timings = PhaseTimings::new();
        let mut comm = CommStats::default();
        for run in 0..c.runs {
            let mut cfg = c.cfg.clone();
            cfg.seed = c.cfg.seed.wrapping_add(run as u64 * 7919);
            let plan = ckpt.as_ref().map(|p| CkptPlan {
                resume: p.resume && has_checkpoint(&cfg, algo),
                stop: p.stop,
            });
            let (out, _) = match run_with_engine_ckpt(algo, &ds, &cfg, engine, false, plan)? {
                RunOutcome::Done(both) => *both,
                RunOutcome::Interrupted { next_sweep } => {
                    return Ok(ComparisonRun::Interrupted { algorithm: algo, run, next_sweep });
                }
            };
            wall.push(out.wall_secs);
            sim_wall.push(out.sim_wall_secs);
            mse.push(out.test_metrics.mse);
            acc.push(out.test_metrics.acc);
            r2.push(out.test_metrics.r2);
            timings = out.timings;
            comm = out.comm;
            log::debug!(
                "{} run {run}: wall={:.2}s mse={:.4} acc={:.4}",
                algo.name(),
                out.wall_secs,
                out.test_metrics.mse,
                out.test_metrics.acc
            );
        }
        series.push(AlgoSeries { algorithm: algo, wall, sim_wall, mse, acc, r2, timings, comm });
    }
    Ok(ComparisonRun::Done(Box::new((series, ds))))
}

/// Render the figure table. `binary` selects accuracy (Fig 7) vs MSE (Fig 6).
pub fn render_table(title: &str, series: &[AlgoSeries], binary: bool) -> String {
    let mut out = String::new();
    out.push_str(&format!("=== {title} ===\n"));
    let base_wall = series
        .iter()
        .find(|s| s.algorithm == Algorithm::NonParallel)
        .map(|s| s.sim_wall.mean())
        .unwrap_or(f64::NAN);
    let quality_hdr = if binary { "accuracy" } else { "test-MSE" };
    out.push_str(&format!(
        "{:<20} {:>9} {:>8} {:>9} {:>10} {:>8} {:>8} {:>10}\n",
        "algorithm", "time(s)", "±sd", "wall1c(s)", quality_hdr, "±sd", "speedup", "comm(MB)"
    ));
    for s in series {
        let quality = if binary { &s.acc } else { &s.mse };
        out.push_str(&format!(
            "{:<20} {:>9.3} {:>8.3} {:>9.3} {:>10.4} {:>8.4} {:>7.2}x {:>10.2}\n",
            s.algorithm.name(),
            s.sim_wall.mean(),
            s.sim_wall.std(),
            s.wall.mean(),
            quality.mean(),
            quality.std(),
            base_wall / s.sim_wall.mean(),
            s.comm.total() as f64 / 1e6,
        ));
    }
    out.push_str(
        "time(s) = simulated M-core machine time (max worker CPU + serial phases, \
         DESIGN.md §3); wall1c(s) = real wall on this 1-core container\n",
    );
    out.push_str("phase breakdown (last run, worker-CPU seconds):\n");
    for s in series {
        out.push_str(&format!("  {:<20} {}\n", s.algorithm.name(), s.timings.render()));
    }
    out
}

/// Sanity assertions on the *shape* of the paper's result (who wins, in
/// which direction) — used by the integration tests and the benches'
/// self-check mode. Tolerant: shape, not absolute numbers.
pub fn check_fig_shape(series: &[AlgoSeries], binary: bool) -> anyhow::Result<()> {
    let get = |a: Algorithm| {
        series
            .iter()
            .find(|s| s.algorithm == a)
            .ok_or_else(|| anyhow::anyhow!("missing series for {}", a.name()))
    };
    let nonp = get(Algorithm::NonParallel)?;
    let naive = get(Algorithm::NaiveCombination)?;
    let simple = get(Algorithm::SimpleAverage)?;
    let weighted = get(Algorithm::WeightedAverage)?;

    // Quality: Naive must be clearly worse; Simple/Weighted comparable to
    // NonParallel (paper allows them to be even slightly better).
    if binary {
        anyhow::ensure!(
            naive.acc.mean() < simple.acc.mean(),
            "naive accuracy {} should trail simple {}",
            naive.acc.mean(),
            simple.acc.mean()
        );
        anyhow::ensure!(
            simple.acc.mean() > 0.9 * nonp.acc.mean(),
            "simple accuracy {} too far below non-parallel {}",
            simple.acc.mean(),
            nonp.acc.mean()
        );
    } else {
        anyhow::ensure!(
            naive.mse.mean() > simple.mse.mean(),
            "naive mse {} should exceed simple {}",
            naive.mse.mean(),
            simple.mse.mean()
        );
        anyhow::ensure!(
            simple.mse.mean() < 1.5 * nonp.mse.mean(),
            "simple mse {} too far above non-parallel {}",
            simple.mse.mean(),
            nonp.mse.mean()
        );
    }
    // Speed: parallel training algorithms beat NonParallel; Weighted pays
    // the full-train prediction penalty and is the slowest of the three
    // parallel arms (paper: even slower than NonParallel on large corpora).
    anyhow::ensure!(
        naive.sim_wall.mean() < nonp.sim_wall.mean(),
        "naive ({:.3}s) should be faster than non-parallel ({:.3}s)",
        naive.sim_wall.mean(),
        nonp.sim_wall.mean()
    );
    anyhow::ensure!(
        simple.sim_wall.mean() < nonp.sim_wall.mean(),
        "simple ({:.3}s) should be faster than non-parallel ({:.3}s)",
        simple.sim_wall.mean(),
        nonp.sim_wall.mean()
    );
    anyhow::ensure!(
        weighted.sim_wall.mean() > simple.sim_wall.mean(),
        "weighted ({:.3}s) should be slower than simple ({:.3}s)",
        weighted.sim_wall.mean(),
        simple.sim_wall.mean()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_comparison() -> Comparison {
        let mut c = Comparison::fig6(0.06, 1); // ~250 docs
        c.cfg.engine = crate::config::schema::EngineKind::Native;
        c.cfg.model.topics = 8;
        c.cfg.train.sweeps = 12;
        c.cfg.train.burnin = 3;
        c.cfg.train.eta_every = 3;
        c.cfg.train.predict_sweeps = 6;
        c.cfg.train.predict_burnin = 2;
        c
    }

    #[test]
    fn comparison_produces_series_and_table() {
        let c = tiny_comparison();
        let engine = EngineHandle::native();
        let (series, ds) = run_comparison(&c, &engine).unwrap();
        assert_eq!(series.len(), 4);
        assert_eq!(ds.train.num_docs(), c.n_train);
        let table = render_table("Fig 6 (tiny)", &series, false);
        assert!(table.contains("non-parallel"));
        assert!(table.contains("test-MSE"));
        assert!(table.contains("speedup"));
        for s in &series {
            assert_eq!(s.wall.n, 1);
            assert!(s.wall.mean() > 0.0);
            assert!(s.mse.mean().is_finite());
        }
    }

    #[test]
    fn fig7_preset_is_binary() {
        let c = Comparison::fig7(0.01, 1);
        assert_eq!(c.cfg.response, ResponseKind::Binary);
        assert!(c.n_train < c.spec.docs);
    }
}
