//! Measured reproductions of the paper's illustrative Figures 1-3.
//!
//! The paper's figures are schematics; we reproduce their *content* as
//! measurable experiments:
//!
//! * **Fig 1** (unimodal works): a 1-D Gaussian posterior sampled by three
//!   independent Metropolis chains; pooling the sub-samples matches the
//!   true posterior (small KS distance).
//! * **Fig 2** (multimodal fails): a 3-mode Gaussian-mixture posterior;
//!   short-stepping chains started in different basins never hop modes
//!   (quasi-ergodicity), and pooling chains stuck in the *wrong mix* of
//!   modes misrepresents the posterior (large KS distance).
//! * **Fig 3** (prediction projection fixes sLDA): train M sLDA shards;
//!   their topic-word posteriors disagree under the identity labeling but
//!   agree after Hungarian alignment (large permutation gap = different
//!   modes of the permutation-symmetric posterior), while their test
//!   *predictions* — the 1-D projection — agree closely.

use crate::config::schema::ExperimentConfig;
use crate::data::corpus::Dataset;
use crate::eval::mode_diag::{mode_divergence, ModeDivergence};
use crate::parallel::leader::{run_with_engine, Algorithm};
use crate::runtime::EngineHandle;
use crate::util::math::normal_logpdf;
use crate::util::rng::Pcg64;
use crate::util::stats::{ks_two_sample, Summary};

/// Random-walk Metropolis chain over a 1-D log-density.
pub fn mh_chain(
    logpdf: impl Fn(f64) -> f64,
    x0: f64,
    step: f64,
    n: usize,
    burnin: usize,
    rng: &mut Pcg64,
) -> Vec<f64> {
    let mut x = x0;
    let mut lp = logpdf(x);
    let mut out = Vec::with_capacity(n);
    for i in 0..n + burnin {
        let prop = x + step * rng.next_gaussian();
        let lp_prop = logpdf(prop);
        if lp_prop - lp >= 0.0 || rng.next_f64() < (lp_prop - lp).exp() {
            x = prop;
            lp = lp_prop;
        }
        if i >= burnin {
            out.push(x);
        }
    }
    out
}

/// Log-density of an equal-weight Gaussian mixture.
pub fn mixture_logpdf(x: f64, means: &[f64], var: f64) -> f64 {
    let terms: Vec<f64> =
        means.iter().map(|&m| normal_logpdf(x, m, var) - (means.len() as f64).ln()).collect();
    crate::util::math::logsumexp(&terms)
}

/// Result of the Fig-1 / Fig-2 pooling demos.
#[derive(Clone, Debug)]
pub struct PoolingDemo {
    /// KS distance between pooled sub-chain samples and an iid reference.
    pub ks_pooled: f64,
    /// Mean KS distance of each individual chain vs the reference.
    pub ks_single_mean: f64,
    /// Fraction of pooled samples in each mode basin (diagnostic).
    pub basin_mass: Vec<f64>,
}

/// Fig 1: unimodal posterior, M chains, pooling is valid.
pub fn fig1_unimodal(chains: usize, n_per_chain: usize, seed: u64) -> PoolingDemo {
    let mut rng = Pcg64::seed_from_u64(seed);
    let logpdf = |x: f64| normal_logpdf(x, 0.0, 1.0);
    let mut pooled = Vec::new();
    let mut ks_single = Summary::new();
    let reference: Vec<f64> = (0..chains * n_per_chain).map(|_| rng.next_gaussian()).collect();
    for c in 0..chains {
        let mut crng = rng.split(c as u64);
        let xs = mh_chain(logpdf, 0.0, 1.0, n_per_chain, 500, &mut crng);
        ks_single.push(ks_two_sample(&xs, &reference));
        pooled.extend(xs);
    }
    PoolingDemo {
        ks_pooled: ks_two_sample(&pooled, &reference),
        ks_single_mean: ks_single.mean(),
        basin_mass: vec![1.0],
    }
}

/// Fig 2: 3-mode posterior; chains get stuck in their starting basin and a
/// lopsided start assignment (2 left, 1 right, middle mode unvisited) makes
/// the pooled sample badly misrepresent the posterior.
pub fn fig2_multimodal(n_per_chain: usize, seed: u64) -> PoolingDemo {
    let mut rng = Pcg64::seed_from_u64(seed);
    let means = [-4.0, 0.0, 4.0];
    let var = 0.09; // well-separated basins; RW step too small to hop
    let logpdf = |x: f64| mixture_logpdf(x, &means, var);
    // iid reference by exact mixture sampling
    let reference: Vec<f64> = (0..3 * n_per_chain)
        .map(|_| {
            let k = rng.gen_range(3);
            means[k] + var.sqrt() * rng.next_gaussian()
        })
        .collect();
    // the paper's Fig-2 situation: two machines in the leftmost mode, one in
    // the rightmost, middle mode unexplored.
    let starts = [-4.0, -4.0, 4.0];
    let mut pooled = Vec::new();
    let mut ks_single = Summary::new();
    for (c, &x0) in starts.iter().enumerate() {
        let mut crng = rng.split(c as u64);
        let xs = mh_chain(logpdf, x0, 0.3, n_per_chain, 500, &mut crng);
        ks_single.push(ks_two_sample(&xs, &reference));
        pooled.extend(xs);
    }
    let n = pooled.len() as f64;
    let basin_mass = vec![
        pooled.iter().filter(|&&x| x < -2.0).count() as f64 / n,
        pooled.iter().filter(|&&x| (-2.0..2.0).contains(&x)).count() as f64 / n,
        pooled.iter().filter(|&&x| x >= 2.0).count() as f64 / n,
    ];
    PoolingDemo {
        ks_pooled: ks_two_sample(&pooled, &reference),
        ks_single_mean: ks_single.mean(),
        basin_mass,
    }
}

/// Fig 3 result: topic-space multimodality vs prediction-space agreement.
#[derive(Clone, Debug)]
pub struct Fig3Report {
    /// Topic-space divergence across shard models (Hungarian probe).
    pub modes: ModeDivergence,
    /// Mean pairwise KS distance between shards' local test predictions.
    pub prediction_ks_mean: f64,
    /// Mean pairwise correlation between shards' local test predictions.
    pub prediction_corr_mean: f64,
}

/// Fig 3: run SimpleAverage with kept models, measure the permutation gap
/// in topic space vs the agreement of local predictions.
pub fn fig3_projection(
    ds: &Dataset,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
) -> anyhow::Result<Fig3Report> {
    let (out, models) = run_with_engine(Algorithm::SimpleAverage, ds, cfg, engine, true)?;
    let phis: Vec<Vec<Vec<f64>>> = models.iter().map(|m| m.phi_topic_rows()).collect();
    let modes = mode_divergence(&phis);

    // Local predictions: reconstruct per-shard yhat from the run output is
    // not possible (combined), so recompute via worker-equivalent calls is
    // wasteful; instead we use the kept models to predict a slice of the
    // test set cheaply.
    let m = models.len();
    let mut preds: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut rng = Pcg64::seed_from_u64(cfg.seed ^ 0xF16_3);
    let take = ds.test.num_docs().min(400);
    let idx: Vec<usize> = (0..take).collect();
    let sub = ds.test.view_of(&idx); // zero-copy slice of the test arena
    for model in &models {
        let (p, _) = crate::sampler::gibbs_predict::predict_corpus(
            model, sub, &cfg.train, engine, None, &mut rng,
        )?;
        preds.push(p.yhat);
    }
    let mut ks = Summary::new();
    let mut corr = Summary::new();
    for a in 0..m {
        for b in a + 1..m {
            ks.push(ks_two_sample(&preds[a], &preds[b]));
            corr.push(pearson(&preds[a], &preds[b]));
        }
    }
    let _ = out;
    Ok(Fig3Report {
        modes,
        prediction_ks_mean: ks.mean(),
        prediction_corr_mean: corr.mean(),
    })
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

/// Render the three demos as the experiment report.
pub fn render(fig1: &PoolingDemo, fig2: &PoolingDemo, fig3: &Fig3Report) -> String {
    let mut s = String::new();
    s.push_str("=== Fig 1: embarrassingly parallel MCMC, unimodal posterior ===\n");
    s.push_str(&format!(
        "KS(pooled, true) = {:.4}   mean KS(single chain, true) = {:.4}\n",
        fig1.ks_pooled, fig1.ks_single_mean
    ));
    s.push_str("-> pooling sub-chains is a valid posterior sample (small KS)\n\n");

    s.push_str("=== Fig 2: quasi-ergodicity, 3-modal posterior ===\n");
    s.push_str(&format!(
        "KS(pooled, true) = {:.4}   mean KS(single chain, true) = {:.4}\n",
        fig2.ks_pooled, fig2.ks_single_mean
    ));
    s.push_str(&format!(
        "pooled basin mass (true = 1/3 each): left={:.3} mid={:.3} right={:.3}\n",
        fig2.basin_mass[0], fig2.basin_mass[1], fig2.basin_mass[2]
    ));
    s.push_str("-> chains never hop modes; pooled sample misrepresents the posterior\n\n");

    s.push_str("=== Fig 3: prediction projection restores unimodality (sLDA) ===\n");
    s.push_str(&format!(
        "topic space : identity TV = {:.4}  aligned TV = {:.4}  permutation gap = {:.4}\n",
        fig3.modes.mean_identity,
        fig3.modes.mean_aligned,
        fig3.modes.permutation_gap()
    ));
    s.push_str(&format!(
        "              permuted topic fraction = {:.2}\n",
        fig3.modes.mean_permuted_fraction
    ));
    s.push_str(&format!(
        "prediction  : mean pairwise KS = {:.4}  mean pairwise corr = {:.4}\n",
        fig3.prediction_ks_mean, fig3.prediction_corr_mean
    ));
    s.push_str(
        "-> shards disagree on topic labels (multimodal) but agree on predictions (unimodal)\n",
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_split, SyntheticSpec};

    #[test]
    fn fig1_pooling_is_valid() {
        let d = fig1_unimodal(3, 4000, 1);
        assert!(d.ks_pooled < 0.05, "ks={}", d.ks_pooled);
    }

    #[test]
    fn fig2_pooling_fails() {
        let d = fig2_multimodal(4000, 2);
        // chains stuck: middle mode unvisited, left over-weighted
        assert!(d.basin_mass[1] < 0.01, "mid mass {}", d.basin_mass[1]);
        assert!(d.basin_mass[0] > 0.55, "left mass {}", d.basin_mass[0]);
        // pooled KS far worse than the unimodal case
        assert!(d.ks_pooled > 0.2, "ks={}", d.ks_pooled);
    }

    #[test]
    fn fig3_gap_large_predictions_agree() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = generate_split(&spec, 200, &mut rng);
        let mut cfg = ExperimentConfig::quick();
        cfg.engine = crate::config::schema::EngineKind::Native;
        cfg.train.sweeps = 15;
        cfg.train.burnin = 3;
        cfg.train.eta_every = 3;
        let engine = EngineHandle::native();
        let r = fig3_projection(&ds, &cfg, &engine).unwrap();
        // Topic labels across shards must be (at least partly) permuted.
        assert!(
            r.modes.permutation_gap() > 0.05,
            "expected a permutation gap, got {:?}",
            r.modes
        );
        // Predictions must correlate strongly despite the topic permutation.
        assert!(
            r.prediction_corr_mean > 0.5,
            "local predictions should agree: corr={}",
            r.prediction_corr_mean
        );
        let text = render(&fig1_unimodal(3, 500, 1), &fig2_multimodal(500, 2), &r);
        assert!(text.contains("permutation gap"));
    }

    #[test]
    fn mh_chain_targets_distribution() {
        let mut rng = Pcg64::seed_from_u64(4);
        let xs = mh_chain(|x| normal_logpdf(x, 2.0, 0.25), 2.0, 0.8, 20_000, 1000, &mut rng);
        let s = Summary::from_slice(&xs);
        assert!((s.mean() - 2.0).abs() < 0.05, "mean={}", s.mean());
        assert!((s.var() - 0.25).abs() < 0.05, "var={}", s.var());
    }

    #[test]
    fn mixture_logpdf_normalizes_mass() {
        // numeric integral of exp(logpdf) ~ 1
        let means = [-1.0, 1.0];
        let h = 0.001;
        let total: f64 = (-8000..8000)
            .map(|i| (mixture_logpdf(i as f64 * h, &means, 0.2)).exp() * h)
            .sum();
        assert!((total - 1.0).abs() < 1e-3, "total={total}");
    }
}
