//! Experiment runners: one module per paper figure (see DESIGN.md §5 for
//! the figure -> module -> bench index).
//!
//! * [`fig123`] — the quasi-ergodicity demonstrations: unimodal pooling
//!   works (Fig 1), multimodal pooling fails (Fig 2), prediction projection
//!   restores unimodality for sLDA (Fig 3).
//! * [`fig5`] — label-distribution histogram + normality probe.
//! * [`runner`] — the shared four-algorithm comparison harness behind
//!   Fig 6 (continuous MD&A/EPS) and Fig 7 (binary sentiment), plus the
//!   ablation sweeps (shards, topics, weight schemes).

pub mod fig123;
pub mod fig5;
pub mod runner;
