//! Property-testing micro-framework (proptest is not in the offline vendor
//! set). Deterministic seeded case generation with failure-seed reporting:
//! every failure message names the case seed so it can be replayed exactly.

use crate::util::rng::Pcg64;

pub mod failfs;

/// Run `prop` for `cases` generated inputs. On panic, re-raises with the
/// case seed in the message.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl Fn(&T) + std::panic::RefUnwindSafe,
) where
    T: std::panic::RefUnwindSafe,
{
    for case in 0..cases {
        let seed = 0x5EED_0000_u64 + case as u64;
        let mut rng = Pcg64::seed_from_u64(seed);
        let input = gen(&mut rng);
        let result = std::panic::catch_unwind(|| prop(&input));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x})\n\
                 input: {input:?}\ncause: {msg}"
            );
        }
    }
}

/// Uniform usize in [lo, hi].
pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + rng.gen_range(hi - lo + 1)
}

/// Uniform f64 in [lo, hi).
pub fn f64_in(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * rng.next_f64()
}

/// Random f32 vector with entries in [lo, hi).
pub fn vec_f32(rng: &mut Pcg64, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| lo + (hi - lo) * rng.next_f32()).collect()
}

/// Random f64 vector with entries in [lo, hi).
pub fn vec_f64(rng: &mut Pcg64, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| f64_in(rng, lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("sum-commutes", 25, |rng| (rng.next_f64(), rng.next_f64()), |&(a, b)| {
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        forall("always-fails", 3, |rng| rng.next_u64(), |_| panic!("nope"));
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Pcg64::seed_from_u64(1);
        for _ in 0..1000 {
            let u = usize_in(&mut rng, 3, 7);
            assert!((3..=7).contains(&u));
            let f = f64_in(&mut rng, -1.0, 2.0);
            assert!((-1.0..2.0).contains(&f));
        }
        assert_eq!(vec_f32(&mut rng, 5, 0.0, 1.0).len(), 5);
        assert_eq!(vec_f64(&mut rng, 4, 0.0, 1.0).len(), 4);
    }
}
