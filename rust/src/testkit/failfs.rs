//! Fault-injecting [`CkptFs`]: the checkpoint subsystem's crash simulator.
//!
//! `FailpointFs` wraps [`StdFs`] and counts every mutating operation
//! (write / fsync / rename) in program order. A test arms a single failure
//! at an exact operation index — the `FailKind` decides what the operation
//! leaves on disk — and optionally marks the process "dead" from that point
//! on, after which **every** subsequent operation fails. That models a hard
//! crash (`kill -9`): the interrupted op's partial effects persist, and
//! nothing else ever happens. Recovery code is then exercised against the
//! exact on-disk state each crash window leaves behind (DESIGN.md
//! §Durability, "Failpoint testing").
//!
//! Reads and directory listings are never failed: recovery runs on the
//! *next* process, which sees a healthy filesystem containing whatever the
//! crash left.

use crate::ckpt::fs::{CkptFs, StdFs};
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// What happens at the armed operation index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailKind {
    /// `write` persists only a prefix of the payload, then errors — a torn
    /// write (power loss mid-`write(2)`).
    TornWrite { keep: usize },
    /// `write` persists an arbitrary-length prefix (half the payload) and
    /// *reports success* — a short write the caller never notices.
    ShortWrite,
    /// `write` persists the full payload with one bit flipped — media
    /// corruption between write and read-back.
    BitFlip { byte: usize, mask: u8 },
    /// `fsync` fails (EIO); file contents may or may not be durable.
    ErrFsync,
    /// `rename` fails without renaming anything.
    ErrRename,
}

struct Armed {
    at: u64,
    kind: FailKind,
    /// After firing, treat the process as dead: all later mutating ops fail.
    then_die: bool,
}

/// See module docs. Counted ops are write/fsync/rename, in call order.
pub struct FailpointFs {
    inner: StdFs,
    ops: AtomicU64,
    dead: AtomicBool,
    armed: Mutex<Option<Armed>>,
}

impl Default for FailpointFs {
    fn default() -> Self {
        Self::new()
    }
}

impl FailpointFs {
    pub fn new() -> FailpointFs {
        FailpointFs {
            inner: StdFs,
            ops: AtomicU64::new(0),
            dead: AtomicBool::new(false),
            armed: Mutex::new(None),
        }
    }

    /// Arm `kind` to fire at mutating-operation index `at` (0-based over
    /// write/fsync/rename calls). With `then_die`, every operation after
    /// the armed one also fails — a crash, not a transient error.
    pub fn arm(&self, at: u64, kind: FailKind, then_die: bool) {
        *self.armed.lock().unwrap() = Some(Armed { at, kind, then_die });
    }

    /// Mutating operations observed so far. Run the workload once against
    /// a pristine `FailpointFs` to learn the op schedule, then arm replays
    /// at each index.
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::SeqCst)
    }

    /// Has the armed failure fired (or was the fs killed)?
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    fn dead_err(&self) -> io::Error {
        io::Error::other("failpoint: process dead")
    }

    /// Returns the armed kind if this op index is the trigger.
    fn tick(&self) -> Result<Option<FailKind>, io::Error> {
        if self.dead.load(Ordering::SeqCst) {
            return Err(self.dead_err());
        }
        let idx = self.ops.fetch_add(1, Ordering::SeqCst);
        let mut armed = self.armed.lock().unwrap();
        if let Some(a) = armed.as_ref() {
            if a.at == idx {
                let a = armed.take().unwrap();
                if a.then_die {
                    self.dead.store(true, Ordering::SeqCst);
                }
                return Ok(Some(a.kind));
            }
        }
        Ok(None)
    }
}

impl CkptFs for FailpointFs {
    fn create_dir_all(&self, p: &Path) -> io::Result<()> {
        // Not a counted op: directory creation is idempotent setup.
        if self.dead.load(Ordering::SeqCst) {
            return Err(self.dead_err());
        }
        self.inner.create_dir_all(p)
    }

    fn write(&self, p: &Path, bytes: &[u8]) -> io::Result<()> {
        match self.tick()? {
            None => self.inner.write(p, bytes),
            Some(FailKind::TornWrite { keep }) => {
                let keep = keep.min(bytes.len());
                self.inner.write(p, &bytes[..keep])?;
                Err(io::Error::other("failpoint: torn write"))
            }
            Some(FailKind::ShortWrite) => self.inner.write(p, &bytes[..bytes.len() / 2]),
            Some(FailKind::BitFlip { byte, mask }) => {
                let mut copy = bytes.to_vec();
                if !copy.is_empty() {
                    let i = byte % copy.len();
                    copy[i] ^= if mask == 0 { 1 } else { mask };
                }
                self.inner.write(p, &copy)
            }
            Some(FailKind::ErrFsync) | Some(FailKind::ErrRename) => {
                // Armed for a different op kind than fired here: still fail
                // loudly — an op-schedule drift should break the test, not
                // silently pass.
                Err(io::Error::other("failpoint: armed kind mismatches op"))
            }
        }
    }

    fn fsync(&self, p: &Path) -> io::Result<()> {
        match self.tick()? {
            None => self.inner.fsync(p),
            Some(FailKind::ErrFsync) => Err(io::Error::other("failpoint: fsync EIO")),
            Some(_) => Err(io::Error::other("failpoint: armed kind mismatches op")),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        match self.tick()? {
            None => self.inner.rename(from, to),
            Some(FailKind::ErrRename) => Err(io::Error::other("failpoint: rename EIO")),
            Some(_) => Err(io::Error::other("failpoint: armed kind mismatches op")),
        }
    }

    fn read(&self, p: &Path) -> io::Result<Vec<u8>> {
        self.inner.read(p)
    }

    fn list_dir(&self, p: &Path) -> io::Result<Vec<String>> {
        self.inner.list_dir(p)
    }

    fn remove_dir_all(&self, p: &Path) -> io::Result<()> {
        if self.dead.load(Ordering::SeqCst) {
            return Err(self.dead_err());
        }
        self.inner.remove_dir_all(p)
    }

    fn exists(&self, p: &Path) -> bool {
        self.inner.exists(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_failfs_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn clean_passthrough_counts_ops() {
        let dir = tmpdir("count");
        let fs = FailpointFs::new();
        let a = dir.join("a");
        let b = dir.join("b");
        fs.write(&a, b"12345").unwrap(); // op 0
        fs.fsync(&a).unwrap(); // op 1
        fs.rename(&a, &b).unwrap(); // op 2
        assert_eq!(fs.ops(), 3);
        assert!(!fs.is_dead());
        assert_eq!(fs.read(&b).unwrap(), b"12345");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_write_leaves_prefix_and_kills() {
        let dir = tmpdir("torn");
        let fs = FailpointFs::new();
        let a = dir.join("a");
        fs.arm(0, FailKind::TornWrite { keep: 3 }, true);
        assert!(fs.write(&a, b"123456").is_err());
        assert_eq!(std::fs::read(&a).unwrap(), b"123");
        assert!(fs.is_dead());
        // everything after the crash fails
        assert!(fs.write(&dir.join("b"), b"x").is_err());
        assert!(fs.fsync(&a).is_err());
        assert!(fs.rename(&a, &dir.join("c")).is_err());
        // but reads (the next process's recovery) still work
        assert_eq!(fs.read(&a).unwrap(), b"123");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn short_write_reports_success_with_half_the_bytes() {
        let dir = tmpdir("short");
        let fs = FailpointFs::new();
        let a = dir.join("a");
        fs.arm(0, FailKind::ShortWrite, false);
        fs.write(&a, b"12345678").unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), b"1234");
        // not dead: later ops proceed
        fs.write(&a, b"ok").unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), b"ok");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bitflip_corrupts_exactly_one_bit() {
        let dir = tmpdir("flip");
        let fs = FailpointFs::new();
        let a = dir.join("a");
        fs.arm(0, FailKind::BitFlip { byte: 2, mask: 0x08 }, false);
        fs.write(&a, b"\x00\x00\x00\x00").unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), b"\x00\x00\x08\x00");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fsync_and_rename_failures_fire_at_index() {
        let dir = tmpdir("errs");
        let fs = FailpointFs::new();
        let a = dir.join("a");
        fs.write(&a, b"x").unwrap(); // op 0
        fs.arm(1, FailKind::ErrFsync, false);
        assert!(fs.fsync(&a).is_err()); // op 1 fires
        fs.arm(2, FailKind::ErrRename, false);
        assert!(fs.rename(&a, &dir.join("b")).is_err()); // op 2 fires
        assert!(fs.exists(&a), "failed rename must not move the file");
        std::fs::remove_dir_all(&dir).ok();
    }
}
