//! # cfslda — Communication-Free Parallel Supervised Topic Models
//!
//! A production reproduction of *"Communication-Free Parallel Supervised
//! Topic Models"* (Gao & Zheng, 2017): embarrassingly parallel MCMC for
//! supervised LDA that sidesteps the quasi-ergodicity problem of multimodal
//! topic posteriors by combining **predictions** (one-dimensional, unimodal)
//! instead of **topic samples** (high-dimensional, one posterior mode per
//! topic-label permutation).
//!
//! ## Architecture (three layers, python never on the request path)
//!
//! * **L3 (this crate)** — the coordinator: corpus pipeline, collapsed Gibbs
//!   sampler, communication-free shard workers, the paper's three combination
//!   rules (Naive / Simple Average / Weighted Average) plus the non-parallel
//!   baseline, evaluation, experiment runners, CLI, and the batched,
//!   hot-swappable prediction server ([`serve`]).
//! * **L2 (python/compile/model.py)** — the dense sLDA algebra (ridge eta
//!   solve, batched prediction, weighted combination, Gaussian response
//!   log-densities) as JAX graphs, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels inside those graphs.
//!
//! [`runtime`] loads the AOT artifacts through the PJRT C API (`xla` crate)
//! and exposes them behind the [`runtime::Engine`] trait; a bit-compatible
//! pure-rust [`runtime::native`] engine serves as fallback and as the
//! cross-validation oracle in integration tests.
//!
//! ## Quick start
//!
//! ```no_run
//! use cfslda::config::schema::ExperimentConfig;
//! use cfslda::data::synthetic::{SyntheticSpec, generate};
//! use cfslda::parallel::leader::{run_algorithm, Algorithm};
//! use cfslda::util::rng::Pcg64;
//!
//! let mut rng = Pcg64::seed_from_u64(7);
//! let spec = SyntheticSpec::continuous_small();
//! let dataset = generate(&spec, &mut rng);
//! let cfg = ExperimentConfig::quick();
//! let out = run_algorithm(Algorithm::SimpleAverage, &dataset, &cfg).unwrap();
//! println!("test MSE = {:.4}", out.test_metrics.mse);
//! ```

/// With `--features bench-alloc`, route every heap allocation through the
/// counting wrapper so serve-bench can report allocs/request for the
/// streaming codec (see [`util::alloc_count`]).
#[cfg(feature = "bench-alloc")]
#[global_allocator]
static ALLOC_COUNTER: util::alloc_count::CountingAlloc = util::alloc_count::CountingAlloc;

pub mod bench_harness;
pub mod ckpt;
pub mod cli;
pub mod combine;
pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod model;
pub mod obs;
pub mod parallel;
pub mod regress;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod testkit;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
