//! Benchmark harness (criterion substitute — criterion is not in the
//! offline vendor set).
//!
//! Provides warmup + repeated timing with median/IQR reporting, and the
//! table renderer shared by all `rust/benches/*.rs` targets (which are
//! `harness = false` binaries). Benches accept `--quick` (fewer reps,
//! smaller workloads) so `cargo bench` stays tractable on laptop-class
//! hardware; full-scale parameters are documented per bench.

use crate::util::stats::{median, quantile};
use crate::util::timer::Stopwatch;

/// One benchmark's timing samples.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples_secs: Vec<f64>,
    /// Optional throughput denominator (e.g. tokens per iteration).
    pub work_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn median(&self) -> f64 {
        median(&self.samples_secs)
    }

    pub fn iqr(&self) -> (f64, f64) {
        (quantile(&self.samples_secs, 0.25), quantile(&self.samples_secs, 0.75))
    }

    /// Work units per second at the median (when `work_per_iter` is set).
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.median())
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::new();
        f();
        samples.push(sw.elapsed_secs());
    }
    BenchResult { name: name.to_string(), samples_secs: samples, work_per_iter: None }
}

/// Like [`bench`] but records a throughput denominator.
pub fn bench_throughput(
    name: &str,
    warmup: usize,
    iters: usize,
    work_per_iter: f64,
    f: impl FnMut(),
) -> BenchResult {
    let mut r = bench(name, warmup, iters, f);
    r.work_per_iter = Some(work_per_iter);
    r
}

/// Render a results table.
pub fn render_table(title: &str, results: &[BenchResult]) -> String {
    let mut s = format!("== bench: {title} ==\n");
    s.push_str(&format!(
        "{:<36} {:>10} {:>10} {:>10} {:>6} {:>14}\n",
        "case", "median(s)", "q25(s)", "q75(s)", "n", "throughput"
    ));
    for r in results {
        let (q25, q75) = r.iqr();
        let tp = match r.throughput() {
            Some(t) if t >= 1e6 => format!("{:.2}M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("{:.2}k/s", t / 1e3),
            Some(t) => format!("{t:.2}/s"),
            None => "-".to_string(),
        };
        s.push_str(&format!(
            "{:<36} {:>10.4} {:>10.4} {:>10.4} {:>6} {:>14}\n",
            r.name,
            r.median(),
            q25,
            q75,
            r.samples_secs.len(),
            tp
        ));
    }
    s
}

/// Shared CLI convention for bench binaries: returns true when `--quick`
/// was passed (reduced reps/workloads for CI-class machines).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("CFSLDA_BENCH_QUICK").is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut count = 0usize;
        let r = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7); // warmup + iters
        assert_eq!(r.samples_secs.len(), 5);
        assert!(r.median() >= 0.0);
        let (q25, q75) = r.iqr();
        assert!(q25 <= r.median() && r.median() <= q75);
    }

    #[test]
    fn throughput_reporting() {
        let r = bench_throughput("sleepy", 0, 3, 1000.0, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        let t = r.throughput().unwrap();
        assert!(t > 100_000.0 && t < 1_000_000.0, "t={t}");
        let table = render_table("t", &[r]);
        assert!(table.contains("sleepy"));
        assert!(table.contains("k/s"));
    }
}
