//! Padding helpers: the AOT artifacts have fixed shapes (row bucket x topic
//! bucket); the runtime zero-pads inputs and masks padding rows with w = 0.
//! Property tests assert padding round-trips and never changes results.

/// Pad a row-major [rows, cols] f32 matrix to [rows_pad, cols_pad] with zeros.
pub fn pad_matrix(data: &[f32], rows: usize, cols: usize, rows_pad: usize, cols_pad: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), rows * cols);
    debug_assert!(rows_pad >= rows && cols_pad >= cols);
    let mut out = vec![0.0f32; rows_pad * cols_pad];
    for r in 0..rows {
        out[r * cols_pad..r * cols_pad + cols].copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
    out
}

/// Pad a vector to `len_pad` with `fill`.
pub fn pad_vec(data: &[f32], len_pad: usize, fill: f32) -> Vec<f32> {
    debug_assert!(len_pad >= data.len());
    let mut out = Vec::with_capacity(len_pad);
    out.extend_from_slice(data);
    out.resize(len_pad, fill);
    out
}

/// f64 slice -> padded f32 vector.
pub fn pad_vec_f64(data: &[f64], len_pad: usize, fill: f32) -> Vec<f32> {
    debug_assert!(len_pad >= data.len());
    let mut out: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    out.resize(len_pad, fill);
    out
}

/// Mask vector: 1.0 for the first `valid` entries, 0.0 after.
pub fn mask(valid: usize, len_pad: usize) -> Vec<f32> {
    let mut m = vec![1.0f32; valid];
    m.resize(len_pad, 0.0);
    m
}

/// Row-chunk iterator bounds: yields (start_row, rows_in_chunk) covering
/// `rows` in chunks of at most `bucket`.
pub fn chunks(rows: usize, bucket: usize) -> Vec<(usize, usize)> {
    assert!(bucket > 0);
    let mut out = Vec::new();
    let mut start = 0;
    while start < rows {
        let take = bucket.min(rows - start);
        out.push((start, take));
        start += take;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_padding_layout() {
        let m = [1.0, 2.0, 3.0, 4.0]; // 2x2
        let p = pad_matrix(&m, 2, 2, 3, 4);
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..4], &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(&p[4..8], &[3.0, 4.0, 0.0, 0.0]);
        assert_eq!(&p[8..12], &[0.0; 4]);
    }

    #[test]
    fn identity_padding_is_copy() {
        let m = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(pad_matrix(&m, 2, 3, 2, 3), m.to_vec());
    }

    #[test]
    fn vec_padding() {
        assert_eq!(pad_vec(&[1.0, 2.0], 4, 9.0), vec![1.0, 2.0, 9.0, 9.0]);
        assert_eq!(pad_vec_f64(&[0.5f64], 3, 0.0), vec![0.5f32, 0.0, 0.0]);
        assert_eq!(mask(2, 4), vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn chunk_bounds_cover_exactly() {
        for &(rows, bucket) in &[(10usize, 4usize), (8, 4), (3, 100), (4096, 4096), (9000, 4096)] {
            let cs = chunks(rows, bucket);
            let total: usize = cs.iter().map(|&(_, n)| n).sum();
            assert_eq!(total, rows, "rows={rows} bucket={bucket}");
            let mut expect = 0;
            for &(start, n) in &cs {
                assert_eq!(start, expect);
                assert!(n <= bucket && n > 0);
                expect += n;
            }
        }
        assert!(chunks(0, 8).is_empty());
    }
}
