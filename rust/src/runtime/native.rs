//! Pure-rust engine: bit-compatible twin of the AOT XLA artifacts.
//!
//! Exists for three reasons: (1) fallback when artifacts are absent,
//! (2) cross-validation oracle — `rust/tests/integration_runtime.rs`
//! asserts XLA-vs-native agreement on random inputs, (3) an ablation arm
//! for the engine-overhead bench (`benches/runtime_engines.rs`).

use super::{EngineImpl, Prediction};
use crate::regress::ridge;

/// Pure-rust reference engine.
#[derive(Debug, Default)]
pub struct NativeEngine {}

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine {}
    }
}

impl EngineImpl for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn eta_solve(
        &self,
        zbar: &[f32],
        y: &[f64],
        t: usize,
        lambda: f64,
        mu: f64,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        let w = vec![1.0f64; y.len()];
        ridge::ridge_fit(zbar, y, &w, t, lambda, mu)
    }

    fn predict(
        &self,
        zbar: &[f32],
        eta: &[f64],
        y: Option<&[f64]>,
        t: usize,
    ) -> anyhow::Result<Prediction> {
        anyhow::ensure!(eta.len() == t, "eta len {} != t {}", eta.len(), t);
        anyhow::ensure!(zbar.len() % t == 0, "zbar not a multiple of t");
        let rows = zbar.len() / t;
        let mut yhat = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &zbar[r * t..(r + 1) * t];
            yhat.push(row.iter().zip(eta).map(|(&z, &e)| z as f64 * e).sum());
        }
        let (mut mse, mut acc) = (0.0, 0.0);
        if let Some(ys) = y {
            anyhow::ensure!(ys.len() == rows, "labels len {} != rows {}", ys.len(), rows);
            if rows > 0 {
                let mut se = 0.0;
                let mut hits = 0usize;
                for (p, &obs) in yhat.iter().zip(ys) {
                    se += (p - obs) * (p - obs);
                    if (*p > 0.5) == (obs > 0.5) {
                        hits += 1;
                    }
                }
                mse = se / rows as f64;
                acc = hits as f64 / rows as f64;
            }
        }
        Ok(Prediction { yhat, mse, acc })
    }

    fn combine(&self, preds: &[Vec<f64>], weights: &[f64]) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(!preds.is_empty(), "no predictions to combine");
        anyhow::ensure!(preds.len() == weights.len(), "preds/weights length mismatch");
        let b = preds[0].len();
        anyhow::ensure!(preds.iter().all(|p| p.len() == b), "ragged prediction rows");
        let wsum: f64 = weights.iter().sum();
        anyhow::ensure!(wsum > 0.0, "combination weights sum to {wsum}");
        let mut out = vec![0.0f64; b];
        for (p, &w) in preds.iter().zip(weights) {
            let wn = w / wsum;
            for (o, &v) in out.iter_mut().zip(p) {
                *o += wn * v;
            }
        }
        Ok(out)
    }

    fn loglik(&self, y: &[f64], mu: &[f32], t: usize, rho: f64) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(rho > 0.0, "rho must be positive");
        anyhow::ensure!(mu.len() == y.len() * t, "mu shape mismatch");
        let c = -0.5 * (2.0 * std::f64::consts::PI * rho).ln();
        let inv2rho = 1.0 / (2.0 * rho);
        let mut out = Vec::with_capacity(mu.len());
        for (r, &yr) in y.iter().enumerate() {
            for ti in 0..t {
                let d = yr - mu[r * t + ti] as f64;
                out.push((c - d * d * inv2rho) as f32);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::normal_logpdf;
    use crate::util::rng::Pcg64;

    #[test]
    fn predict_and_metrics() {
        let e = NativeEngine::new();
        let zbar = [1.0f32, 0.0, 0.0, 1.0, 0.5, 0.5];
        let eta = [2.0f64, -1.0];
        let y = [2.0f64, -1.0, 1.0];
        let p = e.predict(&zbar, &eta, Some(&y), 2).unwrap();
        assert_eq!(p.yhat, vec![2.0, -1.0, 0.5]);
        // errors: 0, 0, 0.5 -> mse = 0.25/3
        assert!((p.mse - 0.25 / 3.0).abs() < 1e-12);
        // thresholds: (2>0.5)==(2>0.5), (-1)==(-1), (0.5>0.5)=false == (1>0.5)=true -> miss
        assert!((p.acc - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn combine_matches_manual() {
        let e = NativeEngine::new();
        let out = e
            .combine(&[vec![1.0, 2.0], vec![3.0, 6.0]], &[1.0, 3.0])
            .unwrap();
        assert!((out[0] - (0.25 + 2.25)).abs() < 1e-12);
        assert!((out[1] - (0.5 + 4.5)).abs() < 1e-12);
        assert!(e.combine(&[], &[]).is_err());
        assert!(e.combine(&[vec![1.0]], &[0.0]).is_err());
    }

    #[test]
    fn loglik_matches_normal_logpdf() {
        let e = NativeEngine::new();
        let y = [0.3f64, -1.0];
        let mu = [0.0f32, 1.0, -1.0, 0.5];
        let ll = e.loglik(&y, &mu, 2, 0.7).unwrap();
        for r in 0..2 {
            for t in 0..2 {
                let want = normal_logpdf(y[r], mu[r * 2 + t] as f64, 0.7);
                assert!((ll[r * 2 + t] as f64 - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn eta_solve_counts_is_bitwise_equal_to_zbar_eta_solve() {
        use crate::model::counts::CountMatrices;
        use crate::runtime::EngineHandle;
        let mut rng = Pcg64::seed_from_u64(6);
        let (d, t, w) = (40usize, 5usize, 12usize);
        let mut counts = CountMatrices::new(d, t, w);
        for di in 0..d {
            for _ in 0..10 + di % 7 {
                counts.inc(di, rng.gen_range(w) as u32, rng.gen_range(t));
            }
        }
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let engine = EngineHandle::native();
        let (eta_a, mse_a) =
            engine.eta_solve(&counts.zbar_matrix(), &y, t, 0.1, 0.0).unwrap();
        let (eta_b, mse_b) =
            engine.eta_solve_counts(&counts, &y, 0.1, 0.0, &mut Vec::new()).unwrap();
        assert_eq!(eta_a, eta_b, "count-sided eta must match the zbar path bitwise");
        assert_eq!(mse_a, mse_b);
    }

    #[test]
    fn eta_solve_delegates_to_ridge() {
        let mut rng = Pcg64::seed_from_u64(5);
        let (d, t) = (300, 4);
        let eta_true: Vec<f64> = (0..t).map(|_| rng.next_gaussian()).collect();
        let mut zbar = vec![0.0f32; d * t];
        let mut y = vec![0.0f64; d];
        for di in 0..d {
            let theta = rng.next_dirichlet_sym(0.4, t);
            for ti in 0..t {
                zbar[di * t + ti] = theta[ti] as f32;
            }
            y[di] = theta.iter().zip(&eta_true).map(|(a, b)| a * b).sum();
        }
        let e = NativeEngine::new();
        let (eta, mse) = e.eta_solve(&zbar, &y, t, 1e-6, 0.0).unwrap();
        assert!(mse < 1e-8, "mse={mse}");
        for (a, b) in eta.iter().zip(&eta_true) {
            assert!((a - b).abs() < 1e-2);
        }
    }
}
