//! Engine service thread: makes the (non-`Send`) [`XlaEngine`] usable from
//! the parallel shard workers.
//!
//! One dedicated OS thread owns the PJRT client and compiled executables;
//! [`XlaService`] is a cheap clonable handle that ships requests over an
//! mpsc channel and blocks on a per-request response channel. Engine calls
//! are coarse-grained (one per stochastic-EM eta step, one per prediction
//! batch), so the serialization point is never the bottleneck — the
//! `runtime_engines` bench quantifies the overhead.
//!
//! The service thread exits when the last handle is dropped.

use super::xla::XlaEngine;
use super::{EngineImpl, Prediction};
use anyhow::Context;
use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

enum Request {
    EtaSolve {
        zbar: Vec<f32>,
        y: Vec<f64>,
        t: usize,
        lambda: f64,
        mu: f64,
        reply: mpsc::Sender<anyhow::Result<(Vec<f64>, f64)>>,
    },
    Predict {
        zbar: Vec<f32>,
        eta: Vec<f64>,
        y: Option<Vec<f64>>,
        t: usize,
        reply: mpsc::Sender<anyhow::Result<Prediction>>,
    },
    Combine {
        preds: Vec<Vec<f64>>,
        weights: Vec<f64>,
        reply: mpsc::Sender<anyhow::Result<Vec<f64>>>,
    },
    Loglik {
        y: Vec<f64>,
        mu: Vec<f32>,
        t: usize,
        rho: f64,
        reply: mpsc::Sender<anyhow::Result<Vec<f32>>>,
    },
}

/// Clonable, `Send + Sync` handle to the XLA service thread.
#[derive(Clone)]
pub struct XlaService {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
}

impl XlaService {
    /// Spawn the service thread; fails fast if the manifest/client cannot
    /// be initialized.
    pub fn spawn(artifacts_dir: &Path) -> anyhow::Result<XlaService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<anyhow::Result<()>>();
        let dir = artifacts_dir.to_path_buf();
        std::thread::Builder::new()
            .name("xla-engine".into())
            .spawn(move || {
                let engine = match XlaEngine::load(&dir) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::EtaSolve { zbar, y, t, lambda, mu, reply } => {
                            let _ = reply.send(engine.eta_solve(&zbar, &y, t, lambda, mu));
                        }
                        Request::Predict { zbar, eta, y, t, reply } => {
                            let _ = reply.send(engine.predict(&zbar, &eta, y.as_deref(), t));
                        }
                        Request::Combine { preds, weights, reply } => {
                            let _ = reply.send(engine.combine(&preds, &weights));
                        }
                        Request::Loglik { y, mu, t, rho, reply } => {
                            let _ = reply.send(engine.loglik(&y, &mu, t, rho));
                        }
                    }
                }
            })
            .context("spawning xla service thread")?;
        init_rx.recv().context("xla service thread died during init")??;
        Ok(XlaService { tx: Arc::new(Mutex::new(tx)) })
    }

    fn send(&self, req: Request) -> anyhow::Result<()> {
        self.tx
            .lock()
            .map_err(|_| anyhow::anyhow!("xla service mutex poisoned"))?
            .send(req)
            .map_err(|_| anyhow::anyhow!("xla service thread has exited"))
    }

    pub fn eta_solve(
        &self,
        zbar: &[f32],
        y: &[f64],
        t: usize,
        lambda: f64,
        mu: f64,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::EtaSolve {
            zbar: zbar.to_vec(),
            y: y.to_vec(),
            t,
            lambda,
            mu,
            reply,
        })?;
        rx.recv().context("xla service dropped the request")?
    }

    pub fn predict(
        &self,
        zbar: &[f32],
        eta: &[f64],
        y: Option<&[f64]>,
        t: usize,
    ) -> anyhow::Result<Prediction> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Predict {
            zbar: zbar.to_vec(),
            eta: eta.to_vec(),
            y: y.map(|v| v.to_vec()),
            t,
            reply,
        })?;
        rx.recv().context("xla service dropped the request")?
    }

    pub fn combine(&self, preds: &[Vec<f64>], weights: &[f64]) -> anyhow::Result<Vec<f64>> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Combine { preds: preds.to_vec(), weights: weights.to_vec(), reply })?;
        rx.recv().context("xla service dropped the request")?
    }

    pub fn loglik(&self, y: &[f64], mu: &[f32], t: usize, rho: f64) -> anyhow::Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Loglik { y: y.to_vec(), mu: mu.to_vec(), t, rho, reply })?;
        rx.recv().context("xla service dropped the request")?
    }
}
