//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed from `artifacts/manifest.json`.

use crate::config::json::{self, Value};
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Metadata of one AOT-lowered function.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// Path of the HLO text file (absolute, resolved against the manifest dir).
    pub path: PathBuf,
    /// Parameter shapes in call order.
    pub param_shapes: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Padded row bucket for eta_solve / gram / predict / loglik.
    pub row_bucket: usize,
    /// Padded shard axis for combine.
    pub shard_bucket: usize,
    /// Available topic buckets, ascending.
    pub topic_buckets: Vec<usize>,
    pub functions: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let v = json::parse(&text).context("parsing manifest.json")?;
        Self::from_value(&v, dir)
    }

    pub fn from_value(v: &Value, dir: &Path) -> anyhow::Result<Manifest> {
        let version = v.get("version").and_then(|x| x.as_usize()).context("missing version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let row_bucket =
            v.get("row_bucket").and_then(|x| x.as_usize()).context("missing row_bucket")?;
        let shard_bucket =
            v.get("shard_bucket").and_then(|x| x.as_usize()).context("missing shard_bucket")?;
        let mut topic_buckets: Vec<usize> = v
            .get("topic_buckets")
            .and_then(|x| x.as_array())
            .context("missing topic_buckets")?
            .iter()
            .map(|x| x.as_usize().context("bad topic bucket"))
            .collect::<anyhow::Result<_>>()?;
        topic_buckets.sort_unstable();
        let mut functions = BTreeMap::new();
        for f in v.get("functions").and_then(|x| x.as_array()).context("missing functions")? {
            let name = f.get("name").and_then(|x| x.as_str()).context("fn missing name")?;
            let file = f.get("file").and_then(|x| x.as_str()).context("fn missing file")?;
            let mut param_shapes = Vec::new();
            for p in f.get("params").and_then(|x| x.as_array()).context("fn missing params")? {
                let shape: Vec<usize> = p
                    .get("shape")
                    .and_then(|x| x.as_array())
                    .context("param missing shape")?
                    .iter()
                    .map(|d| d.as_usize().context("bad dim"))
                    .collect::<anyhow::Result<_>>()?;
                param_shapes.push(shape);
            }
            functions.insert(
                name.to_string(),
                ArtifactMeta { name: name.to_string(), path: dir.join(file), param_shapes },
            );
        }
        if functions.is_empty() {
            bail!("manifest lists no functions");
        }
        Ok(Manifest { row_bucket, shard_bucket, topic_buckets, functions })
    }

    /// Smallest topic bucket >= t.
    pub fn topic_bucket_for(&self, t: usize) -> anyhow::Result<usize> {
        self.topic_buckets
            .iter()
            .copied()
            .find(|&b| b >= t)
            .with_context(|| {
                format!(
                    "no topic bucket >= {t} (available: {:?}); re-run `make artifacts` \
                     with a larger --topics or use the native engine",
                    self.topic_buckets
                )
            })
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.functions.get(name).with_context(|| {
            format!("artifact '{name}' not in manifest (have: {:?})",
                    self.functions.keys().collect::<Vec<_>>())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(dir: &Path) -> Manifest {
        let v = json::parse(
            r#"{
              "version": 1, "row_bucket": 4096, "shard_bucket": 16,
              "topic_buckets": [64, 8, 16, 32], "dtype": "f32",
              "functions": [
                {"name": "gram_T8", "file": "gram_T8.hlo.txt",
                 "params": [{"shape": [4096, 8], "dtype": "float32"},
                            {"shape": [4096], "dtype": "float32"},
                            {"shape": [4096], "dtype": "float32"}]}
              ]
            }"#,
        )
        .unwrap();
        Manifest::from_value(&v, dir).unwrap()
    }

    #[test]
    fn parses_and_sorts_buckets() {
        let m = sample(Path::new("/tmp/a"));
        assert_eq!(m.topic_buckets, vec![8, 16, 32, 64]);
        assert_eq!(m.row_bucket, 4096);
        let a = m.artifact("gram_T8").unwrap();
        assert_eq!(a.path, Path::new("/tmp/a/gram_T8.hlo.txt"));
        assert_eq!(a.param_shapes[0], vec![4096, 8]);
    }

    #[test]
    fn bucket_selection() {
        let m = sample(Path::new("/tmp"));
        assert_eq!(m.topic_bucket_for(3).unwrap(), 8);
        assert_eq!(m.topic_bucket_for(8).unwrap(), 8);
        assert_eq!(m.topic_bucket_for(9).unwrap(), 16);
        assert_eq!(m.topic_bucket_for(64).unwrap(), 64);
        assert!(m.topic_bucket_for(65).is_err());
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = sample(Path::new("/tmp"));
        let e = m.artifact("nope").unwrap_err().to_string();
        assert!(e.contains("nope"));
    }

    #[test]
    fn rejects_bad_versions_and_shapes() {
        let dir = Path::new("/tmp");
        let bad = json::parse(r#"{"version": 2, "row_bucket": 1, "shard_bucket": 1, "topic_buckets": [], "functions": []}"#).unwrap();
        assert!(Manifest::from_value(&bad, dir).is_err());
        let empty = json::parse(r#"{"version": 1, "row_bucket": 1, "shard_bucket": 1, "topic_buckets": [8], "functions": []}"#).unwrap();
        assert!(Manifest::from_value(&empty, dir).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Exercised against the actual artifacts when they have been built.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.functions.contains_key("eta_solve_T8"));
            assert!(m.functions.contains_key("gram_T16"));
            assert!(m.functions.contains_key("combine_M16"));
            assert_eq!(m.row_bucket, 4096);
        }
    }
}
