//! XLA engine: loads the AOT HLO-text artifacts and executes them on the
//! PJRT CPU client (pattern from /opt/xla-example/load_hlo.rs).
//!
//! Shape discipline: every artifact was compiled at fixed padded shapes
//! (manifest row/topic/shard buckets). This engine owns all padding:
//!
//! * `eta_solve` — single artifact call when D fits the row bucket;
//!   otherwise row chunks stream through the `gram_T*` artifact (the
//!   L1 Pallas Gram kernel) and the tiny T x T ridge system is solved
//!   coordinator-side (`regress::ridge`).
//! * `predict` / `loglik` — row-chunked artifact calls, metrics combined
//!   across chunks weighted by valid-row counts.
//! * `combine` — column-chunked `combine_M*` calls with zero-weight padding
//!   shards.
//!
//! NOT `Send` (PJRT client is `Rc`-based): lives on the service thread, see
//! `runtime::service`.

use super::manifest::Manifest;
use super::pad::{chunks, mask, pad_matrix, pad_vec, pad_vec_f64};
use super::{EngineImpl, Prediction};
use crate::regress::ridge;
use anyhow::Context;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

/// The XLA-backed engine (single-threaded; see module docs).
pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl XlaEngine {
    /// Create a CPU PJRT client and parse the artifact manifest.
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "xla engine: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.functions.len()
        );
        Ok(XlaEngine { client, manifest, executables: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (once) and execute artifact `name` with the given inputs,
    /// returning the decomposed output tuple.
    fn run(&self, name: &str, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        if !self.executables.borrow().contains_key(name) {
            let meta = self.manifest.artifact(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {:?}", meta.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            log::debug!("compiled artifact '{name}'");
            self.executables.borrow_mut().insert(name.to_string(), exe);
        }
        let cache = self.executables.borrow();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{name}'"))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(result.to_tuple()?)
    }

    fn matrix_literal(data: &[f32], rows: usize, cols: usize) -> anyhow::Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    fn scalar_literal(x: f64) -> xla::Literal {
        xla::Literal::scalar(x as f32)
    }
}

impl EngineImpl for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn eta_solve(
        &self,
        zbar: &[f32],
        y: &[f64],
        t: usize,
        lambda: f64,
        mu: f64,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        let rows = y.len();
        anyhow::ensure!(zbar.len() == rows * t, "zbar shape mismatch");
        anyhow::ensure!(rows > 0, "eta_solve on empty input");
        let tb = self.manifest.topic_bucket_for(t)?;
        let rb = self.manifest.row_bucket;

        if rows <= rb {
            // Single-shot artifact: (zbar, y, w, lam, mu) -> (eta, mse, wsum)
            let zp = pad_matrix(zbar, rows, t, rb, tb);
            let yp = pad_vec_f64(y, rb, 0.0);
            let wp = mask(rows, rb);
            let out = self.run(
                &format!("eta_solve_T{tb}"),
                &[
                    Self::matrix_literal(&zp, rb, tb)?,
                    xla::Literal::vec1(&yp),
                    xla::Literal::vec1(&wp),
                    Self::scalar_literal(lambda),
                    Self::scalar_literal(mu),
                ],
            )?;
            let eta_p = out[0].to_vec::<f32>()?;
            let mse = out[1].to_vec::<f32>()?[0] as f64;
            let eta: Vec<f64> = eta_p[..t].iter().map(|&x| x as f64).collect();
            return Ok((eta, mse));
        }

        // Chunked path: stream row chunks through the gram artifact, sum the
        // moments, solve the T x T system natively, compute MSE natively.
        let mut g_sum = vec![0.0f64; tb * tb];
        let mut b_sum = vec![0.0f64; tb];
        for (start, n) in chunks(rows, rb) {
            let zc = pad_matrix(&zbar[start * t..(start + n) * t], n, t, rb, tb);
            let yc = pad_vec_f64(&y[start..start + n], rb, 0.0);
            let wc = mask(n, rb);
            let out = self.run(
                &format!("gram_T{tb}"),
                &[
                    Self::matrix_literal(&zc, rb, tb)?,
                    xla::Literal::vec1(&yc),
                    xla::Literal::vec1(&wc),
                ],
            )?;
            let g = out[0].to_vec::<f32>()?;
            let b = out[1].to_vec::<f32>()?;
            for (acc, &v) in g_sum.iter_mut().zip(&g) {
                *acc += v as f64;
            }
            for (acc, &v) in b_sum.iter_mut().zip(&b) {
                *acc += v as f64;
            }
        }
        // Trim padded topics out of the moments (their rows/cols are zero).
        let mut g_t = vec![0.0f64; t * t];
        for i in 0..t {
            for j in 0..t {
                g_t[i * t + j] = g_sum[i * tb + j];
            }
        }
        let eta = ridge::ridge_solve_moments(&g_t, &b_sum[..t], t, lambda, mu)?;
        let w = vec![1.0f64; rows];
        let mse = ridge::weighted_mse(zbar, &eta, y, &w, t);
        Ok((eta, mse))
    }

    fn predict(
        &self,
        zbar: &[f32],
        eta: &[f64],
        y: Option<&[f64]>,
        t: usize,
    ) -> anyhow::Result<Prediction> {
        anyhow::ensure!(eta.len() == t, "eta len mismatch");
        anyhow::ensure!(zbar.len() % t == 0, "zbar not a multiple of t");
        let rows = zbar.len() / t;
        let tb = self.manifest.topic_bucket_for(t)?;
        let rb = self.manifest.row_bucket;
        let eta_p = pad_vec(&eta.iter().map(|&e| e as f32).collect::<Vec<f32>>(), tb, 0.0);

        let mut yhat = Vec::with_capacity(rows);
        let (mut se_n, mut hit_n, mut n_tot) = (0.0f64, 0.0f64, 0.0f64);
        for (start, n) in chunks(rows, rb) {
            let zc = pad_matrix(&zbar[start * t..(start + n) * t], n, t, rb, tb);
            let yc = match y {
                Some(ys) => pad_vec_f64(&ys[start..start + n], rb, 0.0),
                None => vec![0.0f32; rb],
            };
            let wc = mask(n, rb);
            let out = self.run(
                &format!("predict_T{tb}"),
                &[
                    Self::matrix_literal(&zc, rb, tb)?,
                    xla::Literal::vec1(&eta_p),
                    xla::Literal::vec1(&yc),
                    xla::Literal::vec1(&wc),
                ],
            )?;
            let yh = out[0].to_vec::<f32>()?;
            yhat.extend(yh[..n].iter().map(|&x| x as f64));
            let mse_c = out[1].to_vec::<f32>()?[0] as f64;
            let acc_c = out[2].to_vec::<f32>()?[0] as f64;
            se_n += mse_c * n as f64;
            hit_n += acc_c * n as f64;
            n_tot += n as f64;
        }
        let (mse, acc) = if y.is_some() && n_tot > 0.0 {
            (se_n / n_tot, hit_n / n_tot)
        } else {
            (0.0, 0.0)
        };
        Ok(Prediction { yhat, mse, acc })
    }

    fn combine(&self, preds: &[Vec<f64>], weights: &[f64]) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(!preds.is_empty(), "no predictions to combine");
        anyhow::ensure!(preds.len() == weights.len(), "preds/weights length mismatch");
        let m = preds.len();
        let mb = self.manifest.shard_bucket;
        anyhow::ensure!(m <= mb, "{m} shards exceed the shard bucket {mb}");
        let b = preds[0].len();
        anyhow::ensure!(preds.iter().all(|p| p.len() == b), "ragged prediction rows");
        let wsum: f64 = weights.iter().sum();
        anyhow::ensure!(wsum > 0.0, "combination weights sum to {wsum}");
        let rb = self.manifest.row_bucket;
        let w_p = pad_vec_f64(weights, mb, 0.0);

        let mut out = Vec::with_capacity(b);
        for (start, n) in chunks(b, rb) {
            // [M, n] column chunk, padded to [mb, rb].
            let mut block = vec![0.0f32; mb * rb];
            for (mi, p) in preds.iter().enumerate() {
                for j in 0..n {
                    block[mi * rb + j] = p[start + j] as f32;
                }
            }
            let res = self.run(
                &format!("combine_M{mb}"),
                &[Self::matrix_literal(&block, mb, rb)?, xla::Literal::vec1(&w_p)],
            )?;
            let yh = res[0].to_vec::<f32>()?;
            out.extend(yh[..n].iter().map(|&x| x as f64));
        }
        Ok(out)
    }

    fn loglik(&self, y: &[f64], mu: &[f32], t: usize, rho: f64) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(rho > 0.0, "rho must be positive");
        anyhow::ensure!(mu.len() == y.len() * t, "mu shape mismatch");
        let rows = y.len();
        let tb = self.manifest.topic_bucket_for(t)?;
        let rb = self.manifest.row_bucket;
        let mut out = Vec::with_capacity(rows * t);
        for (start, n) in chunks(rows, rb) {
            let yc = pad_vec_f64(&y[start..start + n], rb, 0.0);
            let mc = pad_matrix(&mu[start * t..(start + n) * t], n, t, rb, tb);
            let res = self.run(
                &format!("loglik_T{tb}"),
                &[
                    xla::Literal::vec1(&yc),
                    Self::matrix_literal(&mc, rb, tb)?,
                    Self::scalar_literal(rho),
                ],
            )?;
            let grid = res[0].to_vec::<f32>()?;
            for r in 0..n {
                out.extend_from_slice(&grid[r * tb..r * tb + t]);
            }
        }
        Ok(out)
    }
}

// No #[cfg(test)] unit tests here: XLA-vs-native agreement is covered by
// rust/tests/integration_runtime.rs (needs built artifacts), which keeps
// `cargo test --lib` artifact-free.
