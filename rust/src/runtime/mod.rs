//! Numerical engine: executes the dense sLDA algebra (eta solve, batched
//! prediction, combination, response log-densities).
//!
//! Two implementations behind one interface:
//!
//! * [`xla::XlaEngine`] — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//!   produced once by `make artifacts`) and runs them on the PJRT CPU
//!   client. The `xla` crate's client is `Rc`-based (not `Send`), so the
//!   engine lives on a dedicated **service thread** and worker threads talk
//!   to it through the clonable [`EngineHandle`]; calls are coarse (once per
//!   eta step / prediction batch), so serialization is immaterial.
//! * [`native::NativeEngine`] — bit-compatible pure-rust fallback and the
//!   cross-validation oracle for integration tests.

pub mod manifest;
pub mod native;
pub mod pad;
pub mod service;
pub mod xla;

use crate::config::schema::EngineKind;
use crate::model::counts::CountMatrices;
use crate::regress::ridge;
use std::path::Path;
use std::sync::Arc;

/// Result of a batched prediction.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// Point predictions, one per input row.
    pub yhat: Vec<f64>,
    /// Weighted MSE against the supplied labels (0.0 if labels absent).
    pub mse: f64,
    /// Accuracy at the 0.5 threshold (binary responses).
    pub acc: f64,
}

/// The engine operations (all row-major f32 matrices).
pub trait EngineImpl {
    fn name(&self) -> &'static str;

    /// MAP eta (paper eq. 2): zbar is [D, T]; returns (eta, train MSE).
    fn eta_solve(
        &self,
        zbar: &[f32],
        y: &[f64],
        t: usize,
        lambda: f64,
        mu: f64,
    ) -> anyhow::Result<(Vec<f64>, f64)>;

    /// Batched yhat = zbar @ eta (paper eq. 5) + metrics vs optional labels.
    fn predict(
        &self,
        zbar: &[f32],
        eta: &[f64],
        y: Option<&[f64]>,
        t: usize,
    ) -> anyhow::Result<Prediction>;

    /// Weighted combination over shards (paper eqs. 7-9); weights need not
    /// be normalized.
    fn combine(&self, preds: &[Vec<f64>], weights: &[f64]) -> anyhow::Result<Vec<f64>>;

    /// Gaussian response log-density grid: y[B] x mu[B, T] -> [B, T].
    fn loglik(&self, y: &[f64], mu: &[f32], t: usize, rho: f64) -> anyhow::Result<Vec<f32>>;
}

/// Thread-safe, clonable handle to an engine.
#[derive(Clone)]
pub enum EngineHandle {
    Native(Arc<native::NativeEngine>),
    Xla(service::XlaService),
}

impl EngineHandle {
    /// Pure-rust engine.
    pub fn native() -> Self {
        EngineHandle::Native(Arc::new(native::NativeEngine::new()))
    }

    /// XLA engine backed by the artifacts directory (spawns the service
    /// thread and compiles lazily per artifact).
    pub fn xla(artifacts_dir: &Path) -> anyhow::Result<Self> {
        Ok(EngineHandle::Xla(service::XlaService::spawn(artifacts_dir)?))
    }

    /// Select by [`EngineKind`]; `Auto` takes XLA when the manifest exists.
    pub fn from_kind(kind: EngineKind, artifacts_dir: &Path) -> anyhow::Result<Self> {
        match kind {
            EngineKind::Native => Ok(Self::native()),
            EngineKind::Xla => Self::xla(artifacts_dir),
            EngineKind::Auto => {
                if artifacts_dir.join("manifest.json").exists() {
                    Self::xla(artifacts_dir)
                } else {
                    log::warn!(
                        "no artifacts manifest under {artifacts_dir:?}; falling back to native \
                         engine (run `make artifacts` for the XLA path)"
                    );
                    Ok(Self::native())
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineHandle::Native(e) => e.name(),
            EngineHandle::Xla(_) => "xla",
        }
    }

    pub fn eta_solve(
        &self,
        zbar: &[f32],
        y: &[f64],
        t: usize,
        lambda: f64,
        mu: f64,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        match self {
            EngineHandle::Native(e) => e.eta_solve(zbar, y, t, lambda, mu),
            EngineHandle::Xla(s) => s.eta_solve(zbar, y, t, lambda, mu),
        }
    }

    /// MAP eta (paper eq. 2) straight from the Gibbs count state. The
    /// native engine accumulates the Gram moments over the counts'
    /// non-zeros ([`ridge::gram_moments_from_counts`], O(Σ_d nnz_d²)) and
    /// never touches `zbar_scratch`; the XLA engine materializes zbar into
    /// the caller's reusable buffer and dispatches the AOT gram kernel as
    /// before. Numerically identical to [`EngineHandle::eta_solve`] on
    /// [`CountMatrices::zbar_matrix`]'s output (bitwise, on the native
    /// path).
    pub fn eta_solve_counts(
        &self,
        counts: &CountMatrices,
        y: &[f64],
        lambda: f64,
        mu: f64,
        zbar_scratch: &mut Vec<f32>,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        match self {
            EngineHandle::Native(_) => {
                let t = counts.t;
                let (g, b, _) = ridge::gram_moments_from_counts(counts, y, None);
                let eta = ridge::ridge_solve_moments(&g, &b, t, lambda, mu)?;
                let mse = ridge::mse_from_counts(counts, &eta, y, None);
                Ok((eta, mse))
            }
            EngineHandle::Xla(s) => {
                counts.zbar_matrix_into(zbar_scratch);
                s.eta_solve(zbar_scratch, y, counts.t, lambda, mu)
            }
        }
    }

    pub fn predict(
        &self,
        zbar: &[f32],
        eta: &[f64],
        y: Option<&[f64]>,
        t: usize,
    ) -> anyhow::Result<Prediction> {
        match self {
            EngineHandle::Native(e) => e.predict(zbar, eta, y, t),
            EngineHandle::Xla(s) => s.predict(zbar, eta, y, t),
        }
    }

    pub fn combine(&self, preds: &[Vec<f64>], weights: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self {
            EngineHandle::Native(e) => e.combine(preds, weights),
            EngineHandle::Xla(s) => s.combine(preds, weights),
        }
    }

    pub fn loglik(&self, y: &[f64], mu: &[f32], t: usize, rho: f64) -> anyhow::Result<Vec<f32>> {
        match self {
            EngineHandle::Native(e) => e.loglik(y, mu, t, rho),
            EngineHandle::Xla(s) => s.loglik(y, mu, t, rho),
        }
    }
}
