//! Generation-oriented checkpoint store with atomic commits.
//!
//! Disk layout under the checkpoint directory:
//!
//! ```text
//! DIR/gen-<N>/shard-<i>.ckpt   one CFSCKPT1 snapshot per shard chain
//! DIR/gen-<N>/MANIFEST         CFSMANI1 commit record, written last
//! ```
//!
//! `<N>` is the snapshot's `next_sweep` — strictly increasing across a run,
//! so lexicographic-by-number ordering is recovery order. Every file lands
//! via write-temp → fsync → rename → fsync(dir); the manifest rename is the
//! generation's commit point, and an older committed generation is only
//! deleted (retention keeps the newest two) after a newer manifest has
//! landed. A crash at any instant therefore leaves either (a) the previous
//! committed generation intact plus ignorable debris, or (b) the new
//! generation committed — never a half-trusted state (DESIGN.md
//! §Durability; the crash windows are enumerated in the `FailpointFs`
//! tests).
//!
//! Recovery ([`Store::load_latest`]) scans generations newest-first. A
//! generation that fails *integrity* (missing manifest, checksum mismatch,
//! shard file absent or not matching its manifest entry) is logged and
//! skipped — that is exactly the debris a crash is allowed to leave. A
//! generation that is internally valid but carries the wrong config
//! fingerprint is a hard error: resuming a different chain must never be
//! silent.

use super::format::{Manifest, ManifestShard, ShardState};
use super::fs::CkptFs;
use crate::model::persist::fnv1a;
use anyhow::{bail, Context};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Committed generations kept on disk (newest two: the freshly committed
/// one and its predecessor, so a torn newest still has a fallback).
pub const RETAIN_GENERATIONS: usize = 2;

/// A fully restored generation, ready to seed resumed chains.
#[derive(Debug)]
pub struct Resume {
    pub generation: u64,
    pub next_sweep: u64,
    /// One state per shard, sorted by `shard_id`.
    pub states: Vec<ShardState>,
}

pub struct Store<'f> {
    fs: &'f dyn CkptFs,
    dir: PathBuf,
}

impl<'f> Store<'f> {
    pub fn new(fs: &'f dyn CkptFs, dir: impl Into<PathBuf>) -> Store<'f> {
        Store { fs, dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn gen_dir(&self, generation: u64) -> PathBuf {
        self.dir.join(format!("gen-{generation}"))
    }

    /// Write one shard's snapshot into `gen-<generation>` atomically.
    /// Returns the manifest entry binding the file. No generation is
    /// trusted until [`Store::commit_manifest`] lands.
    pub fn write_shard(
        &self,
        generation: u64,
        state: &ShardState,
    ) -> anyhow::Result<ManifestShard> {
        let gdir = self.gen_dir(generation);
        self.fs
            .create_dir_all(&gdir)
            .with_context(|| format!("creating checkpoint dir {gdir:?}"))?;
        let bytes = state.encode();
        let name = format!("shard-{}.ckpt", state.shard_id);
        let tmp = gdir.join(format!("{name}.tmp"));
        let fin = gdir.join(&name);
        self.fs.write(&tmp, &bytes).with_context(|| format!("writing {tmp:?}"))?;
        self.fs.fsync(&tmp).with_context(|| format!("fsync {tmp:?}"))?;
        self.fs.rename(&tmp, &fin).with_context(|| format!("renaming {tmp:?} -> {fin:?}"))?;
        self.fs.fsync(&gdir).with_context(|| format!("fsync dir {gdir:?}"))?;
        crate::obs::registry().training.ckpt_writes.inc();
        Ok(ManifestShard {
            shard_id: state.shard_id,
            bytes: bytes.len() as u64,
            file_fnv: fnv1a(&bytes),
        })
    }

    /// Commit a generation: land its manifest atomically, update telemetry,
    /// and prune generations older than [`RETAIN_GENERATIONS`].
    pub fn commit_manifest(
        &self,
        generation: u64,
        manifest: &Manifest,
        write_us: u64,
    ) -> anyhow::Result<()> {
        let gdir = self.gen_dir(generation);
        let bytes = manifest.encode();
        let tmp = gdir.join("MANIFEST.tmp");
        let fin = gdir.join("MANIFEST");
        self.fs.write(&tmp, &bytes).with_context(|| format!("writing {tmp:?}"))?;
        self.fs.fsync(&tmp).with_context(|| format!("fsync {tmp:?}"))?;
        self.fs.rename(&tmp, &fin).with_context(|| format!("renaming {tmp:?} -> {fin:?}"))?;
        self.fs.fsync(&gdir).with_context(|| format!("fsync dir {gdir:?}"))?;
        // Make the gen-<N> directory entry itself durable.
        self.fs.fsync(&self.dir).with_context(|| format!("fsync dir {:?}", self.dir))?;

        let tr = &crate::obs::registry().training;
        tr.ckpt_generations.inc();
        tr.ckpt_last_sweep.set(manifest.next_sweep);
        tr.ckpt_last_bytes
            .set(manifest.shards.iter().map(|s| s.bytes).sum::<u64>() + bytes.len() as u64);
        tr.ckpt_last_write_us.set(write_us);
        if let Ok(now) = std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH) {
            tr.ckpt_last_unix_secs.set(now.as_secs());
        }

        // Retention: only after the new commit point exists. Failure to
        // prune is not a checkpoint failure — just disk debris.
        if let Err(e) = self.retain(RETAIN_GENERATIONS) {
            log::warn!("checkpoint retention in {:?}: {e:#}", self.dir);
        }
        Ok(())
    }

    /// Generation numbers present under the store directory, ascending.
    /// Non-generation entries are ignored.
    pub fn list_generations(&self) -> anyhow::Result<Vec<u64>> {
        if !self.fs.exists(&self.dir) {
            return Ok(Vec::new());
        }
        let names = self
            .fs
            .list_dir(&self.dir)
            .with_context(|| format!("listing checkpoint dir {:?}", self.dir))?;
        let mut gens: Vec<u64> = names
            .iter()
            .filter_map(|n| n.strip_prefix("gen-").and_then(|s| s.parse().ok()))
            .collect();
        gens.sort_unstable();
        gens.dedup();
        Ok(gens)
    }

    /// Is there at least one generation with a landed commit record? A
    /// cheap existence probe (no integrity verification): pre-commit crash
    /// debris — generation directories without a `MANIFEST` — does not
    /// count.
    pub fn has_committed_generation(&self) -> anyhow::Result<bool> {
        Ok(self
            .list_generations()?
            .iter()
            .any(|&g| self.fs.exists(&self.gen_dir(g).join("MANIFEST"))))
    }

    /// Delete all but the newest `keep` generations.
    pub fn retain(&self, keep: usize) -> anyhow::Result<()> {
        let gens = self.list_generations()?;
        for &g in gens.iter().rev().skip(keep) {
            let gdir = self.gen_dir(g);
            self.fs.remove_dir_all(&gdir).with_context(|| format!("removing {gdir:?}"))?;
        }
        Ok(())
    }

    /// Load one generation in full, verifying the manifest, every shard
    /// file's size and checksum against its manifest entry, and internal
    /// consistency. Does not check the fingerprint (the caller decides how
    /// a mismatch is handled).
    fn load_generation(&self, generation: u64) -> anyhow::Result<(Manifest, Vec<ShardState>)> {
        let gdir = self.gen_dir(generation);
        let mpath = gdir.join("MANIFEST");
        let mbytes = self.fs.read(&mpath).with_context(|| format!("reading {mpath:?}"))?;
        let manifest = Manifest::decode(&mbytes).with_context(|| format!("in {mpath:?}"))?;
        if manifest.next_sweep != generation {
            bail!(
                "manifest in {gdir:?} records next_sweep {} (want {generation})",
                manifest.next_sweep
            );
        }
        let mut states = Vec::with_capacity(manifest.shards.len());
        for entry in &manifest.shards {
            let spath = gdir.join(format!("shard-{}.ckpt", entry.shard_id));
            let sbytes = self.fs.read(&spath).with_context(|| format!("reading {spath:?}"))?;
            if sbytes.len() as u64 != entry.bytes || fnv1a(&sbytes) != entry.file_fnv {
                bail!(
                    "{spath:?} does not match its manifest entry \
                     ({} bytes on disk, {} expected)",
                    sbytes.len(),
                    entry.bytes
                );
            }
            let state = ShardState::decode(&sbytes).with_context(|| format!("in {spath:?}"))?;
            if state.shard_id != entry.shard_id || state.next_sweep != generation {
                bail!(
                    "{spath:?} identifies as shard {} at sweep {} \
                     (manifest says shard {} at sweep {generation})",
                    state.shard_id,
                    state.next_sweep,
                    entry.shard_id
                );
            }
            states.push(state);
        }
        Ok((manifest, states))
    }

    /// Restore the newest *valid* generation. Integrity failures fall back
    /// to older generations with a warning; a valid generation whose
    /// fingerprint differs from `expect_fingerprint` is a hard error; no
    /// valid generation at all is a hard error.
    pub fn load_latest(&self, expect_fingerprint: u64) -> anyhow::Result<Resume> {
        let gens = self.list_generations()?;
        if gens.is_empty() {
            bail!("no checkpoint generations found in {:?}", self.dir);
        }
        let mut last_err = None;
        for &g in gens.iter().rev() {
            match self.load_generation(g) {
                Ok((manifest, states)) => {
                    if manifest.fingerprint != expect_fingerprint {
                        bail!(
                            "checkpoint generation {g} in {:?} was written by a different \
                             run configuration (fingerprint {:#018x}, live config is \
                             {expect_fingerprint:#018x}); refusing to resume a different \
                             chain — pass the original config/seed/corpus or choose a \
                             fresh checkpoint directory",
                            self.dir,
                            manifest.fingerprint
                        );
                    }
                    crate::obs::registry()
                        .training
                        .ckpt_restores
                        .add(states.len() as u64);
                    return Ok(Resume { generation: g, next_sweep: manifest.next_sweep, states });
                }
                Err(e) => {
                    log::warn!(
                        "checkpoint generation {g} in {:?} is unusable (likely an \
                         interrupted write): {e:#}; trying an older generation",
                        self.dir
                    );
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap()).with_context(|| {
            format!("no valid checkpoint generation in {:?} (all candidates corrupt)", self.dir)
        })
    }
}

/// Cross-thread completion tracker for one run's generations: each worker
/// reports its shard write, and the last one to land a given generation
/// gets the assembled manifest back to commit. Workers drift through
/// boundaries at their own pace, so multiple generations can be pending.
pub struct GenCoordinator {
    shards: usize,
    fingerprint: u64,
    inner: Mutex<HashMap<u64, Pending>>,
}

struct Pending {
    entries: Vec<ManifestShard>,
    write_us: u64,
}

impl GenCoordinator {
    pub fn new(shards: usize, fingerprint: u64) -> GenCoordinator {
        GenCoordinator { shards, fingerprint, inner: Mutex::new(HashMap::new()) }
    }

    /// Record one shard's landed snapshot for `generation`. Returns the
    /// complete manifest (shards sorted) plus the summed per-shard write
    /// time exactly once — to the caller that completes the set.
    pub fn shard_done(
        &self,
        generation: u64,
        entry: ManifestShard,
        write_us: u64,
    ) -> Option<(Manifest, u64)> {
        let mut map = self.inner.lock().unwrap();
        let pending = map
            .entry(generation)
            .or_insert_with(|| Pending { entries: Vec::new(), write_us: 0 });
        pending.entries.push(entry);
        pending.write_us += write_us;
        if pending.entries.len() < self.shards {
            return None;
        }
        let mut done = map.remove(&generation).unwrap();
        done.entries.sort_by_key(|e| e.shard_id);
        Some((
            Manifest {
                fingerprint: self.fingerprint,
                next_sweep: generation,
                shards: done.entries,
            },
            done.write_us,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::format::config_fingerprint;
    use crate::ckpt::fs::StdFs;
    use crate::config::schema::ExperimentConfig;
    use crate::util::rng::Pcg64;

    fn tmpdir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_store_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    fn state(shard_id: u32, next_sweep: u64, seed: u64) -> ShardState {
        let mut rng = Pcg64::seed_from_u64(seed);
        let (t, w, d) = (3usize, 5usize, 2usize);
        ShardState {
            shard_id,
            next_sweep,
            t: t as u32,
            w: w as u32,
            d: d as u32,
            rho: 1.0,
            eta_active: false,
            tokens_sampled: 10,
            resp_proposed: 0,
            resp_accepted: 0,
            alias_rebuilds: 0,
            rng_state: rng.next_u64() as u128,
            rng_inc: (rng.next_u64() as u128) | 1,
            eta: vec![0.0; t],
            z: (0..8).map(|_| rng.gen_range(t) as u16).collect(),
            ndt: vec![1; d * t],
            nd: vec![3; d],
            ntw: vec![1; w * t],
            nt: vec![2; t],
            history: vec![],
        }
    }

    fn commit_gen(store: &Store, fp: u64, sweep: u64, shards: u32) {
        let coord = GenCoordinator::new(shards as usize, fp);
        for i in 0..shards {
            let entry = store.write_shard(sweep, &state(i, sweep, sweep * 10 + i as u64)).unwrap();
            if let Some((m, us)) = coord.shard_done(sweep, entry, 5) {
                assert_eq!(us, 5 * shards as u64);
                store.commit_manifest(sweep, &m, us).unwrap();
            }
        }
    }

    #[test]
    fn write_commit_load_roundtrip() {
        let dir = tmpdir("rt");
        let fs = StdFs;
        let store = Store::new(&fs, &dir);
        let fp = config_fingerprint(&ExperimentConfig::quick(), 2, 8, 5, "non-parallel", 2);
        commit_gen(&store, fp, 10, 2);
        let r = store.load_latest(fp).unwrap();
        assert_eq!(r.generation, 10);
        assert_eq!(r.next_sweep, 10);
        assert_eq!(r.states.len(), 2);
        assert_eq!(r.states[0], state(0, 10, 100));
        assert_eq!(r.states[1], state(1, 10, 101));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retention_keeps_newest_two() {
        let dir = tmpdir("retain");
        let fs = StdFs;
        let store = Store::new(&fs, &dir);
        for sweep in [10u64, 20, 30] {
            commit_gen(&store, 7, sweep, 1);
        }
        assert_eq!(store.list_generations().unwrap(), vec![20, 30]);
        let r = store.load_latest(7).unwrap();
        assert_eq!(r.generation, 30);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn uncommitted_generation_falls_back_to_previous() {
        let dir = tmpdir("fallback");
        let fs = StdFs;
        let store = Store::new(&fs, &dir);
        commit_gen(&store, 7, 10, 2);
        // newer generation with shards but no manifest: the pre-commit
        // crash window
        store.write_shard(20, &state(0, 20, 1)).unwrap();
        store.write_shard(20, &state(1, 20, 2)).unwrap();
        let r = store.load_latest(7).unwrap();
        assert_eq!(r.generation, 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_newest_falls_back_missing_shard_and_bitflip() {
        let dir = tmpdir("corrupt");
        let fs = StdFs;
        let store = Store::new(&fs, &dir);
        commit_gen(&store, 7, 10, 2);
        commit_gen(&store, 7, 20, 2);
        // bit-flip one shard of the newest committed generation
        let victim = dir.join("gen-20").join("shard-1.ckpt");
        let mut b = std::fs::read(&victim).unwrap();
        let mid = b.len() / 2;
        b[mid] ^= 0x40;
        std::fs::write(&victim, &b).unwrap();
        let r = store.load_latest(7).unwrap();
        assert_eq!(r.generation, 10, "bit-flipped gen must be skipped");
        // now remove a shard file entirely
        std::fs::remove_file(&victim).unwrap();
        let r = store.load_latest(7).unwrap();
        assert_eq!(r.generation, 10, "missing-shard gen must be skipped");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_mismatch_is_a_hard_error() {
        let dir = tmpdir("fp");
        let fs = StdFs;
        let store = Store::new(&fs, &dir);
        commit_gen(&store, 7, 10, 1);
        let err = store.load_latest(8).unwrap_err().to_string();
        assert!(err.contains("different"), "{err}");
        assert!(err.contains("fingerprint"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_or_all_corrupt_dir_is_an_error_not_a_panic() {
        let dir = tmpdir("empty");
        let fs = StdFs;
        let store = Store::new(&fs, &dir);
        let err = store.load_latest(7).unwrap_err().to_string();
        assert!(err.contains("no checkpoint generations"), "{err}");
        // a generation dir with garbage manifest only
        std::fs::create_dir_all(dir.join("gen-5")).unwrap();
        std::fs::write(dir.join("gen-5").join("MANIFEST"), b"garbage").unwrap();
        let err = store.load_latest(7).unwrap_err().to_string();
        assert!(err.contains("no valid checkpoint generation"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn committed_generation_probe_ignores_debris() {
        let dir = tmpdir("probe");
        let fs = StdFs;
        let store = Store::new(&fs, &dir);
        assert!(!store.has_committed_generation().unwrap());
        // pre-commit debris: a shard file but no manifest
        store.write_shard(5, &state(0, 5, 1)).unwrap();
        assert!(!store.has_committed_generation().unwrap());
        commit_gen(&store, 7, 10, 1);
        assert!(store.has_committed_generation().unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The crash-window enumeration (DESIGN.md §Durability): a hard kill at
    /// *every* mutating operation of a generation's write sequence must
    /// leave recovery on a committed, fully-valid generation — the previous
    /// one before the manifest rename lands, the new one after.
    #[test]
    fn kill_at_every_crash_window_recovers_to_a_committed_generation() {
        use crate::testkit::failfs::{FailKind, FailpointFs};
        // One 1-shard generation = 9 counted ops: write_shard is
        // {write tmp, fsync tmp, rename, fsync gen-dir}, commit_manifest is
        // {write tmp, fsync tmp, rename, fsync gen-dir, fsync store-dir}.
        // Each slot's FailKind matches the op type at that index.
        let kinds = [
            FailKind::TornWrite { keep: 3 }, // shard tmp write
            FailKind::ErrFsync,              // shard tmp fsync
            FailKind::ErrRename,             // shard rename
            FailKind::ErrFsync,              // gen-dir fsync
            FailKind::TornWrite { keep: 3 }, // manifest tmp write
            FailKind::ErrFsync,              // manifest tmp fsync
            FailKind::ErrRename,             // manifest rename = commit point
            FailKind::ErrFsync,              // gen-dir fsync
            FailKind::ErrFsync,              // store-dir fsync
        ];
        const COMMIT_RENAME: usize = 6;
        let fp = 99;
        for (kill_at, kind) in kinds.iter().enumerate() {
            let dir = tmpdir(&format!("kill{kill_at}"));
            let fs = FailpointFs::new();
            let store = Store::new(&fs, &dir);
            // Generation 5 lands cleanly, then the process dies somewhere
            // in generation 10's write sequence.
            commit_gen(&store, fp, 5, 1);
            fs.arm(fs.ops() + kill_at as u64, *kind, true);
            let attempt = (|| -> anyhow::Result<()> {
                let coord = GenCoordinator::new(1, fp);
                let entry = store.write_shard(10, &state(0, 10, 77))?;
                if let Some((m, us)) = coord.shard_done(10, entry, 5) {
                    store.commit_manifest(10, &m, us)?;
                }
                Ok(())
            })();
            assert!(attempt.is_err(), "armed op {kill_at} must surface an Err");
            assert!(fs.is_dead());
            // Recovery runs in the "next process": reads still work.
            let r = store
                .load_latest(fp)
                .unwrap_or_else(|e| panic!("kill at op {kill_at}: {e:#}"));
            if kill_at > COMMIT_RENAME {
                assert_eq!(r.generation, 10, "op {kill_at}: manifest already renamed");
                assert_eq!(r.states[0], state(0, 10, 77));
            } else {
                assert_eq!(r.generation, 5, "op {kill_at}: must fall back to gen 5");
                assert_eq!(r.states[0], state(0, 5, 50));
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn kill_between_shards_leaves_previous_generation_authoritative() {
        use crate::testkit::failfs::{FailKind, FailpointFs};
        let dir = tmpdir("killshard");
        let fs = FailpointFs::new();
        let store = Store::new(&fs, &dir);
        let fp = 7;
        commit_gen(&store, fp, 5, 2);
        // Shard 0 of gen 10 lands (4 ops), then the process dies on shard
        // 1's very first write — no manifest ever commits.
        fs.arm(fs.ops() + 4, FailKind::TornWrite { keep: 0 }, true);
        store.write_shard(10, &state(0, 10, 1)).unwrap();
        assert!(store.write_shard(10, &state(1, 10, 2)).is_err());
        let r = store.load_latest(fp).unwrap();
        assert_eq!(r.generation, 5);
        assert_eq!(r.states.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn coordinator_completes_each_generation_once() {
        let coord = GenCoordinator::new(3, 42);
        let e = |id| ManifestShard { shard_id: id, bytes: 10, file_fnv: 1 };
        assert!(coord.shard_done(30, e(2), 1).is_none());
        assert!(coord.shard_done(30, e(0), 2).is_none());
        // a second generation can be pending concurrently
        assert!(coord.shard_done(60, e(1), 9).is_none());
        let (m, us) = coord.shard_done(30, e(1), 3).expect("third shard completes gen 30");
        assert_eq!(us, 6);
        assert_eq!(m.fingerprint, 42);
        assert_eq!(m.next_sweep, 30);
        let ids: Vec<u32> = m.shards.iter().map(|s| s.shard_id).collect();
        assert_eq!(ids, vec![0, 1, 2], "entries sorted regardless of completion order");
    }
}
