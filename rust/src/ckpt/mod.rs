//! Crash-safe training durability (DESIGN.md §Durability).
//!
//! The paper's communication-free design makes the shard chain the natural
//! unit of checkpointing: each worker snapshots its own chain with zero
//! coordination beyond a last-writer-commits manifest. This module provides
//! the pieces:
//!
//! * [`format`] — the `CFSCKPT1` shard snapshot and `CFSMANI1` manifest
//!   codecs plus [`config_fingerprint`], all checksummed and hardened
//!   against truncated/bit-flipped/hostile inputs.
//! * [`fs`] — the [`CkptFs`] seam ([`StdFs`] in production, the testkit's
//!   `FailpointFs` under fault injection).
//! * [`store`] — atomic generation commits, retention, and newest-valid
//!   recovery ([`Store`], [`GenCoordinator`]).
//!
//! The contract the rest of the system builds on: a run checkpointed at
//! sweep k, killed, and resumed with `--resume` is **byte-identical** to
//! the same run left uninterrupted (see `sampler::gibbs_train` for the
//! kernel-epoch reset that makes the RNG/count state at a boundary a pure
//! function of the snapshot).

pub mod format;
pub mod fs;
pub mod store;

pub use format::{config_fingerprint, Manifest, ManifestShard, ShardState};
pub use fs::{CkptFs, StdFs};
pub use store::{GenCoordinator, Resume, Store, RETAIN_GENERATIONS};
