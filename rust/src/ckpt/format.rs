//! On-disk checkpoint formats: `CFSCKPT1` shard snapshots and `CFSMANI1`
//! generation manifests.
//!
//! Both reuse the model-persistence framing (`crate::model::persist`):
//! 8-byte magic | little-endian body | trailing FNV-1a-64 over the body.
//! The checksum is verified before any structural parsing, and every length
//! field is proven backed by bytes before a buffer is allocated for it, so
//! a truncated or bit-flipped file yields `Err` with offset context — never
//! a panic or a hostile-length allocation (same contract as the hardened
//! model loader).
//!
//! A shard snapshot captures *everything* its chain needs to continue
//! byte-identically (DESIGN.md §Durability): the token-topic assignments
//! `z`, all four count matrices, the regression state (eta / eta_active /
//! rho), the raw PCG64 state of the worker's RNG stream, kernel counter
//! baselines, the eta-step history, and the sweep to resume at. The
//! manifest binds one generation's shard files together with their sizes
//! and checksums plus the config fingerprint, and is written last — its
//! rename is the generation's commit point.

use crate::config::schema::ExperimentConfig;
use crate::model::persist::fnv1a;
use crate::sampler::gibbs_train::SweepStats;
use anyhow::bail;

pub const SHARD_MAGIC: &[u8; 8] = b"CFSCKPT1";
pub const MANIFEST_MAGIC: &[u8; 8] = b"CFSMANI1";

/// Ceilings mirroring the model loader's plausibility bounds: topic ids are
/// `u16`-backed, vocab/doc counts beyond 2^28 are corrupted length fields.
const MAX_T: usize = 1 << 16;
const MAX_W: usize = 1 << 28;
const MAX_D: usize = 1 << 28;
/// More history entries than one per sweep at the cadence floor is corrupt.
const MAX_HISTORY: usize = 1 << 24;
/// Shard count ceiling (config allows at most 16; leave headroom).
const MAX_SHARDS: usize = 1 << 10;

/// Complete resumable state of one shard chain.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardState {
    pub shard_id: u32,
    /// First sweep the resumed chain will run (sweeps before it are done).
    pub next_sweep: u64,
    pub t: u32,
    pub w: u32,
    pub d: u32,
    /// Current noise variance (differs from config under `learn_rho`).
    pub rho: f64,
    pub eta_active: bool,
    pub tokens_sampled: u64,
    /// Kernel counter baselines: totals accumulated by kernels that were
    /// already torn down at earlier checkpoint boundaries (the live kernel's
    /// counters are added on top at the next boundary / at completion).
    pub resp_proposed: u64,
    pub resp_accepted: u64,
    pub alias_rebuilds: u64,
    /// Raw PCG64 (state, increment) of the worker's RNG stream.
    pub rng_state: u128,
    pub rng_inc: u128,
    pub eta: Vec<f64>,
    /// Token-topic assignments in corpus-view arena order.
    pub z: Vec<u16>,
    pub ndt: Vec<u32>,
    pub nd: Vec<u32>,
    pub ntw: Vec<u32>,
    pub nt: Vec<u32>,
    pub history: Vec<SweepStats>,
}

/// One shard's entry in a generation manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestShard {
    pub shard_id: u32,
    /// Size of the shard file in bytes (magic + body + checksum).
    pub bytes: u64,
    /// FNV-1a over the *whole* shard file (cheap cross-file binding on top
    /// of the file's own internal checksum).
    pub file_fnv: u64,
}

/// Generation manifest: the commit record binding shard files to a config.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// [`config_fingerprint`] of the run that wrote the generation.
    pub fingerprint: u64,
    pub next_sweep: u64,
    /// Sorted by `shard_id`; exactly one entry per shard of the run.
    pub shards: Vec<ManifestShard>,
}

/// Frame a body: magic | body | fnv1a(body).
fn frame(magic: &[u8; 8], body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + body.len() + 8);
    out.extend_from_slice(magic);
    out.extend_from_slice(body);
    out.extend_from_slice(&fnv1a(body).to_le_bytes());
    out
}

/// Verify magic + checksum, return the body slice.
fn unframe<'a>(magic: &[u8; 8], bytes: &'a [u8], what: &str) -> anyhow::Result<&'a [u8]> {
    if bytes.len() < 16 {
        bail!("truncated {what}: {} bytes, need at least 16", bytes.len());
    }
    if &bytes[..8] != magic {
        bail!(
            "not a {what} (bad magic {:02x?}, want {:?})",
            &bytes[..8],
            String::from_utf8_lossy(magic)
        );
    }
    let (body, ck) = bytes[8..].split_at(bytes.len() - 16);
    let want = u64::from_le_bytes(ck.try_into().unwrap());
    if fnv1a(body) != want {
        bail!("{what} checksum mismatch — corrupted file");
    }
    Ok(body)
}

/// Bounds-checked little-endian cursor with offset-bearing errors.
struct Cur<'a> {
    body: &'a [u8],
    off: usize,
    what: &'static str,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let avail = self.body.len() - self.off;
        if n > avail {
            bail!(
                "truncated {} body at offset {}: need {n} bytes, {avail} available",
                self.what,
                self.off
            );
        }
        let s = &self.body[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u128(&mut self) -> anyhow::Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Prove `n` elements of `elem_bytes` each are backed by bytes (with
    /// checked arithmetic) before any allocation for them.
    fn ensure_backed(&self, n: usize, elem_bytes: usize, field: &str) -> anyhow::Result<()> {
        let need = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| anyhow::anyhow!("{} length {n} for '{field}' overflows", self.what))?;
        let avail = self.body.len() - self.off;
        if need > avail {
            bail!(
                "truncated {} body at offset {}: '{field}' needs {need} bytes, {avail} available",
                self.what,
                self.off
            );
        }
        Ok(())
    }

    fn vec_u16(&mut self, n: usize, field: &str) -> anyhow::Result<Vec<u16>> {
        self.ensure_backed(n, 2, field)?;
        let raw = self.take(n * 2)?;
        Ok(raw.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
    }

    fn vec_u32(&mut self, n: usize, field: &str) -> anyhow::Result<Vec<u32>> {
        self.ensure_backed(n, 4, field)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn vec_f64(&mut self, n: usize, field: &str) -> anyhow::Result<Vec<f64>> {
        self.ensure_backed(n, 8, field)?;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> anyhow::Result<()> {
        if self.off != self.body.len() {
            bail!(
                "trailing bytes in {} body: {} past offset {}",
                self.what,
                self.body.len() - self.off,
                self.off
            );
        }
        Ok(())
    }
}

impl ShardState {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(
            128 + self.eta.len() * 8
                + self.z.len() * 2
                + (self.ndt.len() + self.nd.len() + self.ntw.len() + self.nt.len()) * 4
                + self.history.len() * 32,
        );
        b.extend_from_slice(&self.shard_id.to_le_bytes());
        b.extend_from_slice(&self.next_sweep.to_le_bytes());
        b.extend_from_slice(&self.t.to_le_bytes());
        b.extend_from_slice(&self.w.to_le_bytes());
        b.extend_from_slice(&self.d.to_le_bytes());
        b.extend_from_slice(&self.rho.to_le_bytes());
        b.push(self.eta_active as u8);
        b.extend_from_slice(&self.tokens_sampled.to_le_bytes());
        b.extend_from_slice(&self.resp_proposed.to_le_bytes());
        b.extend_from_slice(&self.resp_accepted.to_le_bytes());
        b.extend_from_slice(&self.alias_rebuilds.to_le_bytes());
        b.extend_from_slice(&self.rng_state.to_le_bytes());
        b.extend_from_slice(&self.rng_inc.to_le_bytes());
        for &e in &self.eta {
            b.extend_from_slice(&e.to_le_bytes());
        }
        b.extend_from_slice(&(self.z.len() as u64).to_le_bytes());
        for &zi in &self.z {
            b.extend_from_slice(&zi.to_le_bytes());
        }
        for v in [&self.ndt, &self.nd, &self.ntw, &self.nt] {
            for &x in v.iter() {
                b.extend_from_slice(&x.to_le_bytes());
            }
        }
        b.extend_from_slice(&(self.history.len() as u32).to_le_bytes());
        for h in &self.history {
            b.extend_from_slice(&(h.sweep as u64).to_le_bytes());
            b.extend_from_slice(&h.train_mse.to_le_bytes());
            b.extend_from_slice(&h.rho.to_le_bytes());
            b.extend_from_slice(&h.eta_l2.to_le_bytes());
        }
        frame(SHARD_MAGIC, &b)
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<ShardState> {
        let body = unframe(SHARD_MAGIC, bytes, "shard checkpoint")?;
        let mut c = Cur { body, off: 0, what: "shard checkpoint" };
        let shard_id = c.u32()?;
        let next_sweep = c.u64()?;
        let t = c.u32()?;
        let w = c.u32()?;
        let d = c.u32()?;
        let (tu, wu, du) = (t as usize, w as usize, d as usize);
        if tu < 2 || tu > MAX_T || wu == 0 || wu > MAX_W || du == 0 || du > MAX_D {
            bail!("implausible checkpoint dims t={t} w={w} d={d}");
        }
        let rho = c.f64()?;
        let eta_active = match c.u8()? {
            0 => false,
            1 => true,
            x => bail!("bad eta_active flag {x} at offset {}", c.off - 1),
        };
        let tokens_sampled = c.u64()?;
        let resp_proposed = c.u64()?;
        let resp_accepted = c.u64()?;
        let alias_rebuilds = c.u64()?;
        let rng_state = c.u128()?;
        let rng_inc = c.u128()?;
        let eta = c.vec_f64(tu, "eta")?;
        let n_tokens = c.u64()? as usize;
        // z is the largest section; its length is attacker-controlled until
        // proven backed (ensure_backed inside vec_u16 does that).
        let z = c.vec_u16(n_tokens, "z")?;
        let ndt = c.vec_u32(du.checked_mul(tu).unwrap_or(usize::MAX), "ndt")?;
        let nd = c.vec_u32(du, "nd")?;
        let ntw = c.vec_u32(wu.checked_mul(tu).unwrap_or(usize::MAX), "ntw")?;
        let nt = c.vec_u32(tu, "nt")?;
        let hlen = c.u32()? as usize;
        if hlen > MAX_HISTORY {
            bail!("implausible history length {hlen}");
        }
        c.ensure_backed(hlen, 32, "history")?;
        let mut history = Vec::with_capacity(hlen);
        for _ in 0..hlen {
            history.push(SweepStats {
                sweep: c.u64()? as usize,
                train_mse: c.f64()?,
                rho: c.f64()?,
                eta_l2: c.f64()?,
            });
        }
        c.done()?;
        Ok(ShardState {
            shard_id,
            next_sweep,
            t,
            w,
            d,
            rho,
            eta_active,
            tokens_sampled,
            resp_proposed,
            resp_accepted,
            alias_rebuilds,
            rng_state,
            rng_inc,
            eta,
            z,
            ndt,
            nd,
            ntw,
            nt,
            history,
        })
    }
}

impl Manifest {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(24 + self.shards.len() * 20);
        b.extend_from_slice(&self.fingerprint.to_le_bytes());
        b.extend_from_slice(&self.next_sweep.to_le_bytes());
        b.extend_from_slice(&(self.shards.len() as u32).to_le_bytes());
        for s in &self.shards {
            b.extend_from_slice(&s.shard_id.to_le_bytes());
            b.extend_from_slice(&s.bytes.to_le_bytes());
            b.extend_from_slice(&s.file_fnv.to_le_bytes());
        }
        frame(MANIFEST_MAGIC, &b)
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<Manifest> {
        let body = unframe(MANIFEST_MAGIC, bytes, "checkpoint manifest")?;
        let mut c = Cur { body, off: 0, what: "checkpoint manifest" };
        let fingerprint = c.u64()?;
        let next_sweep = c.u64()?;
        let n = c.u32()? as usize;
        if n == 0 || n > MAX_SHARDS {
            bail!("implausible manifest shard count {n}");
        }
        c.ensure_backed(n, 20, "shards")?;
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            shards.push(ManifestShard {
                shard_id: c.u32()?,
                bytes: c.u64()?,
                file_fnv: c.u64()?,
            });
        }
        c.done()?;
        for pair in shards.windows(2) {
            if pair[0].shard_id >= pair[1].shard_id {
                bail!(
                    "manifest shard ids not strictly increasing: {} then {}",
                    pair[0].shard_id,
                    pair[1].shard_id
                );
            }
        }
        Ok(Manifest { fingerprint, next_sweep, shards })
    }
}

/// Fingerprint of everything that makes a checkpoint's chain *the same
/// chain* as the resuming run: the full config (with `checkpoint_dir`
/// cleared — moving a checkpoint directory must not invalidate it), the
/// corpus dimensions, the algorithm, and the shard count. Resume refuses a
/// mismatch: continuing a chain under a different config would silently
/// produce a run that is neither the old one nor a fresh one.
pub fn config_fingerprint(
    cfg: &ExperimentConfig,
    n_docs: usize,
    n_tokens: usize,
    vocab: usize,
    algorithm: &str,
    shards: usize,
) -> u64 {
    let mut c = cfg.clone();
    c.train.checkpoint_dir = String::new();
    let mut buf = c.to_json().into_bytes();
    buf.extend_from_slice(&(n_docs as u64).to_le_bytes());
    buf.extend_from_slice(&(n_tokens as u64).to_le_bytes());
    buf.extend_from_slice(&(vocab as u64).to_le_bytes());
    buf.extend_from_slice(&(shards as u64).to_le_bytes());
    buf.extend_from_slice(algorithm.as_bytes());
    fnv1a(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    pub(crate) fn sample_state(seed: u64) -> ShardState {
        let mut rng = Pcg64::seed_from_u64(seed);
        let (t, w, d) = (4usize, 9usize, 3usize);
        let n_tokens = 17usize;
        ShardState {
            shard_id: 2,
            next_sweep: 10,
            t: t as u32,
            w: w as u32,
            d: d as u32,
            rho: 0.37,
            eta_active: true,
            tokens_sampled: 1234,
            resp_proposed: 55,
            resp_accepted: 33,
            alias_rebuilds: 7,
            rng_state: ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128,
            rng_inc: (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) | 1,
            eta: (0..t).map(|_| rng.next_gaussian()).collect(),
            z: (0..n_tokens).map(|_| rng.gen_range(t) as u16).collect(),
            ndt: (0..d * t).map(|_| rng.gen_range(5) as u32).collect(),
            nd: (0..d).map(|_| rng.gen_range(9) as u32).collect(),
            ntw: (0..w * t).map(|_| rng.gen_range(5) as u32).collect(),
            nt: (0..t).map(|_| rng.gen_range(20) as u32).collect(),
            history: vec![
                SweepStats { sweep: 4, train_mse: 1.5, rho: 0.4, eta_l2: 0.9 },
                SweepStats { sweep: 8, train_mse: 1.1, rho: 0.37, eta_l2: 1.3 },
            ],
        }
    }

    #[test]
    fn shard_state_roundtrips_exactly() {
        let s = sample_state(1);
        let bytes = s.encode();
        assert_eq!(&bytes[..8], SHARD_MAGIC);
        let s2 = ShardState::decode(&bytes).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn manifest_roundtrips_and_orders() {
        let m = Manifest {
            fingerprint: 0xDEAD_BEEF,
            next_sweep: 40,
            shards: vec![
                ManifestShard { shard_id: 0, bytes: 100, file_fnv: 1 },
                ManifestShard { shard_id: 1, bytes: 200, file_fnv: 2 },
            ],
        };
        let m2 = Manifest::decode(&m.encode()).unwrap();
        assert_eq!(m, m2);
        // out-of-order / duplicate shard ids rejected
        let bad = Manifest {
            shards: vec![
                ManifestShard { shard_id: 1, bytes: 1, file_fnv: 1 },
                ManifestShard { shard_id: 1, bytes: 1, file_fnv: 1 },
            ],
            ..m
        };
        assert!(Manifest::decode(&bad.encode()).is_err());
    }

    #[test]
    fn corruption_and_truncation_detected() {
        let bytes = sample_state(2).encode();
        // bit flip anywhere → checksum catches it
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x10;
        let err = ShardState::decode(&flipped).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // raw truncation
        assert!(ShardState::decode(&bytes[..bytes.len() - 9]).is_err());
        assert!(ShardState::decode(&bytes[..4]).is_err());
        // wrong magic
        let mut wrong = bytes.clone();
        wrong[..8].copy_from_slice(b"CFSLDA2\0");
        let err = ShardState::decode(&wrong).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn hostile_token_count_rejected_before_allocation() {
        // Restamp a body claiming 2^60 tokens: the decoder must refuse from
        // the byte-availability check, not attempt the allocation.
        let s = sample_state(3);
        let bytes = s.encode();
        let mut body = bytes[8..bytes.len() - 8].to_vec();
        // n_tokens sits after the fixed head (41 bytes) + rng (32) + eta (t*8)
        let off = 4 + 8 + 4 + 4 + 4 + 8 + 1 + 8 * 4 + 16 * 2 + s.eta.len() * 8;
        body[off..off + 8].copy_from_slice(&(1u64 << 60).to_le_bytes());
        let mut out = Vec::new();
        out.extend_from_slice(SHARD_MAGIC);
        out.extend_from_slice(&body);
        out.extend_from_slice(&crate::model::persist::fnv1a(&body).to_le_bytes());
        let err = ShardState::decode(&out).unwrap_err().to_string();
        assert!(err.contains("'z'"), "{err}");
        assert!(err.contains("offset"), "{err}");
    }

    #[test]
    fn mangled_checkpoint_corpus_never_panics() {
        use crate::testkit::{forall, usize_in};
        let src = sample_state(4).encode();
        let man = Manifest {
            fingerprint: 9,
            next_sweep: 20,
            shards: vec![ManifestShard { shard_id: 0, bytes: src.len() as u64, file_fnv: 0 }],
        }
        .encode();
        forall(
            "ckpt-mangled-files",
            80,
            |rng| {
                let base = if rng.gen_range(2) == 0 { &src } else { &man };
                let mode = rng.gen_range(3);
                match mode {
                    0 => {
                        let mut b = base.clone();
                        let i = rng.gen_range(b.len());
                        b[i] ^= 1 << rng.gen_range(8);
                        b
                    }
                    1 => {
                        let n = usize_in(rng, 0, base.len().saturating_sub(1));
                        base[..n].to_vec()
                    }
                    _ => {
                        // truncate the body and restamp a valid checksum so
                        // the structural parser is exercised
                        let body = &base[8..base.len() - 8];
                        let n = usize_in(rng, 0, body.len().saturating_sub(1));
                        let mut out = Vec::new();
                        out.extend_from_slice(&base[..8]);
                        out.extend_from_slice(&body[..n]);
                        out.extend_from_slice(&fnv1a(&body[..n]).to_le_bytes());
                        out
                    }
                }
            },
            |bytes| {
                // Err expected, Ok tolerated for no-op mutations; a panic
                // fails the property with a replayable case seed.
                let _ = ShardState::decode(bytes);
                let _ = Manifest::decode(bytes);
            },
        );
    }

    #[test]
    fn fingerprint_tracks_chain_identity() {
        let cfg = ExperimentConfig::quick();
        let base = config_fingerprint(&cfg, 100, 5000, 200, "non-parallel", 1);
        // identical inputs → identical fingerprint
        assert_eq!(base, config_fingerprint(&cfg, 100, 5000, 200, "non-parallel", 1));
        // checkpoint_dir is excluded: relocating a checkpoint keeps it valid
        let mut moved = cfg.clone();
        moved.train.checkpoint_dir = "/elsewhere".to_string();
        assert_eq!(base, config_fingerprint(&moved, 100, 5000, 200, "non-parallel", 1));
        // anything chain-defining changes it
        let mut c = cfg.clone();
        c.seed = 999;
        assert_ne!(base, config_fingerprint(&c, 100, 5000, 200, "non-parallel", 1));
        let mut c = cfg.clone();
        c.train.checkpoint_every = 7;
        assert_ne!(base, config_fingerprint(&c, 100, 5000, 200, "non-parallel", 1));
        assert_ne!(base, config_fingerprint(&cfg, 101, 5000, 200, "non-parallel", 1));
        assert_ne!(base, config_fingerprint(&cfg, 100, 5000, 200, "simple-average", 1));
        assert_ne!(base, config_fingerprint(&cfg, 100, 5000, 200, "non-parallel", 4));
    }
}
