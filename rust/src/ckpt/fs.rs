//! Filesystem seam for the checkpoint store.
//!
//! Everything the store touches on disk goes through [`CkptFs`], so the
//! fault-injection harness (`crate::testkit::failfs::FailpointFs`) can
//! interpose torn writes, failed fsyncs, and crashed renames at exact
//! operation indices while [`StdFs`] serves production unchanged. The trait
//! is deliberately tiny — just the operations the atomic-write protocol
//! (DESIGN.md §Durability) needs — and returns `io::Result` so failure
//! injection composes with real OS errors.

use std::io;
use std::path::Path;

/// Filesystem operations used by [`crate::ckpt::Store`].
pub trait CkptFs: Sync {
    /// `mkdir -p`.
    fn create_dir_all(&self, p: &Path) -> io::Result<()>;
    /// Create/truncate `p` and write `bytes` in full (no durability implied;
    /// pair with [`CkptFs::fsync`]).
    fn write(&self, p: &Path, bytes: &[u8]) -> io::Result<()>;
    /// Flush file (or directory) contents + metadata to stable storage.
    fn fsync(&self, p: &Path) -> io::Result<()>;
    /// Atomically replace `to` with `from` (POSIX `rename`).
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// Read the whole file.
    fn read(&self, p: &Path) -> io::Result<Vec<u8>>;
    /// File names (not paths) of the direct children of `p`.
    fn list_dir(&self, p: &Path) -> io::Result<Vec<String>>;
    /// `rm -rf` (used by generation retention).
    fn remove_dir_all(&self, p: &Path) -> io::Result<()>;
    /// Does the path exist?
    fn exists(&self, p: &Path) -> bool;
}

/// Production [`CkptFs`]: thin passthrough to `std::fs`.
#[derive(Debug, Default, Clone, Copy)]
pub struct StdFs;

impl CkptFs for StdFs {
    fn create_dir_all(&self, p: &Path) -> io::Result<()> {
        std::fs::create_dir_all(p)
    }

    fn write(&self, p: &Path, bytes: &[u8]) -> io::Result<()> {
        std::fs::write(p, bytes)
    }

    fn fsync(&self, p: &Path) -> io::Result<()> {
        // Opening read-only works for both regular files and directories
        // (directory fsync is how the rename itself is made durable).
        std::fs::File::open(p)?.sync_all()
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)
    }

    fn read(&self, p: &Path) -> io::Result<Vec<u8>> {
        std::fs::read(p)
    }

    fn list_dir(&self, p: &Path) -> io::Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(p)? {
            names.push(entry?.file_name().to_string_lossy().into_owned());
        }
        names.sort();
        Ok(names)
    }

    fn remove_dir_all(&self, p: &Path) -> io::Result<()> {
        std::fs::remove_dir_all(p)
    }

    fn exists(&self, p: &Path) -> bool {
        p.exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_ckptfs_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn stdfs_roundtrip_and_listing() {
        let dir = tmp("rt");
        let fs = StdFs;
        std::fs::remove_dir_all(&dir).ok();
        fs.create_dir_all(&dir).unwrap();
        let a = dir.join("a.bin");
        let b = dir.join("b.bin");
        fs.write(&a, b"hello").unwrap();
        fs.fsync(&a).unwrap();
        fs.rename(&a, &b).unwrap();
        fs.fsync(&dir).unwrap();
        assert!(!fs.exists(&a));
        assert!(fs.exists(&b));
        assert_eq!(fs.read(&b).unwrap(), b"hello");
        assert_eq!(fs.list_dir(&dir).unwrap(), vec!["b.bin".to_string()]);
        fs.remove_dir_all(&dir).unwrap();
        assert!(!fs.exists(&dir));
    }
}
