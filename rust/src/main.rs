//! `cfslda` — leader binary for the communication-free parallel sLDA stack.
//!
//! See `cfslda help` for commands. The heavy lifting lives in the library
//! (rust/src/); AOT XLA artifacts are produced once by `make artifacts`.

use cfslda::cli::args::Args;
use cfslda::cli::commands;

fn main() {
    cfslda::util::logging::init();
    let code = match Args::from_env().and_then(commands::dispatch) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
