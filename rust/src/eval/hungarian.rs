//! Hungarian algorithm (Kuhn-Munkres, O(n^3)) for minimum-cost assignment.
//!
//! Substrate for the quasi-ergodicity diagnostics: the topic posterior of
//! (s)LDA has one mode per topic-label *permutation*, so comparing two
//! chains' topic-word matrices requires solving an assignment problem —
//! "which topic of chain A is which topic of chain B". The optimal matching
//! cost is the permutation-invariant distance between the chains' modes.

/// Solve the min-cost assignment for a square `n x n` cost matrix
/// (row-major). Returns (assignment, total_cost) where `assignment[row] =
/// col`.
///
/// Implementation: the classic potentials + augmenting-path formulation
/// (Jonker-style), O(n^3), exact.
pub fn solve(cost: &[f64], n: usize) -> (Vec<usize>, f64) {
    assert_eq!(cost.len(), n * n, "cost matrix must be n x n");
    if n == 0 {
        return (Vec::new(), 0.0);
    }
    const INF: f64 = f64::INFINITY;
    // 1-indexed potentials; way[j] = previous column on the augmenting path.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row assigned to column j (1-indexed)
    let mut way = vec![0usize; n + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[(i0 - 1) * n + (j - 1)] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assignment = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            assignment[p[j] - 1] = j - 1;
        }
    }
    let total: f64 = (0..n).map(|r| cost[r * n + assignment[r]]).sum();
    (assignment, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn identity_when_diagonal_cheap() {
        let cost = vec![
            0.0, 9.0, 9.0, //
            9.0, 0.0, 9.0, //
            9.0, 9.0, 0.0,
        ];
        let (a, c) = solve(&cost, 3);
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(c, 0.0);
    }

    #[test]
    fn known_3x3() {
        // classic example: optimal cost 5 with assignment (0->1, 1->0, 2->2)
        let cost = vec![
            4.0, 1.0, 3.0, //
            2.0, 0.0, 5.0, //
            3.0, 2.0, 2.0,
        ];
        let (a, c) = solve(&cost, 3);
        assert_eq!(c, 5.0);
        // verify it's a permutation
        let mut seen = a.clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn permutation_recovery() {
        // cost[i][j] = 0 iff j == perm[i], else 1 — must recover perm.
        let perm = [3usize, 0, 4, 1, 2];
        let n = 5;
        let mut cost = vec![1.0; n * n];
        for (i, &j) in perm.iter().enumerate() {
            cost[i * n + j] = 0.0;
        }
        let (a, c) = solve(&cost, n);
        assert_eq!(a, perm.to_vec());
        assert_eq!(c, 0.0);
    }

    #[test]
    fn beats_identity_and_random_on_random_instances() {
        let mut rng = Pcg64::seed_from_u64(5);
        for n in [2usize, 4, 8, 13] {
            let cost: Vec<f64> = (0..n * n).map(|_| rng.next_f64()).collect();
            let (a, c) = solve(&cost, n);
            // assignment is a permutation
            let mut seen = a.clone();
            seen.sort_unstable();
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
            // not worse than identity
            let id_cost: f64 = (0..n).map(|i| cost[i * n + i]).sum();
            assert!(c <= id_cost + 1e-12);
            // exact on small n: compare against brute force
            if n <= 4 {
                let mut best = f64::INFINITY;
                let mut perm: Vec<usize> = (0..n).collect();
                permutohedron_heap(&mut perm, &mut |p: &[usize]| {
                    let v: f64 = (0..n).map(|i| cost[i * n + p[i]]).sum();
                    if v < best {
                        best = v;
                    }
                });
                assert!((c - best).abs() < 1e-9, "n={n} got {c} best {best}");
            }
        }
    }

    /// Minimal Heap's algorithm for the brute-force check.
    fn permutohedron_heap(arr: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
        fn heap(k: usize, arr: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
            if k == 1 {
                f(arr);
                return;
            }
            for i in 0..k {
                heap(k - 1, arr, f);
                if k % 2 == 0 {
                    arr.swap(i, k - 1);
                } else {
                    arr.swap(0, k - 1);
                }
            }
        }
        let k = arr.len();
        heap(k, arr, f);
    }

    #[test]
    fn empty_input() {
        let (a, c) = solve(&[], 0);
        assert!(a.is_empty());
        assert_eq!(c, 0.0);
    }
}
