//! Quasi-ergodicity diagnostics (the measured version of paper Figs. 2-3).
//!
//! Independent Gibbs chains on different shards converge to different
//! topic-label permutations (different modes of the multimodal posterior).
//! We quantify this with two numbers per chain pair:
//!
//! * **aligned distance** — mean total-variation distance between topic-word
//!   rows *after* optimally matching topics (Hungarian on the TV-cost
//!   matrix). Small when the chains found the same mode structure.
//! * **identity distance** — the same mean TV distance *without* matching
//!   (topic i vs topic i). Large when the labels are permuted.
//!
//! A large `identity - aligned` **permutation gap** is the fingerprint of
//! quasi-ergodicity: the chains agree about the topics but not about their
//! labels — precisely the situation in which Naive Combination's pooled
//! counts blur distinct topics together while prediction-space combination
//! is unaffected (predictions are permutation-invariant).

use super::hungarian;

/// Total-variation distance between two distributions.
pub fn tv_distance(p: &[f64], q: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    0.5 * p.iter().zip(q).map(|(&a, &b)| (a - b).abs()).sum::<f64>()
}

/// Pairwise alignment report between two chains' topic sets.
#[derive(Clone, Debug)]
pub struct AlignmentReport {
    /// Optimal topic matching (chain A topic i -> chain B topic perm[i]).
    pub permutation: Vec<usize>,
    /// Mean TV distance under the optimal matching.
    pub aligned_distance: f64,
    /// Mean TV distance under the identity matching.
    pub identity_distance: f64,
    /// Fraction of topics whose optimal match is NOT the identity.
    pub permuted_fraction: f64,
}

impl AlignmentReport {
    /// identity - aligned: the quasi-ergodicity fingerprint.
    pub fn permutation_gap(&self) -> f64 {
        self.identity_distance - self.aligned_distance
    }
}

/// Align two chains' topic-word matrices (topic-major rows over the vocab).
pub fn align_topics(phi_a: &[Vec<f64>], phi_b: &[Vec<f64>]) -> AlignmentReport {
    let t = phi_a.len();
    assert_eq!(t, phi_b.len(), "chains must share the topic count");
    let mut cost = vec![0.0f64; t * t];
    for i in 0..t {
        for j in 0..t {
            cost[i * t + j] = tv_distance(&phi_a[i], &phi_b[j]);
        }
    }
    let (permutation, total) = hungarian::solve(&cost, t);
    let aligned = total / t as f64;
    let identity: f64 = (0..t).map(|i| cost[i * t + i]).sum::<f64>() / t as f64;
    let permuted =
        permutation.iter().enumerate().filter(|&(i, &j)| i != j).count() as f64 / t as f64;
    AlignmentReport {
        permutation,
        aligned_distance: aligned,
        identity_distance: identity,
        permuted_fraction: permuted,
    }
}

/// Mean pairwise alignment report over all chain pairs (the Fig-3 summary).
#[derive(Clone, Debug, Default)]
pub struct ModeDivergence {
    pub pairs: usize,
    pub mean_aligned: f64,
    pub mean_identity: f64,
    pub mean_permuted_fraction: f64,
}

impl ModeDivergence {
    pub fn permutation_gap(&self) -> f64 {
        self.mean_identity - self.mean_aligned
    }
}

/// Compute pairwise divergence across M chains' topic rows.
pub fn mode_divergence(phis: &[Vec<Vec<f64>>]) -> ModeDivergence {
    let m = phis.len();
    let mut out = ModeDivergence::default();
    if m < 2 {
        return out;
    }
    for a in 0..m {
        for b in a + 1..m {
            let r = align_topics(&phis[a], &phis[b]);
            out.pairs += 1;
            out.mean_aligned += r.aligned_distance;
            out.mean_identity += r.identity_distance;
            out.mean_permuted_fraction += r.permuted_fraction;
        }
    }
    let n = out.pairs as f64;
    out.mean_aligned /= n;
    out.mean_identity /= n;
    out.mean_permuted_fraction /= n;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_topics(t: usize, w: usize, rng: &mut Pcg64) -> Vec<Vec<f64>> {
        (0..t).map(|_| rng.next_dirichlet_sym(0.05, w)).collect()
    }

    #[test]
    fn tv_basics() {
        assert_eq!(tv_distance(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        assert_eq!(tv_distance(&[1.0, 0.0], &[0.0, 1.0]), 1.0);
        assert!((tv_distance(&[0.5, 0.5], &[1.0, 0.0]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn permuted_chains_have_large_gap() {
        // Chain B = chain A with topics rotated by 1: identity distance is
        // large, aligned distance zero, and the permutation is recovered.
        let mut rng = Pcg64::seed_from_u64(1);
        let a = random_topics(6, 200, &mut rng);
        let mut b = a.clone();
        b.rotate_left(1);
        let r = align_topics(&a, &b);
        assert!(r.aligned_distance < 1e-12);
        assert!(r.identity_distance > 0.5, "identity={}", r.identity_distance);
        assert!(r.permutation_gap() > 0.5);
        assert_eq!(r.permuted_fraction, 1.0);
        // permutation maps a-topic i to b-row holding the same topic
        for (i, &j) in r.permutation.iter().enumerate() {
            assert_eq!(tv_distance(&a[i], &b[j]), 0.0);
        }
    }

    #[test]
    fn identical_chains_have_no_gap() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = random_topics(5, 100, &mut rng);
        let r = align_topics(&a, &a);
        assert_eq!(r.permutation, vec![0, 1, 2, 3, 4]);
        assert_eq!(r.permutation_gap(), 0.0);
        assert_eq!(r.permuted_fraction, 0.0);
    }

    #[test]
    fn unrelated_chains_have_no_gap_but_large_distance() {
        // Independent random topic sets: aligned ~ identity (both large).
        let mut rng = Pcg64::seed_from_u64(3);
        let a = random_topics(6, 500, &mut rng);
        let b = random_topics(6, 500, &mut rng);
        let r = align_topics(&a, &b);
        assert!(r.aligned_distance > 0.5);
        assert!(r.permutation_gap() < 0.2, "gap={}", r.permutation_gap());
    }

    #[test]
    fn divergence_aggregates_pairs() {
        let mut rng = Pcg64::seed_from_u64(4);
        let a = random_topics(4, 50, &mut rng);
        let mut b = a.clone();
        b.rotate_left(2);
        let mut c = a.clone();
        c.rotate_left(1);
        let d = mode_divergence(&[a, b, c]);
        assert_eq!(d.pairs, 3);
        assert!(d.permutation_gap() > 0.3);
        assert!(mode_divergence(&[]).pairs == 0);
    }
}
