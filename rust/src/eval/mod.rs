//! Evaluation: prediction metrics, topic-mode diagnostics (the
//! quasi-ergodicity probe), and held-out perplexity.

pub mod hungarian;
pub mod metrics;
pub mod mode_diag;
pub mod perplexity;
