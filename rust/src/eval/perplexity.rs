//! Held-out per-word perplexity for (s)LDA models.
//!
//! Extended diagnostic (not a paper figure): measures topic quality
//! independently of the supervised head. Uses the standard
//! fold-in evaluation: infer each held-out document's empirical topic
//! distribution with the frozen phi-hat, then score
//!   perplexity = exp( - sum_dn log p(w_dn) / sum_d N_d ),
//!   p(w) = sum_t theta_hat_dt phi_hat_{t, w}.

use crate::config::schema::TrainConfig;
use crate::data::corpus::CorpusView;
use crate::model::slda::SldaModel;
use crate::sampler::gibbs_predict::infer_zbar;
use crate::util::rng::Pcg64;

/// Fold-in perplexity of `model` on a held-out corpus (or view).
pub fn perplexity<'a>(
    model: &SldaModel,
    corpus: impl Into<CorpusView<'a>>,
    cfg: &TrainConfig,
    rng: &mut Pcg64,
) -> f64 {
    let corpus: CorpusView<'a> = corpus.into();
    let t = model.t;
    let zbar = infer_zbar(model, corpus, cfg, rng);
    let alpha = model.alpha;
    let mut loglik = 0.0f64;
    let mut tokens = 0usize;
    for di in 0..corpus.num_docs() {
        let doc_tokens = corpus.doc_tokens(di);
        // smooth theta-hat with the Dirichlet prior
        let nd = doc_tokens.len() as f64;
        let denom = nd + t as f64 * alpha;
        let theta: Vec<f64> = (0..t)
            .map(|ti| (zbar[di * t + ti] as f64 * nd + alpha) / denom)
            .collect();
        for &wi in doc_tokens {
            let phi = model.phi_row(wi);
            let p: f64 = theta.iter().zip(phi).map(|(&th, &ph)| th * ph as f64).sum();
            loglik += p.max(1e-300).ln();
            tokens += 1;
        }
    }
    (-loglik / tokens.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ExperimentConfig;
    use crate::data::synthetic::{generate_split, SyntheticSpec};
    use crate::runtime::EngineHandle;
    use crate::sampler::gibbs_train::train;

    #[test]
    fn trained_model_beats_uniform() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = generate_split(&spec, 180, &mut rng);
        let mut cfg = ExperimentConfig::quick();
        cfg.train.sweeps = 15;
        cfg.train.burnin = 3;
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg, &engine, &mut rng).unwrap();
        let ppl = perplexity(&out.model, &ds.test, &cfg.train, &mut rng);
        // Uniform model perplexity == vocab size.
        assert!(
            ppl < 0.8 * spec.vocab as f64,
            "perplexity {ppl} should beat uniform {}",
            spec.vocab
        );
        assert!(ppl > 1.0);
    }

    #[test]
    fn degenerate_uniform_model_scores_vocab_size() {
        // A model whose phi is exactly uniform must have ppl == W.
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = generate_split(&spec, 180, &mut rng);
        let (t, w) = (4usize, spec.vocab);
        let model = SldaModel {
            t,
            w,
            eta: vec![0.0; t],
            phi: vec![1.0 / w as f32; w * t],
            rho: 1.0,
            alpha: 0.5,
            train_mse: 0.0,
            train_acc: 0.0,
        };
        let cfg = ExperimentConfig::quick();
        let ppl = perplexity(&model, &ds.test, &cfg.train, &mut rng);
        let rel = (ppl - w as f64).abs() / w as f64;
        assert!(rel < 1e-3, "ppl={ppl} vs W={w}");
    }
}
