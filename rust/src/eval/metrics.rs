//! Prediction quality metrics: the paper evaluates test-set MSE
//! (Experiment I, continuous EPS) and test-set accuracy (Experiment II,
//! binary sentiment); we add RMSE / MAE / R² / confusion counts for the
//! extended reports.

/// Full metric set for one prediction vector against ground truth.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metrics {
    pub n: usize,
    pub mse: f64,
    pub rmse: f64,
    pub mae: f64,
    /// 1 - SSE/SST (0 when SST is 0).
    pub r2: f64,
    /// Accuracy at the 0.5 threshold.
    pub acc: f64,
    pub tp: usize,
    pub tn: usize,
    pub fp: usize,
    pub fn_: usize,
}

/// Compute all metrics. `yhat` are raw (continuous) predictions; binary
/// classification thresholds both sides at 0.5 as in the paper.
pub fn compute(yhat: &[f64], y: &[f64]) -> Metrics {
    assert_eq!(yhat.len(), y.len(), "prediction/label length mismatch");
    let n = y.len();
    if n == 0 {
        return Metrics::default();
    }
    let mean_y: f64 = y.iter().sum::<f64>() / n as f64;
    let (mut sse, mut sae, mut sst) = (0.0f64, 0.0f64, 0.0f64);
    let (mut tp, mut tn, mut fp, mut fn_) = (0usize, 0usize, 0usize, 0usize);
    for (&p, &obs) in yhat.iter().zip(y) {
        let e = p - obs;
        sse += e * e;
        sae += e.abs();
        sst += (obs - mean_y) * (obs - mean_y);
        match (p > 0.5, obs > 0.5) {
            (true, true) => tp += 1,
            (false, false) => tn += 1,
            (true, false) => fp += 1,
            (false, true) => fn_ += 1,
        }
    }
    let mse = sse / n as f64;
    Metrics {
        n,
        mse,
        rmse: mse.sqrt(),
        mae: sae / n as f64,
        r2: if sst > 0.0 { 1.0 - sse / sst } else { 0.0 },
        acc: (tp + tn) as f64 / n as f64,
        tp,
        tn,
        fp,
        fn_,
    }
}

impl Metrics {
    /// One-line rendering used by the experiment tables.
    pub fn render(&self, binary: bool) -> String {
        if binary {
            format!("acc={:.4} (tp={} tn={} fp={} fn={})", self.acc, self.tp, self.tn, self.fp, self.fn_)
        } else {
            format!("mse={:.4} rmse={:.4} mae={:.4} r2={:.4}", self.mse, self.rmse, self.mae, self.r2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictions() {
        let y = [1.0, 2.0, 3.0];
        let m = compute(&y, &y);
        assert_eq!(m.mse, 0.0);
        assert_eq!(m.mae, 0.0);
        assert_eq!(m.r2, 1.0);
        assert_eq!(m.acc, 1.0);
    }

    #[test]
    fn known_values() {
        let m = compute(&[1.0, 0.0], &[0.0, 0.0]);
        assert!((m.mse - 0.5).abs() < 1e-12);
        assert!((m.rmse - 0.5f64.sqrt()).abs() < 1e-12);
        assert!((m.mae - 0.5).abs() < 1e-12);
        // y constant -> sst = 0 -> r2 defined as 0
        assert_eq!(m.r2, 0.0);
    }

    #[test]
    fn r2_of_mean_prediction_is_zero() {
        let y = [1.0, 2.0, 3.0, 4.0];
        let m = compute(&[2.5; 4], &y);
        assert!(m.r2.abs() < 1e-12);
    }

    #[test]
    fn binary_confusion() {
        // yhat: 0.9, 0.1, 0.6, 0.2 vs y: 1, 0, 0, 1
        let m = compute(&[0.9, 0.1, 0.6, 0.2], &[1.0, 0.0, 0.0, 1.0]);
        assert_eq!((m.tp, m.tn, m.fp, m.fn_), (1, 1, 1, 1));
        assert_eq!(m.acc, 0.5);
    }

    #[test]
    fn empty_input_is_default() {
        assert_eq!(compute(&[], &[]), Metrics::default());
    }

    #[test]
    fn render_modes() {
        let m = compute(&[0.9], &[1.0]);
        assert!(m.render(false).contains("mse="));
        assert!(m.render(true).contains("acc="));
    }
}
