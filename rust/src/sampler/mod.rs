//! Collapsed Gibbs sampling for sLDA (paper §III-B).
//!
//! * [`kernel`] — the pluggable token-update kernels: the classic dense
//!   O(T) conditional and the SparseLDA-style bucket-decomposed sparse
//!   kernel, draw-for-draw interchangeable under a fixed seed (selected by
//!   `sampler.kernel` in the experiment config).
//! * [`gibbs_train`] — posterior inference by stochastic EM: the eq. (1)
//!   token-topic sweep alternating with the eq. (2) eta optimization
//!   (dispatched to the [`crate::runtime`] engine).
//! * [`gibbs_predict`] — test-time inference with frozen phi-hat (eq. 4)
//!   and response prediction (eq. 5), averaging post-burn-in samples of the
//!   empirical topic distribution (Nguyen et al. 2014: "averaging is best").
//!
//! The token sweep is the system's hot path; see DESIGN.md §Perf for the
//! layout/bucket/fast-exp decisions and `benches/gibbs_hotpath.rs` for the
//! per-kernel tokens/second tracking bench.

pub mod gibbs_predict;
pub mod gibbs_train;
pub mod kernel;
