//! Prediction: Gibbs inference on unseen documents with frozen phi-hat.
//!
//! Paper eq. (4): p(z = t) ∝ (N_dt + alpha) · phi_hat_{t, w}. The response
//! is *not* part of the conditional (test labels are unknown at inference
//! time), so the whole path is kernel-eligible: the sparse kernel's bucket
//! decomposition `α·phi_t + N_dt·phi_t` makes each token update O(nnz(N_d))
//! instead of O(T) (DESIGN.md §Perf). After `predict_burnin` sweeps the
//! empirical topic distribution is averaged over the remaining sweeps
//! (Nguyen et al. 2014), and the final responses are computed in one
//! batched engine call: yhat = Zbar eta (eq. 5) — the `predict_T*` AOT
//! artifact on the XLA path.

use crate::config::schema::{KernelKind, TrainConfig};
use crate::data::corpus::Corpus;
use crate::model::slda::SldaModel;
use crate::runtime::{EngineHandle, Prediction};
use crate::sampler::kernel::{self, PredictState};
use crate::util::rng::Pcg64;

/// Infer averaged empirical topic distributions for every document with an
/// explicit kernel choice. Returns a row-major [D, T] matrix. The kernels
/// are draw-for-draw identical, so the choice affects throughput only.
pub fn infer_zbar_with_kernel(
    model: &SldaModel,
    corpus: &Corpus,
    cfg: &TrainConfig,
    kernel_kind: KernelKind,
    rng: &mut Pcg64,
) -> Vec<f32> {
    let t = model.t;
    let d = corpus.num_docs();
    let mut zbar = vec![0.0f32; d * t];
    let mut ndt = vec![0u32; t];
    let mut acc = vec![0.0f64; t];
    let mut probs = vec![0.0f64; t];
    let mut kern = kernel::make_kernel(kernel_kind, t);
    // Per-word cumulative smoothing masses alpha * phi (shared by both
    // kernels; phi is frozen for the whole call).
    let phi_cum = kernel::build_phi_cum(&model.phi, t, model.alpha);

    for (di, doc) in corpus.docs.iter().enumerate() {
        let nd = doc.len();
        ndt.iter_mut().for_each(|c| *c = 0);
        acc.iter_mut().for_each(|a| *a = 0.0);
        // init: sample from phi alone (ndt empty -> prior-proportional)
        let mut zd: Vec<u16> = Vec::with_capacity(nd);
        for &wi in &doc.tokens {
            let phi = model.phi_row(wi);
            for ti in 0..t {
                probs[ti] = phi[ti] as f64;
            }
            let z = rng.sample_discrete(&probs);
            ndt[z] += 1;
            zd.push(z as u16);
        }
        let mut samples = 0usize;
        for sweep in 0..cfg.predict_sweeps {
            let mut ps = PredictState {
                t,
                phi: &model.phi,
                phi_cum: &phi_cum,
                ndt: &mut ndt,
                rng: &mut *rng,
            };
            kern.sweep_doc_predict(&mut ps, &doc.tokens, &mut zd);
            if sweep >= cfg.predict_burnin {
                for ti in 0..t {
                    acc[ti] += ndt[ti] as f64;
                }
                samples += 1;
            }
        }
        let denom = (samples.max(1) * nd) as f64;
        for ti in 0..t {
            zbar[di * t + ti] = (acc[ti] / denom) as f32;
        }
    }
    zbar
}

/// [`infer_zbar_with_kernel`] with the `auto` kernel heuristic.
pub fn infer_zbar(
    model: &SldaModel,
    corpus: &Corpus,
    cfg: &TrainConfig,
    rng: &mut Pcg64,
) -> Vec<f32> {
    infer_zbar_with_kernel(model, corpus, cfg, KernelKind::Auto, rng)
}

/// Full prediction pipeline with an explicit kernel: infer zbar, then
/// batched yhat + metrics. `labels`: pass the ground truth to obtain
/// MSE/accuracy (paper's test evaluation), or `None` for pure inference.
pub fn predict_corpus_with_kernel(
    model: &SldaModel,
    corpus: &Corpus,
    cfg: &TrainConfig,
    kernel_kind: KernelKind,
    engine: &EngineHandle,
    labels: Option<&[f64]>,
    rng: &mut Pcg64,
) -> anyhow::Result<(Prediction, Vec<f32>)> {
    let zbar = infer_zbar_with_kernel(model, corpus, cfg, kernel_kind, rng);
    let pred = engine.predict(&zbar, &model.eta, labels, model.t)?;
    Ok((pred, zbar))
}

/// [`predict_corpus_with_kernel`] with the `auto` kernel heuristic.
pub fn predict_corpus(
    model: &SldaModel,
    corpus: &Corpus,
    cfg: &TrainConfig,
    engine: &EngineHandle,
    labels: Option<&[f64]>,
    rng: &mut Pcg64,
) -> anyhow::Result<(Prediction, Vec<f32>)> {
    predict_corpus_with_kernel(model, corpus, cfg, KernelKind::Auto, engine, labels, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ExperimentConfig;
    use crate::data::synthetic::{generate_split, SyntheticSpec};
    use crate::sampler::gibbs_train::train;
    use crate::util::stats::Summary;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::quick();
        c.train.sweeps = 25;
        c.train.burnin = 5;
        c.train.eta_every = 5;
        c.train.predict_sweeps = 12;
        c.train.predict_burnin = 4;
        c
    }

    #[test]
    fn zbar_rows_are_distributions() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = generate_split(&spec, 180, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
        let zbar = infer_zbar(&out.model, &ds.test, &cfg().train, &mut rng);
        let t = out.model.t;
        for d in 0..ds.test.num_docs() {
            let s: f32 = zbar[d * t..(d + 1) * t].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "doc {d} zbar sums to {s}");
        }
    }

    #[test]
    fn end_to_end_beats_mean_baseline() {
        // The paper's core premise: sLDA predictions must beat predicting
        // the train-mean for every test document.
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = generate_split(&spec, 180, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
        let ys = ds.test.responses();
        let (pred, _) =
            predict_corpus(&out.model, &ds.test, &cfg().train, &engine, Some(&ys), &mut rng)
                .unwrap();
        let var = Summary::from_slice(&ys).var(); // mean-baseline MSE
        assert!(
            pred.mse < 0.6 * var,
            "test mse {} should beat mean baseline {var}",
            pred.mse
        );
        assert_eq!(pred.yhat.len(), ds.test.num_docs());
    }

    #[test]
    fn prediction_without_labels_reports_zero_metrics() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = generate_split(&spec, 180, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
        let (pred, zbar) =
            predict_corpus(&out.model, &ds.test, &cfg().train, &engine, None, &mut rng).unwrap();
        assert_eq!(pred.mse, 0.0);
        assert_eq!(pred.acc, 0.0);
        assert_eq!(zbar.len(), ds.test.num_docs() * out.model.t);
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SyntheticSpec::continuous_small();
        let engine = EngineHandle::native();
        let mk = || {
            let mut rng = Pcg64::seed_from_u64(9);
            let ds = generate_split(&spec, 180, &mut rng);
            let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
            let ys = ds.test.responses();
            predict_corpus(&out.model, &ds.test, &cfg().train, &engine, Some(&ys), &mut rng)
                .unwrap()
                .0
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.yhat, b.yhat);
        assert_eq!(a.mse, b.mse);
    }
}
