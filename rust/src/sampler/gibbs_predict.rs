//! Prediction: Gibbs inference on unseen documents with frozen phi-hat.
//!
//! Paper eq. (4): p(z = t) ∝ (N_dt + alpha) · phi_hat_{t, w}. The response
//! is *not* part of the conditional (test labels are unknown at inference
//! time), so the whole path is kernel-eligible: the sparse kernel's bucket
//! decomposition `α·phi_t + N_dt·phi_t` makes each token update O(nnz(N_d))
//! instead of O(T) (DESIGN.md §Perf). After `predict_burnin` sweeps the
//! empirical topic distribution is averaged over the remaining sweeps
//! (Nguyen et al. 2014), and the final responses are computed in one
//! batched engine call: yhat = Zbar eta (eq. 5) — the `predict_T*` AOT
//! artifact on the XLA path.

use crate::config::schema::{KernelKind, TrainConfig};
use crate::data::corpus::CorpusView;
use crate::model::slda::SldaModel;
use crate::runtime::{EngineHandle, Prediction};
use crate::sampler::kernel::{self, PhiAliasTables, PredictState, SamplerKernel};
use crate::util::pool::scoped_map;
use crate::util::rng::{splitmix64, Pcg64};

/// FNV-1a hash of a token sequence (little-endian id bytes). Identifies a
/// document's *content*: the serving cache key and the per-document RNG
/// stream are both derived from it, so a given (model, seed, doc) always
/// produces the same prediction regardless of batch composition.
pub fn token_hash(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in tokens {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Seed of a document's private RNG stream: mixes the base seed with the
/// doc's [`token_hash`]. Used by the parallel prediction path and the serve
/// batcher so per-document draws are independent of worker/batch layout.
pub fn doc_stream_seed(seed: u64, token_hash: u64) -> u64 {
    let mut s = seed.wrapping_add(token_hash.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    splitmix64(&mut s)
}

/// Reusable single-document inference state: the kernel instance plus all
/// per-document scratch buffers, allocated once and reused across documents
/// (and, in the serving subsystem, across requests). The per-model tables —
/// `phi_cum` (sparse smoothing, [`kernel::build_phi_cum`]) and the frozen-phi
/// alias tables ([`kernel::PhiAliasTables`], required when the resolved
/// kernel is alias) — are deliberately *not* owned here: they are built once
/// per model and shared by every scratch instance (the serve registry keeps
/// both resident).
pub struct DocInfer {
    t: usize,
    kind: KernelKind,
    kern: Box<dyn SamplerKernel>,
    ndt: Vec<u32>,
    acc: Vec<f64>,
    probs: Vec<f64>,
    zd: Vec<u16>,
}

impl DocInfer {
    /// Allocate scratch for `t` topics; `Auto` resolves per the prediction
    /// rule ([`KernelKind::resolve_predict`] — alias at every T).
    pub fn new(kind: KernelKind, t: usize) -> Self {
        let kind = kind.resolve_predict(t);
        DocInfer {
            t,
            kind,
            kern: kernel::make_predict_kernel(kind, t),
            ndt: vec![0u32; t],
            acc: vec![0.0f64; t],
            probs: vec![0.0f64; t],
            zd: Vec::new(),
        }
    }

    pub fn topics(&self) -> usize {
        self.t
    }

    /// The resolved kernel kind this scratch runs (never `Auto`).
    pub fn kernel_kind(&self) -> KernelKind {
        self.kind
    }

    /// Infer one document's averaged empirical topic distribution into
    /// `out` (length T). For the dense/sparse kernels this is the identical
    /// operation/RNG-consumption sequence to the historical corpus loop, so
    /// those paths stay byte-for-byte deterministic; the alias kernel is a
    /// different (still seed-deterministic) chain and additionally needs
    /// the model's prebuilt `alias` tables. Empty documents yield a zero
    /// row.
    #[allow(clippy::too_many_arguments)]
    pub fn infer_doc(
        &mut self,
        model: &SldaModel,
        phi_cum: &[f64],
        alias: Option<&PhiAliasTables>,
        cfg: &TrainConfig,
        tokens: &[u32],
        rng: &mut Pcg64,
        out: &mut [f32],
    ) {
        let t = self.t;
        debug_assert_eq!(t, model.t);
        debug_assert_eq!(out.len(), t);
        let nd = tokens.len();
        if nd == 0 {
            out.iter_mut().for_each(|z| *z = 0.0);
            return;
        }
        self.ndt.iter_mut().for_each(|c| *c = 0);
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        // init: sample from phi alone (ndt empty -> prior-proportional)
        self.zd.clear();
        for &wi in tokens {
            let phi = model.phi_row(wi);
            for ti in 0..t {
                self.probs[ti] = phi[ti] as f64;
            }
            let z = rng.sample_discrete(&self.probs);
            self.ndt[z] += 1;
            self.zd.push(z as u16);
        }
        let mut samples = 0usize;
        for sweep in 0..cfg.predict_sweeps {
            let mut ps = PredictState {
                t,
                phi: &model.phi,
                phi_cum,
                alias,
                alpha: model.alpha,
                ndt: &mut self.ndt,
                rng: &mut *rng,
            };
            self.kern.sweep_doc_predict(&mut ps, tokens, &mut self.zd);
            if sweep >= cfg.predict_burnin {
                for ti in 0..t {
                    self.acc[ti] += self.ndt[ti] as f64;
                }
                samples += 1;
            }
        }
        let denom = (samples.max(1) * nd) as f64;
        for ti in 0..t {
            out[ti] = (self.acc[ti] / denom) as f32;
        }
    }
}

/// Build the per-model frozen-phi alias tables when (and only when) the
/// resolved prediction kernel needs them.
fn build_alias_tables(model: &SldaModel, kind: KernelKind) -> Option<PhiAliasTables> {
    match kind.resolve_predict(model.t) {
        KernelKind::Alias => Some(PhiAliasTables::build(&model.phi, model.t)),
        _ => None,
    }
}

/// Infer averaged empirical topic distributions for every document with an
/// explicit kernel choice. Returns a row-major [D, T] matrix. Dense and
/// sparse are draw-for-draw identical (the choice affects throughput only);
/// alias is statistically equivalent but a different seed-deterministic
/// chain. Accepts `&Corpus` or any [`CorpusView`] (e.g. a zero-copy shard
/// window).
pub fn infer_zbar_with_kernel<'a>(
    model: &SldaModel,
    corpus: impl Into<CorpusView<'a>>,
    cfg: &TrainConfig,
    kernel_kind: KernelKind,
    rng: &mut Pcg64,
) -> Vec<f32> {
    let corpus: CorpusView<'a> = corpus.into();
    let t = model.t;
    let d = corpus.num_docs();
    let mut zbar = vec![0.0f32; d * t];
    let mut scratch = DocInfer::new(kernel_kind, t);
    // Per-model tables, built once per call (phi is frozen throughout):
    // cumulative smoothing masses alpha * phi for dense/sparse, Walker
    // alias tables for the alias kernel.
    let phi_cum = kernel::build_phi_cum(&model.phi, t, model.alpha);
    let alias = build_alias_tables(model, kernel_kind);

    for di in 0..d {
        scratch.infer_doc(
            model,
            &phi_cum,
            alias.as_ref(),
            cfg,
            corpus.doc_tokens(di),
            rng,
            &mut zbar[di * t..(di + 1) * t],
        );
    }
    zbar
}

/// Parallel, per-document-seeded zbar inference: documents are split into
/// `jobs` contiguous ranges over [`scoped_map`] workers, each with its own
/// [`DocInfer`] scratch, and every document draws from a private RNG stream
/// seeded by [`doc_stream_seed`]`(seed, `[`token_hash`]`(doc))`. The result
/// is therefore identical for any `jobs` value — and identical to what the
/// serving subsystem computes for the same (model, seed, doc).
pub fn infer_zbar_parallel<'a>(
    model: &SldaModel,
    corpus: impl Into<CorpusView<'a>>,
    cfg: &TrainConfig,
    kernel_kind: KernelKind,
    seed: u64,
    jobs: usize,
) -> Vec<f32> {
    let corpus: CorpusView<'a> = corpus.into();
    let t = model.t;
    let d = corpus.num_docs();
    if d == 0 {
        return Vec::new();
    }
    let jobs = jobs.max(1).min(d);
    let per = d.div_ceil(jobs);
    let phi_cum = kernel::build_phi_cum(&model.phi, t, model.alpha);
    // Shared read-only across the fan-out, like phi_cum.
    let alias = build_alias_tables(model, kernel_kind);
    let alias_ref = alias.as_ref();
    let ranges: Vec<(usize, usize)> = (0..jobs)
        .map(|j| (j * per, ((j + 1) * per).min(d)))
        .filter(|&(lo, hi)| lo < hi)
        .collect();
    let chunks = scoped_map(&ranges, jobs, |_, &(lo, hi)| {
        let mut scratch = DocInfer::new(kernel_kind, t);
        let mut out = vec![0.0f32; (hi - lo) * t];
        for di in lo..hi {
            let tokens = corpus.doc_tokens(di);
            let mut rng = Pcg64::seed_from_u64(doc_stream_seed(seed, token_hash(tokens)));
            let row = &mut out[(di - lo) * t..(di - lo + 1) * t];
            scratch.infer_doc(model, &phi_cum, alias_ref, cfg, tokens, &mut rng, row);
        }
        out
    });
    chunks.concat()
}

/// [`infer_zbar_parallel`] plus the batched engine prediction call.
#[allow(clippy::too_many_arguments)]
pub fn predict_corpus_parallel<'a>(
    model: &SldaModel,
    corpus: impl Into<CorpusView<'a>>,
    cfg: &TrainConfig,
    kernel_kind: KernelKind,
    engine: &EngineHandle,
    labels: Option<&[f64]>,
    seed: u64,
    jobs: usize,
) -> anyhow::Result<(Prediction, Vec<f32>)> {
    let zbar = infer_zbar_parallel(model, corpus, cfg, kernel_kind, seed, jobs);
    let pred = engine.predict(&zbar, &model.eta, labels, model.t)?;
    Ok((pred, zbar))
}

/// [`infer_zbar_with_kernel`] with the `auto` kernel heuristic.
pub fn infer_zbar<'a>(
    model: &SldaModel,
    corpus: impl Into<CorpusView<'a>>,
    cfg: &TrainConfig,
    rng: &mut Pcg64,
) -> Vec<f32> {
    infer_zbar_with_kernel(model, corpus, cfg, KernelKind::Auto, rng)
}

/// Full prediction pipeline with an explicit kernel: infer zbar, then
/// batched yhat + metrics. `labels`: pass the ground truth to obtain
/// MSE/accuracy (paper's test evaluation), or `None` for pure inference.
pub fn predict_corpus_with_kernel<'a>(
    model: &SldaModel,
    corpus: impl Into<CorpusView<'a>>,
    cfg: &TrainConfig,
    kernel_kind: KernelKind,
    engine: &EngineHandle,
    labels: Option<&[f64]>,
    rng: &mut Pcg64,
) -> anyhow::Result<(Prediction, Vec<f32>)> {
    let zbar = infer_zbar_with_kernel(model, corpus, cfg, kernel_kind, rng);
    let pred = engine.predict(&zbar, &model.eta, labels, model.t)?;
    Ok((pred, zbar))
}

/// [`predict_corpus_with_kernel`] with the `auto` kernel heuristic.
pub fn predict_corpus<'a>(
    model: &SldaModel,
    corpus: impl Into<CorpusView<'a>>,
    cfg: &TrainConfig,
    engine: &EngineHandle,
    labels: Option<&[f64]>,
    rng: &mut Pcg64,
) -> anyhow::Result<(Prediction, Vec<f32>)> {
    predict_corpus_with_kernel(model, corpus, cfg, KernelKind::Auto, engine, labels, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ExperimentConfig;
    use crate::data::synthetic::{generate_split, SyntheticSpec};
    use crate::sampler::gibbs_train::train;
    use crate::util::stats::Summary;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::quick();
        c.train.sweeps = 25;
        c.train.burnin = 5;
        c.train.eta_every = 5;
        c.train.predict_sweeps = 12;
        c.train.predict_burnin = 4;
        c
    }

    #[test]
    fn zbar_rows_are_distributions() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = generate_split(&spec, 180, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
        let zbar = infer_zbar(&out.model, &ds.test, &cfg().train, &mut rng);
        let t = out.model.t;
        for d in 0..ds.test.num_docs() {
            let s: f32 = zbar[d * t..(d + 1) * t].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "doc {d} zbar sums to {s}");
        }
    }

    #[test]
    fn end_to_end_beats_mean_baseline() {
        // The paper's core premise: sLDA predictions must beat predicting
        // the train-mean for every test document.
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = generate_split(&spec, 180, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
        let ys = ds.test.responses();
        let (pred, _) =
            predict_corpus(&out.model, &ds.test, &cfg().train, &engine, Some(&ys), &mut rng)
                .unwrap();
        let var = Summary::from_slice(&ys).var(); // mean-baseline MSE
        assert!(
            pred.mse < 0.6 * var,
            "test mse {} should beat mean baseline {var}",
            pred.mse
        );
        assert_eq!(pred.yhat.len(), ds.test.num_docs());
    }

    #[test]
    fn prediction_without_labels_reports_zero_metrics() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = generate_split(&spec, 180, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
        let (pred, zbar) =
            predict_corpus(&out.model, &ds.test, &cfg().train, &engine, None, &mut rng).unwrap();
        assert_eq!(pred.mse, 0.0);
        assert_eq!(pred.acc, 0.0);
        assert_eq!(zbar.len(), ds.test.num_docs() * out.model.t);
    }

    #[test]
    fn parallel_inference_independent_of_jobs() {
        // Per-document seeding: the same (model, seed, doc) must yield the
        // same zbar row for any worker count — the serving determinism
        // guarantee (DESIGN.md §Serving).
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(31);
        let ds = generate_split(&spec, 180, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
        let z1 = infer_zbar_parallel(
            &out.model, &ds.test, &cfg().train, KernelKind::Auto, 77, 1,
        );
        let z4 = infer_zbar_parallel(
            &out.model, &ds.test, &cfg().train, KernelKind::Auto, 77, 4,
        );
        let z9 = infer_zbar_parallel(
            &out.model, &ds.test, &cfg().train, KernelKind::Auto, 77, 9,
        );
        assert_eq!(z1, z4);
        assert_eq!(z1, z9);
        // dense and sparse stay draw-for-draw interchangeable (auto now
        // resolves to the alias-MH chain on the prediction path, which is
        // only statistically equivalent — tests/alias_equivalence.rs)
        let zd = infer_zbar_parallel(
            &out.model, &ds.test, &cfg().train, KernelKind::Dense, 77, 2,
        );
        let zs = infer_zbar_parallel(
            &out.model, &ds.test, &cfg().train, KernelKind::Sparse, 77, 3,
        );
        assert_eq!(zd, zs);
        // rows are still distributions under the alias chain
        let t = out.model.t;
        for d in 0..ds.test.num_docs() {
            let s: f32 = z1[d * t..(d + 1) * t].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "doc {d} zbar sums to {s}");
        }
    }

    #[test]
    fn parallel_prediction_reorders_with_documents() {
        // Content-addressed seeding: moving a document does not change its
        // prediction.
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(33);
        let ds = generate_split(&spec, 180, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
        let fwd: Vec<usize> = (0..ds.test.num_docs()).collect();
        let rev: Vec<usize> = fwd.iter().rev().copied().collect();
        let (pf, _) = predict_corpus_parallel(
            &out.model, &ds.test.select(&fwd), &cfg().train, KernelKind::Auto,
            &engine, None, 5, 2,
        )
        .unwrap();
        let (pr, _) = predict_corpus_parallel(
            &out.model, &ds.test.select(&rev), &cfg().train, KernelKind::Auto,
            &engine, None, 5, 3,
        )
        .unwrap();
        let rf: Vec<f64> = pf.yhat.iter().rev().copied().collect();
        assert_eq!(rf, pr.yhat);
    }

    #[test]
    fn token_hash_and_stream_seed_are_stable() {
        let a = token_hash(&[1, 2, 3]);
        assert_eq!(a, token_hash(&[1, 2, 3]));
        assert_ne!(a, token_hash(&[3, 2, 1]));
        assert_ne!(a, token_hash(&[1, 2]));
        assert_eq!(doc_stream_seed(7, a), doc_stream_seed(7, a));
        assert_ne!(doc_stream_seed(7, a), doc_stream_seed(8, a));
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SyntheticSpec::continuous_small();
        let engine = EngineHandle::native();
        let mk = || {
            let mut rng = Pcg64::seed_from_u64(9);
            let ds = generate_split(&spec, 180, &mut rng);
            let out = train(&ds.train, &cfg(), &engine, &mut rng).unwrap();
            let ys = ds.test.responses();
            predict_corpus(&out.model, &ds.test, &cfg().train, &engine, Some(&ys), &mut rng)
                .unwrap()
                .0
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.yhat, b.yhat);
        assert_eq!(a.mse, b.mse);
    }
}
