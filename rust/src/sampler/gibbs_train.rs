//! Training: collapsed Gibbs + stochastic EM for sLDA.
//!
//! Implements the paper's posterior-inference loop exactly:
//!
//! * **Gibbs step** (eq. 1): for every token, resample its topic from
//!     p(z = t) ∝ N(y_d; mu_{d,n}, rho) · (N_dt + alpha) · (N_tw + beta)/(N_t + W beta)
//!   with mu_{d,n} = (sum_t' eta_t' N^{-dn}_dt' + eta_t) / N_d. The document
//!   ratio's denominator (N_d - 1 + T alpha) is constant in t and dropped.
//! * **eta step** (eq. 2): ridge MAP solve, dispatched to the engine (AOT
//!   XLA artifact or native), every `eta_every` sweeps after burn-in; rho is
//!   re-estimated from residuals when `learn_rho` is set.
//!
//! The token updates are delegated to the configured [`kernel`]: while eta
//! is all-zero (every burn-in sweep) the response factor is constant and the
//! kernel's plain-LDA path runs — the sparse kernel exploits the bucket
//! decomposition there, the alias kernel its O(1) MH proposals. Once eta
//! activates, the same kernel's supervised entry point
//! [`kernel::SamplerKernel::sweep_doc_resp`] takes over: exact
//! O(T)-per-token Gaussian-margin sweeps on the dense kernel (and under
//! `sampler.resp_mode = exact`), Metropolis-Hastings-corrected sparse/alias
//! proposals with the O(1) response ratio under `resp_mode = mh|auto`
//! (DESIGN.md §Perf "Supervised MH decomposition"). The eta step itself
//! consumes the Gram moments straight from the count state
//! ([`EngineHandle::eta_solve_counts`]) — no [D, T] zbar materialization
//! per step.
//!
//! The trainer consumes a [`CorpusView`]: a shard worker trains directly on
//! a borrowed window of the leader's token arena (zero setup copies,
//! DESIGN.md §Memory layout). Per-document state (`z`, responses, zbar
//! scratch) lives in flat buffers allocated once per `train` call.

use crate::ckpt::ShardState;
use crate::config::schema::{ExperimentConfig, KernelKind};
use crate::data::corpus::CorpusView;
use crate::model::counts::CountMatrices;
use crate::model::slda::SldaModel;
use crate::runtime::EngineHandle;
use crate::sampler::kernel::{self, GaussScratch, RespState, TrainState};
use crate::util::rng::Pcg64;
use crate::util::timer::{CpuStopwatch, PhaseTimings};
use std::sync::atomic::{AtomicBool, Ordering};

/// Per-eta-step trace used for convergence reporting (DESIGN.md §5).
#[derive(Clone, Debug, PartialEq)]
pub struct SweepStats {
    pub sweep: usize,
    pub train_mse: f64,
    pub rho: f64,
    pub eta_l2: f64,
}

/// Result of training one chain on one (sub-)corpus.
#[derive(Clone, Debug)]
pub struct TrainOutput {
    pub model: SldaModel,
    /// Final count state (needed by the Naive Combination pooling).
    pub counts: CountMatrices,
    /// Final token-topic assignments (z), one per token in corpus-view
    /// order; document d's run is `z[z_offsets[d] as usize..z_offsets[d+1]
    /// as usize]`.
    pub z: Vec<u16>,
    /// CSR offsets delimiting `z` per document (length `docs + 1`).
    pub z_offsets: Vec<u32>,
    /// Responses of the training documents, in `counts` row order (needed
    /// by the Naive Combination pooling stage to align the pooled zbar rows
    /// with their labels).
    pub responses: Vec<f64>,
    /// eta-step history.
    pub history: Vec<SweepStats>,
    /// Total token updates performed (throughput accounting).
    pub tokens_sampled: u64,
    /// Supervised-MH proposals issued across all eta-active sweeps (0 when
    /// the supervised path ran the exact conditional).
    pub resp_proposed: u64,
    /// Supervised-MH proposals accepted (self-proposals count as accepted).
    pub resp_accepted: u64,
    /// Alias-table rebuilds across the whole run (0 for kernels without
    /// alias tables) — pairs with the staleness budget for the
    /// amortization accounting in `BENCH_gibbs_hotpath.json`.
    pub alias_rebuilds: u64,
    /// Phase timing breakdown (gibbs vs eta-solve).
    pub timings: PhaseTimings,
}

/// Checkpoint/resume/interrupt plumbing for one training chain.
///
/// The hook only *moves data*; whether checkpoint boundaries exist at all
/// is decided by `cfg.train.checkpoint_every` alone, so a hookless run
/// under the same config walks the exact same chain (see the kernel-epoch
/// reset note in [`train_ckpt`]).
pub struct CkptHook<'h> {
    pub shard_id: u32,
    /// Snapshot to continue from instead of random initialization.
    pub resume: Option<ShardState>,
    /// Called with a full snapshot at every checkpoint boundary. A sink
    /// error is logged and counted — training continues.
    #[allow(clippy::type_complexity)]
    pub sink: Option<&'h (dyn Fn(ShardState) -> anyhow::Result<()> + Sync)>,
    /// Graceful-shutdown flag, checked only at checkpoint boundaries
    /// (right after the snapshot is offered to the sink).
    pub stop: Option<&'h AtomicBool>,
}

/// How a [`train_ckpt`] call ended.
pub enum TrainRun {
    Done(Box<TrainOutput>),
    /// Stopped at a checkpoint boundary by the hook's stop flag; resume
    /// from the checkpoint directory to continue at `next_sweep`.
    Interrupted { next_sweep: u64 },
}

/// Train an sLDA model with collapsed Gibbs + stochastic EM. Accepts
/// `&Corpus` or any [`CorpusView`] (e.g. a zero-copy shard window).
pub fn train<'a>(
    corpus: impl Into<CorpusView<'a>>,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    rng: &mut Pcg64,
) -> anyhow::Result<TrainOutput> {
    match train_ckpt(corpus, cfg, engine, rng, None)? {
        TrainRun::Done(out) => Ok(*out),
        // unreachable without a stop flag, which only a hook can carry
        TrainRun::Interrupted { .. } => {
            anyhow::bail!("training interrupted without a checkpoint hook")
        }
    }
}

/// [`train`] with durability: checkpoint at every `checkpoint_every`
/// boundary, optionally start from a restored [`ShardState`], and honor a
/// stop flag at boundaries.
///
/// **Byte-identical-resume contract** (DESIGN.md §Durability): at every
/// boundary the chain's kernel state is torn down and re-derived from the
/// count matrices — fresh kernel, re-enabled sparse index / alias reverse
/// map, `1/(N_t + W·beta)` table recomputed from the counts rather than
/// carried incrementally. That makes everything the next sweep reads a
/// pure function of (counts, z, eta, rho, RNG state) = the snapshot, so a
/// resumed chain and an uninterrupted one cannot diverge — not even in
/// floating-point accumulation order. The reset happens whenever the
/// config asks for checkpoints, hook or no hook, which is why
/// `checkpoint_every` is chain-defining and part of the config
/// fingerprint.
pub fn train_ckpt<'a>(
    corpus: impl Into<CorpusView<'a>>,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    rng: &mut Pcg64,
    hook: Option<CkptHook<'_>>,
) -> anyhow::Result<TrainRun> {
    let corpus: CorpusView<'a> = corpus.into();
    let t = cfg.model.topics;
    let w = corpus.vocab_size();
    let d = corpus.num_docs();
    anyhow::ensure!(d > 0, "cannot train on an empty corpus");
    anyhow::ensure!(t >= 2, "need at least 2 topics");

    let alpha = cfg.model.alpha;
    let beta = cfg.model.beta;
    let wbeta = w as f64 * beta;
    let mut rho = cfg.model.rho;
    let mut eta = vec![0.0f64; t];
    let mut eta_active = false; // all-zero eta => response term is constant

    let (shard_id, resume, sink, stop) = match hook {
        Some(h) => (h.shard_id, h.resume, h.sink, h.stop),
        None => (0, None, None, None),
    };

    let z_offsets = corpus.local_doc_offsets();
    let mut history = Vec::new();
    let mut tokens_sampled: u64 = 0;
    // Counter totals from kernel epochs already torn down at earlier
    // checkpoint boundaries (each boundary resets the live kernel).
    let (mut base_proposed, mut base_accepted, mut base_rebuilds) = (0u64, 0u64, 0u64);
    let mut start_sweep = 0usize;

    let (mut counts, mut z) = match resume {
        None => {
            // Random initialization of topic assignments: flat z in arena
            // order.
            CountMatrices::init_random(corpus, t, rng)
        }
        Some(s) => {
            // Restore the chain exactly: the snapshot's RNG stream already
            // reflects initialization and every sweep before `next_sweep`,
            // so no draws happen here at all.
            anyhow::ensure!(
                s.t as usize == t && s.w as usize == w && s.d as usize == d,
                "checkpoint dims t={} w={} d={} do not match run t={t} w={w} d={d}",
                s.t,
                s.w,
                s.d
            );
            anyhow::ensure!(
                s.z.len() == corpus.num_tokens(),
                "checkpoint has {} token assignments, corpus has {}",
                s.z.len(),
                corpus.num_tokens()
            );
            anyhow::ensure!(
                s.z.iter().all(|&zi| (zi as usize) < t),
                "checkpoint token assignment out of range (t={t})"
            );
            anyhow::ensure!(
                s.eta.len() == t,
                "checkpoint eta has {} entries, want {t}",
                s.eta.len()
            );
            anyhow::ensure!(
                (s.next_sweep as usize) < cfg.train.sweeps,
                "checkpoint next_sweep {} is past train.sweeps {}",
                s.next_sweep,
                cfg.train.sweeps
            );
            let counts = CountMatrices::from_parts(t, w, d, s.ndt, s.nd, s.ntw, s.nt)?;
            *rng = Pcg64::from_raw(s.rng_state, s.rng_inc);
            eta = s.eta;
            eta_active = s.eta_active;
            rho = s.rho;
            history = s.history;
            tokens_sampled = s.tokens_sampled;
            (base_proposed, base_accepted, base_rebuilds) =
                (s.resp_proposed, s.resp_accepted, s.alias_rebuilds);
            start_sweep = s.next_sweep as usize;
            (counts, s.z)
        }
    };

    // Responses materialized once for the whole run (the only per-document
    // data a shard worker copies out of the arena).
    let y: Vec<f64> = corpus.responses();

    // Kernel selection (DESIGN.md §Perf): `auto` resolves by topic count,
    // `resp_mode` per kernel (exact for dense, MH for sparse/alias). The
    // sparse kernel needs the counts' non-zero index and the alias kernel
    // the per-word update counters; both are maintained incrementally by
    // inc/dec through every sweep — burn-in and supervised alike — so the
    // MH supervised path keeps drawing from live structures.
    let resolved = cfg.sampler.kernel.resolve_train(t);
    match resolved {
        KernelKind::Sparse => counts.enable_sparse_index(),
        KernelKind::Alias => counts.enable_alias_rev(),
        _ => {}
    }
    let mut kern = kernel::make_train_kernel(
        resolved,
        t,
        cfg.sampler.alias_staleness,
        cfg.sampler.resp_mode,
    );

    // Incrementally maintained 1/(N_t + W beta): replaces T divisions per
    // token with 2 reciprocal updates (§Perf opt A). `ssum` caches its sum
    // (the sparse kernel's smoothing-bucket mass).
    let mut inv_nt: Vec<f64> =
        counts.nt.iter().map(|&n| 1.0 / (n as f64 + wbeta)).collect();
    let mut ssum: f64 = inv_nt.iter().sum();
    // Per-document response-margin tables (§Perf opt B): with e_t =
    // eta_t / N_d fixed within a document-sweep,
    //   N(y; mu_t, rho) ∝ exp(2c e_t - e_t^2) / 2rho            (c = y - s/N_d)
    //                   = exp((c/rho) e_t) * exp(-e_t^2 / 2rho)
    // so u_t = exp(-e_t^2/2rho) costs T exps per *document* and each token
    // pays one fused multiply inside the remaining exp.
    let mut scratch = GaussScratch::new(t);
    // Reusable zbar buffer: only the XLA engine's eta path materializes
    // into it (native consumes the counts directly); the final model-card
    // fit below reuses it too.
    let mut zbar_buf: Vec<f32> = Vec::new();
    let mut timings = PhaseTimings::new();

    // Training telemetry (DESIGN.md §Observability): per-sweep counters and
    // throughput gauges on the global registry. Every record is a relaxed
    // atomic op on a preregistered cell — nothing here allocates or locks.
    let telemetry = cfg.obs.train_telemetry;

    for sweep in start_sweep..cfg.train.sweeps {
        let sw = CpuStopwatch::new();
        let tokens_before = tokens_sampled;
        for di in 0..d {
            let tokens = corpus.doc_tokens(di);
            let zd = &mut z[z_offsets[di] as usize..z_offsets[di + 1] as usize];
            let mut st = TrainState {
                counts: &mut counts,
                inv_nt: &mut inv_nt,
                ssum: &mut ssum,
                alpha,
                beta,
                wbeta,
                rng: &mut *rng,
            };
            if eta_active {
                let mut rs = RespState { eta: &eta, y: y[di], rho, scratch: &mut scratch };
                kern.sweep_doc_resp(&mut st, &mut rs, di, tokens, zd);
            } else {
                kern.sweep_doc_lda(&mut st, di, tokens, zd);
            }
            tokens_sampled += tokens.len() as u64;
        }
        let gibbs_secs = sw.elapsed_secs();
        timings.add("gibbs", gibbs_secs);
        if telemetry {
            let tr = &crate::obs::registry().training;
            tr.sweeps.inc();
            let swept = tokens_sampled - tokens_before;
            tr.tokens.add(swept);
            if gibbs_secs > 0.0 {
                tr.tokens_per_sec.set((swept as f64 / gibbs_secs) as u64);
            }
        }

        // eta step (eq. 2) after burn-in, every eta_every sweeps, and on the
        // final sweep so the returned model always reflects the last state.
        let due = sweep + 1 > cfg.train.burnin
            && (sweep + 1 - cfg.train.burnin) % cfg.train.eta_every == 0;
        let last = sweep + 1 == cfg.train.sweeps;
        if due || last {
            let sw = CpuStopwatch::new();
            let lambda = cfg.model.lambda(rho);
            // Gram moments straight from the counts (O(Σ_d nnz_d²), no
            // [D, T] zbar materialization) — numerically identical to the
            // zbar-matrix path (DESIGN.md §Perf).
            let (eta_new, mse) =
                engine.eta_solve_counts(&counts, &y, lambda, cfg.model.mu, &mut zbar_buf)?;
            eta = eta_new;
            eta_active = eta.iter().any(|&e| e != 0.0);
            if cfg.model.learn_rho {
                rho = mse.max(1e-4);
            }
            timings.add("eta_solve", sw.elapsed_secs());
            history.push(SweepStats {
                sweep: sweep + 1,
                train_mse: mse,
                rho,
                eta_l2: eta.iter().map(|e| e * e).sum::<f64>().sqrt(),
            });
        }

        // Checkpoint boundary: every `checkpoint_every` sweeps, except the
        // final one (a finished run has nothing to resume). The boundary is
        // a *kernel epoch* edge regardless of whether a hook is attached:
        // kernel counters roll into the baselines, the snapshot (if a sink
        // wants one) is taken, and then the whole kernel state is re-derived
        // from the counts — the same derivation a resumed process performs —
        // so the chain after the boundary is a pure function of the
        // snapshot (see [`train_ckpt`] docs).
        let every = cfg.train.checkpoint_every;
        if every > 0 && (sweep + 1) % every == 0 && sweep + 1 < cfg.train.sweeps {
            let sw = CpuStopwatch::new();
            let (p, a) = kern.resp_mh_stats().unwrap_or((0, 0));
            base_proposed += p;
            base_accepted += a;
            let (reb, _) = kern.alias_stats().unwrap_or((0, 0));
            base_rebuilds += reb;
            if let Some(sink) = sink {
                let (rng_state, rng_inc) = rng.to_raw();
                let state = ShardState {
                    shard_id,
                    next_sweep: (sweep + 1) as u64,
                    t: t as u32,
                    w: w as u32,
                    d: d as u32,
                    rho,
                    eta_active,
                    tokens_sampled,
                    resp_proposed: base_proposed,
                    resp_accepted: base_accepted,
                    alias_rebuilds: base_rebuilds,
                    rng_state,
                    rng_inc,
                    eta: eta.clone(),
                    z: z.clone(),
                    ndt: counts.ndt.clone(),
                    nd: counts.nd.clone(),
                    ntw: counts.ntw.clone(),
                    nt: counts.nt.clone(),
                    history: history.clone(),
                };
                if let Err(e) = sink(state) {
                    // A failed checkpoint must not kill a healthy run: log,
                    // count, continue — the previous generation still stands.
                    log::warn!(
                        "checkpoint at sweep {} failed: {e:#}; training continues",
                        sweep + 1
                    );
                    crate::obs::registry().training.ckpt_failures.inc();
                }
            }
            timings.add("checkpoint", sw.elapsed_secs());
            if stop.is_some_and(|s| s.load(Ordering::SeqCst)) {
                return Ok(TrainRun::Interrupted { next_sweep: (sweep + 1) as u64 });
            }
            // Kernel-epoch reset: re-derive everything the sampler reads
            // from the count state, exactly as a resume would.
            match resolved {
                KernelKind::Sparse => counts.enable_sparse_index(),
                KernelKind::Alias => counts.enable_alias_rev(),
                _ => {}
            }
            kern = kernel::make_train_kernel(
                resolved,
                t,
                cfg.sampler.alias_staleness,
                cfg.sampler.resp_mode,
            );
            for (i, iv) in inv_nt.iter_mut().enumerate() {
                *iv = 1.0 / (counts.nt[i] as f64 + wbeta);
            }
            ssum = inv_nt.iter().sum();
        }
    }

    // Final in-sample metrics on the fitted zbar (model card data; the
    // Weighted Average combiner computes its weights separately by
    // *predicting* the whole training set, as the paper specifies). The
    // only place the native path still materializes the [D, T] zbar.
    counts.zbar_matrix_into(&mut zbar_buf);
    let fit = engine.predict(&zbar_buf, &eta, Some(&y), t)?;

    let phi = SldaModel::phi_from_counts(&counts, beta);
    let model = SldaModel {
        t,
        w,
        eta,
        phi,
        rho,
        alpha,
        train_mse: fit.mse,
        train_acc: fit.acc,
    };
    let (live_proposed, live_accepted) = kern.resp_mh_stats().unwrap_or((0, 0));
    let (live_rebuilds, alias_staleness) = kern.alias_stats().unwrap_or((0, 0));
    let resp_proposed = base_proposed + live_proposed;
    let resp_accepted = base_accepted + live_accepted;
    let alias_rebuilds = base_rebuilds + live_rebuilds;
    if telemetry {
        let tr = &crate::obs::registry().training;
        tr.resp_proposed.add(resp_proposed);
        tr.resp_accepted.add(resp_accepted);
        tr.alias_rebuilds.add(alias_rebuilds);
        if alias_staleness > 0 {
            tr.alias_staleness.set(alias_staleness);
        }
    }
    Ok(TrainRun::Done(Box::new(TrainOutput {
        model,
        counts,
        z,
        z_offsets,
        responses: y,
        history,
        tokens_sampled,
        resp_proposed,
        resp_accepted,
        alias_rebuilds,
        timings,
    })))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ExperimentConfig;
    use crate::data::corpus::Corpus;
    use crate::data::synthetic::{generate_with_truth, SyntheticSpec};

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::quick();
        cfg.train.sweeps = 20;
        cfg.train.burnin = 4;
        cfg.train.eta_every = 4;
        cfg
    }

    #[test]
    fn training_reduces_mse_and_keeps_invariants() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(42);
        let (corpus, _) = generate_with_truth(&spec, &mut rng);
        let cfg = quick_cfg();
        let engine = EngineHandle::native();
        let out = train(&corpus, &cfg, &engine, &mut rng).unwrap();

        out.counts.check_invariants().unwrap();
        assert_eq!(out.counts.total_tokens(), corpus.num_tokens() as u64);
        assert_eq!(out.tokens_sampled, (corpus.num_tokens() * cfg.train.sweeps) as u64);
        assert_eq!(out.z.len(), corpus.num_tokens());
        assert_eq!(out.z_offsets, corpus.doc_offsets);

        // MSE at the last eta step must improve over the first.
        let first = out.history.first().unwrap().train_mse;
        let last = out.history.last().unwrap().train_mse;
        assert!(
            last < first * 0.9,
            "no learning signal: first={first} last={last} (history {:?})",
            out.history
        );
        // In-sample fit should explain a large share of label variance.
        let ys = corpus.responses();
        let var = crate::util::stats::Summary::from_slice(&ys).var();
        assert!(out.model.train_mse < 0.5 * var, "mse={} var={var}", out.model.train_mse);
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SyntheticSpec::continuous_small();
        let cfg = quick_cfg();
        let engine = EngineHandle::native();
        let mk = || {
            let mut rng = Pcg64::seed_from_u64(7);
            let (corpus, _) = generate_with_truth(&spec, &mut rng);
            train(&corpus, &cfg, &engine, &mut rng).unwrap()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.model.eta, b.model.eta);
        assert_eq!(a.counts.ndt, b.counts.ndt);
        assert_eq!(a.model.train_mse, b.model.train_mse);
    }

    #[test]
    fn view_training_equals_whole_corpus_training() {
        // Training on corpus.view() and on an indexed identity view must be
        // draw-for-draw identical to training on &corpus.
        let spec = SyntheticSpec::continuous_small();
        let cfg = quick_cfg();
        let engine = EngineHandle::native();
        let mut rng = Pcg64::seed_from_u64(8);
        let (corpus, _) = generate_with_truth(&spec, &mut rng);
        let ids: Vec<usize> = (0..corpus.num_docs()).collect();
        let a = train(&corpus, &cfg, &engine, &mut Pcg64::seed_from_u64(55)).unwrap();
        let b =
            train(corpus.view(), &cfg, &engine, &mut Pcg64::seed_from_u64(55)).unwrap();
        let c = train(corpus.view_of(&ids), &cfg, &engine, &mut Pcg64::seed_from_u64(55))
            .unwrap();
        assert_eq!(a.z, b.z);
        assert_eq!(a.z, c.z);
        assert_eq!(a.model.eta, b.model.eta);
        assert_eq!(a.model.eta, c.model.eta);
        assert_eq!(a.counts.ndt, c.counts.ndt);
    }

    #[test]
    fn supervised_mh_dispatch_reports_acceptance_and_learns() {
        use crate::config::schema::KernelKind;
        let spec = SyntheticSpec::continuous_small();
        let engine = EngineHandle::native();
        let run = |kernel: KernelKind| {
            let mut rng = Pcg64::seed_from_u64(21);
            let (corpus, _) = generate_with_truth(&spec, &mut rng);
            let mut cfg = quick_cfg();
            cfg.sampler.kernel = kernel;
            train(&corpus, &cfg, &engine, &mut rng).unwrap()
        };
        // resp_mode auto => MH supervised sweeps on sparse/alias: the
        // eta-active phase runs the kernel (not the dense fallback) and
        // reports its acceptance counters.
        for kernel in [KernelKind::Sparse, KernelKind::Alias] {
            let out = run(kernel);
            assert!(out.resp_proposed > 0, "{kernel:?} never proposed");
            assert!(
                out.resp_accepted > 0 && out.resp_accepted <= out.resp_proposed,
                "{kernel:?} acceptance out of range: {}/{}",
                out.resp_accepted,
                out.resp_proposed
            );
            out.counts.check_invariants().unwrap();
            let first = out.history.first().unwrap().train_mse;
            let last = out.history.last().unwrap().train_mse;
            assert!(last < first, "{kernel:?} no learning: first={first} last={last}");
        }
        // the alias kernel is the only one with tables to rebuild
        let out = run(KernelKind::Alias);
        assert!(out.alias_rebuilds > 0, "alias kernel never rebuilt a table");
        let out = run(KernelKind::Sparse);
        assert_eq!(out.alias_rebuilds, 0);
        // the dense kernel's supervised path is exact: no MH activity
        let out = run(KernelKind::Dense);
        assert_eq!((out.resp_proposed, out.resp_accepted), (0, 0));
    }

    #[test]
    fn binary_training_learns_accuracy() {
        let spec = SyntheticSpec::binary_small();
        let mut rng = Pcg64::seed_from_u64(11);
        let (corpus, _) = generate_with_truth(&spec, &mut rng);
        let mut cfg = quick_cfg();
        cfg.response = crate::config::schema::ResponseKind::Binary;
        let engine = EngineHandle::native();
        let out = train(&corpus, &cfg, &engine, &mut rng).unwrap();
        assert!(out.model.train_acc > 0.7, "train_acc={}", out.model.train_acc);
    }

    #[test]
    fn phi_rows_are_distributions() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(3);
        let (corpus, _) = generate_with_truth(&spec, &mut rng);
        let engine = EngineHandle::native();
        let out = train(&corpus, &quick_cfg(), &engine, &mut rng).unwrap();
        let m = &out.model;
        for ti in 0..m.t {
            let s: f64 = (0..m.w).map(|wi| m.phi[wi * m.t + ti] as f64).sum();
            assert!((s - 1.0).abs() < 1e-4, "topic {ti} sums to {s}");
        }
    }

    #[test]
    fn rejects_empty_corpus() {
        let corpus = Corpus::new(vec![], 10);
        let engine = EngineHandle::native();
        let mut rng = Pcg64::seed_from_u64(1);
        assert!(train(&corpus, &quick_cfg(), &engine, &mut rng).is_err());
    }

    #[test]
    fn checkpoint_resume_is_byte_identical_across_kernels() {
        use crate::config::schema::{KernelKind, RespMode};
        use std::sync::Mutex;
        let spec = SyntheticSpec::continuous_small();
        let engine = EngineHandle::native();
        for (kernel, mode) in [
            (KernelKind::Dense, RespMode::Auto),
            (KernelKind::Sparse, RespMode::Exact),
            (KernelKind::Sparse, RespMode::Mh),
            (KernelKind::Alias, RespMode::Exact),
            (KernelKind::Alias, RespMode::Mh),
        ] {
            let mut cfg = quick_cfg();
            cfg.train.checkpoint_every = 6; // boundaries at sweeps 6, 12, 18
            cfg.sampler.kernel = kernel;
            cfg.sampler.resp_mode = mode;

            // Reference: a plain hookless run. `checkpoint_every` alone
            // defines the chain, so every variant below must match it.
            let mut rng = Pcg64::seed_from_u64(77);
            let (corpus, _) = generate_with_truth(&spec, &mut rng);
            let full = train(&corpus, &cfg, &engine, &mut rng).unwrap();
            let rng_after = rng.to_raw();

            // Hooked run capturing every boundary snapshot.
            let captured: Mutex<Vec<ShardState>> = Mutex::new(Vec::new());
            let sink = |s: ShardState| -> anyhow::Result<()> {
                captured.lock().unwrap().push(s);
                Ok(())
            };
            let mut rng2 = Pcg64::seed_from_u64(77);
            let (corpus2, _) = generate_with_truth(&spec, &mut rng2);
            let hook = CkptHook { shard_id: 3, resume: None, sink: Some(&sink), stop: None };
            let hooked =
                match train_ckpt(&corpus2, &cfg, &engine, &mut rng2, Some(hook)).unwrap() {
                    TrainRun::Done(out) => *out,
                    TrainRun::Interrupted { .. } => panic!("no stop flag was set"),
                };
            assert_eq!(full.z, hooked.z, "{kernel:?}/{mode:?}: hook must not change the chain");
            assert_eq!(rng2.to_raw(), rng_after);
            let snaps = std::mem::take(&mut *captured.lock().unwrap());
            assert_eq!(
                snaps.iter().map(|s| s.next_sweep).collect::<Vec<_>>(),
                vec![6, 12, 18],
                "{kernel:?}/{mode:?}"
            );

            // "Kill" at each boundary: resuming from any snapshot in a
            // fresh "process" (fresh RNG, overwritten by the restore) must
            // land bitwise-equal to the uninterrupted run.
            for snap in snaps {
                let from = snap.next_sweep;
                assert_eq!(snap.shard_id, 3);
                let mut rng3 = Pcg64::seed_from_u64(0xDEAD_BEEF);
                let hook =
                    CkptHook { shard_id: 3, resume: Some(snap), sink: None, stop: None };
                let resumed =
                    match train_ckpt(&corpus2, &cfg, &engine, &mut rng3, Some(hook)).unwrap() {
                        TrainRun::Done(out) => *out,
                        TrainRun::Interrupted { .. } => panic!("no stop flag was set"),
                    };
                let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
                let tag = format!("{kernel:?}/{mode:?} resumed from sweep {from}");
                assert_eq!(full.z, resumed.z, "{tag}: z");
                assert_eq!(full.counts.ndt, resumed.counts.ndt, "{tag}: ndt");
                assert_eq!(full.counts.ntw, resumed.counts.ntw, "{tag}: ntw");
                assert_eq!(bits(&full.model.eta), bits(&resumed.model.eta), "{tag}: eta");
                assert_eq!(full.model.phi, resumed.model.phi, "{tag}: phi");
                assert_eq!(
                    full.model.train_mse.to_bits(),
                    resumed.model.train_mse.to_bits(),
                    "{tag}: mse"
                );
                assert_eq!(full.tokens_sampled, resumed.tokens_sampled, "{tag}");
                assert_eq!(full.history, resumed.history, "{tag}: history");
                assert_eq!(
                    (full.resp_proposed, full.resp_accepted, full.alias_rebuilds),
                    (resumed.resp_proposed, resumed.resp_accepted, resumed.alias_rebuilds),
                    "{tag}: kernel counters"
                );
                assert_eq!(rng3.to_raw(), rng_after, "{tag}: RNG stream must continue");
            }
        }
    }

    #[test]
    fn stop_flag_interrupts_at_the_boundary_after_snapshotting() {
        use std::sync::Mutex;
        let spec = SyntheticSpec::continuous_small();
        let engine = EngineHandle::native();
        let mut cfg = quick_cfg();
        cfg.train.checkpoint_every = 6;
        let mut rng = Pcg64::seed_from_u64(9);
        let (corpus, _) = generate_with_truth(&spec, &mut rng);
        let captured: Mutex<Vec<ShardState>> = Mutex::new(Vec::new());
        let sink = |s: ShardState| -> anyhow::Result<()> {
            captured.lock().unwrap().push(s);
            Ok(())
        };
        let stop = AtomicBool::new(true); // raised before the first boundary
        let hook = CkptHook { shard_id: 0, resume: None, sink: Some(&sink), stop: Some(&stop) };
        match train_ckpt(&corpus, &cfg, &engine, &mut rng, Some(hook)).unwrap() {
            TrainRun::Interrupted { next_sweep } => assert_eq!(next_sweep, 6),
            TrainRun::Done(_) => panic!("stop flag must interrupt at the boundary"),
        }
        // the final snapshot was offered to the sink before stopping
        let snaps = std::mem::take(&mut *captured.lock().unwrap());
        assert_eq!(snaps.len(), 1);
        assert_eq!(snaps[0].next_sweep, 6);
    }

    #[test]
    fn failing_sink_keeps_training_and_the_chain() {
        let spec = SyntheticSpec::continuous_small();
        let engine = EngineHandle::native();
        let mut cfg = quick_cfg();
        cfg.train.checkpoint_every = 6;
        let mut rng = Pcg64::seed_from_u64(12);
        let (corpus, _) = generate_with_truth(&spec, &mut rng);
        let full = train(&corpus, &cfg, &engine, &mut rng).unwrap();

        let sink =
            |_: ShardState| -> anyhow::Result<()> { anyhow::bail!("disk on fire") };
        let mut rng2 = Pcg64::seed_from_u64(12);
        let (corpus2, _) = generate_with_truth(&spec, &mut rng2);
        let hook = CkptHook { shard_id: 0, resume: None, sink: Some(&sink), stop: None };
        let out = match train_ckpt(&corpus2, &cfg, &engine, &mut rng2, Some(hook)).unwrap() {
            TrainRun::Done(out) => *out,
            TrainRun::Interrupted { .. } => panic!("no stop flag"),
        };
        assert_eq!(full.z, out.z, "sink failures must not perturb the chain");
        assert_eq!(full.model.eta, out.model.eta);
    }

    #[test]
    fn resume_rejects_mismatched_snapshots() {
        let spec = SyntheticSpec::continuous_small();
        let engine = EngineHandle::native();
        let mut cfg = quick_cfg();
        cfg.train.checkpoint_every = 6;
        let mut rng = Pcg64::seed_from_u64(31);
        let (corpus, _) = generate_with_truth(&spec, &mut rng);
        let base = {
            use std::sync::Mutex;
            let captured: Mutex<Vec<ShardState>> = Mutex::new(Vec::new());
            let sink = |s: ShardState| -> anyhow::Result<()> {
                captured.lock().unwrap().push(s);
                Ok(())
            };
            let stop = AtomicBool::new(true);
            let hook =
                CkptHook { shard_id: 0, resume: None, sink: Some(&sink), stop: Some(&stop) };
            train_ckpt(&corpus, &cfg, &engine, &mut rng, Some(hook)).unwrap();
            captured.into_inner().unwrap().remove(0)
        };
        let run = |snap: ShardState| {
            let mut r = Pcg64::seed_from_u64(1);
            let hook = CkptHook { shard_id: 0, resume: Some(snap), sink: None, stop: None };
            train_ckpt(&corpus, &cfg, &engine, &mut r, Some(hook)).map(|_| ())
        };
        // wrong topic count
        let mut bad = base.clone();
        bad.t += 1;
        assert!(run(bad).is_err());
        // z length mismatch
        let mut bad = base.clone();
        bad.z.pop();
        assert!(run(bad).is_err());
        // out-of-range assignment
        let mut bad = base.clone();
        bad.z[0] = cfg.model.topics as u16;
        assert!(run(bad).is_err());
        // next_sweep past the end
        let mut bad = base.clone();
        bad.next_sweep = cfg.train.sweeps as u64;
        assert!(run(bad).is_err());
        // the unmodified snapshot still resumes fine
        assert!(run(base).is_ok());
    }

    #[test]
    fn history_records_eta_steps() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(5);
        let (corpus, _) = generate_with_truth(&spec, &mut rng);
        let engine = EngineHandle::native();
        let cfg = quick_cfg(); // sweeps=20 burnin=4 every=4 -> steps at 8,12,16,20
        let out = train(&corpus, &cfg, &engine, &mut rng).unwrap();
        let sweeps: Vec<usize> = out.history.iter().map(|h| h.sweep).collect();
        assert_eq!(sweeps, vec![8, 12, 16, 20]);
        assert!(out.timings.get("gibbs") > 0.0);
        assert!(out.timings.get("eta_solve") > 0.0);
    }
}
