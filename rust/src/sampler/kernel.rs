//! Pluggable Gibbs token-update kernels (DESIGN.md §Perf).
//!
//! One token-update contract, three implementations:
//!
//! * [`DenseKernel`] — the classic O(T) conditional, extracted from the
//!   formerly duplicated inner loops of `gibbs_train` / `gibbs_predict`.
//! * [`AliasKernel`] — Walker alias tables + cycling doc-/word-proposal
//!   Metropolis-Hastings correction (the LightLDA construction, Yuan et al.
//!   2015): amortized O(1) per token at any T. See the `AliasKernel` docs
//!   for the proposal mix, acceptance ratios and the staleness policy.
//! * [`SparseKernel`] — SparseLDA-style bucket decomposition (Yao, Mimno &
//!   McCallum 2009; Magnusson et al. 2017). The unsupervised conditional
//!
//!   ```text
//!   p(z = t) ∝ (N_dt + α)(N_tw + β) / (N_t + Wβ)
//!            =  αβ·inv_t                    (smoothing bucket, cached)
//!            +  β·N_dt·inv_t               (document bucket, non-zero N_dt)
//!            +  (N_dt + α)·N_tw·inv_t      (word bucket, non-zero N_tw)
//!   ```
//!
//!   with `inv_t = 1/(N_t + Wβ)` is split into three bucket masses; the
//!   smoothing mass `αβ·Σ_t inv_t` is maintained incrementally (O(1) per
//!   token), and the document/word masses iterate only the non-zero entries
//!   of [`crate::model::counts::SparseIndex`]. A uniform draw first picks a
//!   bucket, then walks only that bucket's support.
//!
//! **Draw-for-draw equivalence (dense/sparse only).** Dense and sparse
//! execute the *same* floating-point operation sequence: the dense kernel's
//! extra terms are exact IEEE zeros (a zero count multiplies to `+0.0`, and
//! `x + 0.0 == x` bit-exactly for the non-negative accumulators used here),
//! and the sparse index lists are sorted ascending so accumulation order
//! matches the dense loop. Both consume exactly one `next_f64` per token.
//! The `properties.rs` equivalence test asserts byte-identical `z`, `ndt`
//! and `eta` across those two kernels. The alias kernel is **exempt from
//! the byte-identical contract**: MH draws consume a different RNG
//! sequence, so it carries a *statistical-equivalence* contract instead
//! (same stationary distribution as the exact conditional —
//! `tests/alias_equivalence.rs`) while remaining fully seed-deterministic.
//!
//! **Supervised sweeps.** The Gaussian response factor of the supervised
//! training conditional is dense in every topic (the margin
//! `exp(a·e_t)·u_t` never vanishes), so it cannot be bucket-decomposed —
//! but the conditional *factors* into the plain-LDA term times a response
//! term that is O(1) to evaluate per candidate topic. Each kernel therefore
//! implements [`SamplerKernel::sweep_doc_resp`]: the dense kernel runs the
//! exact O(T)-per-token [`sweep_doc_gauss`] (the reference), while sparse
//! and alias (under `resp_mode = mh`) propose from their unsupervised
//! machinery and Metropolis-Hastings-correct with the Gaussian response
//! ratio `N(y_d; μ_s, ρ)/N(y_d; μ_cur, ρ)` — one `fast_exp` per candidate
//! (see `resp_weight`'s derivation). Burn-in sweeps and the prediction
//! path (no response term) run the kernel-specific unsupervised code as
//! before.

use crate::config::schema::{KernelKind, RespMode};
use crate::model::counts::{insert_sorted, remove_sorted, CountMatrices};
use crate::util::math::fast_exp;
use crate::util::rng::Pcg64;

/// Mutable sampler state threaded through every training token update.
pub struct TrainState<'a> {
    pub counts: &'a mut CountMatrices,
    /// `1/(N_t + Wβ)` per topic, maintained incrementally.
    pub inv_nt: &'a mut [f64],
    /// Running `Σ_t inv_nt[t]` (smoothing-bucket cache), maintained
    /// incrementally alongside `inv_nt`.
    pub ssum: &'a mut f64,
    pub alpha: f64,
    pub beta: f64,
    pub wbeta: f64,
    pub rng: &'a mut Pcg64,
}

/// Mutable sampler state for one document at prediction time (frozen phi).
pub struct PredictState<'a> {
    pub t: usize,
    /// Frozen topic-word distributions, word-major `[w * T + t]`.
    pub phi: &'a [f32],
    /// Per-word cumulative smoothing masses (see [`build_phi_cum`]):
    /// `cum[w*T + t] = Σ_{t' <= t} α·phi[w*T + t']`.
    pub phi_cum: &'a [f64],
    /// Per-word Walker alias tables over frozen phi (exact — phi never
    /// changes at prediction time). Required by the alias kernel, ignored
    /// by dense/sparse. Built once per model ([`PhiAliasTables::build`]);
    /// the serve registry keeps them resident across requests.
    pub alias: Option<&'a PhiAliasTables>,
    /// Dirichlet prior on document-topic proportions (the alias kernel's
    /// doc-proposal smoothing mass; dense/sparse read it from `phi_cum`).
    pub alpha: f64,
    /// The document's topic counts (local, not part of `CountMatrices`).
    pub ndt: &'a mut [u32],
    pub rng: &'a mut Pcg64,
}

/// Per-document inputs of one *supervised* training sweep (paper eq. 1's
/// Gaussian response margin), threaded to
/// [`SamplerKernel::sweep_doc_resp`].
pub struct RespState<'a> {
    /// Current response coefficients (eta-active: not all zero).
    pub eta: &'a [f64],
    /// The document's observed response y_d.
    pub y: f64,
    /// Response variance rho.
    pub rho: f64,
    /// Per-chain buffers for the exact Gaussian path ([`sweep_doc_gauss`]);
    /// the MH paths evaluate the response factor on demand instead.
    pub scratch: &'a mut GaussScratch,
}

/// One token-update contract; dense/sparse implementations must be
/// draw-for-draw interchangeable under a fixed RNG stream (see module docs).
pub trait SamplerKernel {
    fn name(&self) -> &'static str;

    /// Resample every token of document `d` under the plain-LDA conditional
    /// (training, response term inactive).
    fn sweep_doc_lda(&mut self, st: &mut TrainState, d: usize, tokens: &[u32], zd: &mut [u16]);

    /// Resample every token of document `d` under the *supervised* training
    /// conditional (paper eq. 1: the plain-LDA factor times the Gaussian
    /// response margin). The dense kernel — and any kernel constructed with
    /// `resp_mode = exact` — runs the exact O(T)-per-token
    /// [`sweep_doc_gauss`]; sparse/alias under `resp_mode = mh` propose
    /// from their O(nnz)/O(1) unsupervised machinery and MH-correct with
    /// the O(1) response ratio (DESIGN.md §Perf).
    fn sweep_doc_resp(
        &mut self,
        st: &mut TrainState,
        rs: &mut RespState,
        d: usize,
        tokens: &[u32],
        zd: &mut [u16],
    );

    /// Resample every token of one held-out document against frozen phi
    /// (prediction conditional, paper eq. 4).
    fn sweep_doc_predict(&mut self, ps: &mut PredictState, tokens: &[u32], zd: &mut [u16]);

    /// Cumulative (proposals, acceptances) of the supervised MH path since
    /// construction; `None` when this kernel's supervised sweeps run the
    /// exact conditional.
    fn resp_mh_stats(&self) -> Option<(u64, u64)> {
        None
    }

    /// Alias-table bookkeeping: cumulative `(rebuilds, resolved staleness
    /// budget)` since construction; `None` for kernels without alias
    /// tables. Feeds the training telemetry gauges/counters
    /// (`cfslda_train_alias_*`).
    fn alias_stats(&self) -> Option<(u64, u64)> {
        None
    }
}

/// Instantiate the kernel for the **training** path (`Auto` resolves by
/// topic count — see [`KernelKind::resolve_train`]). `alias_staleness` is
/// the alias kernel's rebuild budget (0 = auto); it is ignored by the other
/// kernels. `resp` picks the supervised-sweep mode and is resolved against
/// the resolved kernel ([`RespMode::resolve`]: dense is always exact).
pub fn make_train_kernel(
    kind: KernelKind,
    topics: usize,
    alias_staleness: usize,
    resp: RespMode,
) -> Box<dyn SamplerKernel> {
    let resolved = kind.resolve_train(topics);
    let mh = resp.resolve(resolved) == RespMode::Mh;
    match resolved {
        KernelKind::Sparse => Box::new(SparseKernel::new().with_resp_mh(mh)),
        KernelKind::Alias => {
            Box::new(AliasKernel::new(topics, alias_staleness).with_resp_mh(mh))
        }
        _ => Box::new(DenseKernel),
    }
}

/// Instantiate the kernel for the **prediction** path (`Auto` resolves to
/// alias at every T — see [`KernelKind::resolve_predict`]). The alias
/// kernel additionally needs [`PredictState::alias`] populated with the
/// model's prebuilt [`PhiAliasTables`].
pub fn make_predict_kernel(kind: KernelKind, topics: usize) -> Box<dyn SamplerKernel> {
    match kind.resolve_predict(topics) {
        KernelKind::Sparse => Box::new(SparseKernel::new()),
        KernelKind::Alias => Box::new(AliasKernel::new(topics, 0)),
        _ => Box::new(DenseKernel),
    }
}

/// Remove a token assignment and restore the `inv_nt`/`ssum` caches.
#[inline]
pub fn remove_token(st: &mut TrainState, d: usize, w: u32, topic: usize) {
    st.counts.dec(d, w, topic);
    let old = st.inv_nt[topic];
    let new = 1.0 / (st.counts.nt[topic] as f64 + st.wbeta);
    st.inv_nt[topic] = new;
    *st.ssum += new - old;
}

/// Add a token assignment and restore the `inv_nt`/`ssum` caches.
#[inline]
pub fn add_token(st: &mut TrainState, d: usize, w: u32, topic: usize) {
    st.counts.inc(d, w, topic);
    let old = st.inv_nt[topic];
    let new = 1.0 / (st.counts.nt[topic] as f64 + st.wbeta);
    st.inv_nt[topic] = new;
    *st.ssum += new - old;
}

/// Smoothing-bucket walk: all T topics carry mass `αβ·inv_nt[t]`. Rare
/// (the smoothing mass is a small fraction of the total), shared verbatim
/// by both kernels.
#[inline]
fn smoothing_walk(u: f64, ab: f64, inv_nt: &[f64]) -> usize {
    let mut acc = 0.0;
    let mut last = 0usize;
    for (ti, &inv) in inv_nt.iter().enumerate() {
        acc += ab * inv;
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// Dense bucket draw: identical bucket arithmetic to the sparse draw, but
/// iterating all T topics (zero terms are exact no-ops).
fn dense_lda_draw(st: &mut TrainState, d: usize, w: u32) -> usize {
    let t = st.counts.t;
    let ab = st.alpha * st.beta;
    let s_mass = ab * *st.ssum;
    let ndt = &st.counts.ndt[d * t..(d + 1) * t];
    let ntw = &st.counts.ntw[w as usize * t..(w as usize + 1) * t];
    let inv_nt: &[f64] = &*st.inv_nt;

    let mut r = 0.0;
    for ti in 0..t {
        r += st.beta * ndt[ti] as f64 * inv_nt[ti];
    }
    let mut q = 0.0;
    for ti in 0..t {
        q += (ndt[ti] as f64 + st.alpha) * ntw[ti] as f64 * inv_nt[ti];
    }

    let total = s_mass + r + q;
    let mut u = st.rng.next_f64() * total;
    if u < s_mass {
        return smoothing_walk(u, ab, inv_nt);
    }
    u -= s_mass;
    if u < r {
        let mut acc = 0.0;
        let mut last = 0usize;
        for ti in 0..t {
            let c = ndt[ti];
            if c == 0 {
                continue;
            }
            acc += st.beta * c as f64 * inv_nt[ti];
            last = ti;
            if u < acc {
                return ti;
            }
        }
        return last;
    }
    u -= r;
    let mut acc = 0.0;
    let mut last = 0usize;
    for ti in 0..t {
        let c = ntw[ti];
        if c == 0 {
            continue;
        }
        acc += (ndt[ti] as f64 + st.alpha) * c as f64 * inv_nt[ti];
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// Sparse bucket draw: document and word buckets iterate only the sorted
/// non-zero lists of the [`crate::model::counts::SparseIndex`].
fn sparse_lda_draw(st: &mut TrainState, d: usize, w: u32) -> usize {
    let t = st.counts.t;
    let ab = st.alpha * st.beta;
    let s_mass = ab * *st.ssum;
    let nz = st.counts.nz.as_ref().expect("sparse kernel requires enable_sparse_index()");
    let doc_list: &[u16] = &nz.doc_nz[d];
    let word_list: &[u16] = &nz.word_nz[w as usize];
    let ndt = &st.counts.ndt[d * t..(d + 1) * t];
    let ntw = &st.counts.ntw[w as usize * t..(w as usize + 1) * t];
    let inv_nt: &[f64] = &*st.inv_nt;

    let mut r = 0.0;
    for &tu in doc_list {
        let ti = tu as usize;
        r += st.beta * ndt[ti] as f64 * inv_nt[ti];
    }
    let mut q = 0.0;
    for &tu in word_list {
        let ti = tu as usize;
        q += (ndt[ti] as f64 + st.alpha) * ntw[ti] as f64 * inv_nt[ti];
    }

    let total = s_mass + r + q;
    let mut u = st.rng.next_f64() * total;
    if u < s_mass {
        return smoothing_walk(u, ab, inv_nt);
    }
    u -= s_mass;
    if u < r {
        let mut acc = 0.0;
        let mut last = 0usize;
        for &tu in doc_list {
            let ti = tu as usize;
            acc += st.beta * ndt[ti] as f64 * inv_nt[ti];
            last = ti;
            if u < acc {
                return ti;
            }
        }
        return last;
    }
    u -= r;
    let mut acc = 0.0;
    let mut last = 0usize;
    for &tu in word_list {
        let ti = tu as usize;
        acc += (ndt[ti] as f64 + st.alpha) * ntw[ti] as f64 * inv_nt[ti];
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// Per-word cumulative smoothing table for prediction:
/// `cum[w*T + t] = Σ_{t' <= t} α·phi[w*T + t']`. Built once per corpus
/// inference call and shared by both kernels (the smoothing-bucket topic is
/// then a binary search instead of an O(T) walk).
pub fn build_phi_cum(phi: &[f32], t: usize, alpha: f64) -> Vec<f64> {
    debug_assert_eq!(phi.len() % t, 0);
    let mut cum = vec![0.0f64; phi.len()];
    for w in 0..phi.len() / t {
        let mut acc = 0.0;
        for ti in 0..t {
            acc += alpha * phi[w * t + ti] as f64;
            cum[w * t + ti] = acc;
        }
    }
    cum
}

/// Smoothing-bucket topic at prediction time: smallest t with `u < cum[t]`
/// (same selection as the linear walk over `α·phi`, since `cum` is that
/// walk's accumulator sequence).
#[inline]
fn predict_smoothing_topic(u: f64, cum: &[f64]) -> usize {
    cum.partition_point(|&c| c <= u).min(cum.len() - 1)
}

/// Dense prediction draw: `p(z=t) ∝ (N_dt + α)·phi_t = α·phi_t + N_dt·phi_t`.
fn dense_predict_draw(ps: &mut PredictState, w: u32) -> usize {
    let t = ps.t;
    let phi = &ps.phi[w as usize * t..(w as usize + 1) * t];
    let cum = &ps.phi_cum[w as usize * t..(w as usize + 1) * t];
    let s_mass = cum[t - 1];

    let mut r = 0.0;
    for ti in 0..t {
        r += ps.ndt[ti] as f64 * phi[ti] as f64;
    }
    let total = s_mass + r;
    let mut u = ps.rng.next_f64() * total;
    if u < s_mass {
        return predict_smoothing_topic(u, cum);
    }
    u -= s_mass;
    let mut acc = 0.0;
    let mut last = 0usize;
    for ti in 0..t {
        let c = ps.ndt[ti];
        if c == 0 {
            continue;
        }
        acc += c as f64 * phi[ti] as f64;
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// Sparse prediction draw over the caller-maintained sorted non-zero list.
fn sparse_predict_draw(ps: &mut PredictState, doc_list: &[u16], w: u32) -> usize {
    let t = ps.t;
    let phi = &ps.phi[w as usize * t..(w as usize + 1) * t];
    let cum = &ps.phi_cum[w as usize * t..(w as usize + 1) * t];
    let s_mass = cum[t - 1];

    let mut r = 0.0;
    for &tu in doc_list {
        let ti = tu as usize;
        r += ps.ndt[ti] as f64 * phi[ti] as f64;
    }
    let total = s_mass + r;
    let mut u = ps.rng.next_f64() * total;
    if u < s_mass {
        return predict_smoothing_topic(u, cum);
    }
    u -= s_mass;
    let mut acc = 0.0;
    let mut last = 0usize;
    for &tu in doc_list {
        let ti = tu as usize;
        acc += ps.ndt[ti] as f64 * phi[ti] as f64;
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// The classic dense O(T)-per-token kernel.
pub struct DenseKernel;

impl SamplerKernel for DenseKernel {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn sweep_doc_lda(&mut self, st: &mut TrainState, d: usize, tokens: &[u32], zd: &mut [u16]) {
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            remove_token(st, d, wi, old);
            let new = dense_lda_draw(st, d, wi);
            add_token(st, d, wi, new);
            zd[n] = new as u16;
        }
    }

    fn sweep_doc_resp(
        &mut self,
        st: &mut TrainState,
        rs: &mut RespState,
        d: usize,
        tokens: &[u32],
        zd: &mut [u16],
    ) {
        // The exact supervised conditional — byte-identical to the
        // pre-trait `sweep_doc_gauss` dispatch (pinned by
        // `exact_resp_sweep_is_byte_identical_to_sweep_doc_gauss`).
        sweep_doc_gauss(st, rs.scratch, rs.eta, rs.y, rs.rho, d, tokens, zd);
    }

    fn sweep_doc_predict(&mut self, ps: &mut PredictState, tokens: &[u32], zd: &mut [u16]) {
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            ps.ndt[old] -= 1;
            let new = dense_predict_draw(ps, wi);
            ps.ndt[new] += 1;
            zd[n] = new as u16;
        }
    }
}

/// Bucket proposals per token in the sparse kernel's supervised MH sweep.
/// Each proposal pays one O(nnz) bucket draw; the Gaussian response ratio
/// keeps acceptance near one (the per-token margin shift is O(1/N_d)), so
/// two proposals already mix essentially like the exact Gibbs draw.
const RESP_MH_PROPOSALS: usize = 2;

/// Unnormalized Gaussian response factor of candidate topic `t` for the
/// current token:
///
/// ```text
/// N(y_d; mu_t, rho) ∝ exp(a·e_t − e_t²/2ρ),   e_t = η_t / N_d,
///                                             a   = (y_d − s^{-dn}/N_d)/ρ
/// ```
///
/// (the constant margin factor `exp(−c²/2ρ)` cancels in every draw and MH
/// ratio — same derivation as [`sweep_doc_gauss`]'s per-document tables,
/// but folded into a single `fast_exp` so a proposal's acceptance costs
/// O(1) with no per-document O(T) table fill).
#[inline]
fn resp_weight(eta_t: f64, a: f64, inv_nd: f64, inv2rho: f64) -> f64 {
    let e = eta_t * inv_nd;
    fast_exp(a * e - e * e * inv2rho)
}

/// Shared skeleton of the supervised MH sweeps (sparse and alias): token
/// removal against exclusive counts, the running response dot product
/// `s^{-dn} = η·N^{-dn}_dt` (seeded in O(N_d) from the live assignments,
/// O(1) per token), the per-token `a = (y_d − s^{-dn}/N_d)/ρ`, and
/// count/cache restoration. `propose(st, n, w, zd, old, a)` runs the
/// kernel-specific MH proposal chain and returns the new topic.
fn sweep_doc_resp_mh(
    st: &mut TrainState,
    rs: &mut RespState,
    d: usize,
    tokens: &[u32],
    zd: &mut [u16],
    mut propose: impl FnMut(&mut TrainState, usize, u32, &[u16], usize, f64) -> usize,
) {
    let inv_nd = 1.0 / tokens.len() as f64;
    let inv_rho = 1.0 / rs.rho;
    let mut s_dot: f64 = zd.iter().map(|&ti| rs.eta[ti as usize]).sum();
    for (n, &wi) in tokens.iter().enumerate() {
        let old = zd[n] as usize;
        remove_token(st, d, wi, old);
        s_dot -= rs.eta[old];
        let a = (rs.y - s_dot * inv_nd) * inv_rho;
        let new = propose(st, n, wi, zd, old, a);
        add_token(st, d, wi, new);
        s_dot += rs.eta[new];
        zd[n] = new as u16;
    }
}

/// SparseLDA-style bucket kernel. Training iterates the counts' sparse
/// index; prediction maintains its own per-document non-zero scratch list.
/// Under `resp_mode = mh` the supervised sweep proposes from the
/// bucket-decomposed plain-LDA conditional and MH-corrects with the O(1)
/// Gaussian response ratio (DESIGN.md §Perf).
pub struct SparseKernel {
    doc_nz: Vec<u16>,
    /// Supervised sweeps use the MH correction instead of the exact dense
    /// Gaussian conditional.
    resp_mh: bool,
    resp_proposed: u64,
    resp_accepted: u64,
}

impl SparseKernel {
    pub fn new() -> Self {
        SparseKernel { doc_nz: Vec::new(), resp_mh: false, resp_proposed: 0, resp_accepted: 0 }
    }

    /// Select the supervised-sweep mode (`true` = MH, `false` = exact).
    pub fn with_resp_mh(mut self, mh: bool) -> Self {
        self.resp_mh = mh;
        self
    }

    /// One token's supervised MH chain: propose from the exact (exclusive
    /// counts) bucket-decomposed LDA conditional, accept with the Gaussian
    /// response ratio — the proposal equals the target's LDA factor, so the
    /// acceptance probability collapses to `resp_weight(s)/resp_weight(cur)`.
    /// Counts must already exclude the token (`remove_token` ran). Returns
    /// the new topic.
    #[allow(clippy::too_many_arguments)]
    fn resp_token(
        &mut self,
        st: &mut TrainState,
        d: usize,
        w: u32,
        eta: &[f64],
        a: f64,
        inv_nd: f64,
        inv2rho: f64,
        old: usize,
    ) -> usize {
        let mut cur = old;
        for _ in 0..RESP_MH_PROPOSALS {
            let cand = sparse_lda_draw(st, d, w);
            self.resp_proposed += 1;
            if cand == cur {
                self.resp_accepted += 1;
                continue;
            }
            let ratio = resp_weight(eta[cand], a, inv_nd, inv2rho)
                / resp_weight(eta[cur], a, inv_nd, inv2rho);
            if st.rng.next_f64() < ratio {
                cur = cand;
                self.resp_accepted += 1;
            }
        }
        cur
    }
}

impl Default for SparseKernel {
    fn default() -> Self {
        Self::new()
    }
}

impl SamplerKernel for SparseKernel {
    fn name(&self) -> &'static str {
        "sparse"
    }

    fn sweep_doc_lda(&mut self, st: &mut TrainState, d: usize, tokens: &[u32], zd: &mut [u16]) {
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            remove_token(st, d, wi, old);
            let new = sparse_lda_draw(st, d, wi);
            add_token(st, d, wi, new);
            zd[n] = new as u16;
        }
    }

    fn sweep_doc_resp(
        &mut self,
        st: &mut TrainState,
        rs: &mut RespState,
        d: usize,
        tokens: &[u32],
        zd: &mut [u16],
    ) {
        if !self.resp_mh {
            sweep_doc_gauss(st, rs.scratch, rs.eta, rs.y, rs.rho, d, tokens, zd);
            return;
        }
        let eta = rs.eta;
        let inv_nd = 1.0 / tokens.len() as f64;
        let inv2rho = 1.0 / (2.0 * rs.rho);
        sweep_doc_resp_mh(st, rs, d, tokens, zd, |st, _n, wi, _zd, old, a| {
            self.resp_token(st, d, wi, eta, a, inv_nd, inv2rho, old)
        });
    }

    fn resp_mh_stats(&self) -> Option<(u64, u64)> {
        self.resp_mh.then_some((self.resp_proposed, self.resp_accepted))
    }

    fn sweep_doc_predict(&mut self, ps: &mut PredictState, tokens: &[u32], zd: &mut [u16]) {
        // Rebuild the sorted non-zero list from the document's current
        // counts (O(T) once per sweep, amortized over the token loop).
        self.doc_nz.clear();
        for ti in 0..ps.t {
            if ps.ndt[ti] > 0 {
                self.doc_nz.push(ti as u16);
            }
        }
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            ps.ndt[old] -= 1;
            if ps.ndt[old] == 0 {
                remove_sorted(&mut self.doc_nz, old as u16);
            }
            let new = sparse_predict_draw(ps, &self.doc_nz, wi);
            ps.ndt[new] += 1;
            if ps.ndt[new] == 1 {
                insert_sorted(&mut self.doc_nz, new as u16);
            }
            zd[n] = new as u16;
        }
    }
}

// ---------------------------------------------------------------------------
// Alias-table Metropolis-Hastings kernel (LightLDA construction)
// ---------------------------------------------------------------------------

/// (word-proposal, doc-proposal) MH pairs per token. Each proposal is O(1),
/// so extra cycles buy mixing speed at a small constant cost; two pairs
/// (four proposals) is the LightLDA operating point.
const MH_CYCLES: usize = 2;

/// Walker alias table over an unnormalized non-negative weight vector:
/// O(n) build, O(1) sample, exactly one `next_f64` per draw. The build-time
/// weights are retained so MH acceptance ratios can evaluate the *exact*
/// (possibly stale) proposal distribution the table draws from — the
/// invariant the MH correction's detailed balance depends on.
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
    weight: Vec<f64>,
    total: f64,
}

impl AliasTable {
    pub fn build(weights: &[f64]) -> AliasTable {
        let mut table = AliasTable {
            prob: Vec::new(),
            alias: Vec::new(),
            weight: Vec::new(),
            total: 0.0,
        };
        table.rebuild_from(weights, &mut WalkerScratch::default());
        table
    }

    /// Rebuild this table in place from fresh weights, reusing its own
    /// buffers and the caller's walker scratch — the alias kernel's
    /// staleness-driven rebuild path allocates nothing in steady state.
    pub fn rebuild_from(&mut self, weights: &[f64], scratch: &mut WalkerScratch) {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one outcome");
        self.prob.clear();
        self.prob.resize(n, 1.0);
        self.alias.clear();
        self.alias.extend(0..n as u32);
        self.weight.clear();
        self.weight.extend_from_slice(weights);
        self.total = weights.iter().sum();
        build_walker(
            weights,
            self.total,
            &mut self.prob,
            &mut self.alias,
            &mut scratch.small,
            &mut scratch.large,
            &mut scratch.scaled,
        );
    }

    /// Draw an outcome ∝ the build-time weights; one `next_f64`.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        alias_draw(&self.prob, &self.alias, rng)
    }

    /// Build-time unnormalized weight of outcome `i` — exactly proportional
    /// to this table's sampling distribution (stale w.r.t. live counts).
    #[inline]
    pub fn weight(&self, i: usize) -> f64 {
        self.weight[i]
    }

    /// Sum of the build-time weights.
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Heap bytes held by this table.
    pub fn resident_bytes(&self) -> usize {
        self.prob.len() * 8 + self.alias.len() * 4 + self.weight.len() * 8
    }
}

/// Reusable two-stack scratch for [`build_walker`] (avoids per-rebuild
/// allocation on the training hot path).
#[derive(Default)]
pub struct WalkerScratch {
    small: Vec<u32>,
    large: Vec<u32>,
    scaled: Vec<f64>,
}

/// Walker construction into caller-provided `prob`/`alias` rows. `prob`
/// must be pre-filled with 1.0 and `alias` with the identity mapping; a
/// degenerate row (zero/non-finite total) is then already a valid uniform
/// table. Deterministic: stack order depends only on the weights.
fn build_walker(
    weights: &[f64],
    total: f64,
    prob: &mut [f64],
    alias: &mut [u32],
    small: &mut Vec<u32>,
    large: &mut Vec<u32>,
    scaled: &mut Vec<f64>,
) {
    if !(total > 0.0 && total.is_finite()) {
        return;
    }
    let n = weights.len();
    let scale = n as f64 / total;
    scaled.clear();
    scaled.extend(weights.iter().map(|&w| w * scale));
    small.clear();
    large.clear();
    for (i, &s) in scaled.iter().enumerate() {
        if s < 1.0 {
            small.push(i as u32);
        } else {
            large.push(i as u32);
        }
    }
    while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
        prob[s as usize] = scaled[s as usize];
        alias[s as usize] = l;
        let rem = (scaled[l as usize] + scaled[s as usize]) - 1.0;
        scaled[l as usize] = rem;
        if rem < 1.0 {
            small.push(l);
        } else {
            large.push(l);
        }
    }
    // Leftovers on either stack are fp slack around 1.0: an exact self-loop
    // (prob = 1.0) is the standard resolution.
    while let Some(i) = small.pop() {
        prob[i as usize] = 1.0;
    }
    while let Some(i) = large.pop() {
        prob[i as usize] = 1.0;
    }
}

/// One alias draw over a (prob, alias) row; exactly one `next_f64`.
#[inline]
fn alias_draw(prob: &[f64], alias: &[u32], rng: &mut Pcg64) -> usize {
    let n = prob.len();
    let x = rng.next_f64() * n as f64;
    let k = (x as usize).min(n - 1);
    if x - k as f64 < prob[k] {
        k
    } else {
        alias[k] as usize
    }
}

/// Per-word Walker alias tables over a frozen word-major phi matrix — the
/// prediction path's O(1) word proposal. Phi never changes at inference
/// time, so these tables are **exact, never stale**: built once per model
/// and reused for every document. The serve registry builds them at
/// load/`POST /reload` and shares them across all batcher workers through
/// the pinned entry `Arc`; the batch CLI builds them once per corpus call.
pub struct PhiAliasTables {
    t: usize,
    /// Acceptance thresholds, word-major `[w * T + t]`.
    prob: Vec<f64>,
    /// Alias targets, word-major `[w * T + t]`.
    alias: Vec<u32>,
    /// f64 copies of phi — the exact proposal weights used in MH ratios.
    weight: Vec<f64>,
}

impl PhiAliasTables {
    pub fn build(phi: &[f32], t: usize) -> PhiAliasTables {
        assert!(t > 0 && phi.len() % t == 0, "phi must be word-major [W, T]");
        let n = phi.len();
        let mut prob = vec![1.0f64; n];
        let mut alias = vec![0u32; n];
        let weight: Vec<f64> = phi.iter().map(|&p| p as f64).collect();
        let mut small = Vec::with_capacity(t);
        let mut large = Vec::with_capacity(t);
        let mut scaled = Vec::with_capacity(t);
        for w in 0..n / t {
            let row = w * t..(w + 1) * t;
            for (i, a) in alias[row.clone()].iter_mut().enumerate() {
                *a = i as u32;
            }
            let total: f64 = weight[row.clone()].iter().sum();
            build_walker(
                &weight[row.clone()],
                total,
                &mut prob[row.clone()],
                &mut alias[row],
                &mut small,
                &mut large,
                &mut scaled,
            );
        }
        PhiAliasTables { t, prob, alias, weight }
    }

    pub fn topics(&self) -> usize {
        self.t
    }

    pub fn words(&self) -> usize {
        self.weight.len() / self.t
    }

    /// Draw a topic ∝ phi[w, ·]; exactly one `next_f64`.
    #[inline]
    pub fn sample(&self, w: u32, rng: &mut Pcg64) -> usize {
        let o = w as usize * self.t;
        alias_draw(&self.prob[o..o + self.t], &self.alias[o..o + self.t], rng)
    }

    /// Exact proposal weight phi[w, ti] (as f64) for MH ratios.
    #[inline]
    pub fn weight(&self, w: u32, ti: usize) -> f64 {
        self.weight[w as usize * self.t + ti]
    }

    /// Heap bytes held by the tables (surfaced by serve `/stats`).
    pub fn resident_bytes(&self) -> usize {
        self.prob.len() * 8 + self.alias.len() * 4 + self.weight.len() * 8
    }
}

/// Draw from the exact document proposal q_d(t) ∝ N^{-dn}_dt + α without
/// materializing it: with probability (N_d - 1)/(N_d - 1 + Tα) copy a
/// uniformly chosen *other* token's current topic, otherwise a uniform
/// topic (the α smoothing component). One `next_f64` total. `zd` holds the
/// document's live assignments with token `n` excluded by index-skipping,
/// so the draw matches the exclusive counts exactly — no staleness, and
/// the MH acceptance against it needs only the word factor.
#[inline]
fn sample_doc_proposal(zd: &[u16], n: usize, t: usize, alpha: f64, rng: &mut Pcg64) -> usize {
    let nd = zd.len();
    let others = (nd - 1) as f64;
    let x = rng.next_f64() * (others + t as f64 * alpha);
    if x < others {
        let mut j = x as usize;
        if j >= n {
            j += 1;
        }
        zd[j.min(nd - 1)] as usize
    } else {
        (((x - others) / alpha) as usize).min(t - 1)
    }
}

/// One token's prediction-path MH chain against frozen phi: alternating
/// exact word proposal (alias table ∝ phi[w]) and exact doc proposal
/// (mixture of other tokens' topics and α-uniform). Both proposals equal
/// one factor of the target `(N_dt + α)·phi[w, t]`, so each acceptance
/// ratio reduces to the *other* factor. `ndt` must already exclude token
/// `n`. Returns the new topic.
#[allow(clippy::too_many_arguments)]
#[inline]
fn mh_token_predict(
    tables: &PhiAliasTables,
    ndt: &[u32],
    zd: &[u16],
    n: usize,
    w: u32,
    t: usize,
    alpha: f64,
    old: usize,
    rng: &mut Pcg64,
) -> usize {
    let mut cur = old;
    for _ in 0..MH_CYCLES {
        // Word proposal q ∝ phi[w]: acceptance is the doc factor.
        let s = tables.sample(w, rng);
        if s != cur {
            let ratio = (ndt[s] as f64 + alpha) / (ndt[cur] as f64 + alpha);
            if rng.next_f64() < ratio {
                cur = s;
            }
        }
        // Doc proposal q ∝ N^{-dn}_dt + α: acceptance is the word factor.
        let s = sample_doc_proposal(zd, n, t, alpha, rng);
        if s != cur {
            let ratio = tables.weight(w, s) / tables.weight(w, cur);
            if rng.next_f64() < ratio {
                cur = s;
            }
        }
    }
    cur
}

/// Alias-MH kernel: amortized O(1) per token at any T (DESIGN.md §Perf).
///
/// **Training (burn-in LDA path).** Target conditional
/// `π(t) ∝ (N_dt + α)(N_tw + β)/(N_t + Wβ)` with exclusive counts. Two
/// proposals alternate per MH cycle:
///
/// * *word proposal* — a per-word Walker alias table over the word factor
///   `(N_tw + β)/(N_t + Wβ)`, rebuilt lazily on a staleness budget
///   (LightLDA-style). The table's build-time weights are retained, so the
///   acceptance ratio `π(s)·q̃(cur) / (π(cur)·q̃(s))` evaluates the exact
///   stale proposal — staleness costs mixing speed, never correctness.
/// * *doc proposal* — the exact mixture `q_d(t) ∝ N^{-dn}_dt + α`, sampled
///   in O(1) by copying a random other token's topic (or α-uniform); its
///   acceptance reduces to the word-factor ratio.
///
/// **Staleness policy.** A word's table is rebuilt at the next touch after
/// it absorbed `staleness` count updates ([`CountMatrices::enable_alias_rev`]
/// hook); without the hook a uses-since-build fallback bounds drift. The
/// budget resolves `0` to `max(T, 16)`, making the amortized rebuild cost
/// O(1) per token.
///
/// **Prediction.** Phi is frozen, so the per-word tables
/// ([`PhiAliasTables`], supplied via [`PredictState::alias`]) are built
/// once and are exact; every proposal matches one factor of the target
/// `(N_dt + α)·phi[w, t]` and serving pays amortized O(1) per token at any
/// T.
///
/// Exempt from the dense/sparse byte-identical contract (different RNG
/// consumption), but fully seed-deterministic and statistically equivalent
/// (`tests/alias_equivalence.rs`). **Supervised sweeps** (`resp_mode = mh`)
/// run the same word-/doc-proposal cycle with the O(1) Gaussian response
/// factor folded into every acceptance ratio
/// (`resp_token_train`, `tests/resp_equivalence.rs`);
/// `resp_mode = exact` falls back to the shared [`sweep_doc_gauss`].
pub struct AliasKernel {
    /// Rebuild budget in per-word count updates (and, absent the counts
    /// hook, in table uses). Resolved from the config knob: 0 => max(T, 16).
    staleness: usize,
    tables: Vec<Option<AliasTable>>,
    built_rev: Vec<u32>,
    uses: Vec<u32>,
    weights: Vec<f64>,
    scratch: WalkerScratch,
    /// Supervised sweeps fold the Gaussian response ratio into the MH
    /// acceptance instead of falling back to the exact dense conditional.
    resp_mh: bool,
    resp_proposed: u64,
    resp_accepted: u64,
    /// Cumulative table rebuilds (misses + staleness evictions) across all
    /// words since construction — the telemetry counterweight to the
    /// staleness budget.
    rebuilds: u64,
}

impl AliasKernel {
    pub fn new(t: usize, staleness: usize) -> Self {
        AliasKernel {
            staleness: if staleness == 0 { t.max(16) } else { staleness },
            tables: Vec::new(),
            built_rev: Vec::new(),
            uses: Vec::new(),
            weights: Vec::with_capacity(t),
            scratch: WalkerScratch::default(),
            resp_mh: false,
            resp_proposed: 0,
            resp_accepted: 0,
            rebuilds: 0,
        }
    }

    /// Select the supervised-sweep mode (`true` = MH, `false` = exact).
    pub fn with_resp_mh(mut self, mh: bool) -> Self {
        self.resp_mh = mh;
        self
    }

    fn ensure_words(&mut self, w: usize) {
        if self.tables.len() < w {
            self.tables.resize_with(w, || None);
            self.built_rev.resize(w, 0);
            self.uses.resize(w, 0);
        }
    }

    /// Rebuild word `w`'s table if it is missing or has exceeded the
    /// staleness budget, then count this use.
    fn refresh_word_table(&mut self, st: &TrainState, w: u32) {
        let wi = w as usize;
        let rev = st.counts.alias_rev.as_ref().map_or(0, |r| r[wi]);
        let fresh = self.tables[wi].is_some() && {
            let updates = rev.wrapping_sub(self.built_rev[wi]) as usize;
            let drift_ok = updates < self.staleness;
            // Without the counts hook, bound drift by uses instead.
            let uses_ok = st.counts.alias_rev.is_some()
                || (self.uses[wi] as usize) < self.staleness;
            drift_ok && uses_ok
        };
        if !fresh {
            let t = st.counts.t;
            let ntw = &st.counts.ntw[wi * t..(wi + 1) * t];
            self.weights.clear();
            self.weights.extend(
                ntw.iter().zip(st.inv_nt.iter()).map(|(&c, &inv)| (c as f64 + st.beta) * inv),
            );
            // In-place rebuild: reuses the table's buffers and the kernel's
            // walker scratch — no steady-state allocation.
            let table = self.tables[wi].get_or_insert_with(|| AliasTable {
                prob: Vec::new(),
                alias: Vec::new(),
                weight: Vec::new(),
                total: 0.0,
            });
            table.rebuild_from(&self.weights, &mut self.scratch);
            self.built_rev[wi] = rev;
            self.uses[wi] = 0;
            self.rebuilds += 1;
        }
        self.uses[wi] = self.uses[wi].wrapping_add(1);
    }

    /// One token's training-path MH chain. Counts must already exclude the
    /// token (`remove_token` ran); `zd` is consulted by the doc proposal
    /// with token `n` index-skipped. Returns the new topic.
    fn mh_token_train(
        &mut self,
        st: &mut TrainState,
        d: usize,
        w: u32,
        n: usize,
        zd: &[u16],
        old: usize,
    ) -> usize {
        let t = st.counts.t;
        let alpha = st.alpha;
        let beta = st.beta;
        let mut cur = old;
        for _ in 0..MH_CYCLES {
            // Word proposal from the (stale) alias table; full MH ratio
            // against the exact conditional.
            self.refresh_word_table(st, w);
            let table = self.tables[w as usize].as_ref().unwrap();
            let s = table.sample(st.rng);
            if s != cur {
                let ndt = &st.counts.ndt[d * t..(d + 1) * t];
                let ntw = &st.counts.ntw[w as usize * t..(w as usize + 1) * t];
                let pi_s = (ndt[s] as f64 + alpha) * (ntw[s] as f64 + beta) * st.inv_nt[s];
                let pi_c = (ndt[cur] as f64 + alpha) * (ntw[cur] as f64 + beta) * st.inv_nt[cur];
                let ratio = pi_s * table.weight(cur) / (pi_c * table.weight(s));
                if st.rng.next_f64() < ratio {
                    cur = s;
                }
            }
            // Doc proposal is exact, so the ratio is the word factor alone.
            let s = sample_doc_proposal(zd, n, t, alpha, st.rng);
            if s != cur {
                let ntw = &st.counts.ntw[w as usize * t..(w as usize + 1) * t];
                let ratio = (ntw[s] as f64 + beta) * st.inv_nt[s]
                    / ((ntw[cur] as f64 + beta) * st.inv_nt[cur]);
                if st.rng.next_f64() < ratio {
                    cur = s;
                }
            }
        }
        cur
    }

    /// One token's *supervised* MH chain: the burn-in word-/doc-proposal
    /// cycle of [`AliasKernel::mh_token_train`] with the Gaussian response
    /// factor [`resp_weight`] folded into every acceptance ratio — the
    /// target becomes the full supervised conditional (paper eq. 1) while
    /// each proposal stays O(1). Counts must already exclude the token.
    /// Returns the new topic.
    #[allow(clippy::too_many_arguments)]
    fn resp_token_train(
        &mut self,
        st: &mut TrainState,
        d: usize,
        w: u32,
        n: usize,
        zd: &[u16],
        old: usize,
        eta: &[f64],
        a: f64,
        inv_nd: f64,
        inv2rho: f64,
    ) -> usize {
        let t = st.counts.t;
        let alpha = st.alpha;
        let beta = st.beta;
        let mut cur = old;
        for _ in 0..MH_CYCLES {
            // Word proposal from the (stale) alias table; full MH ratio
            // against the exact supervised conditional.
            self.refresh_word_table(st, w);
            let table = self.tables[w as usize].as_ref().unwrap();
            let s = table.sample(st.rng);
            self.resp_proposed += 1;
            if s == cur {
                self.resp_accepted += 1;
            } else {
                let ndt = &st.counts.ndt[d * t..(d + 1) * t];
                let ntw = &st.counts.ntw[w as usize * t..(w as usize + 1) * t];
                let pi_s = (ndt[s] as f64 + alpha)
                    * (ntw[s] as f64 + beta)
                    * st.inv_nt[s]
                    * resp_weight(eta[s], a, inv_nd, inv2rho);
                let pi_c = (ndt[cur] as f64 + alpha)
                    * (ntw[cur] as f64 + beta)
                    * st.inv_nt[cur]
                    * resp_weight(eta[cur], a, inv_nd, inv2rho);
                let ratio = pi_s * table.weight(cur) / (pi_c * table.weight(s));
                if st.rng.next_f64() < ratio {
                    cur = s;
                    self.resp_accepted += 1;
                }
            }
            // Doc proposal is exact in the document factor, so the ratio is
            // the word factor times the response factor.
            let s = sample_doc_proposal(zd, n, t, alpha, st.rng);
            self.resp_proposed += 1;
            if s == cur {
                self.resp_accepted += 1;
            } else {
                let ntw = &st.counts.ntw[w as usize * t..(w as usize + 1) * t];
                let ratio = (ntw[s] as f64 + beta)
                    * st.inv_nt[s]
                    * resp_weight(eta[s], a, inv_nd, inv2rho)
                    / ((ntw[cur] as f64 + beta)
                        * st.inv_nt[cur]
                        * resp_weight(eta[cur], a, inv_nd, inv2rho));
                if st.rng.next_f64() < ratio {
                    cur = s;
                    self.resp_accepted += 1;
                }
            }
        }
        cur
    }
}

impl SamplerKernel for AliasKernel {
    fn name(&self) -> &'static str {
        "alias"
    }

    fn sweep_doc_lda(&mut self, st: &mut TrainState, d: usize, tokens: &[u32], zd: &mut [u16]) {
        self.ensure_words(st.counts.w);
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            remove_token(st, d, wi, old);
            let new = self.mh_token_train(st, d, wi, n, zd, old);
            add_token(st, d, wi, new);
            zd[n] = new as u16;
        }
    }

    fn sweep_doc_resp(
        &mut self,
        st: &mut TrainState,
        rs: &mut RespState,
        d: usize,
        tokens: &[u32],
        zd: &mut [u16],
    ) {
        if !self.resp_mh {
            sweep_doc_gauss(st, rs.scratch, rs.eta, rs.y, rs.rho, d, tokens, zd);
            return;
        }
        self.ensure_words(st.counts.w);
        let eta = rs.eta;
        let inv_nd = 1.0 / tokens.len() as f64;
        let inv2rho = 1.0 / (2.0 * rs.rho);
        sweep_doc_resp_mh(st, rs, d, tokens, zd, |st, n, wi, zd, old, a| {
            self.resp_token_train(st, d, wi, n, zd, old, eta, a, inv_nd, inv2rho)
        });
    }

    fn sweep_doc_predict(&mut self, ps: &mut PredictState, tokens: &[u32], zd: &mut [u16]) {
        let tables = ps
            .alias
            .expect("alias kernel needs PredictState.alias (prebuilt frozen-phi tables)");
        let t = ps.t;
        let alpha = ps.alpha;
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            ps.ndt[old] -= 1;
            let new = mh_token_predict(tables, ps.ndt, zd, n, wi, t, alpha, old, ps.rng);
            ps.ndt[new] += 1;
            zd[n] = new as u16;
        }
    }

    fn resp_mh_stats(&self) -> Option<(u64, u64)> {
        self.resp_mh.then_some((self.resp_proposed, self.resp_accepted))
    }

    fn alias_stats(&self) -> Option<(u64, u64)> {
        Some((self.rebuilds, self.staleness as u64))
    }
}

/// Exact supervised-conditional sweep (paper eq. 1 with the Gaussian
/// response margin), O(T) per token. This is the dense kernel's
/// [`SamplerKernel::sweep_doc_resp`] and the `resp_mode = exact` fallback
/// of the sparse/alias kernels — the reference chain the MH supervised
/// sweeps are statistically equivalent to (`tests/resp_equivalence.rs`).
/// The hot-path tricks are unchanged from the original inner loop
/// (DESIGN.md §Perf): running dot product `s_d = η·N_dt`, per-document
/// `e`/`u` tables, `fast_exp`, dropped constant margin factor.
#[allow(clippy::too_many_arguments)]
pub fn sweep_doc_gauss(
    st: &mut TrainState,
    scratch: &mut GaussScratch,
    eta: &[f64],
    y: f64,
    rho: f64,
    d: usize,
    tokens: &[u32],
    zd: &mut [u16],
) {
    let t = st.counts.t;
    let nd = tokens.len();
    let inv_nd = 1.0 / nd as f64;
    let inv2rho = 1.0 / (2.0 * rho);
    let inv_rho = 1.0 / rho;
    // Running response dot product s_d = eta . N_dt.
    let mut s: f64 =
        st.counts.ndt_row(d).iter().zip(eta).map(|(&c, &e)| c as f64 * e).sum();
    for ti in 0..t {
        let e = eta[ti] * inv_nd;
        scratch.e_buf[ti] = e;
        scratch.u_buf[ti] = fast_exp(-(e * e) * inv2rho);
    }
    for (n, &wi) in tokens.iter().enumerate() {
        let old = zd[n] as usize;
        remove_token(st, d, wi, old);
        s -= eta[old];
        {
            let ndt = &st.counts.ndt[d * t..(d + 1) * t];
            let ntw = &st.counts.ntw[wi as usize * t..(wi as usize + 1) * t];
            // a = c/rho with c = y - s^{-dn}/N_d (constant exp factor
            // exp(-c^2/2rho) dropped: cancels in the draw)
            let a = (y - s * inv_nd) * inv_rho;
            for ti in 0..t {
                let gauss = fast_exp(a * scratch.e_buf[ti]) * scratch.u_buf[ti];
                scratch.probs[ti] = gauss
                    * (ndt[ti] as f64 + st.alpha)
                    * (ntw[ti] as f64 + st.beta)
                    * st.inv_nt[ti];
            }
        }
        let new = st.rng.sample_discrete(&scratch.probs);
        add_token(st, d, wi, new);
        s += eta[new];
        zd[n] = new as u16;
    }
}

/// Reusable per-chain buffers for [`sweep_doc_gauss`].
pub struct GaussScratch {
    pub probs: Vec<f64>,
    pub e_buf: Vec<f64>,
    pub u_buf: Vec<f64>,
}

impl GaussScratch {
    pub fn new(t: usize) -> Self {
        GaussScratch { probs: vec![0.0; t], e_buf: vec![0.0; t], u_buf: vec![0.0; t] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Random count state with every cache consistent; sparse index enabled
    /// (the dense kernel ignores it).
    fn random_state(
        rng: &mut Pcg64,
        d: usize,
        t: usize,
        w: usize,
        tokens_per_doc: usize,
    ) -> (CountMatrices, Vec<f64>, f64) {
        let mut c = CountMatrices::new(d, t, w);
        for di in 0..d {
            for _ in 0..tokens_per_doc {
                c.inc(di, rng.gen_range(w) as u32, rng.gen_range(t));
            }
        }
        c.enable_sparse_index();
        let wbeta = w as f64 * 0.1;
        let inv_nt: Vec<f64> = c.nt.iter().map(|&n| 1.0 / (n as f64 + wbeta)).collect();
        let ssum: f64 = inv_nt.iter().sum();
        (c, inv_nt, ssum)
    }

    #[allow(clippy::too_many_arguments)]
    fn draw_once(
        sparse: bool,
        seed: u64,
        counts: &mut CountMatrices,
        inv_nt: &mut [f64],
        ssum: &mut f64,
        alpha: f64,
        beta: f64,
        wbeta: f64,
        di: usize,
        wi: u32,
    ) -> usize {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut st = TrainState { counts, inv_nt, ssum, alpha, beta, wbeta, rng: &mut rng };
        if sparse {
            sparse_lda_draw(&mut st, di, wi)
        } else {
            dense_lda_draw(&mut st, di, wi)
        }
    }

    #[test]
    fn dense_and_sparse_draws_agree_token_for_token() {
        let (alpha, beta) = (0.5, 0.1);
        let mut meta = Pcg64::seed_from_u64(11);
        for trial in 0..200usize {
            let (d, t, w) = (4usize, 2 + (trial % 13), 20usize);
            let wbeta = w as f64 * beta;
            let (mut counts, mut inv_nt, mut ssum) =
                random_state(&mut meta, d, t, w, 1 + trial % 30);
            let di = meta.gen_range(d);
            let wi = meta.gen_range(w) as u32;
            let seed = meta.next_u64();

            let a = draw_once(
                false, seed, &mut counts, &mut inv_nt, &mut ssum, alpha, beta, wbeta, di, wi,
            );
            let b = draw_once(
                true, seed, &mut counts, &mut inv_nt, &mut ssum, alpha, beta, wbeta, di, wi,
            );
            assert_eq!(a, b, "trial {trial}: dense chose {a}, sparse chose {b}");
        }
    }

    #[test]
    fn bucket_draw_matches_full_conditional_distribution() {
        // Empirical draw frequencies of the decomposed draw must match the
        // directly computed conditional p(t) ∝ (N_dt+α)(N_tw+β)/(N_t+Wβ).
        let (alpha, beta) = (0.5, 0.1);
        let (d, t, w) = (2usize, 5usize, 8usize);
        let wbeta = w as f64 * beta;
        let mut meta = Pcg64::seed_from_u64(3);
        let (mut counts, mut inv_nt, mut ssum) = random_state(&mut meta, d, t, w, 25);
        let (di, wi) = (0usize, 3u32);

        let probs: Vec<f64> = (0..t)
            .map(|ti| {
                (counts.ndt[di * t + ti] as f64 + alpha)
                    * (counts.ntw[wi as usize * t + ti] as f64 + beta)
                    / (counts.nt[ti] as f64 + wbeta)
            })
            .collect();
        let total: f64 = probs.iter().sum();

        let n = 200_000usize;
        let mut hits = vec![0usize; t];
        let mut rng = Pcg64::seed_from_u64(99);
        for _ in 0..n {
            let mut st = TrainState {
                counts: &mut counts,
                inv_nt: &mut inv_nt,
                ssum: &mut ssum,
                alpha,
                beta,
                wbeta,
                rng: &mut rng,
            };
            hits[dense_lda_draw(&mut st, di, wi)] += 1;
        }
        for ti in 0..t {
            let want = probs[ti] / total * n as f64;
            let got = hits[ti] as f64;
            let sd = (want.max(1.0)).sqrt();
            assert!(
                (got - want).abs() < 6.0 * sd + 3.0,
                "topic {ti}: got {got} want {want} (hits {hits:?})"
            );
        }
    }

    fn predict_draw_once(
        sparse: bool,
        seed: u64,
        t: usize,
        alpha: f64,
        phi: &[f32],
        phi_cum: &[f64],
        ndt: &mut [u32],
    ) -> usize {
        let mut rng = Pcg64::seed_from_u64(seed);
        let list: Vec<u16> =
            (0..t).filter(|&ti| ndt[ti] > 0).map(|ti| ti as u16).collect();
        let mut ps =
            PredictState { t, phi, phi_cum, alias: None, alpha, ndt, rng: &mut rng };
        if sparse {
            sparse_predict_draw(&mut ps, &list, 0)
        } else {
            dense_predict_draw(&mut ps, 0)
        }
    }

    #[test]
    fn predict_draws_agree_and_match_distribution() {
        let t = 6usize;
        let alpha = 0.4;
        let mut meta = Pcg64::seed_from_u64(21);
        // One word's phi row (positive, unnormalized is fine for the draw).
        let phi: Vec<f32> = (0..t).map(|_| 0.01 + meta.next_f32() * 0.2).collect();
        let phi_cum = build_phi_cum(&phi, t, alpha);
        let mut ndt: Vec<u32> = vec![0, 3, 0, 1, 0, 7];

        // cross-kernel agreement over many RNG streams
        for trial in 0..200u64 {
            let seed = 1000 + trial;
            let a = predict_draw_once(false, seed, t, alpha, &phi, &phi_cum, &mut ndt);
            let b = predict_draw_once(true, seed, t, alpha, &phi, &phi_cum, &mut ndt);
            assert_eq!(a, b, "seed {seed}");
        }

        // distribution check: p(t) ∝ (ndt + alpha) * phi
        let probs: Vec<f64> =
            (0..t).map(|ti| (ndt[ti] as f64 + alpha) * phi[ti] as f64).collect();
        let total: f64 = probs.iter().sum();
        let n = 100_000usize;
        let mut hits = vec![0usize; t];
        let mut rng = Pcg64::seed_from_u64(7);
        for _ in 0..n {
            let mut ps = PredictState {
                t,
                phi: &phi,
                phi_cum: &phi_cum,
                alias: None,
                alpha,
                ndt: &mut ndt,
                rng: &mut rng,
            };
            hits[dense_predict_draw(&mut ps, 0)] += 1;
        }
        for ti in 0..t {
            let want = probs[ti] / total * n as f64;
            let got = hits[ti] as f64;
            let sd = want.max(1.0).sqrt();
            assert!(
                (got - want).abs() < 6.0 * sd + 3.0,
                "topic {ti}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn kernel_factories_resolve_auto_by_path() {
        let auto = RespMode::Auto;
        // train: dense -> sparse -> alias by topic count
        assert_eq!(make_train_kernel(KernelKind::Auto, 8, 0, auto).name(), "dense");
        assert_eq!(make_train_kernel(KernelKind::Auto, 64, 0, auto).name(), "sparse");
        assert_eq!(make_train_kernel(KernelKind::Auto, 256, 0, auto).name(), "alias");
        assert_eq!(make_train_kernel(KernelKind::Dense, 256, 0, auto).name(), "dense");
        assert_eq!(make_train_kernel(KernelKind::Sparse, 8, 0, auto).name(), "sparse");
        assert_eq!(make_train_kernel(KernelKind::Alias, 8, 0, auto).name(), "alias");
        // predict: frozen phi makes alias tables exact, so auto is alias at
        // every T
        assert_eq!(make_predict_kernel(KernelKind::Auto, 2).name(), "alias");
        assert_eq!(make_predict_kernel(KernelKind::Auto, 1024).name(), "alias");
        assert_eq!(make_predict_kernel(KernelKind::Dense, 8).name(), "dense");
        assert_eq!(make_predict_kernel(KernelKind::Sparse, 8).name(), "sparse");
    }

    #[test]
    fn kernel_factory_resolves_resp_mode_per_kernel() {
        // auto/mh give sparse and alias the MH supervised path (counters
        // exposed), exact disables it, and dense never has one.
        for (kind, resp, want) in [
            (KernelKind::Sparse, RespMode::Auto, true),
            (KernelKind::Sparse, RespMode::Mh, true),
            (KernelKind::Sparse, RespMode::Exact, false),
            (KernelKind::Alias, RespMode::Auto, true),
            (KernelKind::Alias, RespMode::Mh, true),
            (KernelKind::Alias, RespMode::Exact, false),
            (KernelKind::Dense, RespMode::Auto, false),
            (KernelKind::Dense, RespMode::Exact, false),
        ] {
            let k = make_train_kernel(kind, 8, 0, resp);
            assert_eq!(
                k.resp_mh_stats().is_some(),
                want,
                "kind {kind:?} resp {resp:?}"
            );
        }
    }

    #[test]
    fn alias_table_draw_frequencies_match_weights() {
        let mut meta = Pcg64::seed_from_u64(31);
        let weights: Vec<f64> = (0..9).map(|_| 0.05 + meta.next_f64() * 2.0).collect();
        let table = AliasTable::build(&weights);
        let total: f64 = weights.iter().sum();
        assert!((table.total() - total).abs() < 1e-12);
        for (i, &w) in weights.iter().enumerate() {
            assert_eq!(table.weight(i), w);
        }
        let n = 200_000usize;
        let mut hits = vec![0usize; weights.len()];
        let mut rng = Pcg64::seed_from_u64(77);
        for _ in 0..n {
            hits[table.sample(&mut rng)] += 1;
        }
        for (i, &w) in weights.iter().enumerate() {
            let want = w / total * n as f64;
            let sd = want.max(1.0).sqrt();
            assert!(
                (hits[i] as f64 - want).abs() < 6.0 * sd + 3.0,
                "outcome {i}: got {} want {want}",
                hits[i]
            );
        }
    }

    #[test]
    fn alias_table_degenerate_weights_fall_back_to_uniform() {
        // all-zero mass: every outcome must still be reachable (uniform)
        let table = AliasTable::build(&[0.0, 0.0, 0.0]);
        let mut rng = Pcg64::seed_from_u64(5);
        let mut hits = [0usize; 3];
        for _ in 0..6000 {
            hits[table.sample(&mut rng)] += 1;
        }
        for &h in &hits {
            assert!(h > 1500, "hits {hits:?}");
        }
        // single outcome
        let one = AliasTable::build(&[2.5]);
        assert_eq!(one.sample(&mut rng), 0);
    }

    #[test]
    fn phi_alias_tables_match_per_row_tables() {
        let (t, w) = (7usize, 11usize);
        let mut meta = Pcg64::seed_from_u64(13);
        let phi: Vec<f32> = (0..w * t).map(|_| 0.01 + meta.next_f32()).collect();
        let tables = PhiAliasTables::build(&phi, t);
        assert_eq!(tables.topics(), t);
        assert_eq!(tables.words(), w);
        assert!(tables.resident_bytes() >= w * t * 20);
        for wi in 0..w {
            let row: Vec<f64> =
                (0..t).map(|ti| phi[wi * t + ti] as f64).collect();
            let single = AliasTable::build(&row);
            for ti in 0..t {
                assert_eq!(tables.weight(wi as u32, ti), row[ti]);
            }
            // identical draws: the flat build and the per-row build must
            // produce the same table
            for seed in 0..50u64 {
                let a = tables.sample(wi as u32, &mut Pcg64::seed_from_u64(seed));
                let b = single.sample(&mut Pcg64::seed_from_u64(seed));
                assert_eq!(a, b, "word {wi} seed {seed}");
            }
        }
    }

    /// Build a single-document count state whose `zd` is consistent with
    /// `ndt` — the fixture for the MH chain tests.
    fn doc_fixture(
        rng: &mut Pcg64,
        t: usize,
        w: usize,
        nd: usize,
    ) -> (CountMatrices, Vec<u32>, Vec<u16>, Vec<f64>, f64) {
        let mut counts = CountMatrices::new(1, t, w);
        let mut tokens = Vec::with_capacity(nd);
        let mut zd = Vec::with_capacity(nd);
        for _ in 0..nd {
            let wi = rng.gen_range(w) as u32;
            let ti = rng.gen_range(t);
            counts.inc(0, wi, ti);
            tokens.push(wi);
            zd.push(ti as u16);
        }
        let wbeta = w as f64 * 0.1;
        let inv_nt: Vec<f64> =
            counts.nt.iter().map(|&c| 1.0 / (c as f64 + wbeta)).collect();
        let ssum: f64 = inv_nt.iter().sum();
        (counts, tokens, zd, inv_nt, ssum)
    }

    /// The training-path MH chain resampling one token must have the exact
    /// conditional as its stationary distribution — for a fresh table
    /// (staleness 1) and for a table that is never rebuilt: staleness only
    /// affects mixing, never the target.
    #[test]
    fn alias_train_chain_matches_exact_conditional() {
        let (alpha, beta) = (0.5, 0.1);
        let (t, w, nd) = (6usize, 10usize, 30usize);
        let wbeta = w as f64 * beta;
        for &staleness in &[1usize, 1 << 30] {
            let mut meta = Pcg64::seed_from_u64(17);
            let (mut counts, tokens, mut zd, mut inv_nt, mut ssum) =
                doc_fixture(&mut meta, t, w, nd);
            counts.enable_alias_rev();
            let n = 4usize; // the resampled token position
            let wi = tokens[n];

            // exact conditional from the exclusive counts
            let probs: Vec<f64> = {
                let old = zd[n] as usize;
                counts.dec(0, wi, old);
                let p: Vec<f64> = (0..t)
                    .map(|ti| {
                        (counts.ndt[ti] as f64 + alpha)
                            * (counts.ntw[wi as usize * t + ti] as f64 + beta)
                            / (counts.nt[ti] as f64 + wbeta)
                    })
                    .collect();
                counts.inc(0, wi, old);
                p
            };
            let total: f64 = probs.iter().sum();

            let mut kern = AliasKernel::new(t, staleness);
            kern.ensure_words(w);
            if staleness > 1 {
                // Inject a deliberately wrong (but full-support) table for
                // the sampled word and pin it via the huge budget: the MH
                // correction must still target the exact conditional — a
                // stale proposal costs mixing speed, never correctness.
                let skewed: Vec<f64> =
                    (0..t).map(|ti| 0.2 + ((ti * 7) % 5) as f64).collect();
                kern.tables[wi as usize] = Some(AliasTable::build(&skewed));
            }
            let mut rng = Pcg64::seed_from_u64(4000 + staleness as u64);
            let iters = 200_000usize;
            let mut hits = vec![0usize; t];
            for _ in 0..iters {
                let mut st = TrainState {
                    counts: &mut counts,
                    inv_nt: &mut inv_nt,
                    ssum: &mut ssum,
                    alpha,
                    beta,
                    wbeta,
                    rng: &mut rng,
                };
                let old = zd[n] as usize;
                remove_token(&mut st, 0, wi, old);
                let new = kern.mh_token_train(&mut st, 0, wi, n, &zd, old);
                add_token(&mut st, 0, wi, new);
                zd[n] = new as u16;
                hits[new] += 1;
            }
            for ti in 0..t {
                let want = probs[ti] / total * iters as f64;
                let got = hits[ti] as f64;
                // MH samples are autocorrelated: widen the iid band.
                let sd = want.max(1.0).sqrt();
                assert!(
                    (got - want).abs() < 12.0 * sd + 0.02 * want + 30.0,
                    "staleness {staleness} topic {ti}: got {got} want {want} (hits {hits:?})"
                );
            }
        }
    }

    /// The prediction-path MH chain against frozen phi tables must target
    /// the exact conditional (N_dt + α)·phi[w, t].
    #[test]
    fn alias_predict_chain_matches_exact_conditional() {
        let alpha = 0.4f64;
        let (t, w, nd) = (6usize, 8usize, 24usize);
        let mut meta = Pcg64::seed_from_u64(23);
        let phi: Vec<f32> = (0..w * t).map(|_| 0.01 + meta.next_f32() * 0.3).collect();
        let tables = PhiAliasTables::build(&phi, t);

        // document state: zd consistent with ndt
        let mut ndt = vec![0u32; t];
        let mut zd: Vec<u16> = Vec::with_capacity(nd);
        for _ in 0..nd {
            let ti = meta.gen_range(t);
            ndt[ti] += 1;
            zd.push(ti as u16);
        }
        let n = 3usize;
        let wi = 2u32;

        // exact conditional from the exclusive counts
        let old0 = zd[n] as usize;
        ndt[old0] -= 1;
        let probs: Vec<f64> = (0..t)
            .map(|ti| (ndt[ti] as f64 + alpha) * phi[wi as usize * t + ti] as f64)
            .collect();
        ndt[old0] += 1;
        let total: f64 = probs.iter().sum();

        let mut rng = Pcg64::seed_from_u64(91);
        let iters = 200_000usize;
        let mut hits = vec![0usize; t];
        for _ in 0..iters {
            let old = zd[n] as usize;
            ndt[old] -= 1;
            let new =
                mh_token_predict(&tables, &ndt, &zd, n, wi, t, alpha, old, &mut rng);
            ndt[new] += 1;
            zd[n] = new as u16;
            hits[new] += 1;
        }
        for ti in 0..t {
            let want = probs[ti] / total * iters as f64;
            let got = hits[ti] as f64;
            let sd = want.max(1.0).sqrt();
            assert!(
                (got - want).abs() < 12.0 * sd + 0.02 * want + 30.0,
                "topic {ti}: got {got} want {want} (hits {hits:?})"
            );
        }
    }

    #[test]
    fn alias_sweeps_preserve_count_invariants_and_determinism() {
        let (alpha, beta) = (0.5, 0.1);
        let (t, w, nd) = (5usize, 12usize, 40usize);
        let wbeta = w as f64 * beta;
        let run = |seed: u64| {
            let mut meta = Pcg64::seed_from_u64(2);
            let (mut counts, tokens, mut zd, mut inv_nt, mut ssum) =
                doc_fixture(&mut meta, t, w, nd);
            counts.enable_alias_rev();
            let mut kern = AliasKernel::new(t, 8);
            let mut rng = Pcg64::seed_from_u64(seed);
            for _ in 0..10 {
                let mut st = TrainState {
                    counts: &mut counts,
                    inv_nt: &mut inv_nt,
                    ssum: &mut ssum,
                    alpha,
                    beta,
                    wbeta,
                    rng: &mut rng,
                };
                kern.sweep_doc_lda(&mut st, 0, &tokens, &mut zd);
            }
            counts.check_invariants().unwrap();
            assert_eq!(counts.total_tokens(), nd as u64);
            // caches must still match the counts
            for (ti, &inv) in inv_nt.iter().enumerate() {
                let want = 1.0 / (counts.nt[ti] as f64 + wbeta);
                assert!((inv - want).abs() < 1e-12, "inv_nt[{ti}] drifted");
            }
            zd
        };
        assert_eq!(run(42), run(42), "alias kernel must be seed-deterministic");
        assert_ne!(run(42), run(43), "different seeds should move some token");
    }

    /// `resp_mode = exact` must stay byte-identical to a direct
    /// [`sweep_doc_gauss`] call on every kernel — the pre-change supervised
    /// dispatch hardcoded that function, and the trait's exact path pins
    /// those draws bit-for-bit.
    #[test]
    fn exact_resp_sweep_is_byte_identical_to_sweep_doc_gauss() {
        let (alpha, beta) = (0.5, 0.1);
        let (t, w, nd) = (6usize, 10usize, 30usize);
        let wbeta = w as f64 * beta;
        let (y, rho) = (1.7f64, 0.4f64);
        let mut meta = Pcg64::seed_from_u64(29);
        let eta: Vec<f64> = (0..t).map(|_| meta.next_f64() * 2.0 - 1.0).collect();
        let (counts0, tokens, zd0, inv_nt0, ssum0) = doc_fixture(&mut meta, t, w, nd);

        let reference = {
            let mut counts = counts0.clone();
            let mut inv_nt = inv_nt0.clone();
            let mut ssum = ssum0;
            let mut zd = zd0.clone();
            let mut scratch = GaussScratch::new(t);
            let mut rng = Pcg64::seed_from_u64(777);
            for _ in 0..5 {
                let mut st = TrainState {
                    counts: &mut counts,
                    inv_nt: &mut inv_nt,
                    ssum: &mut ssum,
                    alpha,
                    beta,
                    wbeta,
                    rng: &mut rng,
                };
                sweep_doc_gauss(&mut st, &mut scratch, &eta, y, rho, 0, &tokens, &mut zd);
            }
            (zd, counts.ndt.clone(), counts.ntw.clone())
        };

        let kernels: Vec<Box<dyn SamplerKernel>> = vec![
            Box::new(DenseKernel),
            Box::new(SparseKernel::new().with_resp_mh(false)),
            Box::new(AliasKernel::new(t, 0).with_resp_mh(false)),
        ];
        for mut kern in kernels {
            let mut counts = counts0.clone();
            if kern.name() == "sparse" {
                counts.enable_sparse_index();
            }
            let mut inv_nt = inv_nt0.clone();
            let mut ssum = ssum0;
            let mut zd = zd0.clone();
            let mut scratch = GaussScratch::new(t);
            let mut rng = Pcg64::seed_from_u64(777);
            for _ in 0..5 {
                let mut st = TrainState {
                    counts: &mut counts,
                    inv_nt: &mut inv_nt,
                    ssum: &mut ssum,
                    alpha,
                    beta,
                    wbeta,
                    rng: &mut rng,
                };
                let mut rs = RespState { eta: &eta, y, rho, scratch: &mut scratch };
                kern.sweep_doc_resp(&mut st, &mut rs, 0, &tokens, &mut zd);
            }
            assert_eq!(zd, reference.0, "{} exact resp sweep diverged", kern.name());
            assert_eq!(counts.ndt, reference.1, "{} ndt diverged", kern.name());
            assert_eq!(counts.ntw, reference.2, "{} ntw diverged", kern.name());
            assert!(kern.resp_mh_stats().is_none(), "{} exact path has no MH", kern.name());
        }
    }

    /// Exact supervised conditional of one token from exclusive counts:
    /// `(N_dt+α)(N_tw+β)/(N_t+Wβ) · exp(a·e_t − e_t²/2ρ)` — the target both
    /// supervised MH chains must be stationary for.
    #[allow(clippy::too_many_arguments)]
    fn resp_target(
        counts: &mut CountMatrices,
        zd: &[u16],
        n: usize,
        wi: u32,
        alpha: f64,
        beta: f64,
        wbeta: f64,
        eta: &[f64],
        y: f64,
        rho: f64,
    ) -> (Vec<f64>, f64) {
        let t = counts.t;
        let inv_nd = 1.0 / zd.len() as f64;
        let s_excl: f64 =
            zd.iter().enumerate().filter(|&(m, _)| m != n).map(|(_, &ti)| eta[ti as usize]).sum();
        let a = (y - s_excl * inv_nd) / rho;
        let old = zd[n] as usize;
        counts.dec(0, wi, old);
        let probs: Vec<f64> = (0..t)
            .map(|ti| {
                let e = eta[ti] * inv_nd;
                (counts.ndt[ti] as f64 + alpha)
                    * (counts.ntw[wi as usize * t + ti] as f64 + beta)
                    / (counts.nt[ti] as f64 + wbeta)
                    * (a * e - e * e / (2.0 * rho)).exp()
            })
            .collect();
        counts.inc(0, wi, old);
        (probs, a)
    }

    /// The sparse supervised MH chain resampling one token must have the
    /// exact supervised conditional as its stationary distribution.
    #[test]
    fn sparse_resp_chain_matches_exact_conditional() {
        let (alpha, beta) = (0.5, 0.1);
        let (t, w, nd) = (6usize, 10usize, 30usize);
        let wbeta = w as f64 * beta;
        let (y, rho) = (2.5f64, 0.3f64);
        let mut meta = Pcg64::seed_from_u64(37);
        let eta: Vec<f64> = (0..t).map(|_| meta.next_f64() * 3.0 - 1.5).collect();
        let (mut counts, tokens, mut zd, mut inv_nt, mut ssum) = doc_fixture(&mut meta, t, w, nd);
        counts.enable_sparse_index();
        let n = 4usize;
        let wi = tokens[n];

        let (probs, a) = resp_target(&mut counts, &zd, n, wi, alpha, beta, wbeta, &eta, y, rho);
        let total: f64 = probs.iter().sum();
        let inv_nd = 1.0 / nd as f64;
        let inv2rho = 1.0 / (2.0 * rho);

        let mut kern = SparseKernel::new().with_resp_mh(true);
        let mut rng = Pcg64::seed_from_u64(5100);
        let iters = 200_000usize;
        let mut hits = vec![0usize; t];
        for _ in 0..iters {
            let mut st = TrainState {
                counts: &mut counts,
                inv_nt: &mut inv_nt,
                ssum: &mut ssum,
                alpha,
                beta,
                wbeta,
                rng: &mut rng,
            };
            let old = zd[n] as usize;
            remove_token(&mut st, 0, wi, old);
            let new = kern.resp_token(&mut st, 0, wi, &eta, a, inv_nd, inv2rho, old);
            add_token(&mut st, 0, wi, new);
            zd[n] = new as u16;
            hits[new] += 1;
        }
        let (proposed, accepted) = kern.resp_mh_stats().unwrap();
        assert_eq!(proposed, (iters * RESP_MH_PROPOSALS) as u64);
        assert!(accepted > proposed / 3, "acceptance collapsed: {accepted}/{proposed}");
        for ti in 0..t {
            let want = probs[ti] / total * iters as f64;
            let got = hits[ti] as f64;
            // MH samples are autocorrelated: widen the iid band.
            let sd = want.max(1.0).sqrt();
            assert!(
                (got - want).abs() < 12.0 * sd + 0.02 * want + 30.0,
                "topic {ti}: got {got} want {want} (hits {hits:?})"
            );
        }
    }

    /// The alias supervised MH chain must target the exact supervised
    /// conditional — for a fresh table (staleness 1) and for a pinned,
    /// deliberately wrong table: staleness costs mixing, never correctness.
    #[test]
    fn alias_resp_chain_matches_exact_conditional() {
        let (alpha, beta) = (0.5, 0.1);
        let (t, w, nd) = (6usize, 10usize, 30usize);
        let wbeta = w as f64 * beta;
        let (y, rho) = (2.5f64, 0.3f64);
        for &staleness in &[1usize, 1 << 30] {
            let mut meta = Pcg64::seed_from_u64(41);
            let eta: Vec<f64> = (0..t).map(|_| meta.next_f64() * 3.0 - 1.5).collect();
            let (mut counts, tokens, mut zd, mut inv_nt, mut ssum) =
                doc_fixture(&mut meta, t, w, nd);
            counts.enable_alias_rev();
            let n = 4usize;
            let wi = tokens[n];

            let (probs, a) =
                resp_target(&mut counts, &zd, n, wi, alpha, beta, wbeta, &eta, y, rho);
            let total: f64 = probs.iter().sum();
            let inv_nd = 1.0 / nd as f64;
            let inv2rho = 1.0 / (2.0 * rho);

            let mut kern = AliasKernel::new(t, staleness).with_resp_mh(true);
            kern.ensure_words(w);
            if staleness > 1 {
                let skewed: Vec<f64> =
                    (0..t).map(|ti| 0.2 + ((ti * 7) % 5) as f64).collect();
                kern.tables[wi as usize] = Some(AliasTable::build(&skewed));
            }
            let mut rng = Pcg64::seed_from_u64(6200 + staleness as u64);
            let iters = 200_000usize;
            let mut hits = vec![0usize; t];
            for _ in 0..iters {
                let mut st = TrainState {
                    counts: &mut counts,
                    inv_nt: &mut inv_nt,
                    ssum: &mut ssum,
                    alpha,
                    beta,
                    wbeta,
                    rng: &mut rng,
                };
                let old = zd[n] as usize;
                remove_token(&mut st, 0, wi, old);
                let new = kern
                    .resp_token_train(&mut st, 0, wi, n, &zd, old, &eta, a, inv_nd, inv2rho);
                add_token(&mut st, 0, wi, new);
                zd[n] = new as u16;
                hits[new] += 1;
            }
            let (proposed, accepted) = kern.resp_mh_stats().unwrap();
            assert_eq!(proposed, (iters * 2 * MH_CYCLES) as u64);
            assert!(accepted > 0);
            for ti in 0..t {
                let want = probs[ti] / total * iters as f64;
                let got = hits[ti] as f64;
                let sd = want.max(1.0).sqrt();
                assert!(
                    (got - want).abs() < 12.0 * sd + 0.02 * want + 30.0,
                    "staleness {staleness} topic {ti}: got {got} want {want} (hits {hits:?})"
                );
            }
        }
    }

    /// Supervised MH sweeps must keep every incrementally maintained
    /// structure — counts, sparse index, alias rev counters, `inv_nt`/`ssum`
    /// caches — live and consistent, and stay seed-deterministic.
    #[test]
    fn resp_sweeps_preserve_count_invariants_and_determinism() {
        let (alpha, beta) = (0.5, 0.1);
        let (t, w, nd) = (5usize, 12usize, 40usize);
        let wbeta = w as f64 * beta;
        let (y, rho) = (1.2f64, 0.5f64);
        for sparse in [true, false] {
            let run = |seed: u64| {
                let mut meta = Pcg64::seed_from_u64(2);
                let eta: Vec<f64> = (0..t).map(|_| meta.next_f64() - 0.5).collect();
                let (mut counts, tokens, mut zd, mut inv_nt, mut ssum) =
                    doc_fixture(&mut meta, t, w, nd);
                let mut kern: Box<dyn SamplerKernel> = if sparse {
                    counts.enable_sparse_index();
                    Box::new(SparseKernel::new().with_resp_mh(true))
                } else {
                    counts.enable_alias_rev();
                    Box::new(AliasKernel::new(t, 8).with_resp_mh(true))
                };
                let mut scratch = GaussScratch::new(t);
                let mut rng = Pcg64::seed_from_u64(seed);
                for _ in 0..10 {
                    let mut st = TrainState {
                        counts: &mut counts,
                        inv_nt: &mut inv_nt,
                        ssum: &mut ssum,
                        alpha,
                        beta,
                        wbeta,
                        rng: &mut rng,
                    };
                    let mut rs = RespState { eta: &eta, y, rho, scratch: &mut scratch };
                    kern.sweep_doc_resp(&mut st, &mut rs, 0, &tokens, &mut zd);
                }
                // validates ndt/ntw/nt totals AND the sparse lists exactly
                counts.check_invariants().unwrap();
                assert_eq!(counts.total_tokens(), nd as u64);
                for (ti, &inv) in inv_nt.iter().enumerate() {
                    let want = 1.0 / (counts.nt[ti] as f64 + wbeta);
                    assert!((inv - want).abs() < 1e-12, "inv_nt[{ti}] drifted");
                }
                let (proposed, accepted) = kern.resp_mh_stats().unwrap();
                assert!(proposed > 0 && accepted <= proposed);
                zd
            };
            assert_eq!(run(42), run(42), "supervised MH must be seed-deterministic");
            assert_ne!(run(42), run(43), "different seeds should move some token");
        }
    }
}
