//! Pluggable Gibbs token-update kernels (DESIGN.md §Perf).
//!
//! One token-update contract, two implementations:
//!
//! * [`DenseKernel`] — the classic O(T) conditional, extracted from the
//!   formerly duplicated inner loops of `gibbs_train` / `gibbs_predict`.
//! * [`SparseKernel`] — SparseLDA-style bucket decomposition (Yao, Mimno &
//!   McCallum 2009; Magnusson et al. 2017). The unsupervised conditional
//!
//!   ```text
//!   p(z = t) ∝ (N_dt + α)(N_tw + β) / (N_t + Wβ)
//!            =  αβ·inv_t                    (smoothing bucket, cached)
//!            +  β·N_dt·inv_t               (document bucket, non-zero N_dt)
//!            +  (N_dt + α)·N_tw·inv_t      (word bucket, non-zero N_tw)
//!   ```
//!
//!   with `inv_t = 1/(N_t + Wβ)` is split into three bucket masses; the
//!   smoothing mass `αβ·Σ_t inv_t` is maintained incrementally (O(1) per
//!   token), and the document/word masses iterate only the non-zero entries
//!   of [`crate::model::counts::SparseIndex`]. A uniform draw first picks a
//!   bucket, then walks only that bucket's support.
//!
//! **Draw-for-draw equivalence.** Both kernels execute the *same* floating-
//! point operation sequence: the dense kernel's extra terms are exact IEEE
//! zeros (a zero count multiplies to `+0.0`, and `x + 0.0 == x` bit-exactly
//! for the non-negative accumulators used here), and the sparse index lists
//! are sorted ascending so accumulation order matches the dense loop. Both
//! consume exactly one `next_f64` per token. The `properties.rs` equivalence
//! test asserts byte-identical `z`, `ndt` and `eta` across kernels.
//!
//! The Gaussian response factor of the *supervised* training conditional is
//! dense in every topic (the margin `exp(a·e_t)·u_t` never vanishes), so
//! eta-active sweeps fall back to the shared [`sweep_doc_gauss`] path for
//! both kernels; burn-in sweeps and the entire prediction path (which has no
//! response term) run the kernel-specific code.

use crate::config::schema::KernelKind;
use crate::model::counts::{insert_sorted, remove_sorted, CountMatrices};
use crate::util::math::fast_exp;
use crate::util::rng::Pcg64;

/// Mutable sampler state threaded through every training token update.
pub struct TrainState<'a> {
    pub counts: &'a mut CountMatrices,
    /// `1/(N_t + Wβ)` per topic, maintained incrementally.
    pub inv_nt: &'a mut [f64],
    /// Running `Σ_t inv_nt[t]` (smoothing-bucket cache), maintained
    /// incrementally alongside `inv_nt`.
    pub ssum: &'a mut f64,
    pub alpha: f64,
    pub beta: f64,
    pub wbeta: f64,
    pub rng: &'a mut Pcg64,
}

/// Mutable sampler state for one document at prediction time (frozen phi).
pub struct PredictState<'a> {
    pub t: usize,
    /// Frozen topic-word distributions, word-major `[w * T + t]`.
    pub phi: &'a [f32],
    /// Per-word cumulative smoothing masses (see [`build_phi_cum`]):
    /// `cum[w*T + t] = Σ_{t' <= t} α·phi[w*T + t']`.
    pub phi_cum: &'a [f64],
    /// The document's topic counts (local, not part of `CountMatrices`).
    pub ndt: &'a mut [u32],
    pub rng: &'a mut Pcg64,
}

/// One token-update contract; implementations must be draw-for-draw
/// interchangeable under a fixed RNG stream (see module docs).
pub trait SamplerKernel {
    fn name(&self) -> &'static str;

    /// Resample every token of document `d` under the plain-LDA conditional
    /// (training, response term inactive).
    fn sweep_doc_lda(&mut self, st: &mut TrainState, d: usize, tokens: &[u32], zd: &mut [u16]);

    /// Resample every token of one held-out document against frozen phi
    /// (prediction conditional, paper eq. 4).
    fn sweep_doc_predict(&mut self, ps: &mut PredictState, tokens: &[u32], zd: &mut [u16]);
}

/// Instantiate the kernel for a resolved [`KernelKind`] (`Auto` resolves by
/// topic count first — see [`KernelKind::resolve`]).
pub fn make_kernel(kind: KernelKind, topics: usize) -> Box<dyn SamplerKernel> {
    match kind.resolve(topics) {
        KernelKind::Sparse => Box::new(SparseKernel::new()),
        _ => Box::new(DenseKernel),
    }
}

/// Remove a token assignment and restore the `inv_nt`/`ssum` caches.
#[inline]
pub fn remove_token(st: &mut TrainState, d: usize, w: u32, topic: usize) {
    st.counts.dec(d, w, topic);
    let old = st.inv_nt[topic];
    let new = 1.0 / (st.counts.nt[topic] as f64 + st.wbeta);
    st.inv_nt[topic] = new;
    *st.ssum += new - old;
}

/// Add a token assignment and restore the `inv_nt`/`ssum` caches.
#[inline]
pub fn add_token(st: &mut TrainState, d: usize, w: u32, topic: usize) {
    st.counts.inc(d, w, topic);
    let old = st.inv_nt[topic];
    let new = 1.0 / (st.counts.nt[topic] as f64 + st.wbeta);
    st.inv_nt[topic] = new;
    *st.ssum += new - old;
}

/// Smoothing-bucket walk: all T topics carry mass `αβ·inv_nt[t]`. Rare
/// (the smoothing mass is a small fraction of the total), shared verbatim
/// by both kernels.
#[inline]
fn smoothing_walk(u: f64, ab: f64, inv_nt: &[f64]) -> usize {
    let mut acc = 0.0;
    let mut last = 0usize;
    for (ti, &inv) in inv_nt.iter().enumerate() {
        acc += ab * inv;
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// Dense bucket draw: identical bucket arithmetic to the sparse draw, but
/// iterating all T topics (zero terms are exact no-ops).
fn dense_lda_draw(st: &mut TrainState, d: usize, w: u32) -> usize {
    let t = st.counts.t;
    let ab = st.alpha * st.beta;
    let s_mass = ab * *st.ssum;
    let ndt = &st.counts.ndt[d * t..(d + 1) * t];
    let ntw = &st.counts.ntw[w as usize * t..(w as usize + 1) * t];
    let inv_nt: &[f64] = &*st.inv_nt;

    let mut r = 0.0;
    for ti in 0..t {
        r += st.beta * ndt[ti] as f64 * inv_nt[ti];
    }
    let mut q = 0.0;
    for ti in 0..t {
        q += (ndt[ti] as f64 + st.alpha) * ntw[ti] as f64 * inv_nt[ti];
    }

    let total = s_mass + r + q;
    let mut u = st.rng.next_f64() * total;
    if u < s_mass {
        return smoothing_walk(u, ab, inv_nt);
    }
    u -= s_mass;
    if u < r {
        let mut acc = 0.0;
        let mut last = 0usize;
        for ti in 0..t {
            let c = ndt[ti];
            if c == 0 {
                continue;
            }
            acc += st.beta * c as f64 * inv_nt[ti];
            last = ti;
            if u < acc {
                return ti;
            }
        }
        return last;
    }
    u -= r;
    let mut acc = 0.0;
    let mut last = 0usize;
    for ti in 0..t {
        let c = ntw[ti];
        if c == 0 {
            continue;
        }
        acc += (ndt[ti] as f64 + st.alpha) * c as f64 * inv_nt[ti];
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// Sparse bucket draw: document and word buckets iterate only the sorted
/// non-zero lists of the [`crate::model::counts::SparseIndex`].
fn sparse_lda_draw(st: &mut TrainState, d: usize, w: u32) -> usize {
    let t = st.counts.t;
    let ab = st.alpha * st.beta;
    let s_mass = ab * *st.ssum;
    let nz = st.counts.nz.as_ref().expect("sparse kernel requires enable_sparse_index()");
    let doc_list: &[u16] = &nz.doc_nz[d];
    let word_list: &[u16] = &nz.word_nz[w as usize];
    let ndt = &st.counts.ndt[d * t..(d + 1) * t];
    let ntw = &st.counts.ntw[w as usize * t..(w as usize + 1) * t];
    let inv_nt: &[f64] = &*st.inv_nt;

    let mut r = 0.0;
    for &tu in doc_list {
        let ti = tu as usize;
        r += st.beta * ndt[ti] as f64 * inv_nt[ti];
    }
    let mut q = 0.0;
    for &tu in word_list {
        let ti = tu as usize;
        q += (ndt[ti] as f64 + st.alpha) * ntw[ti] as f64 * inv_nt[ti];
    }

    let total = s_mass + r + q;
    let mut u = st.rng.next_f64() * total;
    if u < s_mass {
        return smoothing_walk(u, ab, inv_nt);
    }
    u -= s_mass;
    if u < r {
        let mut acc = 0.0;
        let mut last = 0usize;
        for &tu in doc_list {
            let ti = tu as usize;
            acc += st.beta * ndt[ti] as f64 * inv_nt[ti];
            last = ti;
            if u < acc {
                return ti;
            }
        }
        return last;
    }
    u -= r;
    let mut acc = 0.0;
    let mut last = 0usize;
    for &tu in word_list {
        let ti = tu as usize;
        acc += (ndt[ti] as f64 + st.alpha) * ntw[ti] as f64 * inv_nt[ti];
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// Per-word cumulative smoothing table for prediction:
/// `cum[w*T + t] = Σ_{t' <= t} α·phi[w*T + t']`. Built once per corpus
/// inference call and shared by both kernels (the smoothing-bucket topic is
/// then a binary search instead of an O(T) walk).
pub fn build_phi_cum(phi: &[f32], t: usize, alpha: f64) -> Vec<f64> {
    debug_assert_eq!(phi.len() % t, 0);
    let mut cum = vec![0.0f64; phi.len()];
    for w in 0..phi.len() / t {
        let mut acc = 0.0;
        for ti in 0..t {
            acc += alpha * phi[w * t + ti] as f64;
            cum[w * t + ti] = acc;
        }
    }
    cum
}

/// Smoothing-bucket topic at prediction time: smallest t with `u < cum[t]`
/// (same selection as the linear walk over `α·phi`, since `cum` is that
/// walk's accumulator sequence).
#[inline]
fn predict_smoothing_topic(u: f64, cum: &[f64]) -> usize {
    cum.partition_point(|&c| c <= u).min(cum.len() - 1)
}

/// Dense prediction draw: `p(z=t) ∝ (N_dt + α)·phi_t = α·phi_t + N_dt·phi_t`.
fn dense_predict_draw(ps: &mut PredictState, w: u32) -> usize {
    let t = ps.t;
    let phi = &ps.phi[w as usize * t..(w as usize + 1) * t];
    let cum = &ps.phi_cum[w as usize * t..(w as usize + 1) * t];
    let s_mass = cum[t - 1];

    let mut r = 0.0;
    for ti in 0..t {
        r += ps.ndt[ti] as f64 * phi[ti] as f64;
    }
    let total = s_mass + r;
    let mut u = ps.rng.next_f64() * total;
    if u < s_mass {
        return predict_smoothing_topic(u, cum);
    }
    u -= s_mass;
    let mut acc = 0.0;
    let mut last = 0usize;
    for ti in 0..t {
        let c = ps.ndt[ti];
        if c == 0 {
            continue;
        }
        acc += c as f64 * phi[ti] as f64;
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// Sparse prediction draw over the caller-maintained sorted non-zero list.
fn sparse_predict_draw(ps: &mut PredictState, doc_list: &[u16], w: u32) -> usize {
    let t = ps.t;
    let phi = &ps.phi[w as usize * t..(w as usize + 1) * t];
    let cum = &ps.phi_cum[w as usize * t..(w as usize + 1) * t];
    let s_mass = cum[t - 1];

    let mut r = 0.0;
    for &tu in doc_list {
        let ti = tu as usize;
        r += ps.ndt[ti] as f64 * phi[ti] as f64;
    }
    let total = s_mass + r;
    let mut u = ps.rng.next_f64() * total;
    if u < s_mass {
        return predict_smoothing_topic(u, cum);
    }
    u -= s_mass;
    let mut acc = 0.0;
    let mut last = 0usize;
    for &tu in doc_list {
        let ti = tu as usize;
        acc += ps.ndt[ti] as f64 * phi[ti] as f64;
        last = ti;
        if u < acc {
            return ti;
        }
    }
    last
}

/// The classic dense O(T)-per-token kernel.
pub struct DenseKernel;

impl SamplerKernel for DenseKernel {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn sweep_doc_lda(&mut self, st: &mut TrainState, d: usize, tokens: &[u32], zd: &mut [u16]) {
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            remove_token(st, d, wi, old);
            let new = dense_lda_draw(st, d, wi);
            add_token(st, d, wi, new);
            zd[n] = new as u16;
        }
    }

    fn sweep_doc_predict(&mut self, ps: &mut PredictState, tokens: &[u32], zd: &mut [u16]) {
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            ps.ndt[old] -= 1;
            let new = dense_predict_draw(ps, wi);
            ps.ndt[new] += 1;
            zd[n] = new as u16;
        }
    }
}

/// SparseLDA-style bucket kernel. Training iterates the counts' sparse
/// index; prediction maintains its own per-document non-zero scratch list.
pub struct SparseKernel {
    doc_nz: Vec<u16>,
}

impl SparseKernel {
    pub fn new() -> Self {
        SparseKernel { doc_nz: Vec::new() }
    }
}

impl Default for SparseKernel {
    fn default() -> Self {
        Self::new()
    }
}

impl SamplerKernel for SparseKernel {
    fn name(&self) -> &'static str {
        "sparse"
    }

    fn sweep_doc_lda(&mut self, st: &mut TrainState, d: usize, tokens: &[u32], zd: &mut [u16]) {
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            remove_token(st, d, wi, old);
            let new = sparse_lda_draw(st, d, wi);
            add_token(st, d, wi, new);
            zd[n] = new as u16;
        }
    }

    fn sweep_doc_predict(&mut self, ps: &mut PredictState, tokens: &[u32], zd: &mut [u16]) {
        // Rebuild the sorted non-zero list from the document's current
        // counts (O(T) once per sweep, amortized over the token loop).
        self.doc_nz.clear();
        for ti in 0..ps.t {
            if ps.ndt[ti] > 0 {
                self.doc_nz.push(ti as u16);
            }
        }
        for (n, &wi) in tokens.iter().enumerate() {
            let old = zd[n] as usize;
            ps.ndt[old] -= 1;
            if ps.ndt[old] == 0 {
                remove_sorted(&mut self.doc_nz, old as u16);
            }
            let new = sparse_predict_draw(ps, &self.doc_nz, wi);
            ps.ndt[new] += 1;
            if ps.ndt[new] == 1 {
                insert_sorted(&mut self.doc_nz, new as u16);
            }
            zd[n] = new as u16;
        }
    }
}

/// Shared supervised-conditional sweep (paper eq. 1 with the Gaussian
/// response margin). The margin is dense in every topic, so both kernels
/// use this identical path whenever `eta` is active; see the module docs.
/// The hot-path tricks are unchanged from the original inner loop
/// (DESIGN.md §Perf): running dot product `s_d = η·N_dt`, per-document
/// `e`/`u` tables, `fast_exp`, dropped constant margin factor.
#[allow(clippy::too_many_arguments)]
pub fn sweep_doc_gauss(
    st: &mut TrainState,
    scratch: &mut GaussScratch,
    eta: &[f64],
    y: f64,
    rho: f64,
    d: usize,
    tokens: &[u32],
    zd: &mut [u16],
) {
    let t = st.counts.t;
    let nd = tokens.len();
    let inv_nd = 1.0 / nd as f64;
    let inv2rho = 1.0 / (2.0 * rho);
    let inv_rho = 1.0 / rho;
    // Running response dot product s_d = eta . N_dt.
    let mut s: f64 =
        st.counts.ndt_row(d).iter().zip(eta).map(|(&c, &e)| c as f64 * e).sum();
    for ti in 0..t {
        let e = eta[ti] * inv_nd;
        scratch.e_buf[ti] = e;
        scratch.u_buf[ti] = fast_exp(-(e * e) * inv2rho);
    }
    for (n, &wi) in tokens.iter().enumerate() {
        let old = zd[n] as usize;
        remove_token(st, d, wi, old);
        s -= eta[old];
        {
            let ndt = &st.counts.ndt[d * t..(d + 1) * t];
            let ntw = &st.counts.ntw[wi as usize * t..(wi as usize + 1) * t];
            // a = c/rho with c = y - s^{-dn}/N_d (constant exp factor
            // exp(-c^2/2rho) dropped: cancels in the draw)
            let a = (y - s * inv_nd) * inv_rho;
            for ti in 0..t {
                let gauss = fast_exp(a * scratch.e_buf[ti]) * scratch.u_buf[ti];
                scratch.probs[ti] = gauss
                    * (ndt[ti] as f64 + st.alpha)
                    * (ntw[ti] as f64 + st.beta)
                    * st.inv_nt[ti];
            }
        }
        let new = st.rng.sample_discrete(&scratch.probs);
        add_token(st, d, wi, new);
        s += eta[new];
        zd[n] = new as u16;
    }
}

/// Reusable per-chain buffers for [`sweep_doc_gauss`].
pub struct GaussScratch {
    pub probs: Vec<f64>,
    pub e_buf: Vec<f64>,
    pub u_buf: Vec<f64>,
}

impl GaussScratch {
    pub fn new(t: usize) -> Self {
        GaussScratch { probs: vec![0.0; t], e_buf: vec![0.0; t], u_buf: vec![0.0; t] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Random count state with every cache consistent; sparse index enabled
    /// (the dense kernel ignores it).
    fn random_state(
        rng: &mut Pcg64,
        d: usize,
        t: usize,
        w: usize,
        tokens_per_doc: usize,
    ) -> (CountMatrices, Vec<f64>, f64) {
        let mut c = CountMatrices::new(d, t, w);
        for di in 0..d {
            for _ in 0..tokens_per_doc {
                c.inc(di, rng.gen_range(w) as u32, rng.gen_range(t));
            }
        }
        c.enable_sparse_index();
        let wbeta = w as f64 * 0.1;
        let inv_nt: Vec<f64> = c.nt.iter().map(|&n| 1.0 / (n as f64 + wbeta)).collect();
        let ssum: f64 = inv_nt.iter().sum();
        (c, inv_nt, ssum)
    }

    #[allow(clippy::too_many_arguments)]
    fn draw_once(
        sparse: bool,
        seed: u64,
        counts: &mut CountMatrices,
        inv_nt: &mut [f64],
        ssum: &mut f64,
        alpha: f64,
        beta: f64,
        wbeta: f64,
        di: usize,
        wi: u32,
    ) -> usize {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut st = TrainState { counts, inv_nt, ssum, alpha, beta, wbeta, rng: &mut rng };
        if sparse {
            sparse_lda_draw(&mut st, di, wi)
        } else {
            dense_lda_draw(&mut st, di, wi)
        }
    }

    #[test]
    fn dense_and_sparse_draws_agree_token_for_token() {
        let (alpha, beta) = (0.5, 0.1);
        let mut meta = Pcg64::seed_from_u64(11);
        for trial in 0..200usize {
            let (d, t, w) = (4usize, 2 + (trial % 13), 20usize);
            let wbeta = w as f64 * beta;
            let (mut counts, mut inv_nt, mut ssum) =
                random_state(&mut meta, d, t, w, 1 + trial % 30);
            let di = meta.gen_range(d);
            let wi = meta.gen_range(w) as u32;
            let seed = meta.next_u64();

            let a = draw_once(
                false, seed, &mut counts, &mut inv_nt, &mut ssum, alpha, beta, wbeta, di, wi,
            );
            let b = draw_once(
                true, seed, &mut counts, &mut inv_nt, &mut ssum, alpha, beta, wbeta, di, wi,
            );
            assert_eq!(a, b, "trial {trial}: dense chose {a}, sparse chose {b}");
        }
    }

    #[test]
    fn bucket_draw_matches_full_conditional_distribution() {
        // Empirical draw frequencies of the decomposed draw must match the
        // directly computed conditional p(t) ∝ (N_dt+α)(N_tw+β)/(N_t+Wβ).
        let (alpha, beta) = (0.5, 0.1);
        let (d, t, w) = (2usize, 5usize, 8usize);
        let wbeta = w as f64 * beta;
        let mut meta = Pcg64::seed_from_u64(3);
        let (mut counts, mut inv_nt, mut ssum) = random_state(&mut meta, d, t, w, 25);
        let (di, wi) = (0usize, 3u32);

        let probs: Vec<f64> = (0..t)
            .map(|ti| {
                (counts.ndt[di * t + ti] as f64 + alpha)
                    * (counts.ntw[wi as usize * t + ti] as f64 + beta)
                    / (counts.nt[ti] as f64 + wbeta)
            })
            .collect();
        let total: f64 = probs.iter().sum();

        let n = 200_000usize;
        let mut hits = vec![0usize; t];
        let mut rng = Pcg64::seed_from_u64(99);
        for _ in 0..n {
            let mut st = TrainState {
                counts: &mut counts,
                inv_nt: &mut inv_nt,
                ssum: &mut ssum,
                alpha,
                beta,
                wbeta,
                rng: &mut rng,
            };
            hits[dense_lda_draw(&mut st, di, wi)] += 1;
        }
        for ti in 0..t {
            let want = probs[ti] / total * n as f64;
            let got = hits[ti] as f64;
            let sd = (want.max(1.0)).sqrt();
            assert!(
                (got - want).abs() < 6.0 * sd + 3.0,
                "topic {ti}: got {got} want {want} (hits {hits:?})"
            );
        }
    }

    fn predict_draw_once(
        sparse: bool,
        seed: u64,
        t: usize,
        phi: &[f32],
        phi_cum: &[f64],
        ndt: &mut [u32],
    ) -> usize {
        let mut rng = Pcg64::seed_from_u64(seed);
        let list: Vec<u16> =
            (0..t).filter(|&ti| ndt[ti] > 0).map(|ti| ti as u16).collect();
        let mut ps = PredictState { t, phi, phi_cum, ndt, rng: &mut rng };
        if sparse {
            sparse_predict_draw(&mut ps, &list, 0)
        } else {
            dense_predict_draw(&mut ps, 0)
        }
    }

    #[test]
    fn predict_draws_agree_and_match_distribution() {
        let t = 6usize;
        let alpha = 0.4;
        let mut meta = Pcg64::seed_from_u64(21);
        // One word's phi row (positive, unnormalized is fine for the draw).
        let phi: Vec<f32> = (0..t).map(|_| 0.01 + meta.next_f32() * 0.2).collect();
        let phi_cum = build_phi_cum(&phi, t, alpha);
        let mut ndt: Vec<u32> = vec![0, 3, 0, 1, 0, 7];

        // cross-kernel agreement over many RNG streams
        for trial in 0..200u64 {
            let seed = 1000 + trial;
            let a = predict_draw_once(false, seed, t, &phi, &phi_cum, &mut ndt);
            let b = predict_draw_once(true, seed, t, &phi, &phi_cum, &mut ndt);
            assert_eq!(a, b, "seed {seed}");
        }

        // distribution check: p(t) ∝ (ndt + alpha) * phi
        let probs: Vec<f64> =
            (0..t).map(|ti| (ndt[ti] as f64 + alpha) * phi[ti] as f64).collect();
        let total: f64 = probs.iter().sum();
        let n = 100_000usize;
        let mut hits = vec![0usize; t];
        let mut rng = Pcg64::seed_from_u64(7);
        for _ in 0..n {
            let mut ps = PredictState {
                t,
                phi: &phi,
                phi_cum: &phi_cum,
                ndt: &mut ndt,
                rng: &mut rng,
            };
            hits[dense_predict_draw(&mut ps, 0)] += 1;
        }
        for ti in 0..t {
            let want = probs[ti] / total * n as f64;
            let got = hits[ti] as f64;
            let sd = want.max(1.0).sqrt();
            assert!(
                (got - want).abs() < 6.0 * sd + 3.0,
                "topic {ti}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn kernel_factory_resolves_auto_by_topic_count() {
        assert_eq!(make_kernel(KernelKind::Auto, 8).name(), "dense");
        assert_eq!(make_kernel(KernelKind::Auto, 64).name(), "sparse");
        assert_eq!(make_kernel(KernelKind::Dense, 256).name(), "dense");
        assert_eq!(make_kernel(KernelKind::Sparse, 8).name(), "sparse");
    }
}
