//! Multi-process communication-free training over an mmapped arena
//! (DESIGN.md §Out-of-core).
//!
//! `cfslda train-shard --arena corpus.arena --shard j/M` runs exactly one
//! worker's chain in its own OS process and persists a
//! [`ShardArtifact`]; `cfslda combine` loads the M artifacts and applies
//! the paper's combination rules. Because the processes share the arena
//! file read-only through the page cache and never talk to each other,
//! this is the paper's communication-free claim taken literally: the only
//! bytes that move are the final model shards.
//!
//! **Determinism.** A multi-process run is byte-identical to the
//! in-process `run_with_engine` for the same config: [`plan_run`] replays
//! the exact leader RNG draws — the `seed ^ 0x5911_7001` train/test
//! shuffle the CLI performs, then `random_shards` and the per-shard
//! `split(i)` derivations on the `seed` stream, in order (each `split`
//! consumes leader state, so all M are replayed even though a process
//! keeps only its own). Shard j's documents are the same documents in the
//! same order — views over the mapped arena compose the train/test
//! selection with the shard partition — so every Gibbs chain sees
//! identical bytes and makes identical draws. A leader-level test pins
//! `train-shard`×M + `combine` bit-for-bit against the in-process run.
//!
//! [`ShardArtifact`]: crate::combine::artifact::ShardArtifact

use crate::ckpt::{config_fingerprint, GenCoordinator, ShardState, StdFs, Store};
use crate::combine::artifact::ShardArtifact;
use crate::combine::rules::combine_median;
use crate::combine::{combine_predictions, weights, CombineRule, WeightScheme};
use crate::config::schema::ExperimentConfig;
use crate::config::validate::validate;
use crate::data::arena_file::ArenaMap;
use crate::data::partition::{random_shards, split_indices};
use crate::eval::metrics::{compute, Metrics};
use crate::parallel::comm::{
    mmap_setup_bytes, model_bytes, predictions_bytes, CommLedger, CommStats,
};
use crate::parallel::leader::Algorithm;
use crate::parallel::worker::{run_worker_ckpt, WorkerPlan, WorkerRun};
use crate::runtime::EngineHandle;
use crate::sampler::gibbs_train::CkptHook;
use crate::util::rng::Pcg64;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;

/// `--shard j/M`: which worker this process is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub shard: usize,
    pub m: usize,
}

impl ShardSpec {
    /// Parse `"j/M"` (0-based shard index, total count).
    pub fn parse(s: &str) -> anyhow::Result<ShardSpec> {
        let (a, b) = s
            .split_once('/')
            .ok_or_else(|| anyhow::anyhow!("--shard wants 'j/M' (e.g. 0/4), got '{s}'"))?;
        let shard: usize = a.trim().parse().map_err(|_| {
            anyhow::anyhow!("bad shard index '{a}' in --shard '{s}'")
        })?;
        let m: usize = b.trim().parse().map_err(|_| {
            anyhow::anyhow!("bad shard count '{b}' in --shard '{s}'")
        })?;
        anyhow::ensure!(m > 0, "--shard count must be positive, got {m}");
        anyhow::ensure!(shard < m, "--shard index {shard} out of range 0..{m}");
        Ok(ShardSpec { shard, m })
    }
}

/// The replayed leader plan: what the in-process leader would have drawn.
/// `shards[j]` holds *positions into `train_ids`* (the in-process shard
/// partition is over the selected training corpus); compose with
/// `train_ids` via [`MultiprocPlan::shard_arena_ids`] to get arena doc ids.
#[derive(Clone, Debug)]
pub struct MultiprocPlan {
    /// Arena doc ids of the training documents, selection order.
    pub train_ids: Vec<usize>,
    /// Arena doc ids of the test documents, selection order.
    pub test_ids: Vec<usize>,
    pub shards: Vec<Vec<usize>>,
    /// Per-shard RNG streams, exactly the leader's `rng.split(i)` results.
    pub worker_rngs: Vec<Pcg64>,
}

impl MultiprocPlan {
    /// Arena doc ids of shard `j`'s documents, in chain order.
    pub fn shard_arena_ids(&self, j: usize) -> Vec<usize> {
        self.shards[j].iter().map(|&k| self.train_ids[k]).collect()
    }
}

/// Replay the in-process leader's RNG draws for a corpus of `n_docs`
/// documents split into `n_train` training docs and `m` shards.
///
/// Draw-for-draw mirror of the single-process path: `cmd_run`'s
/// `seed ^ 0x5911_7001` stream shuffles the train/test permutation, then
/// `run_with_engine`'s `seed` stream feeds `random_shards` and the
/// per-shard `Pcg64::split(i)` calls for i = 0..M **in order** — `split`
/// advances the parent stream, so skipping earlier shards would derange
/// every later one.
pub fn plan_run(cfg: &ExperimentConfig, n_docs: usize, n_train: usize, m: usize) -> MultiprocPlan {
    let mut split_rng = Pcg64::seed_from_u64(cfg.seed ^ 0x5911_7001);
    let (train_ids, test_ids) = split_indices(n_docs, n_train, &mut split_rng);
    let mut rng = Pcg64::seed_from_u64(cfg.seed);
    let shards = random_shards(train_ids.len(), m, &mut rng);
    let worker_rngs = (0..m).map(|i| rng.split(i as u64)).collect();
    MultiprocPlan { train_ids, test_ids, shards, worker_rngs }
}

/// The combination rule a prediction-combining algorithm runs under
/// (train-shard / combine support exactly these three).
fn rule_for(algo: Algorithm, cfg: &ExperimentConfig) -> anyhow::Result<CombineRule> {
    Ok(match algo {
        Algorithm::SimpleAverage => CombineRule::Simple,
        Algorithm::WeightedAverage => {
            CombineRule::Weighted(WeightScheme::for_response(cfg.response))
        }
        Algorithm::MedianAverage => CombineRule::Median,
        Algorithm::NonParallel | Algorithm::NaiveCombination => anyhow::bail!(
            "train-shard/combine supports the prediction-combining algorithms \
             (simple/weighted/median); '{}' needs the in-process runner",
            algo.name()
        ),
    })
}

/// Everything one `train-shard` process needs.
pub struct TrainShardJob<'a> {
    pub arena: &'a ArenaMap,
    pub cfg: &'a ExperimentConfig,
    pub engine: &'a EngineHandle,
    pub algo: Algorithm,
    pub spec: ShardSpec,
    /// Training-set size (the CLI defaults it to `3/4 · docs` exactly like
    /// `cfslda run`).
    pub n_train: usize,
    /// Artifact output path.
    pub out: PathBuf,
    pub resume: bool,
    pub stop: Option<&'a AtomicBool>,
}

/// Result of one shard process.
pub enum ShardRunOutcome {
    Done { artifact: Box<ShardArtifact>, comm: CommStats },
    /// Stopped cleanly at a checkpoint boundary (`--resume` continues it).
    Interrupted { next_sweep: u64 },
}

/// Checkpoint store directory for one shard process:
/// `<checkpoint_dir>/<algorithm>-seed<seed>-shard<j>of<m>`. Each process
/// owns its directory outright — crash recovery needs no cross-process
/// manifest coordination, each shard commits generations alone.
pub fn shard_store_dir(cfg: &ExperimentConfig, algo: Algorithm, spec: ShardSpec) -> PathBuf {
    Path::new(&cfg.train.checkpoint_dir).join(format!(
        "{}-seed{}-shard{}of{}",
        algo.name(),
        cfg.seed,
        spec.shard,
        spec.m
    ))
}

/// Run shard `spec.shard` of an M-process run against the mapped arena and
/// persist its artifact. Byte-identical to worker `spec.shard` of the
/// in-process `run_with_engine` with `cfg.parallel.shards = spec.m`.
pub fn run_train_shard(job: TrainShardJob<'_>) -> anyhow::Result<ShardRunOutcome> {
    let TrainShardJob { arena, cfg, engine, algo, spec, n_train, out, resume, stop } = job;
    validate(cfg)?;
    let rule = rule_for(algo, cfg)?;
    anyhow::ensure!(
        n_train <= arena.num_docs(),
        "n_train {n_train} > arena docs {}",
        arena.num_docs()
    );

    let plan = plan_run(cfg, arena.num_docs(), n_train, spec.m);
    let shard_ids = plan.shard_arena_ids(spec.shard);
    let shard_view = arena.view_of(&shard_ids)?;
    let test_view = arena.view_of(&plan.test_ids)?;
    let full_train_view = arena.view_of(&plan.train_ids)?;

    // Out-of-core accounting: the whole mapped file is referenced, nothing
    // is copied — doc-id lists are derived in-process, not shipped.
    let ledger = CommLedger::new();
    let (copied, referenced) = mmap_setup_bytes(arena.mapped_len());
    ledger.add_setup_copied(copied);
    ledger.add_setup_referenced(referenced);

    let wplan = WorkerPlan {
        predict_test: true,
        predict_full_train: matches!(
            rule,
            CombineRule::Weighted(WeightScheme::InverseMse)
                | CombineRule::Weighted(WeightScheme::Accuracy)
        ),
    };

    // The fingerprint matches the in-process run's (train dims + algorithm
    // + shard count M), so artifacts and checkpoints from different
    // configurations can never be combined or resumed across.
    let fingerprint = config_fingerprint(
        cfg,
        full_train_view.num_docs(),
        full_train_view.num_tokens(),
        arena.vocab_size(),
        algo.name(),
        spec.m,
    );

    let fs = StdFs;
    let enabled = cfg.train.checkpoint_every > 0 && !cfg.train.checkpoint_dir.is_empty();
    anyhow::ensure!(
        !resume || enabled,
        "--resume requested but checkpointing is disabled \
         (set train.checkpoint_every and train.checkpoint_dir)"
    );
    let store = enabled.then(|| Store::new(&fs, shard_store_dir(cfg, algo, spec)));
    let coord = GenCoordinator::new(1, fingerprint);
    let resume_state = match (&store, resume) {
        (Some(store), true) => {
            let r = store.load_latest(fingerprint)?;
            anyhow::ensure!(
                r.states.len() == 1,
                "shard checkpoint holds {} states, want exactly 1",
                r.states.len()
            );
            log::info!(
                "train-shard {}/{}: resuming from generation {} (sweep {} of {})",
                spec.shard,
                spec.m,
                r.generation,
                r.next_sweep,
                cfg.train.sweeps
            );
            Some(r.states.into_iter().next().unwrap())
        }
        _ => None,
    };

    let sink = |state: ShardState| -> anyhow::Result<()> {
        let store = store.as_ref().expect("sink only wired when the store exists");
        let generation = state.next_sweep;
        let entry = store.write_shard(generation, &state)?;
        if let Some((manifest, total_us)) = coord.shard_done(generation, entry, 0) {
            store.commit_manifest(generation, &manifest, total_us)?;
        }
        Ok(())
    };
    let hook = store.is_some().then(|| CkptHook {
        shard_id: spec.shard as u32,
        resume: resume_state,
        sink: Some(&sink),
        stop,
    });

    let run = run_worker_ckpt(
        spec.shard,
        shard_view,
        test_view,
        full_train_view,
        wplan,
        cfg,
        engine,
        plan.worker_rngs[spec.shard].clone(),
        hook,
    )?;
    let output = match run {
        WorkerRun::Done(o) => o,
        WorkerRun::Interrupted { next_sweep, .. } => {
            return Ok(ShardRunOutcome::Interrupted { next_sweep });
        }
    };

    // Gather leg: exactly what the in-process leader prices per worker.
    let mut gather = model_bytes(output.train.model.t, output.train.model.w);
    if output.test_pred.is_some() {
        gather += predictions_bytes(test_view.num_docs());
    }
    if output.full_train_quality.is_some() {
        gather += 16; // (mse, acc) pair
    }
    ledger.add_gather(gather);

    let test_pred = output.test_pred.as_ref().expect("planned test prediction");
    let artifact = ShardArtifact {
        fingerprint,
        algorithm: algo.name().to_string(),
        shard_id: spec.shard as u32,
        m: spec.m as u32,
        response: cfg.response,
        model: output.train.model.clone(),
        test_yhat: test_pred.yhat.clone(),
        // Labels ride along so `combine` is standalone; they come from the
        // shared arena, not from another worker — the chains themselves
        // never see them (workers predict unlabeled).
        test_labels: test_view.responses(),
        full_train_quality: output.full_train_quality,
        tokens_sampled: output.train.tokens_sampled,
        docs: shard_ids.len() as u64,
    };
    artifact.save(&out)?;
    Ok(ShardRunOutcome::Done { artifact: Box::new(artifact), comm: ledger.snapshot() })
}

/// `cfslda combine`'s result.
#[derive(Clone, Debug)]
pub struct CombineOutput {
    pub algorithm: Algorithm,
    pub yhat: Vec<f64>,
    pub test_metrics: Metrics,
    pub weights: Vec<f64>,
    /// Gather-side ledger: model shards + local predictions, nothing else.
    pub comm: CommStats,
    pub fingerprint: u64,
    pub tokens_sampled: u64,
}

/// Combine M shard artifacts into the global prediction — the exact
/// combination stage of the in-process `run_prediction_combining`,
/// operating on persisted artifacts instead of in-memory worker outputs.
/// Refuses mixed fingerprints, inconsistent coordinates, incomplete shard
/// sets, and disagreeing test labels.
pub fn combine_artifacts(
    engine: &EngineHandle,
    artifacts: &[ShardArtifact],
) -> anyhow::Result<CombineOutput> {
    anyhow::ensure!(!artifacts.is_empty(), "no shard artifacts to combine");
    let mut arts: Vec<&ShardArtifact> = artifacts.iter().collect();
    arts.sort_by_key(|a| a.shard_id);
    let first = arts[0];
    let m = first.m as usize;
    anyhow::ensure!(
        arts.len() == m,
        "run has M={m} shards but {} artifacts were given",
        arts.len()
    );
    for (j, a) in arts.iter().enumerate() {
        anyhow::ensure!(
            a.shard_id as usize == j,
            "shard set incomplete: expected shard {j}, found {}",
            a.shard_id
        );
        anyhow::ensure!(
            a.fingerprint == first.fingerprint,
            "shard {} was produced by a different run \
             (fingerprint {:#018x}, shard 0 has {:#018x})",
            a.shard_id,
            a.fingerprint,
            first.fingerprint
        );
        anyhow::ensure!(
            a.m == first.m && a.algorithm == first.algorithm && a.response == first.response,
            "shard {} disagrees on run coordinates (m/algorithm/response)",
            a.shard_id
        );
        anyhow::ensure!(
            a.test_yhat.len() == first.test_yhat.len(),
            "shard {} predicted {} test docs, shard 0 predicted {}",
            a.shard_id,
            a.test_yhat.len(),
            first.test_yhat.len()
        );
        let labels_match = a.test_labels.len() == first.test_labels.len()
            && a
                .test_labels
                .iter()
                .zip(&first.test_labels)
                .all(|(x, y)| x.to_bits() == y.to_bits());
        anyhow::ensure!(labels_match, "shard {} carries different test labels", a.shard_id);
    }
    let algo = Algorithm::parse(&first.algorithm)?;
    let rule = match algo {
        Algorithm::SimpleAverage => CombineRule::Simple,
        Algorithm::WeightedAverage => {
            CombineRule::Weighted(WeightScheme::for_response(first.response))
        }
        Algorithm::MedianAverage => CombineRule::Median,
        other => anyhow::bail!("artifacts name non-combinable algorithm '{}'", other.name()),
    };

    // Gather pricing: identical to the in-process leader's per-worker sum.
    let ledger = CommLedger::new();
    for a in &arts {
        let mut gather = model_bytes(a.model.t, a.model.w);
        gather += predictions_bytes(a.test_yhat.len());
        if a.full_train_quality.is_some() {
            gather += 16;
        }
        ledger.add_gather(gather);
    }

    let local_preds: Vec<Vec<f64>> = arts.iter().map(|a| a.test_yhat.clone()).collect();
    let (train_mses, train_accs): (Vec<f64>, Vec<f64>) =
        arts.iter().map(|a| a.full_train_quality.unwrap_or((0.0, 0.0))).unzip();
    let w = weights(rule, &train_mses, &train_accs)?;
    let yhat = if rule == CombineRule::Median {
        combine_median(&local_preds)?
    } else {
        combine_predictions(engine, &local_preds, &w)?
    };
    let metrics = compute(&yhat, &first.test_labels);
    Ok(CombineOutput {
        algorithm: algo,
        yhat,
        test_metrics: metrics,
        weights: w,
        comm: ledger.snapshot(),
        fingerprint: first.fingerprint,
        tokens_sampled: arts.iter().map(|a| a.tokens_sampled).sum(),
    })
}

/// Load every `*.shrd` file in `dir`, sorted by file name.
pub fn load_artifact_dir(dir: &Path) -> anyhow::Result<Vec<ShardArtifact>> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading artifact dir {dir:?}: {e}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "shrd"))
        .collect();
    paths.sort();
    anyhow::ensure!(!paths.is_empty(), "no .shrd artifacts in {dir:?}");
    paths.iter().map(|p| ShardArtifact::load(p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::arena_file::write_arena;
    use crate::data::partition::train_test_split;
    use crate::data::synthetic::{generate_corpus, SyntheticSpec};
    use crate::parallel::leader::run_with_engine;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_mp_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn shard_spec_parses() {
        assert_eq!(ShardSpec::parse("0/4").unwrap(), ShardSpec { shard: 0, m: 4 });
        assert_eq!(ShardSpec::parse("3/4").unwrap(), ShardSpec { shard: 3, m: 4 });
        assert!(ShardSpec::parse("4/4").is_err());
        assert!(ShardSpec::parse("1/0").is_err());
        assert!(ShardSpec::parse("nope").is_err());
        assert!(ShardSpec::parse("1-4").is_err());
    }

    #[test]
    fn plan_matches_in_process_partition() {
        let mut cfg = ExperimentConfig::quick();
        cfg.seed = 77;
        let (n_docs, n_train, m) = (40usize, 30usize, 3usize);
        let plan = plan_run(&cfg, n_docs, n_train, m);
        assert_eq!(plan.train_ids.len(), n_train);
        assert_eq!(plan.test_ids.len(), n_docs - n_train);
        assert_eq!(plan.shards.len(), m);
        assert_eq!(plan.worker_rngs.len(), m);
        // replay the in-process draws by hand and compare
        let mut split_rng = Pcg64::seed_from_u64(cfg.seed ^ 0x5911_7001);
        let (want_train, want_test) = split_indices(n_docs, n_train, &mut split_rng);
        assert_eq!(plan.train_ids, want_train);
        assert_eq!(plan.test_ids, want_test);
        let mut rng = Pcg64::seed_from_u64(cfg.seed);
        let want_shards = random_shards(n_train, m, &mut rng);
        assert_eq!(plan.shards, want_shards);
        // shard_arena_ids composes partition positions with the selection
        let ids = plan.shard_arena_ids(1);
        assert_eq!(ids.len(), plan.shards[1].len());
        for (k, &id) in ids.iter().enumerate() {
            assert_eq!(id, plan.train_ids[plan.shards[1][k]]);
        }
    }

    /// The tentpole's acceptance test: `train-shard`×M through persisted
    /// artifacts + `combine` must be byte-identical to the in-process
    /// `run_with_engine` — same yhat bits, same weights — and the ledger
    /// must show zero setup bytes copied with the mapped file as the only
    /// referenced traffic.
    #[test]
    fn multiproc_is_byte_identical_to_in_process() {
        let mut spec = SyntheticSpec::continuous_small();
        spec.docs = 48;
        let mut cfg = ExperimentConfig::quick();
        cfg.seed = 4242;
        cfg.parallel.shards = 3;
        cfg.parallel.threads = 2;
        let m = cfg.parallel.shards;
        let corpus = generate_corpus(&spec, &mut Pcg64::seed_from_u64(cfg.seed));
        let n_train = corpus.num_docs() * 3 / 4;
        let engine = EngineHandle::native();

        // in-process reference, replaying exactly what `cfslda run` does
        let mut split_rng = Pcg64::seed_from_u64(cfg.seed ^ 0x5911_7001);
        let ds = train_test_split(&corpus, n_train, &mut split_rng);

        for algo in [Algorithm::WeightedAverage, Algorithm::MedianAverage] {
            let (want, _) = run_with_engine(algo, &ds, &cfg, &engine, false).unwrap();

            let arena_path = tmp(&format!("ident_{}.arena", algo.name()));
            write_arena(&corpus, &arena_path).unwrap();
            let arena = ArenaMap::open(&arena_path).unwrap();

            let mut artifacts = Vec::new();
            for j in 0..m {
                let out = tmp(&ShardArtifact::file_name(j as u32, m as u32));
                let outcome = run_train_shard(TrainShardJob {
                    arena: &arena,
                    cfg: &cfg,
                    engine: &engine,
                    algo,
                    spec: ShardSpec { shard: j, m },
                    n_train,
                    out: out.clone(),
                    resume: false,
                    stop: None,
                })
                .unwrap();
                let comm = match outcome {
                    ShardRunOutcome::Done { comm, .. } => comm,
                    ShardRunOutcome::Interrupted { .. } => panic!("no stop flag set"),
                };
                // out-of-core setup: zero copied, the mapping referenced
                assert_eq!(comm.setup_copied_bytes, 0);
                assert_eq!(comm.setup_referenced_bytes, arena.mapped_len() as u64);
                assert_eq!(comm.sampling_syncs, 0);
                // reload through disk — the artifact codec is in the loop
                artifacts.push(ShardArtifact::load(&out).unwrap());
                std::fs::remove_file(&out).ok();
            }

            let got = combine_artifacts(&engine, &artifacts).unwrap();
            assert_eq!(got.algorithm, algo);
            assert_eq!(got.yhat.len(), want.yhat.len());
            let bits_equal = got
                .yhat
                .iter()
                .zip(&want.yhat)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(bits_equal, "{}: multiproc yhat differs from in-process", algo.name());
            assert_eq!(got.weights, want.weights.clone().unwrap());
            assert_eq!(got.test_metrics.mse, want.test_metrics.mse);
            // gather = model shards + local predictions (+ quality pairs)
            let extra = if algo == Algorithm::WeightedAverage { 16 } else { 0 };
            let per_worker = model_bytes(cfg.model.topics, corpus.vocab_size)
                + predictions_bytes(ds.test.num_docs())
                + extra;
            assert_eq!(got.comm.gather_bytes, per_worker * m as u64);
            assert_eq!(got.comm.setup_copied_bytes, 0);

            drop(arena);
            std::fs::remove_file(&arena_path).ok();
        }
    }

    #[test]
    fn combine_refuses_inconsistent_artifact_sets() {
        use crate::combine::artifact::tests::sample;
        let engine = EngineHandle::native();
        // incomplete set
        let err = combine_artifacts(&engine, &[sample(1, 0, 2)]).unwrap_err().to_string();
        assert!(err.contains("M=2"), "{err}");
        // mixed fingerprints
        let a = sample(1, 0, 2);
        let b = sample(2, 1, 2); // different seed → different fingerprint
        let err = combine_artifacts(&engine, &[a.clone(), b]).unwrap_err().to_string();
        assert!(err.contains("different run"), "{err}");
        // duplicate shard ids
        let err =
            combine_artifacts(&engine, &[a.clone(), a.clone()]).unwrap_err().to_string();
        assert!(err.contains("incomplete"), "{err}");
        // disagreeing labels
        let mut c = sample(1, 1, 2);
        c.test_labels[0] += 1.0;
        let err = combine_artifacts(&engine, &[a, c]).unwrap_err().to_string();
        assert!(err.contains("labels"), "{err}");
    }

    #[test]
    fn train_shard_rejects_non_combinable_algorithms() {
        let cfg = ExperimentConfig::quick();
        assert!(rule_for(Algorithm::NonParallel, &cfg).is_err());
        assert!(rule_for(Algorithm::NaiveCombination, &cfg).is_err());
        assert!(rule_for(Algorithm::SimpleAverage, &cfg).is_ok());
    }
}
