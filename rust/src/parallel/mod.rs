//! The communication-free parallel runtime (paper §III-C).
//!
//! * [`comm`] — a byte-level communication ledger. Embarrassingly parallel
//!   MCMC's selling point is *zero* inter-worker traffic during sampling;
//!   the ledger records exactly what moves (shard setup, final gather) and
//!   asserts nothing moves in between.
//! * [`worker`] — one shard's workload: local training, plus local
//!   prediction of the test set (Simple/Weighted) and of the full training
//!   set (Weighted only, for the eq. 8 weights).
//! * [`leader`] — the coordinator: partitions, spawns workers on the thread
//!   pool, runs the combination stage, and reports metrics + timings per
//!   algorithm (NonParallel / NaiveCombination / SimpleAverage /
//!   WeightedAverage).
//! * [`multiproc`] — the same fan-out as separate OS processes over an
//!   mmapped `CFSARENA1` arena (`train-shard` / `combine`), byte-identical
//!   to the in-process run.

pub mod comm;
pub mod leader;
pub mod multiproc;
pub mod worker;
