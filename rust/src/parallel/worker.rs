//! One shard's workload, executed on its own thread with its own RNG
//! stream and **no shared mutable state** (communication-free by
//! construction — the ledger in `comm` audits the only two transfers).
//!
//! Since the token-arena refactor a worker receives [`CorpusView`]s: its
//! shard, the test set and (Weighted Average) the full training set are all
//! borrowed windows into the leader's arena — handing a worker its workload
//! copies doc indices and responses, never token arrays.

use crate::config::schema::ExperimentConfig;
use crate::data::corpus::CorpusView;
use crate::runtime::{EngineHandle, Prediction};
use crate::sampler::{gibbs_predict, gibbs_train};
use crate::util::rng::Pcg64;
use crate::util::timer::{CpuStopwatch, PhaseTimings};

/// What each worker must produce beyond its trained local model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerPlan {
    /// Predict the test set locally (Simple/Weighted Average).
    pub predict_test: bool,
    /// Predict the **whole training set** locally (Weighted Average: the
    /// eq. 8 weights). This is the step the paper identifies as making
    /// Weighted Average slower than Non-parallel.
    pub predict_full_train: bool,
}

/// Result of one shard's work.
pub struct WorkerOutput {
    pub shard_id: usize,
    pub train: gibbs_train::TrainOutput,
    /// Local test predictions yhat^(m) (if planned).
    pub test_pred: Option<Prediction>,
    /// Full-training-set prediction quality (if planned): (mse, acc).
    pub full_train_quality: Option<(f64, f64)>,
    pub timings: PhaseTimings,
}

/// [`run_worker_ckpt`]'s result: done, or cleanly stopped at a checkpoint
/// boundary (the shard's state is already persisted via the hook's sink).
pub enum WorkerRun {
    Done(Box<WorkerOutput>),
    Interrupted { shard_id: usize, next_sweep: u64 },
}

/// Run one shard: train on `shard_corpus`, then the planned predictions.
/// `full_train` is the complete training corpus (all shards' documents).
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    shard_id: usize,
    shard_corpus: CorpusView<'_>,
    test: CorpusView<'_>,
    full_train: CorpusView<'_>,
    plan: WorkerPlan,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    rng: Pcg64,
) -> anyhow::Result<WorkerOutput> {
    let run =
        run_worker_ckpt(shard_id, shard_corpus, test, full_train, plan, cfg, engine, rng, None)?;
    match run {
        WorkerRun::Done(out) => Ok(*out),
        WorkerRun::Interrupted { .. } => {
            anyhow::bail!("worker interrupted without a checkpoint hook")
        }
    }
}

/// [`run_worker`] with checkpoint/resume plumbing: the hook's resume state
/// seeds the chain, its sink receives boundary snapshots, and its stop flag
/// turns the worker into a clean [`WorkerRun::Interrupted`] exit. The
/// post-training predictions continue on the worker's RNG stream, so a
/// resumed worker's predictions are byte-identical to an uninterrupted
/// one's.
#[allow(clippy::too_many_arguments)]
pub fn run_worker_ckpt(
    shard_id: usize,
    shard_corpus: CorpusView<'_>,
    test: CorpusView<'_>,
    full_train: CorpusView<'_>,
    plan: WorkerPlan,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    mut rng: Pcg64,
    ckpt: Option<gibbs_train::CkptHook<'_>>,
) -> anyhow::Result<WorkerRun> {
    let mut timings = PhaseTimings::new();

    let sw = CpuStopwatch::new();
    let train = match gibbs_train::train_ckpt(shard_corpus, cfg, engine, &mut rng, ckpt)? {
        gibbs_train::TrainRun::Done(out) => *out,
        gibbs_train::TrainRun::Interrupted { next_sweep } => {
            return Ok(WorkerRun::Interrupted { shard_id, next_sweep });
        }
    };
    timings.add("train", sw.elapsed_secs());

    let test_pred = if plan.predict_test {
        let sw = CpuStopwatch::new();
        let (pred, _zbar) = gibbs_predict::predict_corpus_with_kernel(
            &train.model,
            test,
            &cfg.train,
            cfg.sampler.kernel,
            engine,
            None, // workers never see test labels
            &mut rng,
        )?;
        timings.add("predict_test", sw.elapsed_secs());
        Some(pred)
    } else {
        None
    };

    let full_train_quality = if plan.predict_full_train {
        let sw = CpuStopwatch::new();
        let ys = full_train.responses();
        let (pred, _zbar) = gibbs_predict::predict_corpus_with_kernel(
            &train.model,
            full_train,
            &cfg.train,
            cfg.sampler.kernel,
            engine,
            Some(&ys),
            &mut rng,
        )?;
        timings.add("predict_train", sw.elapsed_secs());
        Some((pred.mse, pred.acc))
    } else {
        None
    };

    Ok(WorkerRun::Done(Box::new(WorkerOutput {
        shard_id,
        train,
        test_pred,
        full_train_quality,
        timings,
    })))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Corpus;
    use crate::data::partition::{random_shards, shard_views};
    use crate::data::synthetic::{generate_split, SyntheticSpec};

    fn setup() -> (Corpus, Corpus, ExperimentConfig) {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = generate_split(&spec, 180, &mut rng);
        let mut cfg = ExperimentConfig::quick();
        cfg.train.sweeps = 12;
        cfg.train.burnin = 3;
        cfg.train.eta_every = 3;
        (ds.train, ds.test, cfg)
    }

    #[test]
    fn training_only_plan() {
        let (train, test, cfg) = setup();
        let engine = EngineHandle::native();
        let out = run_worker(
            0,
            train.view(),
            test.view(),
            train.view(),
            WorkerPlan { predict_test: false, predict_full_train: false },
            &cfg,
            &engine,
            Pcg64::seed_from_u64(2),
        )
        .unwrap();
        assert!(out.test_pred.is_none());
        assert!(out.full_train_quality.is_none());
        assert!(out.timings.get("train") > 0.0);
        assert_eq!(out.timings.get("predict_test"), 0.0);
    }

    #[test]
    fn full_plan_on_a_shard() {
        let (train, test, cfg) = setup();
        let mut rng = Pcg64::seed_from_u64(3);
        let shards = random_shards(train.num_docs(), 4, &mut rng);
        let views = shard_views(&train, &shards);
        let engine = EngineHandle::native();
        let out = run_worker(
            2,
            views[2],
            test.view(),
            train.view(),
            WorkerPlan { predict_test: true, predict_full_train: true },
            &cfg,
            &engine,
            Pcg64::seed_from_u64(4),
        )
        .unwrap();
        assert_eq!(out.shard_id, 2);
        let tp = out.test_pred.unwrap();
        assert_eq!(tp.yhat.len(), test.num_docs());
        let (mse, acc) = out.full_train_quality.unwrap();
        assert!(mse.is_finite() && mse > 0.0);
        assert!((0.0..=1.0).contains(&acc));
        // Weighted's extra work must show up in the timing breakdown.
        assert!(out.timings.get("predict_train") > 0.0);
    }

    #[test]
    fn shard_view_training_matches_materialized_shard() {
        // A worker training on a zero-copy view must be draw-for-draw
        // identical to one training on the deep-copied sub-corpus.
        let (train, _test, cfg) = setup();
        let mut rng = Pcg64::seed_from_u64(7);
        let shards = random_shards(train.num_docs(), 4, &mut rng);
        let views = shard_views(&train, &shards);
        let sub = train.select(&shards[1]);
        let engine = EngineHandle::native();
        let a = gibbs_train::train(views[1], &cfg, &engine, &mut Pcg64::seed_from_u64(9))
            .unwrap();
        let b = gibbs_train::train(&sub, &cfg, &engine, &mut Pcg64::seed_from_u64(9))
            .unwrap();
        assert_eq!(a.z, b.z);
        assert_eq!(a.counts.ndt, b.counts.ndt);
        assert_eq!(a.model.eta, b.model.eta);
        assert_eq!(a.responses, b.responses);
    }
}
