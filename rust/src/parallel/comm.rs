//! Communication ledger: proves "communication-free".
//!
//! The paper's algorithms exchange data only at two points: shard **setup**
//! (the leader hands each worker its sub-corpus, plus the test set / full
//! training set when local predictions are required) and final **gather**
//! (each worker returns its model summary and local predictions). During
//! sampling there is exactly zero traffic. The ledger measures both in
//! bytes — so the experiment reports can show what an MPI/posterior-sharing
//! parallel sampler would have paid per sweep vs what this one pays total.

use crate::data::corpus::Corpus;
use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe byte counters for one parallel run.
#[derive(Debug, Default)]
pub struct CommLedger {
    setup_bytes: AtomicU64,
    gather_bytes: AtomicU64,
    /// Synchronization events during sampling (always 0 for this system;
    /// present so alternative baselines could be instrumented).
    sampling_syncs: AtomicU64,
}

/// Immutable snapshot for reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    pub setup_bytes: u64,
    pub gather_bytes: u64,
    pub sampling_syncs: u64,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_setup(&self, bytes: u64) {
        self.setup_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_gather(&self, bytes: u64) {
        self.gather_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_sampling_sync(&self) {
        self.sampling_syncs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CommStats {
        CommStats {
            setup_bytes: self.setup_bytes.load(Ordering::Relaxed),
            gather_bytes: self.gather_bytes.load(Ordering::Relaxed),
            sampling_syncs: self.sampling_syncs.load(Ordering::Relaxed),
        }
    }
}

/// Wire size of a corpus: token ids (u32) + one response (f64) + one length
/// (u32) per document.
pub fn corpus_bytes(c: &Corpus) -> u64 {
    (c.num_tokens() * 4 + c.num_docs() * 12) as u64
}

/// Wire size of a trained local model summary: eta (f64 x T) + phi
/// (f32 x W x T) + scalars.
pub fn model_bytes(t: usize, w: usize) -> u64 {
    (t * 8 + w * t * 4 + 32) as u64
}

/// Wire size of a prediction vector.
pub fn predictions_bytes(n: usize) -> u64 {
    (n * 8) as u64
}

impl CommStats {
    pub fn total(&self) -> u64 {
        self.setup_bytes + self.gather_bytes
    }

    pub fn render(&self) -> String {
        format!(
            "setup={:.2}MB gather={:.2}MB sampling_syncs={}",
            self.setup_bytes as f64 / 1e6,
            self.gather_bytes as f64 / 1e6,
            self.sampling_syncs
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Document;

    #[test]
    fn ledger_accumulates_across_threads() {
        let ledger = CommLedger::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    ledger.add_setup(100);
                    ledger.add_gather(10);
                });
            }
        });
        let st = ledger.snapshot();
        assert_eq!(st.setup_bytes, 800);
        assert_eq!(st.gather_bytes, 80);
        assert_eq!(st.sampling_syncs, 0);
        assert_eq!(st.total(), 880);
    }

    #[test]
    fn corpus_bytes_formula() {
        let c = Corpus::new(
            vec![
                Document { tokens: vec![0, 1, 2], response: 0.0 },
                Document { tokens: vec![3], response: 1.0 },
            ],
            4,
        );
        assert_eq!(corpus_bytes(&c), (4 * 4 + 2 * 12) as u64);
    }

    #[test]
    fn model_and_pred_bytes() {
        assert_eq!(model_bytes(8, 100), (8 * 8 + 100 * 8 * 4 + 32) as u64);
        assert_eq!(predictions_bytes(10), 80);
    }

    #[test]
    fn render_contains_sync_count() {
        let ledger = CommLedger::new();
        ledger.add_sampling_sync();
        assert!(ledger.snapshot().render().contains("sampling_syncs=1"));
    }
}
