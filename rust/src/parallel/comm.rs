//! Communication ledger: proves "communication-free".
//!
//! The paper's algorithms exchange data only at two points: shard **setup**
//! (the leader hands each worker its sub-corpus, plus the test set / full
//! training set when local predictions are required) and final **gather**
//! (each worker returns its model summary and local predictions). During
//! sampling there is exactly zero traffic.
//!
//! Since the token-arena refactor (DESIGN.md §Memory layout) the setup step
//! is priced in two currencies:
//!
//! * **copied bytes** — data physically duplicated per worker. With
//!   [`crate::data::corpus::CorpusView`] shard handoff this is only the
//!   shard's doc-index list plus the per-document responses/labels the
//!   worker materializes — never token arrays.
//! * **referenced bytes** — data a worker reads through the shared arena
//!   by reference. This is what an MPI deployment *would* ship at setup
//!   (and what the legacy deep-copy `select` path used to duplicate), so
//!   experiment reports can still quote the paper's wire-transfer totals.

use crate::data::corpus::{Corpus, CorpusView};
use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe byte counters for one parallel run.
#[derive(Debug, Default)]
pub struct CommLedger {
    setup_copied_bytes: AtomicU64,
    setup_referenced_bytes: AtomicU64,
    gather_bytes: AtomicU64,
    /// Synchronization events during sampling (always 0 for this system;
    /// present so alternative baselines could be instrumented).
    sampling_syncs: AtomicU64,
}

/// Immutable snapshot for reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Setup bytes physically duplicated per worker (doc-index lists +
    /// responses/labels; ~0 relative to token data on the view path).
    pub setup_copied_bytes: u64,
    /// Setup bytes shared by reference through the token arena (the wire
    /// cost a distributed deployment would pay).
    pub setup_referenced_bytes: u64,
    pub gather_bytes: u64,
    pub sampling_syncs: u64,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_setup_copied(&self, bytes: u64) {
        self.setup_copied_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_setup_referenced(&self, bytes: u64) {
        self.setup_referenced_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record one view handoff: its copied and referenced costs at once.
    pub fn add_setup_view(&self, view: &CorpusView<'_>) {
        let (copied, referenced) = view_setup_bytes(view);
        self.add_setup_copied(copied);
        self.add_setup_referenced(referenced);
    }

    pub fn add_gather(&self, bytes: u64) {
        self.gather_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn add_sampling_sync(&self) {
        self.sampling_syncs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CommStats {
        CommStats {
            setup_copied_bytes: self.setup_copied_bytes.load(Ordering::Relaxed),
            setup_referenced_bytes: self.setup_referenced_bytes.load(Ordering::Relaxed),
            gather_bytes: self.gather_bytes.load(Ordering::Relaxed),
            sampling_syncs: self.sampling_syncs.load(Ordering::Relaxed),
        }
    }
}

/// Wire size of a corpus: token ids (u32) + one response (f64) + one length
/// (u32) per document.
pub fn corpus_bytes(c: &Corpus) -> u64 {
    (c.num_tokens() * 4 + c.num_docs() * 12) as u64
}

/// Setup cost of handing a worker one [`CorpusView`], split into
/// `(copied, referenced)` bytes.
///
/// * A **full** view is pure aliasing: nothing is copied, the whole corpus
///   wire size is referenced.
/// * A **shard** view copies its doc-index list (8 bytes per doc) plus the
///   responses the worker materializes (8 bytes per doc); the shard's token
///   arrays and lengths — the O(nnz) payload — are referenced only.
pub fn view_setup_bytes(v: &CorpusView<'_>) -> (u64, u64) {
    let referenced = (v.num_tokens() * 4 + v.num_docs() * 12) as u64;
    let copied = if v.is_full() { 0 } else { (v.num_docs() * 16) as u64 };
    (copied, referenced)
}

/// Setup cost of a multi-process worker mapping a `CFSARENA1` file
/// (`cfslda train-shard`): `(copied, referenced)` bytes.
///
/// Nothing is copied at all — not even doc-index lists, which live in the
/// worker's own address space and are derived, not shipped; the whole
/// mapped file is shared by reference through the page cache. This is the
/// out-of-core analogue of a full [`CorpusView`] handoff.
pub fn mmap_setup_bytes(mapped_len: usize) -> (u64, u64) {
    (0, mapped_len as u64)
}

/// Wire size of a trained local model summary: eta (f64 x T) + phi
/// (f32 x W x T) + scalars.
pub fn model_bytes(t: usize, w: usize) -> u64 {
    (t * 8 + w * t * 4 + 32) as u64
}

/// Wire size of a prediction vector.
pub fn predictions_bytes(n: usize) -> u64 {
    (n * 8) as u64
}

impl CommStats {
    /// Total setup volume (copied + referenced).
    pub fn setup_bytes(&self) -> u64 {
        self.setup_copied_bytes + self.setup_referenced_bytes
    }

    pub fn total(&self) -> u64 {
        self.setup_bytes() + self.gather_bytes
    }

    pub fn render(&self) -> String {
        format!(
            "setup[copied={:.1}KB ref={:.2}MB] gather={:.2}MB sampling_syncs={}",
            self.setup_copied_bytes as f64 / 1e3,
            self.setup_referenced_bytes as f64 / 1e6,
            self.gather_bytes as f64 / 1e6,
            self.sampling_syncs
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Document;

    #[test]
    fn ledger_accumulates_across_threads() {
        let ledger = CommLedger::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    ledger.add_setup_copied(25);
                    ledger.add_setup_referenced(75);
                    ledger.add_gather(10);
                });
            }
        });
        let st = ledger.snapshot();
        assert_eq!(st.setup_copied_bytes, 200);
        assert_eq!(st.setup_referenced_bytes, 600);
        assert_eq!(st.setup_bytes(), 800);
        assert_eq!(st.gather_bytes, 80);
        assert_eq!(st.sampling_syncs, 0);
        assert_eq!(st.total(), 880);
    }

    #[test]
    fn corpus_bytes_formula() {
        let c = Corpus::new(
            vec![
                Document { tokens: vec![0, 1, 2], response: 0.0 },
                Document { tokens: vec![3], response: 1.0 },
            ],
            4,
        );
        assert_eq!(corpus_bytes(&c), (4 * 4 + 2 * 12) as u64);
    }

    #[test]
    fn view_setup_is_zero_copy_for_full_and_index_only_for_shards() {
        let c = Corpus::new(
            vec![
                Document { tokens: vec![0, 1, 2], response: 0.0 },
                Document { tokens: vec![3], response: 1.0 },
            ],
            4,
        );
        let (copied, referenced) = view_setup_bytes(&c.view());
        assert_eq!(copied, 0, "full view must copy nothing");
        assert_eq!(referenced, corpus_bytes(&c));

        let ids = vec![1usize];
        let (copied, referenced) = view_setup_bytes(&c.view_of(&ids));
        assert_eq!(copied, 16, "shard view copies doc ids + responses only");
        assert_eq!(referenced, 16); // 1 token * 4B + 1 doc * 12B

        let ledger = CommLedger::new();
        ledger.add_setup_view(&c.view_of(&ids));
        let st = ledger.snapshot();
        assert_eq!(st.setup_copied_bytes, 16);
        assert_eq!(st.setup_referenced_bytes, 16);
    }

    #[test]
    fn model_and_pred_bytes() {
        assert_eq!(model_bytes(8, 100), (8 * 8 + 100 * 8 * 4 + 32) as u64);
        assert_eq!(predictions_bytes(10), 80);
    }

    #[test]
    fn mmap_setup_copies_nothing() {
        assert_eq!(mmap_setup_bytes(1 << 20), (0, 1 << 20));
    }

    #[test]
    fn render_contains_sync_count() {
        let ledger = CommLedger::new();
        ledger.add_sampling_sync();
        assert!(ledger.snapshot().render().contains("sampling_syncs=1"));
    }
}
