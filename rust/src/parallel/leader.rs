//! The leader: runs one of the paper's four algorithms end to end.
//!
//! * **NonParallel** — single-chain sLDA on the full training set (the
//!   paper's quality/time reference).
//! * **NaiveCombination** — the failing baseline: M independent chains,
//!   then pool the *sampled topics* as if one chain had produced them
//!   (word-topic counts summed, zbar rows concatenated), fit one eta by
//!   regression, estimate one pooled phi-hat, predict once. Quasi-ergodicity
//!   (topic-permutation misalignment across chains) blurs the pooled model.
//! * **SimpleAverage** — M chains, each predicts the test set locally; the
//!   leader averages the predictions (eq. 7).
//! * **WeightedAverage** — like SimpleAverage plus each worker predicts the
//!   *whole training set* to derive inverse-MSE / accuracy weights
//!   (eqs. 8-9), the step that makes it slower than NonParallel.

use crate::ckpt::{config_fingerprint, GenCoordinator, ShardState, StdFs, Store};
use crate::combine::rules::combine_median;
use crate::combine::{combine_predictions, weights, CombineRule, WeightScheme};
use crate::config::schema::{ExperimentConfig, ResponseKind};
use crate::config::validate::validate;
use crate::data::corpus::{CorpusView, Dataset};
use crate::data::partition::{random_shards, shard_views};
use crate::eval::metrics::{compute, Metrics};
use crate::model::counts::CountMatrices;
use crate::model::slda::SldaModel;
use crate::parallel::comm::{
    model_bytes, predictions_bytes, CommLedger, CommStats,
};
use crate::parallel::worker::{run_worker_ckpt, WorkerPlan, WorkerOutput, WorkerRun};
use crate::runtime::EngineHandle;
use crate::sampler::gibbs_train::CkptHook;
use crate::sampler::{gibbs_predict, gibbs_train};
use crate::util::pool::scoped_map;
use crate::util::rng::Pcg64;
use crate::util::timer::{CpuStopwatch, PhaseTimings, Stopwatch};
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Condvar, Mutex};

/// The four algorithms compared in the paper's Figures 6 and 7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    NonParallel,
    NaiveCombination,
    SimpleAverage,
    WeightedAverage,
    /// Extension beyond the paper: per-document *median* of the local
    /// predictions (robust combination in the spirit of the
    /// median-posterior work the paper cites as [5]).
    MedianAverage,
}

impl Algorithm {
    /// The paper's four algorithms (Figs. 6/7).
    pub const ALL: [Algorithm; 4] = [
        Algorithm::NonParallel,
        Algorithm::NaiveCombination,
        Algorithm::SimpleAverage,
        Algorithm::WeightedAverage,
    ];

    /// The paper's four plus the median-combination extension.
    pub const ALL_EXTENDED: [Algorithm; 5] = [
        Algorithm::NonParallel,
        Algorithm::NaiveCombination,
        Algorithm::SimpleAverage,
        Algorithm::WeightedAverage,
        Algorithm::MedianAverage,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::NonParallel => "non-parallel",
            Algorithm::NaiveCombination => "naive-combination",
            Algorithm::SimpleAverage => "simple-average",
            Algorithm::WeightedAverage => "weighted-average",
            Algorithm::MedianAverage => "median-average",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Algorithm> {
        Ok(match s {
            "non-parallel" | "nonparallel" => Algorithm::NonParallel,
            "naive-combination" | "naive" => Algorithm::NaiveCombination,
            "simple-average" | "simple" => Algorithm::SimpleAverage,
            "weighted-average" | "weighted" => Algorithm::WeightedAverage,
            "median-average" | "median" => Algorithm::MedianAverage,
            other => anyhow::bail!("unknown algorithm '{other}'"),
        })
    }
}

/// Per-shard summary carried into reports and diagnostics.
#[derive(Clone, Debug)]
pub struct ShardSummary {
    pub shard_id: usize,
    pub docs: usize,
    /// In-sample (fit) MSE of the shard's final eta.
    pub fit_mse: f64,
    pub fit_acc: f64,
    pub tokens_sampled: u64,
    pub eta: Vec<f64>,
}

/// Result of running one algorithm on one dataset.
#[derive(Clone, Debug)]
pub struct RunOutput {
    pub algorithm: Algorithm,
    /// Global test predictions yhat.
    pub yhat: Vec<f64>,
    /// Metrics against the test labels.
    pub test_metrics: Metrics,
    /// End-to-end wall-clock seconds on *this* machine. NOTE: the
    /// benchmark container exposes a single CPU core, so this clock cannot
    /// show parallel speedups — compare `sim_wall_secs`.
    pub wall_secs: f64,
    /// Simulated M-core wall time (DESIGN.md §3): max over workers of
    /// per-thread CPU time, plus the leader's sequential phases. On a
    /// machine with >= threads cores this converges to `wall_secs`; the
    /// paper's "computation time" comparisons use this clock.
    pub sim_wall_secs: f64,
    /// Aggregated phase breakdown (train / predict_test / predict_train /
    /// combine). For parallel algorithms, per-phase times are summed over
    /// workers (CPU time), while `wall_secs` reflects concurrency.
    pub timings: PhaseTimings,
    pub comm: CommStats,
    pub shards: Vec<ShardSummary>,
    /// Combination weights used (None for NonParallel / Naive).
    pub weights: Option<Vec<f64>>,
}

/// Trained models kept for diagnostics (`keep_models = true`).
pub type ShardModels = Vec<SldaModel>;

/// Checkpoint/resume controls for [`run_with_engine_ckpt`]. Checkpointing
/// itself is enabled by the config (`train.checkpoint_every > 0` plus a
/// `train.checkpoint_dir`); this plan only adds the run-level choices.
pub struct CkptPlan<'p> {
    /// Restore the newest valid generation and continue from it instead of
    /// starting fresh. Errors if no valid generation exists or the live
    /// config fingerprint differs from the checkpoint's.
    pub resume: bool,
    /// Cooperative stop flag, polled at checkpoint boundaries right after
    /// the snapshot lands (the CLI wires the SIGINT/SIGTERM flag here).
    pub stop: Option<&'p AtomicBool>,
}

/// Result of a checkpoint-aware run.
pub enum RunOutcome {
    Done(Box<(RunOutput, ShardModels)>),
    /// Stopped cleanly at a checkpoint boundary: every shard has persisted
    /// sweep `next_sweep` or later, so the newest *committed* generation —
    /// what `--resume` restores — is at most `next_sweep`.
    Interrupted { next_sweep: u64 },
}

/// Leader-side checkpoint machinery shared by every worker of one run: the
/// store (rooted at `dir/<algorithm>-seed<seed>`), the last-writer-commits
/// manifest coordinator, and the restored per-shard states when resuming.
struct CkptCtx<'c> {
    store: Store<'c>,
    coord: GenCoordinator,
    resume_states: Option<Vec<ShardState>>,
    stop: Option<&'c AtomicBool>,
}

impl CkptCtx<'_> {
    /// The per-worker snapshot sink: write the shard file atomically,
    /// report it to the coordinator, and commit the manifest if this write
    /// completed the generation.
    fn write(&self, state: ShardState) -> anyhow::Result<()> {
        let sw = Stopwatch::new();
        let generation = state.next_sweep;
        let entry = self.store.write_shard(generation, &state)?;
        let write_us = (sw.elapsed_secs() * 1e6) as u64;
        if let Some((manifest, total_us)) = self.coord.shard_done(generation, entry, write_us) {
            self.store.commit_manifest(generation, &manifest, total_us)?;
        }
        Ok(())
    }

    fn resume_state(&self, shard: usize) -> Option<ShardState> {
        self.resume_states.as_ref().map(|s| s[shard].clone())
    }

    fn hook_for<'h>(
        &self,
        shard: usize,
        sink: &'h (dyn Fn(ShardState) -> anyhow::Result<()> + Sync),
    ) -> CkptHook<'h>
    where
        Self: 'h,
    {
        CkptHook {
            shard_id: shard as u32,
            resume: self.resume_state(shard),
            sink: Some(sink),
            stop: self.stop,
        }
    }
}

/// The checkpoint store directory for one (algorithm, seed) run under the
/// configured checkpoint root. Seed is part of the path because it is part
/// of the chain: two seeds are two different runs.
pub fn checkpoint_store_dir(cfg: &ExperimentConfig, algo: Algorithm) -> PathBuf {
    Path::new(&cfg.train.checkpoint_dir).join(format!("{}-seed{}", algo.name(), cfg.seed))
}

/// Does the configured checkpoint root hold a *committed* generation for
/// this (algorithm, seed)? A cheap existence probe — no integrity or
/// fingerprint verification (resume does that). Multi-run drivers (the
/// `experiment` command) use it to resume only the legs that actually
/// persisted state and start the rest fresh.
pub fn has_checkpoint(cfg: &ExperimentConfig, algo: Algorithm) -> bool {
    if cfg.train.checkpoint_every == 0 || cfg.train.checkpoint_dir.is_empty() {
        return false;
    }
    let fs = StdFs;
    let store = Store::new(&fs, checkpoint_store_dir(cfg, algo));
    store.has_committed_generation().unwrap_or(false)
}

/// Convenience wrapper: build the engine from the config and run.
/// The artifacts directory defaults to `./artifacts` (override with the
/// `CFSLDA_ARTIFACTS` environment variable).
pub fn run_algorithm(
    algo: Algorithm,
    ds: &Dataset,
    cfg: &ExperimentConfig,
) -> anyhow::Result<RunOutput> {
    let dir = std::env::var("CFSLDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let engine = EngineHandle::from_kind(cfg.engine, Path::new(&dir))?;
    run_with_engine(algo, ds, cfg, &engine, false).map(|(out, _)| out)
}

/// Run one algorithm with an explicit engine. When `keep_models` is set the
/// per-shard local models (or the single full model for NonParallel) are
/// returned for diagnostics (Hungarian topic alignment, fig-3).
pub fn run_with_engine(
    algo: Algorithm,
    ds: &Dataset,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    keep_models: bool,
) -> anyhow::Result<(RunOutput, ShardModels)> {
    match run_with_engine_ckpt(algo, ds, cfg, engine, keep_models, None)? {
        RunOutcome::Done(both) => Ok(*both),
        // unreachable: without a plan there is no stop flag to interrupt on
        RunOutcome::Interrupted { .. } => {
            anyhow::bail!("run interrupted without a checkpoint plan")
        }
    }
}

/// [`run_with_engine`] with checkpoint/resume. When the config enables
/// checkpointing, every shard chain snapshots into
/// `<checkpoint_dir>/<algorithm>-seed<seed>/` on the configured cadence;
/// `plan.resume` restores the newest committed generation (hard error on a
/// config-fingerprint mismatch) and `plan.stop` turns the run into a clean
/// [`RunOutcome::Interrupted`] at the next boundary. A resumed run is
/// byte-identical to the same run left uninterrupted.
pub fn run_with_engine_ckpt(
    algo: Algorithm,
    ds: &Dataset,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    keep_models: bool,
    plan: Option<CkptPlan<'_>>,
) -> anyhow::Result<RunOutcome> {
    validate(cfg)?;
    ds.train.validate()?;
    ds.test.validate()?;
    anyhow::ensure!(
        ds.train.vocab_size == ds.test.vocab_size,
        "train/test vocab mismatch"
    );
    let fs = StdFs;
    let enabled = cfg.train.checkpoint_every > 0 && !cfg.train.checkpoint_dir.is_empty();
    let ckpt: Option<CkptCtx<'_>> = match &plan {
        Some(p) if enabled => {
            let shards =
                if algo == Algorithm::NonParallel { 1 } else { cfg.parallel.shards };
            let fingerprint = config_fingerprint(
                cfg,
                ds.train.num_docs(),
                ds.train.num_tokens(),
                ds.train.vocab_size,
                algo.name(),
                shards,
            );
            let store = Store::new(&fs, checkpoint_store_dir(cfg, algo));
            let resume_states = if p.resume {
                let r = store.load_latest(fingerprint)?;
                anyhow::ensure!(
                    r.states.len() == shards,
                    "checkpoint generation {} holds {} shard states, run wants {shards}",
                    r.generation,
                    r.states.len()
                );
                log::info!(
                    "{}: resuming from checkpoint generation {} (sweep {} of {})",
                    algo.name(),
                    r.generation,
                    r.next_sweep,
                    cfg.train.sweeps
                );
                Some(r.states)
            } else {
                None
            };
            Some(CkptCtx {
                store,
                coord: GenCoordinator::new(shards, fingerprint),
                resume_states,
                stop: p.stop,
            })
        }
        Some(p) => {
            anyhow::ensure!(
                !p.resume,
                "--resume requested but checkpointing is disabled \
                 (set train.checkpoint_every and train.checkpoint_dir)"
            );
            None
        }
        None => None,
    };
    let ckpt = ckpt.as_ref();
    let total = Stopwatch::new();
    // Periodic structured progress line while the run is in flight
    // (`obs.heartbeat_secs > 0`); stops on drop at function exit.
    let _heartbeat = Heartbeat::start(cfg.obs.heartbeat_secs);
    let mut rng = Pcg64::seed_from_u64(cfg.seed);
    let test_labels = ds.test.responses();

    let outcome = match algo {
        Algorithm::NonParallel => {
            let mut timings = PhaseTimings::new();
            let sw = CpuStopwatch::new();
            let train = {
                let sink;
                let hook = match ckpt {
                    Some(c) => {
                        sink = move |s: ShardState| c.write(s);
                        Some(c.hook_for(0, &sink))
                    }
                    None => None,
                };
                match gibbs_train::train_ckpt(&ds.train, cfg, engine, &mut rng, hook)? {
                    gibbs_train::TrainRun::Done(out) => *out,
                    gibbs_train::TrainRun::Interrupted { next_sweep } => {
                        return Ok(interrupted(algo, next_sweep));
                    }
                }
            };
            timings.add("train", sw.elapsed_secs());
            let sw = CpuStopwatch::new();
            let (pred, _zbar) = gibbs_predict::predict_corpus_with_kernel(
                &train.model, &ds.test, &cfg.train, cfg.sampler.kernel, engine, None, &mut rng,
            )?;
            timings.add("predict_test", sw.elapsed_secs());
            let sim_wall = timings.get("train") + timings.get("predict_test");
            timings.merge(&train.timings);
            let m = compute(&pred.yhat, &test_labels);
            let shards = vec![ShardSummary {
                shard_id: 0,
                docs: ds.train.num_docs(),
                fit_mse: train.model.train_mse,
                fit_acc: train.model.train_acc,
                tokens_sampled: train.tokens_sampled,
                eta: train.model.eta.clone(),
            }];
            let models = if keep_models { vec![train.model] } else { vec![] };
            RunOutcome::Done(Box::new((
                RunOutput {
                    algorithm: algo,
                    yhat: pred.yhat,
                    test_metrics: m,
                    wall_secs: 0.0,
                    sim_wall_secs: sim_wall,
                    timings,
                    comm: CommStats::default(),
                    shards,
                    weights: None,
                },
                models,
            )))
        }
        Algorithm::NaiveCombination => run_naive(ds, cfg, engine, &mut rng, keep_models, ckpt)?,
        Algorithm::SimpleAverage => run_prediction_combining(
            ds, cfg, engine, &mut rng, CombineRule::Simple, keep_models, ckpt,
        )?,
        Algorithm::WeightedAverage => run_prediction_combining(
            ds,
            cfg,
            engine,
            &mut rng,
            CombineRule::Weighted(WeightScheme::for_response(cfg.response)),
            keep_models,
            ckpt,
        )?,
        Algorithm::MedianAverage => run_prediction_combining(
            ds, cfg, engine, &mut rng, CombineRule::Median, keep_models, ckpt,
        )?,
    };

    match outcome {
        RunOutcome::Done(mut both) => {
            both.0.wall_secs = total.elapsed_secs();
            let out = &both.0;
            log::info!(
                "{}: wall={:.2}s sim_wall={:.2}s {} comm[{}]",
                algo.name(),
                out.wall_secs,
                out.sim_wall_secs,
                out.test_metrics.render(cfg.response == ResponseKind::Binary),
                out.comm.render()
            );
            Ok(RunOutcome::Done(both))
        }
        RunOutcome::Interrupted { next_sweep } => Ok(interrupted(algo, next_sweep)),
    }
}

/// Log + construct a clean boundary interruption.
fn interrupted(algo: Algorithm, next_sweep: u64) -> RunOutcome {
    log::info!(
        "{}: stopped cleanly at checkpoint boundary (sweep {next_sweep}); \
         rerun with --resume to continue",
        algo.name()
    );
    RunOutcome::Interrupted { next_sweep }
}

/// [`parallel_train`]'s result: all workers done, or at least one stopped
/// cleanly at a checkpoint boundary.
enum ParallelRun {
    Done(Vec<WorkerOutput>),
    Interrupted { next_sweep: u64 },
}

/// Shared parallel training stage: partition, spawn workers, gather.
///
/// Shard handoff is **zero-copy** (DESIGN.md §Memory layout): each worker
/// receives [`CorpusView`]s into the leader's token arena — its shard, the
/// test set, and (Weighted Average) the full training set. The only bytes
/// physically duplicated per worker are the shard's doc-index list and the
/// responses it materializes; the ledger records that split.
///
/// With a [`CkptCtx`], each worker checkpoints its own chain through the
/// shared store (communication-free beyond the last-writer-commits
/// manifest) and resumes from its restored state.
fn parallel_train(
    ds: &Dataset,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    rng: &mut Pcg64,
    plan: WorkerPlan,
    ledger: &CommLedger,
    ckpt: Option<&CkptCtx<'_>>,
) -> anyhow::Result<ParallelRun> {
    let m = cfg.parallel.shards;
    // Shard-progress gauges (DESIGN.md §Observability): reset per run so a
    // scrape mid-training reads this run's fan-out, not a stale one.
    let telemetry = cfg.obs.train_telemetry;
    if telemetry {
        let tr = &crate::obs::registry().training;
        tr.shards_total.set(m as u64);
        tr.shards_done.set(0);
        for cell in tr.shard_tokens.iter().take(m.min(crate::obs::SHARD_SLOTS)) {
            cell.set(0);
        }
    }
    let shards = random_shards(ds.train.num_docs(), m, rng);
    let views = shard_views(&ds.train, &shards);
    // Per-shard deterministic RNG streams, derived before the fan-out.
    let jobs: Vec<(usize, CorpusView<'_>, Pcg64)> = views
        .into_iter()
        .enumerate()
        .map(|(i, v)| (i, v, rng.split(i as u64)))
        .collect();

    let test_view = ds.test.view();
    let full_train_view = ds.train.view();
    for (_, v, _) in &jobs {
        ledger.add_setup_view(v);
        if plan.predict_test {
            ledger.add_setup_view(&test_view);
        }
        if plan.predict_full_train {
            ledger.add_setup_view(&full_train_view);
            // The full-train pass materializes every training label in the
            // worker (`CorpusView::responses`): a real per-worker copy the
            // full-view pricing (copied = 0) does not include.
            ledger.add_setup_copied(predictions_bytes(ds.train.num_docs()));
        }
    }

    let results = scoped_map(&jobs, cfg.parallel.threads.max(1), |_, (i, v, worker_rng)| {
        let sink;
        let hook = match ckpt {
            Some(c) => {
                sink = move |s: ShardState| c.write(s);
                Some(c.hook_for(*i, &sink))
            }
            None => None,
        };
        let out = run_worker_ckpt(
            *i,
            *v,
            test_view,
            full_train_view,
            plan,
            cfg,
            engine,
            worker_rng.clone(),
            hook,
        );
        if telemetry {
            if let Ok(WorkerRun::Done(o)) = &out {
                let tr = &crate::obs::registry().training;
                tr.shards_done.add(1);
                if *i < crate::obs::SHARD_SLOTS {
                    tr.shard_tokens[*i].set(o.train.tokens_sampled);
                }
            }
        }
        out
    });
    let runs: anyhow::Result<Vec<WorkerRun>> = results.into_iter().collect();
    let runs = runs?;
    // Any shard stopped at a boundary ends the whole run cleanly; shards
    // drift through boundaries independently, so report the earliest stop
    // (the newest *committed* generation is at most that sweep).
    let stopped = runs
        .iter()
        .filter_map(|r| match r {
            WorkerRun::Interrupted { next_sweep, .. } => Some(*next_sweep),
            WorkerRun::Done(_) => None,
        })
        .min();
    if let Some(next_sweep) = stopped {
        return Ok(ParallelRun::Interrupted { next_sweep });
    }
    let outputs: Vec<WorkerOutput> = runs
        .into_iter()
        .map(|r| match r {
            WorkerRun::Done(o) => *o,
            WorkerRun::Interrupted { .. } => unreachable!("handled above"),
        })
        .collect();

    let mut gathered_model_bytes = 0u64;
    let mut gathered_pred_bytes = 0u64;
    for o in &outputs {
        let mb = model_bytes(o.train.model.t, o.train.model.w);
        gathered_model_bytes += mb;
        let mut gather = mb;
        if o.test_pred.is_some() {
            let pb = predictions_bytes(ds.test.num_docs());
            gathered_pred_bytes += pb;
            gather += pb;
        }
        if o.full_train_quality.is_some() {
            gather += 16; // (mse, acc) pair
        }
        ledger.add_gather(gather);
    }
    if telemetry {
        let snap = ledger.snapshot();
        let tr = &crate::obs::registry().training;
        tr.comm_setup_bytes.set(snap.setup_copied_bytes);
        tr.comm_corpus_bytes.set(snap.setup_referenced_bytes);
        tr.comm_model_bytes.set(gathered_model_bytes);
        tr.comm_predictions_bytes.set(gathered_pred_bytes);
    }
    Ok(ParallelRun::Done(outputs))
}

/// Background thread that logs one structured JSON progress line every
/// `interval_secs` while a [`run_with_engine`] call is in flight, read
/// straight off the global training registry (relaxed atomic loads — the
/// samplers never block on it). The line is `info`-level and
/// machine-parseable:
///
/// ```json
/// {"heartbeat":{"elapsed_secs":1.503,"sweeps":40,"tokens":812000,
///  "tokens_per_sec":540000,"shards_done":2,"shards_total":4,
///  "comm_setup_bytes":2880,"comm_corpus_bytes":1048576}}
/// ```
///
/// Stops promptly on drop (condvar-signalled, no full-interval lag).
struct Heartbeat {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeat {
    fn start(interval_secs: f64) -> Option<Heartbeat> {
        if interval_secs <= 0.0 || !interval_secs.is_finite() {
            return None;
        }
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let shared = Arc::clone(&stop);
        let interval = std::time::Duration::from_secs_f64(interval_secs);
        let t0 = std::time::Instant::now();
        let handle = std::thread::Builder::new()
            .name("cfslda-heartbeat".into())
            .spawn(move || {
                let (lock, cv) = &*shared;
                let mut stopped = lock.lock().unwrap();
                loop {
                    let (guard, timeout) = cv.wait_timeout(stopped, interval).unwrap();
                    stopped = guard;
                    if *stopped {
                        return;
                    }
                    if timeout.timed_out() {
                        let tr = &crate::obs::registry().training;
                        log::info!(
                            "{{\"heartbeat\":{{\"elapsed_secs\":{:.3},\"sweeps\":{},\
                             \"tokens\":{},\"tokens_per_sec\":{},\"shards_done\":{},\
                             \"shards_total\":{},\"comm_setup_bytes\":{},\
                             \"comm_corpus_bytes\":{}}}}}",
                            t0.elapsed().as_secs_f64(),
                            tr.sweeps.get(),
                            tr.tokens.get(),
                            tr.tokens_per_sec.get(),
                            tr.shards_done.get(),
                            tr.shards_total.get(),
                            tr.comm_setup_bytes.get(),
                            tr.comm_corpus_bytes.get(),
                        );
                    }
                }
            })
            .ok()?;
        Some(Heartbeat { stop, handle: Some(handle) })
    }
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        let (lock, cv) = &*self.stop;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn summaries(outputs: &[WorkerOutput]) -> Vec<ShardSummary> {
    outputs
        .iter()
        .map(|o| ShardSummary {
            shard_id: o.shard_id,
            docs: o.train.counts.d,
            fit_mse: o.train.model.train_mse,
            fit_acc: o.train.model.train_acc,
            tokens_sampled: o.train.tokens_sampled,
            eta: o.train.model.eta.clone(),
        })
        .collect()
}

/// Max over workers of per-thread CPU time: the parallel stage's wall
/// time on a machine with one core per worker (DESIGN.md §3).
fn max_worker_cpu(outputs: &[WorkerOutput]) -> f64 {
    outputs.iter().map(|o| o.timings.total()).fold(0.0, f64::max)
}

fn merged_timings(outputs: &[WorkerOutput]) -> PhaseTimings {
    let mut t = PhaseTimings::new();
    for o in outputs {
        t.merge(&o.timings);
        t.merge(&o.train.timings);
    }
    t
}

/// Simple/Weighted Average: combine local *predictions* (the paper's fix).
#[allow(clippy::too_many_arguments)]
fn run_prediction_combining(
    ds: &Dataset,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    rng: &mut Pcg64,
    rule: CombineRule,
    keep_models: bool,
    ckpt: Option<&CkptCtx<'_>>,
) -> anyhow::Result<RunOutcome> {
    let ledger = CommLedger::new();
    let plan = WorkerPlan {
        predict_test: true,
        predict_full_train: matches!(
            rule,
            CombineRule::Weighted(WeightScheme::InverseMse)
                | CombineRule::Weighted(WeightScheme::Accuracy)
        ),
    };
    let outputs = match parallel_train(ds, cfg, engine, rng, plan, &ledger, ckpt)? {
        ParallelRun::Done(outputs) => outputs,
        ParallelRun::Interrupted { next_sweep } => {
            return Ok(RunOutcome::Interrupted { next_sweep });
        }
    };

    let mut timings = merged_timings(&outputs);
    let sw = CpuStopwatch::new();
    let local_preds: Vec<Vec<f64>> = outputs
        .iter()
        .map(|o| o.test_pred.as_ref().expect("planned test prediction").yhat.clone())
        .collect();
    let (train_mses, train_accs): (Vec<f64>, Vec<f64>) = outputs
        .iter()
        .map(|o| o.full_train_quality.unwrap_or((0.0, 0.0)))
        .unzip();
    let w = weights(rule, &train_mses, &train_accs)?;
    let yhat = if rule == CombineRule::Median {
        combine_median(&local_preds)?
    } else {
        combine_predictions(engine, &local_preds, &w)?
    };
    let combine_cpu = sw.elapsed_secs();
    timings.add("combine", combine_cpu);
    let sim_wall = max_worker_cpu(&outputs) + combine_cpu;

    let test_labels = ds.test.responses();
    let m = compute(&yhat, &test_labels);
    let algo = match rule {
        CombineRule::Simple => Algorithm::SimpleAverage,
        CombineRule::Weighted(_) => Algorithm::WeightedAverage,
        CombineRule::Median => Algorithm::MedianAverage,
    };
    let models = if keep_models {
        outputs.iter().map(|o| o.train.model.clone()).collect()
    } else {
        vec![]
    };
    Ok(RunOutcome::Done(Box::new((
        RunOutput {
            algorithm: algo,
            yhat,
            test_metrics: m,
            wall_secs: 0.0,
            sim_wall_secs: sim_wall,
            timings,
            comm: ledger.snapshot(),
            shards: summaries(&outputs),
            weights: Some(w),
        },
        models,
    ))))
}

/// Naive Combination: pool sampled topics, fit one model, predict once.
fn run_naive(
    ds: &Dataset,
    cfg: &ExperimentConfig,
    engine: &EngineHandle,
    rng: &mut Pcg64,
    keep_models: bool,
    ckpt: Option<&CkptCtx<'_>>,
) -> anyhow::Result<RunOutcome> {
    let ledger = CommLedger::new();
    let plan = WorkerPlan { predict_test: false, predict_full_train: false };
    let outputs = match parallel_train(ds, cfg, engine, rng, plan, &ledger, ckpt)? {
        ParallelRun::Done(outputs) => outputs,
        ParallelRun::Interrupted { next_sweep } => {
            return Ok(RunOutcome::Interrupted { next_sweep });
        }
    };
    let mut timings = merged_timings(&outputs);

    let sw = CpuStopwatch::new();
    let t = cfg.model.topics;
    let w = ds.train.vocab_size;

    // Step 3: pool the sub-sampled topics "as if they were directly sampled
    // using all documents": word-topic mass summed, zbar rows concatenated.
    let mut pooled = CountMatrices::new(0, t, w);
    let mut zbar: Vec<f32> = Vec::with_capacity(ds.train.num_docs() * t);
    let mut ys: Vec<f64> = Vec::with_capacity(ds.train.num_docs());
    for o in &outputs {
        pooled.absorb_word_topic(&o.train.counts);
        zbar.extend(o.train.counts.zbar_matrix());
        ys.extend(o.train.responses.iter()); // same row order as zbar
    }

    // Step 3a: "ordinary linear regression" on the pooled topics — a ridge
    // solve with negligible shrinkage for numerical stability.
    let (eta, fit_mse) = engine.eta_solve(&zbar, &ys, t, 1e-6, 0.0)?;
    // Step 3b: pooled phi-hat (eq. 3).
    let phi = SldaModel::phi_from_counts(&pooled, cfg.model.beta);
    let fit = engine.predict(&zbar, &eta, Some(&ys), t)?;
    let pooled_model = SldaModel {
        t,
        w,
        eta,
        phi,
        rho: cfg.model.rho,
        alpha: cfg.model.alpha,
        train_mse: fit_mse,
        train_acc: fit.acc,
    };
    let combine_cpu = sw.elapsed_secs();
    timings.add("combine", combine_cpu);

    // Step 4: ONE prediction pass with the pooled model (why Naive is the
    // fastest — and the least accurate — algorithm in Figs. 6/7).
    let sw = CpuStopwatch::new();
    let (pred, _zbar) = gibbs_predict::predict_corpus_with_kernel(
        &pooled_model, &ds.test, &cfg.train, cfg.sampler.kernel, engine, None, rng,
    )?;
    let predict_cpu = sw.elapsed_secs();
    timings.add("predict_test", predict_cpu);
    let sim_wall = max_worker_cpu(&outputs) + combine_cpu + predict_cpu;

    let test_labels = ds.test.responses();
    let m = compute(&pred.yhat, &test_labels);
    let models = if keep_models {
        let mut v: ShardModels = outputs.iter().map(|o| o.train.model.clone()).collect();
        v.push(pooled_model);
        v
    } else {
        vec![]
    };
    Ok(RunOutcome::Done(Box::new((
        RunOutput {
            algorithm: Algorithm::NaiveCombination,
            yhat: pred.yhat,
            test_metrics: m,
            wall_secs: 0.0,
            sim_wall_secs: sim_wall,
            timings,
            comm: ledger.snapshot(),
            shards: summaries(&outputs),
            weights: None,
        },
        models,
    ))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_split, SyntheticSpec};

    fn fixture() -> (Dataset, ExperimentConfig) {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(100);
        let ds = generate_split(&spec, 180, &mut rng);
        let mut cfg = ExperimentConfig::quick();
        cfg.engine = crate::config::schema::EngineKind::Native;
        cfg.train.sweeps = 15;
        cfg.train.burnin = 3;
        cfg.train.eta_every = 3;
        cfg.train.predict_sweeps = 8;
        cfg.train.predict_burnin = 2;
        cfg.parallel.shards = 4;
        cfg.parallel.threads = 4;
        (ds, cfg)
    }

    #[test]
    fn all_algorithms_run_and_report() {
        let (ds, cfg) = fixture();
        let engine = EngineHandle::native();
        for algo in Algorithm::ALL {
            let (out, _) = run_with_engine(algo, &ds, &cfg, &engine, false).unwrap();
            assert_eq!(out.algorithm, algo);
            assert_eq!(out.yhat.len(), ds.test.num_docs());
            assert!(out.wall_secs > 0.0);
            assert!(out.test_metrics.mse.is_finite());
            match algo {
                Algorithm::NonParallel => {
                    assert_eq!(out.shards.len(), 1);
                    assert_eq!(out.comm.total(), 0);
                    assert!(out.weights.is_none());
                }
                Algorithm::NaiveCombination => {
                    assert_eq!(out.shards.len(), 4);
                    assert!(out.comm.setup_referenced_bytes > 0);
                    assert!(out.weights.is_none());
                    // Zero-copy handoff: the only duplicated setup bytes
                    // are shard doc-index lists + responses (16 B/doc) —
                    // no token arrays.
                    assert_eq!(
                        out.comm.setup_copied_bytes,
                        (ds.train.num_docs() * 16) as u64
                    );
                    // Naive never ships the test set to workers.
                    let per_shard = out.comm.setup_referenced_bytes / 4;
                    assert!(per_shard < crate::parallel::comm::corpus_bytes(&ds.train));
                    // ...and the shard partition references exactly the
                    // training corpus, once.
                    assert_eq!(
                        out.comm.setup_referenced_bytes,
                        crate::parallel::comm::corpus_bytes(&ds.train)
                    );
                }
                Algorithm::SimpleAverage => {
                    let w = out.weights.as_ref().unwrap();
                    assert!(w.iter().all(|&x| x == 1.0));
                    assert!(out.timings.get("predict_test") > 0.0);
                    assert_eq!(out.timings.get("predict_train"), 0.0);
                }
                Algorithm::WeightedAverage => {
                    let w = out.weights.as_ref().unwrap();
                    assert_eq!(w.len(), 4);
                    assert!(w.iter().all(|&x| x > 0.0));
                    // the expensive full-train prediction must have happened
                    assert!(out.timings.get("predict_train") > 0.0);
                }
                Algorithm::MedianAverage => unreachable!("not in ALL"),
            }
            assert_eq!(out.comm.sampling_syncs, 0, "sampling must be communication-free");
        }
    }

    #[test]
    fn kernel_choice_does_not_change_results() {
        // dense and sparse kernels are draw-for-draw identical (under
        // resp_mode = exact — `auto` would give sparse its own supervised
        // MH chain), so a whole parallel run must produce byte-identical
        // predictions either way.
        let (ds, mut cfg) = fixture();
        let engine = EngineHandle::native();
        cfg.sampler.resp_mode = crate::config::schema::RespMode::Exact;
        cfg.sampler.kernel = crate::config::schema::KernelKind::Dense;
        let a = run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap().0;
        cfg.sampler.kernel = crate::config::schema::KernelKind::Sparse;
        let b = run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap().0;
        assert_eq!(a.yhat, b.yhat);
        assert_eq!(a.test_metrics, b.test_metrics);
    }

    #[test]
    fn runs_are_deterministic() {
        let (ds, cfg) = fixture();
        let engine = EngineHandle::native();
        let a = run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap().0;
        let b = run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap().0;
        assert_eq!(a.yhat, b.yhat);
        assert_eq!(a.test_metrics, b.test_metrics);
    }

    #[test]
    fn prediction_combining_beats_naive() {
        // The paper's headline quality claim (Figs. 6/7): Simple Average is
        // close to NonParallel while Naive Combination is clearly worse.
        let (ds, cfg) = fixture();
        let engine = EngineHandle::native();
        let simple =
            run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap().0;
        let naive =
            run_with_engine(Algorithm::NaiveCombination, &ds, &cfg, &engine, false).unwrap().0;
        assert!(
            naive.test_metrics.mse > simple.test_metrics.mse,
            "naive mse {} should exceed simple mse {}",
            naive.test_metrics.mse,
            simple.test_metrics.mse
        );
    }

    #[test]
    fn keep_models_returns_shard_models() {
        let (ds, cfg) = fixture();
        let engine = EngineHandle::native();
        let (_, models) =
            run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, true).unwrap();
        assert_eq!(models.len(), 4);
        // Naive additionally returns the pooled model last.
        let (_, models) =
            run_with_engine(Algorithm::NaiveCombination, &ds, &cfg, &engine, true).unwrap();
        assert_eq!(models.len(), 5);
    }

    #[test]
    fn median_average_runs_and_is_robust_in_form() {
        let (ds, cfg) = fixture();
        let engine = EngineHandle::native();
        let (out, _) =
            run_with_engine(Algorithm::MedianAverage, &ds, &cfg, &engine, false).unwrap();
        assert_eq!(out.algorithm, Algorithm::MedianAverage);
        assert_eq!(out.yhat.len(), ds.test.num_docs());
        assert!(out.test_metrics.mse.is_finite());
        // median needs no train-set prediction pass
        assert_eq!(out.timings.get("predict_train"), 0.0);
        // quality in the same league as simple average
        let (simple, _) =
            run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap();
        assert!(out.test_metrics.mse < 3.0 * simple.test_metrics.mse);
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in Algorithm::ALL_EXTENDED {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
        assert!(Algorithm::parse("bogus").is_err());
    }

    #[test]
    fn heartbeat_starts_ticks_and_stops() {
        // Off at 0 (and for non-finite garbage).
        assert!(Heartbeat::start(0.0).is_none());
        assert!(Heartbeat::start(f64::NAN).is_none());
        // On: must tick at least once and then stop promptly on drop.
        let hb = Heartbeat::start(0.01).expect("heartbeat thread");
        std::thread::sleep(std::time::Duration::from_millis(50));
        let sw = Stopwatch::new();
        drop(hb);
        assert!(sw.elapsed_secs() < 5.0, "drop must not hang on the interval");
    }

    #[test]
    fn parallel_run_populates_training_telemetry() {
        let (ds, cfg) = fixture();
        let engine = EngineHandle::native();
        let tr = &crate::obs::registry().training;
        let (sweeps0, tokens0) = (tr.sweeps.get(), tr.tokens.get());
        run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap();
        // Counters are global and monotonic (other tests may add too), so
        // assert movement, not absolute values.
        assert!(tr.sweeps.get() >= sweeps0 + (cfg.train.sweeps * 4) as u64);
        assert!(tr.tokens.get() > tokens0);
        assert!(tr.shards_total.get() > 0);
        assert!(tr.comm_corpus_bytes.get() > 0);
        assert!(tr.comm_model_bytes.get() > 0);

        // train_telemetry = false must still run clean end to end (other
        // tests mutate the global registry concurrently, so "counters
        // untouched" cannot be asserted race-free here).
        let mut quiet = cfg.clone();
        quiet.obs.train_telemetry = false;
        run_with_engine(Algorithm::NaiveCombination, &ds, &quiet, &engine, false).unwrap();
    }

    fn ckpt_fixture(name: &str) -> (Dataset, ExperimentConfig, std::path::PathBuf) {
        let (ds, mut cfg) = fixture();
        cfg.train.checkpoint_every = 5; // boundaries at sweeps 5, 10 (of 15)
        let mut dir = std::env::temp_dir();
        dir.push(format!("cfslda_leader_ckpt_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        cfg.train.checkpoint_dir = dir.to_string_lossy().into_owned();
        (ds, cfg, dir)
    }

    /// The full crash-safety contract at the leader level: interrupt a
    /// 4-shard parallel run at a boundary, resume in a "new process", and
    /// land byte-identical to the uninterrupted run.
    #[test]
    fn parallel_interrupt_and_resume_is_byte_identical() {
        let (ds, cfg, dir) = ckpt_fixture("resume");
        let engine = EngineHandle::native();

        // Uninterrupted reference. No plan → no disk writes, but the same
        // chain: the checkpoint cadence is chain-defining, hooks are not.
        let reference =
            run_with_engine(Algorithm::SimpleAverage, &ds, &cfg, &engine, false).unwrap().0;

        // Interrupted run: flag raised from the start, so every worker
        // snapshots sweep 5 and exits at its first boundary.
        let stop = AtomicBool::new(true);
        let plan = CkptPlan { resume: false, stop: Some(&stop) };
        match run_with_engine_ckpt(Algorithm::SimpleAverage, &ds, &cfg, &engine, false, Some(plan))
            .unwrap()
        {
            RunOutcome::Interrupted { next_sweep } => assert_eq!(next_sweep, 5),
            RunOutcome::Done(_) => panic!("stop flag must interrupt the run"),
        }
        let gen5 = dir.join(format!("simple-average-seed{}", cfg.seed)).join("gen-5");
        assert!(gen5.join("MANIFEST").exists(), "all shards landed → committed manifest");
        for shard in 0..4 {
            assert!(gen5.join(format!("shard-{shard}.ckpt")).exists());
        }

        // Resume and run to completion: bitwise-equal outputs.
        let plan = CkptPlan { resume: true, stop: None };
        let resumed = match run_with_engine_ckpt(
            Algorithm::SimpleAverage,
            &ds,
            &cfg,
            &engine,
            false,
            Some(plan),
        )
        .unwrap()
        {
            RunOutcome::Done(both) => both.0,
            RunOutcome::Interrupted { .. } => panic!("no stop flag on the resume leg"),
        };
        assert_eq!(reference.yhat, resumed.yhat, "combined predictions must be identical");
        assert_eq!(reference.test_metrics, resumed.test_metrics);
        assert_eq!(reference.weights, resumed.weights);
        for (a, b) in reference.shards.iter().zip(&resumed.shards) {
            assert_eq!(a.shard_id, b.shard_id);
            assert_eq!(a.eta, b.eta, "shard {} eta drifted across resume", a.shard_id);
            assert_eq!(a.fit_mse.to_bits(), b.fit_mse.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_rejects_wrong_config_missing_checkpoints_and_disabled_ckpt() {
        let (ds, cfg, dir) = ckpt_fixture("reject");
        let engine = EngineHandle::native();

        // No checkpoints on disk yet.
        let plan = CkptPlan { resume: true, stop: None };
        let err =
            run_with_engine_ckpt(Algorithm::NonParallel, &ds, &cfg, &engine, false, Some(plan))
                .unwrap_err()
                .to_string();
        assert!(err.contains("no checkpoint generations"), "{err}");

        // Interrupt once to create generation 5.
        let stop = AtomicBool::new(true);
        let plan = CkptPlan { resume: false, stop: Some(&stop) };
        match run_with_engine_ckpt(Algorithm::NonParallel, &ds, &cfg, &engine, false, Some(plan))
            .unwrap()
        {
            RunOutcome::Interrupted { next_sweep } => assert_eq!(next_sweep, 5),
            RunOutcome::Done(_) => panic!("stop flag must interrupt the run"),
        }

        // A config change (sweep budget) fingerprints differently: hard
        // error, never a silently different chain.
        let mut other = cfg.clone();
        other.train.sweeps += 5;
        let plan = CkptPlan { resume: true, stop: None };
        let err =
            run_with_engine_ckpt(Algorithm::NonParallel, &ds, &other, &engine, false, Some(plan))
                .unwrap_err()
                .to_string();
        assert!(err.contains("fingerprint"), "{err}");

        // Resume with checkpointing disabled in the config is refused.
        let mut off = cfg.clone();
        off.train.checkpoint_every = 0;
        off.train.checkpoint_dir.clear();
        let plan = CkptPlan { resume: true, stop: None };
        let err =
            run_with_engine_ckpt(Algorithm::NonParallel, &ds, &off, &engine, false, Some(plan))
                .unwrap_err()
                .to_string();
        assert!(err.contains("disabled"), "{err}");

        // The unmodified config still resumes to completion.
        let plan = CkptPlan { resume: true, stop: None };
        let resumed =
            run_with_engine_ckpt(Algorithm::NonParallel, &ds, &cfg, &engine, false, Some(plan))
                .unwrap();
        assert!(matches!(resumed, RunOutcome::Done(_)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mismatched_vocab_rejected() {
        let (ds, cfg) = fixture();
        let engine = EngineHandle::native();
        let mut bad = ds.clone();
        bad.test.vocab_size += 1;
        assert!(run_with_engine(Algorithm::NonParallel, &bad, &cfg, &engine, false).is_err());
    }
}
