//! Data pipeline: raw text → tokens → pruned vocabulary → bag-of-words
//! corpus with responses → train/test split → M-way shards.
//!
//! The paper's two corpora (SEC 10-K MD&A with EPS labels; IMDB reviews with
//! binary sentiment) are not redistributable, so `synthetic` generates
//! corpora from the sLDA generative process itself at the same scale — see
//! DESIGN.md §3 for the substitution argument. The text path (`tokenizer` +
//! `vocab` + `loader`) is fully functional for users with real corpora.

pub mod arena_file;
pub mod corpus;
pub mod loader;
pub mod partition;
pub mod stats;
pub mod synthetic;
pub mod tokenizer;
pub mod vocab;
