//! Out-of-core token arena: the `CFSARENA1` on-disk format and its
//! zero-copy mmap loader (DESIGN.md §Out-of-core).
//!
//! The file is the CSR corpus laid out verbatim, in the same
//! magic | little-endian body | trailing FNV-1a-64 framing family as
//! `model/persist` and `ckpt/format`:
//!
//! ```text
//! offset  size                 field
//! 0       16                   magic "CFSARENA1" + 7 NULs
//! 16      48                   header: n_docs u64 | n_tokens u64 | vocab u64
//!                              | off_doc_offsets u64 | off_tokens u64
//!                              | off_responses u64
//! 64      (n_docs+1)*4         doc_offsets  u32[]   (CSR prefix sums)
//! align8  n_tokens*4           tokens       u32[]
//! align8  n_docs*8             responses    f64[]
//! end-8   8                    FNV-1a-64 over bytes[16 .. len-8]
//! ```
//!
//! The section offsets are stored *and* recomputed: a file whose header
//! offsets disagree with the canonical layout is rejected, so the offsets
//! carry no authority an attacker could abuse — they exist to make the
//! format self-describing for external tools.
//!
//! Every section sits on an 8-byte boundary (the magic is padded to 16
//! bytes for the same reason), so a page-aligned mapping yields correctly
//! aligned `&[u32]` / `&[f64]` slices and [`ArenaMap`] can hand out the
//! ordinary [`CorpusView`] over mapped memory — no consumer downstream of
//! the view knows whether tokens live on the heap or in the page cache.
//!
//! **Hostile-input contract** (same as `ckpt/format`): the checksum is
//! verified *first*, then header plausibility ceilings, then section
//! bounds with checked arithmetic — every length is proven byte-backed
//! before any slice is taken, and [`parse`] never allocates. [`parse`]
//! itself assumes nothing about the buffer's alignment (it walks
//! `chunks_exact`), so in-memory property tests can mangle plain `Vec<u8>`
//! buffers; only [`ArenaMap`] performs the aligned zero-copy casts, which
//! its page-aligned mapping plus the 8-aligned section offsets make sound.

use super::corpus::{Corpus, CorpusView};
use anyhow::Context;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// 16-byte magic: the 9 format bytes padded with NULs to keep the header
/// (and therefore every section) 8-aligned.
pub const MAGIC: [u8; 16] = *b"CFSARENA1\0\0\0\0\0\0\0";

const HEADER_BYTES: usize = 48;
/// Smallest legal file: empty corpus (one doc_offset entry, no tokens,
/// no responses) = 16 + 48 + align8(4) + 0 + 0 + 8.
const MIN_LEN: usize = 16 + HEADER_BYTES + 8 + 8;

/// Plausibility ceiling on document count (shared with `ckpt/format`).
const MAX_D: u64 = 1 << 28;
/// Plausibility ceiling on vocabulary size.
const MAX_W: u64 = 1 << 28;

/// Incremental FNV-1a-64 — identical constants to
/// `crate::model::persist::fnv1a`, but streamable so the packer can hash a
/// multi-gigabyte token section while copying it instead of holding it in
/// RAM.
#[derive(Clone, Copy)]
pub(crate) struct Fnv1a(u64);

impl Fnv1a {
    pub(crate) fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    #[inline]
    pub(crate) fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        self.0 = h;
    }

    pub(crate) fn finish(self) -> u64 {
        self.0
    }
}

#[inline]
fn align8(x: u64) -> u64 {
    (x + 7) & !7
}

/// Validated section geometry of one `CFSARENA1` buffer. Offsets/lengths
/// are in bytes from the start of the buffer and are guaranteed in-bounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout {
    pub n_docs: usize,
    pub n_tokens: usize,
    pub vocab: usize,
    pub off_doc_offsets: usize,
    pub off_tokens: usize,
    pub off_responses: usize,
}

fn le_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())
}

/// Validate a `CFSARENA1` buffer end to end and return its [`Layout`].
///
/// Checksum first (corruption anywhere surfaces as one uniform error
/// before any structural field is trusted), then header plausibility,
/// then canonical-offset and bounds checks with checked arithmetic, then
/// the full [`Corpus::validate`] semantics (CSR monotonicity, no empty
/// documents, token ids within vocab, finite responses) — the checksum
/// already forces an O(N) scan, so full validation adds no asymptotic
/// cost. Never allocates; makes no alignment assumptions.
pub fn parse(bytes: &[u8]) -> anyhow::Result<Layout> {
    let len = bytes.len();
    anyhow::ensure!(len >= MIN_LEN, "arena file too short: {len} bytes < minimum {MIN_LEN}");
    anyhow::ensure!(bytes[..16] == MAGIC, "bad magic: not a CFSARENA1 file");
    let stored = le_u64(bytes, len - 8);
    let mut h = Fnv1a::new();
    h.update(&bytes[16..len - 8]);
    let computed = h.finish();
    anyhow::ensure!(
        stored == computed,
        "checksum mismatch: stored {stored:#018x}, computed {computed:#018x}"
    );

    let n_docs = le_u64(bytes, 16);
    let n_tokens = le_u64(bytes, 24);
    let vocab = le_u64(bytes, 32);
    let off_doc_offsets = le_u64(bytes, 40);
    let off_tokens = le_u64(bytes, 48);
    let off_responses = le_u64(bytes, 56);

    anyhow::ensure!(n_docs <= MAX_D, "implausible document count {n_docs} (max {MAX_D})");
    anyhow::ensure!(
        n_tokens <= u32::MAX as u64,
        "implausible token count {n_tokens} (u32 CSR offsets cap at {})",
        u32::MAX
    );
    anyhow::ensure!(vocab <= MAX_W, "implausible vocab size {vocab} (max {MAX_W})");

    // Canonical geometry, recomputed with checked arithmetic. n_docs and
    // n_tokens are already ceiling-bounded, so none of these can overflow
    // u64 — checked ops make that explicit rather than assumed.
    let doc_off_bytes = (n_docs + 1).checked_mul(4).context("doc_offsets size overflow")?;
    let want_off_tokens = align8(64u64.checked_add(doc_off_bytes).context("layout overflow")?);
    let tok_bytes = n_tokens.checked_mul(4).context("tokens size overflow")?;
    let want_off_responses =
        align8(want_off_tokens.checked_add(tok_bytes).context("layout overflow")?);
    let resp_bytes = n_docs.checked_mul(8).context("responses size overflow")?;
    let want_len = want_off_responses
        .checked_add(resp_bytes)
        .and_then(|x| x.checked_add(8))
        .context("layout overflow")?;
    anyhow::ensure!(
        off_doc_offsets == 64,
        "doc_offsets section at byte {off_doc_offsets}, canonical layout requires 64"
    );
    anyhow::ensure!(
        off_tokens == want_off_tokens,
        "tokens section at byte {off_tokens}, canonical layout requires {want_off_tokens}"
    );
    anyhow::ensure!(
        off_responses == want_off_responses,
        "responses section at byte {off_responses}, canonical layout requires \
         {want_off_responses}"
    );
    anyhow::ensure!(
        want_len == len as u64,
        "file is {len} bytes but the header describes {want_len}"
    );

    // Sections are now proven byte-backed; walk them without alignment
    // assumptions.
    let doc_off_sec = &bytes[64..64 + doc_off_bytes as usize];
    let mut prev: u32 = 0;
    for (d, ch) in doc_off_sec.chunks_exact(4).enumerate() {
        let off = u32::from_le_bytes(ch.try_into().unwrap());
        if d == 0 {
            anyhow::ensure!(off == 0, "doc_offsets must start with 0, got {off}");
        } else {
            anyhow::ensure!(
                off > prev,
                "document {} is empty or doc_offsets decrease at entry {d}",
                d - 1
            );
        }
        prev = off;
    }
    anyhow::ensure!(
        prev as u64 == n_tokens,
        "last doc offset {prev} != token count {n_tokens}"
    );

    let tok_sec = &bytes[want_off_tokens as usize..(want_off_tokens + tok_bytes) as usize];
    for (i, ch) in tok_sec.chunks_exact(4).enumerate() {
        let w = u32::from_le_bytes(ch.try_into().unwrap());
        anyhow::ensure!(
            (w as u64) < vocab,
            "token {i} has word id {w} >= vocab size {vocab}"
        );
    }

    let resp_sec =
        &bytes[want_off_responses as usize..(want_off_responses + resp_bytes) as usize];
    for (d, ch) in resp_sec.chunks_exact(8).enumerate() {
        let y = f64::from_le_bytes(ch.try_into().unwrap());
        anyhow::ensure!(y.is_finite(), "document {d} has non-finite response {y}");
    }

    Ok(Layout {
        n_docs: n_docs as usize,
        n_tokens: n_tokens as usize,
        vocab: vocab as usize,
        off_doc_offsets: 64,
        off_tokens: want_off_tokens as usize,
        off_responses: want_off_responses as usize,
    })
}

/// Serialize a corpus to an in-memory `CFSARENA1` image (the reference
/// encoder; [`ArenaWriter`] streams the identical bytes without holding
/// the corpus in RAM, and a test pins the two equal).
pub fn encode(corpus: &Corpus) -> anyhow::Result<Vec<u8>> {
    corpus.validate()?;
    let n_docs = corpus.num_docs() as u64;
    let n_tokens = corpus.num_tokens() as u64;
    anyhow::ensure!(n_docs <= MAX_D, "corpus has {n_docs} docs, format cap is {MAX_D}");
    anyhow::ensure!(
        (corpus.vocab_size as u64) <= MAX_W,
        "vocab size {} exceeds format cap {MAX_W}",
        corpus.vocab_size
    );
    let off_tokens = align8(64 + (n_docs + 1) * 4);
    let off_responses = align8(off_tokens + n_tokens * 4);
    let total = (off_responses + n_docs * 8 + 8) as usize;
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&n_docs.to_le_bytes());
    out.extend_from_slice(&n_tokens.to_le_bytes());
    out.extend_from_slice(&(corpus.vocab_size as u64).to_le_bytes());
    out.extend_from_slice(&64u64.to_le_bytes());
    out.extend_from_slice(&off_tokens.to_le_bytes());
    out.extend_from_slice(&off_responses.to_le_bytes());
    for &o in &corpus.doc_offsets {
        out.extend_from_slice(&o.to_le_bytes());
    }
    out.resize(off_tokens as usize, 0);
    for &t in &corpus.tokens {
        out.extend_from_slice(&t.to_le_bytes());
    }
    out.resize(off_responses as usize, 0);
    for &y in &corpus.responses {
        out.extend_from_slice(&y.to_le_bytes());
    }
    let mut h = Fnv1a::new();
    h.update(&out[16..]);
    out.extend_from_slice(&h.finish().to_le_bytes());
    debug_assert_eq!(out.len(), total);
    Ok(out)
}

/// Materialize a heap-owned [`Corpus`] from a `CFSARENA1` buffer (full
/// validation via [`parse`]). The training path maps instead
/// ([`ArenaMap`]); this is the copying fallback for tools and tests.
pub fn decode(bytes: &[u8]) -> anyhow::Result<Corpus> {
    let l = parse(bytes)?;
    let doc_offsets: Vec<u32> = bytes[l.off_doc_offsets..l.off_doc_offsets + (l.n_docs + 1) * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let tokens: Vec<u32> = bytes[l.off_tokens..l.off_tokens + l.n_tokens * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let responses: Vec<f64> = bytes[l.off_responses..l.off_responses + l.n_docs * 8]
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Corpus::from_parts(tokens, doc_offsets, responses, l.vocab)
}

/// Streaming `CFSARENA1` writer: documents are pushed one at a time, token
/// bytes spill to a side file as they arrive, and only the O(D)
/// doc_offsets/responses stay in memory — so packing a corpus bigger than
/// RAM works through constant memory. [`ArenaWriter::finish`] assembles
/// the final file (magic, header, sections, checksum) into `<out>.tmp` and
/// renames it into place atomically.
pub struct ArenaWriter {
    out: PathBuf,
    spill_path: PathBuf,
    spill: BufWriter<std::fs::File>,
    doc_offsets: Vec<u32>,
    responses: Vec<f64>,
    max_token: Option<u32>,
}

impl ArenaWriter {
    pub fn create(out: &Path) -> anyhow::Result<ArenaWriter> {
        let spill_path = PathBuf::from(format!("{}.spill", out.display()));
        let spill = BufWriter::new(
            std::fs::File::create(&spill_path)
                .with_context(|| format!("creating spill file {spill_path:?}"))?,
        );
        Ok(ArenaWriter {
            out: out.to_path_buf(),
            spill_path,
            spill,
            doc_offsets: vec![0],
            responses: Vec::new(),
            max_token: None,
        })
    }

    /// Append one document. Empty documents are rejected (the format, like
    /// [`Corpus::validate`], forbids them — callers skip empties the way
    /// the JSONL/BoW loaders do); non-finite responses are rejected too.
    pub fn push_doc(&mut self, tokens: &[u32], response: f64) -> anyhow::Result<()> {
        anyhow::ensure!(!tokens.is_empty(), "empty document");
        anyhow::ensure!(response.is_finite(), "non-finite response {response}");
        let end = self.doc_offsets.last().unwrap().checked_add(
            u32::try_from(tokens.len()).map_err(|_| anyhow::anyhow!("document too large"))?,
        );
        let end = end.context("token arena exceeds u32::MAX tokens")?;
        anyhow::ensure!(
            (self.responses.len() as u64) < MAX_D,
            "corpus exceeds {MAX_D} documents"
        );
        for &t in tokens {
            self.spill.write_all(&t.to_le_bytes())?;
        }
        self.max_token = self.max_token.max(Some(tokens.iter().copied().max().unwrap()));
        self.doc_offsets.push(end);
        self.responses.push(response);
        Ok(())
    }

    pub fn num_docs(&self) -> usize {
        self.responses.len()
    }

    pub fn num_tokens(&self) -> usize {
        *self.doc_offsets.last().unwrap() as usize
    }

    /// Assemble and atomically publish the arena file. `vocab` must cover
    /// every pushed token id (pass 1 + max id for self-described corpora).
    pub fn finish(mut self, vocab: usize) -> anyhow::Result<()> {
        self.spill.flush()?;
        anyhow::ensure!((vocab as u64) <= MAX_W, "vocab size {vocab} exceeds cap {MAX_W}");
        if let Some(mx) = self.max_token {
            anyhow::ensure!(
                (mx as usize) < vocab,
                "vocab size {vocab} does not cover token id {mx}"
            );
        }
        let n_docs = self.responses.len() as u64;
        let n_tokens = *self.doc_offsets.last().unwrap() as u64;
        let off_tokens = align8(64 + (n_docs + 1) * 4);
        let off_responses = align8(off_tokens + n_tokens * 4);

        let tmp = PathBuf::from(format!("{}.tmp", self.out.display()));
        let mut f = BufWriter::new(
            std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?,
        );
        let mut h = Fnv1a::new();
        let mut write_hashed = |f: &mut BufWriter<std::fs::File>,
                                h: &mut Fnv1a,
                                bytes: &[u8]|
         -> anyhow::Result<()> {
            h.update(bytes);
            f.write_all(bytes)?;
            Ok(())
        };

        f.write_all(&MAGIC)?;
        write_hashed(&mut f, &mut h, &n_docs.to_le_bytes())?;
        write_hashed(&mut f, &mut h, &n_tokens.to_le_bytes())?;
        write_hashed(&mut f, &mut h, &(vocab as u64).to_le_bytes())?;
        write_hashed(&mut f, &mut h, &64u64.to_le_bytes())?;
        write_hashed(&mut f, &mut h, &off_tokens.to_le_bytes())?;
        write_hashed(&mut f, &mut h, &off_responses.to_le_bytes())?;
        for &o in &self.doc_offsets {
            write_hashed(&mut f, &mut h, &o.to_le_bytes())?;
        }
        let pad = [0u8; 8];
        let doc_off_end = 64 + (n_docs + 1) * 4;
        write_hashed(&mut f, &mut h, &pad[..(off_tokens - doc_off_end) as usize])?;

        // Stream the spilled token section through the hasher while
        // copying — the only pass over the O(N) payload.
        let mut spill = BufReader::new(
            std::fs::File::open(&self.spill_path)
                .with_context(|| format!("reopening spill file {:?}", self.spill_path))?,
        );
        let mut buf = [0u8; 64 * 1024];
        let mut copied = 0u64;
        loop {
            let n = spill.read(&mut buf)?;
            if n == 0 {
                break;
            }
            copied += n as u64;
            write_hashed(&mut f, &mut h, &buf[..n])?;
        }
        anyhow::ensure!(
            copied == n_tokens * 4,
            "spill file holds {copied} bytes, expected {} ({} tokens)",
            n_tokens * 4,
            n_tokens
        );
        let tok_end = off_tokens + n_tokens * 4;
        write_hashed(&mut f, &mut h, &pad[..(off_responses - tok_end) as usize])?;
        for &y in &self.responses {
            write_hashed(&mut f, &mut h, &y.to_le_bytes())?;
        }
        f.write_all(&h.finish().to_le_bytes())?;
        f.flush()?;
        drop(f);
        std::fs::rename(&tmp, &self.out)
            .with_context(|| format!("publishing {:?}", self.out))?;
        std::fs::remove_file(&self.spill_path).ok();
        Ok(())
    }
}

/// Stream an in-memory corpus to `out` through the [`ArenaWriter`].
pub fn write_arena(corpus: &Corpus, out: &Path) -> anyhow::Result<()> {
    corpus.validate()?;
    let mut w = ArenaWriter::create(out)?;
    for (tokens, y) in corpus.view().iter_docs() {
        w.push_doc(tokens, y)?;
    }
    w.finish(corpus.vocab_size)
}

/// Summary of one streaming pack run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PackSummary {
    pub docs: usize,
    pub tokens: usize,
    pub vocab: usize,
    pub skipped_empty: usize,
}

/// Streaming converter: read a corpus file and write `out` without ever
/// materializing the corpus in RAM. Two input formats, sniffed from the
/// first line:
///
/// * **BoW** (`#cfslda-bow vocab=<V>` header, then `y w1 w2 ...` lines) —
///   the vocab is known up front.
/// * **Pre-encoded JSONL** (`{"tokens": [...], "response": y}` lines,
///   optional `{"vocab_size": V}` prologue) — vocab is the running
///   `max(declared, 1 + max token id)`.
///
/// Empty documents are skipped exactly as the heap loaders skip them.
pub fn pack_file(input: &Path, out: &Path) -> anyhow::Result<PackSummary> {
    let file = std::fs::File::open(input).with_context(|| format!("opening {input:?}"))?;
    let mut lines = BufReader::new(file).lines();
    let first = match lines.next() {
        Some(l) => l?,
        None => anyhow::bail!("{input:?} is empty"),
    };
    let mut w = ArenaWriter::create(out)?;
    let mut skipped = 0usize;
    let vocab;
    if let Some(rest) = first.strip_prefix("#cfslda-bow vocab=") {
        let v: usize = rest.trim().parse().context("bad vocab size in bow header")?;
        for (lineno, line) in lines.enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let mut parts = line.split_ascii_whitespace();
            let y: f64 = parts
                .next()
                .context("empty bow line")?
                .parse()
                .with_context(|| format!("bad response at data line {}", lineno + 1))?;
            let tokens: Result<Vec<u32>, _> = parts.map(|p| p.parse::<u32>()).collect();
            let tokens =
                tokens.with_context(|| format!("bad token at data line {}", lineno + 1))?;
            if tokens.is_empty() {
                skipped += 1;
                continue;
            }
            w.push_doc(&tokens, y)?;
        }
        vocab = v;
    } else {
        let mut max_vocab = 0usize;
        let mut handle = |line: &str, lineno: usize, w: &mut ArenaWriter| -> anyhow::Result<bool> {
            if line.trim().is_empty() {
                return Ok(false);
            }
            let v = crate::config::json::parse(line)
                .with_context(|| format!("{input:?}:{} invalid json", lineno + 1))?;
            if let Some(vs) = v.get("vocab_size").and_then(|x| x.as_usize()) {
                max_vocab = max_vocab.max(vs);
                return Ok(false);
            }
            let toks = v
                .get("tokens")
                .and_then(|t| t.as_array())
                .with_context(|| format!("{input:?}:{} missing 'tokens'", lineno + 1))?;
            let tokens: Option<Vec<u32>> =
                toks.iter().map(|t| t.as_usize().map(|u| u as u32)).collect();
            let tokens =
                tokens.with_context(|| format!("{input:?}:{} bad token ids", lineno + 1))?;
            let y = v
                .get("response")
                .and_then(|r| r.as_f64())
                .with_context(|| format!("{input:?}:{} missing 'response'", lineno + 1))?;
            if tokens.is_empty() {
                return Ok(true);
            }
            for &t in &tokens {
                max_vocab = max_vocab.max(t as usize + 1);
            }
            w.push_doc(&tokens, y)?;
            Ok(false)
        };
        if handle(&first, 0, &mut w)? {
            skipped += 1;
        }
        for (lineno, line) in lines.enumerate() {
            let line = line?;
            if handle(&line, lineno + 1, &mut w)? {
                skipped += 1;
            }
        }
        vocab = max_vocab;
    }
    let summary =
        PackSummary { docs: w.num_docs(), tokens: w.num_tokens(), vocab, skipped_empty: skipped };
    w.finish(vocab)?;
    Ok(summary)
}

/// RAII read-only shared mapping of one file.
struct Mapping {
    ptr: *mut libc::c_void,
    len: usize,
}

// The mapping is read-only (PROT_READ) and immutable for its lifetime, so
// shared references into it are safe to send and share across the worker
// fan-out.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    fn open(path: &Path) -> anyhow::Result<Mapping> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
        let len = f.metadata()?.len();
        anyhow::ensure!(len > 0, "{path:?} is empty");
        let len = usize::try_from(len).context("file larger than the address space")?;
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_SHARED,
                f.as_raw_fd(),
                0,
            )
        };
        anyhow::ensure!(
            ptr != libc::MAP_FAILED,
            "mmap of {path:?} ({len} bytes) failed: {}",
            std::io::Error::last_os_error()
        );
        // Paging policy: Gibbs sweeps walk the token section front to back
        // every sweep, so prime readahead and ask for the whole file
        // eagerly. Advice is best-effort — a refusal changes paging
        // behavior, not correctness.
        unsafe {
            libc::madvise(ptr, len, libc::MADV_SEQUENTIAL);
            libc::madvise(ptr, len, libc::MADV_WILLNEED);
        }
        // The fd can close now: the mapping keeps the file alive.
        Ok(Mapping { ptr, len })
    }

    fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.ptr, self.len);
        }
    }
}

/// A validated, read-only mmap of a `CFSARENA1` file: the out-of-core
/// corpus. [`ArenaMap::view`] hands out the ordinary [`CorpusView`], so
/// everything downstream (trainer, workers, predictor) is oblivious to
/// the backing store; N independent processes mapping the same file share
/// its pages through the page cache with zero copies.
///
/// **Safety / lifetime argument.** The mapping is `PROT_READ` +
/// `MAP_SHARED` and lives exactly as long as this struct; views borrow
/// `&self`, so the borrow checker pins the mapping open for as long as
/// any view (or slice derived from one) exists. [`parse`] validates the
/// checksum and full structure *through the mapping* before any typed
/// slice is produced, and the 8-aligned section offsets on a page-aligned
/// base make the `&[u32]` / `&[f64]` casts well-aligned. The one hazard
/// mmap cannot close is an *external* truncation of the file while
/// mapped, which raises SIGBUS on touch (documented in DESIGN.md
/// §Out-of-core); treat published `.arena` files as immutable — the
/// writer's tmp+rename publish guarantees readers never observe a partial
/// file.
pub struct ArenaMap {
    map: Mapping,
    layout: Layout,
}

impl ArenaMap {
    /// Map `path` and validate it end to end (checksum first).
    pub fn open(path: &Path) -> anyhow::Result<ArenaMap> {
        let map = Mapping::open(path)?;
        let layout = parse(map.bytes()).with_context(|| format!("validating {path:?}"))?;
        Ok(ArenaMap { map, layout })
    }

    pub fn num_docs(&self) -> usize {
        self.layout.n_docs
    }

    pub fn num_tokens(&self) -> usize {
        self.layout.n_tokens
    }

    pub fn vocab_size(&self) -> usize {
        self.layout.vocab
    }

    /// Total mapped bytes (the comm ledger books these as *referenced*
    /// setup traffic for multi-process runs).
    pub fn mapped_len(&self) -> usize {
        self.map.len
    }

    fn doc_offsets(&self) -> &[u32] {
        let b = self.map.bytes();
        // Alignment: base is page-aligned, offset is 64.
        unsafe {
            std::slice::from_raw_parts(
                b.as_ptr().add(self.layout.off_doc_offsets) as *const u32,
                self.layout.n_docs + 1,
            )
        }
    }

    fn tokens(&self) -> &[u32] {
        let b = self.map.bytes();
        unsafe {
            std::slice::from_raw_parts(
                b.as_ptr().add(self.layout.off_tokens) as *const u32,
                self.layout.n_tokens,
            )
        }
    }

    fn responses(&self) -> &[f64] {
        let b = self.map.bytes();
        unsafe {
            std::slice::from_raw_parts(
                b.as_ptr().add(self.layout.off_responses) as *const f64,
                self.layout.n_docs,
            )
        }
    }

    /// Zero-copy view of the whole mapped corpus.
    pub fn view(&self) -> CorpusView<'_> {
        CorpusView::from_parts(
            self.tokens(),
            self.doc_offsets(),
            self.responses(),
            self.layout.vocab,
            None,
        )
        .expect("parse() already proved the CSR invariants")
    }

    /// Zero-copy view of the documents named by `ids` (a shard of the
    /// mapped corpus). Errors on out-of-range ids.
    pub fn view_of<'a>(&'a self, ids: &'a [usize]) -> anyhow::Result<CorpusView<'a>> {
        CorpusView::from_parts(
            self.tokens(),
            self.doc_offsets(),
            self.responses(),
            self.layout.vocab,
            Some(ids),
        )
    }

    /// Copy the mapped corpus onto the heap (tools/benches; the training
    /// path stays on the mapping).
    pub fn to_corpus(&self) -> Corpus {
        self.view().to_corpus()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Document;
    use crate::data::synthetic::{generate_split, SyntheticSpec};
    use crate::testkit::{forall, usize_in};
    use crate::util::rng::Pcg64;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_arena_{}_{name}", std::process::id()));
        p
    }

    fn mini() -> Corpus {
        Corpus::new(
            vec![
                Document { tokens: vec![0, 1, 1, 2], response: 0.5 },
                Document { tokens: vec![2, 2], response: -1.0 },
                Document { tokens: vec![0], response: 2.0 },
            ],
            3,
        )
    }

    fn sized(seed: u64, docs: usize) -> Corpus {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(seed);
        generate_split(&spec, docs, &mut rng).train
    }

    #[test]
    fn incremental_fnv_matches_oneshot() {
        let data: Vec<u8> = (0..4096u32).map(|i| (i * 31 % 251) as u8).collect();
        let mut h = Fnv1a::new();
        // uneven chunking must not change the digest
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), crate::model::persist::fnv1a(&data));
        assert_eq!(Fnv1a::new().finish(), crate::model::persist::fnv1a(&[]));
    }

    #[test]
    fn encode_parse_decode_round_trip() {
        for c in [mini(), sized(3, 60), Corpus::default()] {
            let bytes = encode(&c).unwrap();
            let l = parse(&bytes).unwrap();
            assert_eq!(l.n_docs, c.num_docs());
            assert_eq!(l.n_tokens, c.num_tokens());
            assert_eq!(l.vocab, c.vocab_size);
            assert_eq!(l.off_doc_offsets, 64);
            assert_eq!(l.off_tokens % 8, 0);
            assert_eq!(l.off_responses % 8, 0);
            let back = decode(&bytes).unwrap();
            assert_eq!(back, c);
        }
    }

    #[test]
    fn streaming_writer_matches_encode_byte_for_byte() {
        let c = sized(7, 45);
        let p = tmp("writer.arena");
        write_arena(&c, &p).unwrap();
        let streamed = std::fs::read(&p).unwrap();
        assert_eq!(streamed, encode(&c).unwrap());
        // spill + tmp are cleaned up
        assert!(!PathBuf::from(format!("{}.spill", p.display())).exists());
        assert!(!PathBuf::from(format!("{}.tmp", p.display())).exists());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn writer_rejects_bad_docs_and_vocab() {
        let p = tmp("reject.arena");
        let mut w = ArenaWriter::create(&p).unwrap();
        assert!(w.push_doc(&[], 1.0).is_err(), "empty doc");
        assert!(w.push_doc(&[1], f64::NAN).is_err(), "NaN response");
        w.push_doc(&[5, 2], 1.0).unwrap();
        assert_eq!(w.num_docs(), 1);
        assert_eq!(w.num_tokens(), 2);
        // vocab must cover the max token id
        assert!(w.finish(5).is_err());
        std::fs::remove_file(PathBuf::from(format!("{}.spill", p.display()))).ok();
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn arena_map_views_match_heap_corpus() {
        let c = sized(11, 40);
        let p = tmp("map.arena");
        write_arena(&c, &p).unwrap();
        let map = ArenaMap::open(&p).unwrap();
        assert_eq!(map.num_docs(), c.num_docs());
        assert_eq!(map.num_tokens(), c.num_tokens());
        assert_eq!(map.vocab_size(), c.vocab_size);
        assert_eq!(map.mapped_len(), std::fs::metadata(&p).unwrap().len() as usize);
        let v = map.view();
        assert!(v.is_full());
        v.validate().unwrap();
        for i in 0..c.num_docs() {
            assert_eq!(v.doc_tokens(i), c.doc_tokens(i));
            assert_eq!(v.response(i), c.response(i));
        }
        assert_eq!(map.to_corpus(), c);
        // shard views over the mapping
        let ids: Vec<usize> = (0..c.num_docs()).step_by(3).collect();
        let s = map.view_of(&ids).unwrap();
        assert_eq!(s.num_docs(), ids.len());
        assert_eq!(s.doc_tokens(1), c.doc_tokens(ids[1]));
        let bad = vec![c.num_docs()];
        assert!(map.view_of(&bad).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mapped_training_is_byte_identical_to_heap_training() {
        use crate::config::schema::ExperimentConfig;
        use crate::runtime::EngineHandle;
        use crate::sampler::gibbs_train;
        let c = sized(13, 50);
        let p = tmp("train.arena");
        write_arena(&c, &p).unwrap();
        let map = ArenaMap::open(&p).unwrap();
        let mut cfg = ExperimentConfig::quick();
        cfg.train.sweeps = 8;
        cfg.train.burnin = 2;
        cfg.train.eta_every = 2;
        let engine = EngineHandle::native();
        let a = gibbs_train::train(&c, &cfg, &engine, &mut Pcg64::seed_from_u64(5)).unwrap();
        let b =
            gibbs_train::train(map.view(), &cfg, &engine, &mut Pcg64::seed_from_u64(5)).unwrap();
        assert_eq!(a.z, b.z);
        assert_eq!(a.model.eta, b.model.eta);
        assert_eq!(a.model.phi, b.model.phi);
        assert_eq!(a.responses, b.responses);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn pack_file_streams_bow_and_jsonl() {
        let c = sized(17, 30);
        // BoW path
        let bow = tmp("pack.bow");
        crate::data::loader::save_bow(&c, &bow).unwrap();
        let out = tmp("pack_bow.arena");
        let s = pack_file(&bow, &out).unwrap();
        assert_eq!(s.docs, c.num_docs());
        assert_eq!(s.tokens, c.num_tokens());
        assert_eq!(s.vocab, c.vocab_size);
        assert_eq!(ArenaMap::open(&out).unwrap().to_corpus(), c);
        // JSONL path (with vocab_size prologue and an empty doc to skip)
        let jl = tmp("pack.jsonl");
        std::fs::write(
            &jl,
            "{\"vocab_size\": 9}\n{\"tokens\": [0, 3, 3], \"response\": 2.0}\n\
             {\"tokens\": [], \"response\": 0.0}\n{\"tokens\": [8], \"response\": -1}\n",
        )
        .unwrap();
        let out2 = tmp("pack_jsonl.arena");
        let s = pack_file(&jl, &out2).unwrap();
        assert_eq!(s.docs, 2);
        assert_eq!(s.tokens, 4);
        assert_eq!(s.vocab, 9);
        assert_eq!(s.skipped_empty, 1);
        let m = ArenaMap::open(&out2).unwrap();
        assert_eq!(m.view().doc_tokens(0), &[0, 3, 3]);
        assert_eq!(m.view().doc_tokens(1), &[8]);
        assert_eq!(m.view().response(1), -1.0);
        for p in [bow, out, jl, out2] {
            std::fs::remove_file(&p).ok();
        }
    }

    /// Restamp helper: recompute the trailing checksum after structural
    /// mangling, so tests reach the *structural* validation layers behind
    /// the checksum gate (the `ckpt/format` technique).
    fn restamp(bytes: &mut Vec<u8>) {
        let len = bytes.len();
        let mut h = Fnv1a::new();
        h.update(&bytes[16..len - 8]);
        let sum = h.finish().to_le_bytes();
        bytes[len - 8..].copy_from_slice(&sum);
    }

    #[test]
    fn checksum_is_checked_before_structure() {
        let mut bytes = encode(&mini()).unwrap();
        // poison the header with an absurd doc count *without* restamping:
        // the checksum error must win, proving validation order
        bytes[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = parse(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum"), "got: {err}");
        // restamped, the structural ceiling fires instead — before any
        // allocation could be sized from the hostile count
        restamp(&mut bytes);
        let err = parse(&bytes).unwrap_err().to_string();
        assert!(err.contains("implausible document count"), "got: {err}");
    }

    #[test]
    fn hostile_headers_rejected_after_restamp() {
        let base = encode(&sized(19, 20)).unwrap();
        // token count beyond u32
        let mut b = base.clone();
        b[24..32].copy_from_slice(&(u32::MAX as u64 + 1).to_le_bytes());
        restamp(&mut b);
        assert!(parse(&b).unwrap_err().to_string().contains("implausible token count"));
        // vocab beyond the ceiling
        let mut b = base.clone();
        b[32..40].copy_from_slice(&(MAX_W + 1).to_le_bytes());
        restamp(&mut b);
        assert!(parse(&b).unwrap_err().to_string().contains("implausible vocab size"));
        // non-canonical section offsets
        for off in [40usize, 48, 56] {
            let mut b = base.clone();
            b[off..off + 8].copy_from_slice(&u64::from(u32::MAX).to_le_bytes());
            restamp(&mut b);
            assert!(parse(&b).is_err(), "offset field at {off} must be pinned");
        }
        // counts that describe a different file length
        let mut b = base.clone();
        b[16..24].copy_from_slice(&1u64.to_le_bytes());
        restamp(&mut b);
        assert!(parse(&b).is_err());
        // wrong magic
        let mut b = base.clone();
        b[0] = b'X';
        assert!(parse(&b).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn semantic_corruption_rejected_after_restamp() {
        let c = mini();
        let bytes = encode(&c).unwrap();
        let l = parse(&bytes).unwrap();
        // out-of-vocab token id
        let mut b = bytes.clone();
        b[l.off_tokens..l.off_tokens + 4].copy_from_slice(&99u32.to_le_bytes());
        restamp(&mut b);
        assert!(parse(&b).unwrap_err().to_string().contains("word id"));
        // empty document (offsets equal)
        let mut b = bytes.clone();
        let o0 = l.off_doc_offsets;
        b[o0 + 4..o0 + 8].copy_from_slice(&0u32.to_le_bytes());
        restamp(&mut b);
        assert!(parse(&b).is_err());
        // non-finite response
        let mut b = bytes.clone();
        b[l.off_responses..l.off_responses + 8]
            .copy_from_slice(&f64::NAN.to_le_bytes());
        restamp(&mut b);
        assert!(parse(&b).unwrap_err().to_string().contains("non-finite response"));
    }

    /// The hostile-input property: arbitrary bit flips, truncations, and
    /// truncate+restamp manglings never panic the parser, and a mangled
    /// image never validates (any in-place bit flip lands in magic, body,
    /// or checksum — all covered).
    #[test]
    fn mangled_arena_never_panics() {
        let base = encode(&sized(23, 25)).unwrap();
        forall(
            "mangled CFSARENA1 image",
            300,
            |rng| {
                let mode = usize_in(rng, 0, 2);
                let mut b = base.clone();
                match mode {
                    0 => {
                        let bit = usize_in(rng, 0, b.len() * 8 - 1);
                        b[bit / 8] ^= 1 << (bit % 8);
                    }
                    1 => {
                        let keep = usize_in(rng, 0, b.len() - 1);
                        b.truncate(keep);
                    }
                    _ => {
                        let keep = usize_in(rng, 24, b.len() - 1);
                        b.truncate(keep);
                        if b.len() >= MIN_LEN {
                            restamp(&mut b);
                        }
                    }
                }
                (mode, b)
            },
            |(mode, b)| {
                let res = parse(b);
                match mode {
                    0 | 1 => assert!(res.is_err(), "mangled image must not validate"),
                    // a restamped truncation passes the checksum but must
                    // still die on structure
                    _ => assert!(res.is_err(), "truncated+restamped image must not validate"),
                }
            },
        );
    }
}
