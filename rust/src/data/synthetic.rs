//! Synthetic corpora drawn from the sLDA generative process (paper §III-B).
//!
//! Substitute for the paper's two proprietary datasets (DESIGN.md §3):
//!
//! * [`SyntheticSpec::mdna`] — Experiment I scale: 4216 documents over a
//!   4238-phrase vocabulary, continuous near-normal response (EPS-like,
//!   reproducing the Fig-5 histogram).
//! * [`SyntheticSpec::imdb`] — Experiment II scale: 25 000 documents,
//!   binary response through the logit-normal reading in the paper.
//!
//! Because the data is drawn from the model family itself, ground truth
//! (phi, eta) is available for diagnostics — e.g. the Hungarian
//! topic-alignment probe that quantifies quasi-ergodicity.

use super::corpus::{Corpus, Dataset};
use crate::config::schema::ResponseKind;
use crate::util::rng::Pcg64;

/// Specification of a synthetic sLDA corpus.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub docs: usize,
    pub vocab: usize,
    pub topics: usize,
    /// Mean document length (Poisson distributed, min 4 tokens).
    pub doc_len_mean: f64,
    /// Dirichlet prior for document-topic proportions used in generation.
    pub alpha: f64,
    /// Dirichlet prior for topic-word distributions used in generation.
    pub beta: f64,
    /// Scale of the generating eta coefficients.
    pub eta_scale: f64,
    /// Response noise variance (the generative rho).
    pub noise_var: f64,
    /// Continuous (EPS-like) or binary (sentiment-like) response.
    pub response: ResponseKind,
    /// Offset added to continuous responses (EPS distributions are not
    /// centered at zero; the paper's Fig-5 histogram peaks near ~1-2).
    pub response_shift: f64,
}

impl SyntheticSpec {
    /// Tiny corpus for unit tests and the quickstart example.
    pub fn continuous_small() -> Self {
        SyntheticSpec {
            docs: 240,
            vocab: 400,
            topics: 8,
            doc_len_mean: 40.0,
            alpha: 0.3,
            beta: 0.05,
            eta_scale: 2.0,
            noise_var: 0.05,
            response: ResponseKind::Continuous,
            response_shift: 0.0,
        }
    }

    /// Tiny binary-response corpus for tests.
    pub fn binary_small() -> Self {
        let mut s = Self::continuous_small();
        s.response = ResponseKind::Binary;
        s
    }

    /// Experiment I scale (paper: 4216 firms, 4238 phrases, EPS response).
    pub fn mdna() -> Self {
        SyntheticSpec {
            docs: 4216,
            vocab: 4238,
            topics: 16,
            doc_len_mean: 150.0,
            alpha: 0.3,
            beta: 0.02,
            eta_scale: 2.5,
            noise_var: 0.25,
            response: ResponseKind::Continuous,
            response_shift: 1.5,
        }
    }

    /// Experiment II scale (paper: 25k labeled IMDB reviews, binary).
    pub fn imdb() -> Self {
        SyntheticSpec {
            docs: 25_000,
            vocab: 5_000,
            topics: 16,
            doc_len_mean: 80.0,
            alpha: 0.3,
            beta: 0.02,
            eta_scale: 3.0,
            noise_var: 0.25,
            response: ResponseKind::Binary,
            response_shift: 0.0,
        }
    }
}

/// The latent variables that generated a synthetic corpus.
#[derive(Clone, Debug)]
pub struct GroundTruth {
    /// Topic-word distributions, row t = phi_t over the vocabulary.
    pub phi: Vec<Vec<f64>>,
    /// Regression coefficients eta (centered for binary responses).
    pub eta: Vec<f64>,
}

/// Poisson sample (Knuth for small mean, normal approximation above 30).
pub fn sample_poisson(rng: &mut Pcg64, mean: f64) -> usize {
    if mean <= 0.0 {
        return 0;
    }
    if mean < 30.0 {
        let l = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= rng.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    } else {
        let x = mean + mean.sqrt() * rng.next_gaussian();
        x.max(0.0).round() as usize
    }
}

#[inline]
fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Draw a full corpus + ground truth from the sLDA generative process.
pub fn generate_with_truth(spec: &SyntheticSpec, rng: &mut Pcg64) -> (Corpus, GroundTruth) {
    let t = spec.topics;
    let v = spec.vocab;

    // 1a) phi_t ~ Dir(beta)
    let phi: Vec<Vec<f64>> = (0..t).map(|_| rng.next_dirichlet_sym(spec.beta, v)).collect();
    // Cumulative tables for O(log V) word draws.
    let phi_cum: Vec<Vec<f64>> = phi
        .iter()
        .map(|row| {
            let mut c = Vec::with_capacity(v);
            let mut s = 0.0;
            for &p in row {
                s += p;
                c.push(s);
            }
            c
        })
        .collect();

    // 1b) eta_t ~ N(0, eta_scale^2), centered so zbar @ eta has mean ~ 0.
    let mut eta: Vec<f64> = (0..t).map(|_| spec.eta_scale * rng.next_gaussian()).collect();
    let mean_eta: f64 = eta.iter().sum::<f64>() / t as f64;
    for e in &mut eta {
        *e -= mean_eta;
    }

    // Documents flow straight into the token arena; one reusable token
    // buffer serves every document.
    let mut corpus = Corpus::with_capacity(
        spec.docs,
        (spec.docs as f64 * spec.doc_len_mean) as usize,
        v,
    );
    let mut tokens: Vec<u32> = Vec::new();
    for _ in 0..spec.docs {
        // 2a) theta_d ~ Dir(alpha)
        let theta = rng.next_dirichlet_sym(spec.alpha, t);
        let n = sample_poisson(rng, spec.doc_len_mean).max(4);
        tokens.clear();
        let mut zbar = vec![0.0f64; t];
        for _ in 0..n {
            // 2b-i) z ~ Multi(theta)
            let z = rng.sample_discrete(&theta);
            zbar[z] += 1.0;
            // 2b-ii) w ~ Multi(phi_z) via binary search on the cumulative.
            let u = rng.next_f64();
            let cum = &phi_cum[z];
            let w = match cum.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(i) => i,
                Err(i) => i.min(v - 1),
            };
            tokens.push(w as u32);
        }
        for zb in &mut zbar {
            *zb /= n as f64;
        }
        // 2c) response
        let signal: f64 = zbar.iter().zip(&eta).map(|(a, b)| a * b).sum();
        let response = match spec.response {
            ResponseKind::Continuous => {
                spec.response_shift + signal + spec.noise_var.sqrt() * rng.next_gaussian()
            }
            ResponseKind::Binary => {
                // Logit-normal (paper §III-B note): latent = signal + noise,
                // y ~ Bernoulli(sigmoid(latent / temperature)).
                let latent = signal + spec.noise_var.sqrt() * rng.next_gaussian();
                let p = sigmoid(4.0 * latent);
                if rng.next_f64() < p { 1.0 } else { 0.0 }
            }
        };
        corpus.push_doc(&tokens, response);
    }

    (corpus, GroundTruth { phi, eta })
}

/// Draw a corpus, discarding the ground truth.
pub fn generate_corpus(spec: &SyntheticSpec, rng: &mut Pcg64) -> Corpus {
    generate_with_truth(spec, rng).0
}

/// Draw a corpus and split it `n_train` / rest as in the paper's protocol
/// (Exp I: 3000/1216, Exp II: 20000/5000).
pub fn generate_split(spec: &SyntheticSpec, n_train: usize, rng: &mut Pcg64) -> Dataset {
    let corpus = generate_corpus(spec, rng);
    super::partition::train_test_split(&corpus, n_train, rng)
}

/// Convenience used by doctests/examples: 75/25 split of the spec'd corpus.
pub fn generate(spec: &SyntheticSpec, rng: &mut Pcg64) -> Dataset {
    let n_train = spec.docs * 3 / 4;
    generate_split(spec, n_train, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn corpus_matches_spec() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(1);
        let (c, gt) = generate_with_truth(&spec, &mut rng);
        assert_eq!(c.num_docs(), spec.docs);
        assert_eq!(c.vocab_size, spec.vocab);
        assert_eq!(gt.phi.len(), spec.topics);
        assert_eq!(gt.eta.len(), spec.topics);
        c.validate().unwrap();
        let mean_len = c.num_tokens() as f64 / c.num_docs() as f64;
        assert!((mean_len - spec.doc_len_mean).abs() < 8.0, "mean_len={mean_len}");
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SyntheticSpec::continuous_small();
        let a = generate_corpus(&spec, &mut Pcg64::seed_from_u64(9));
        let b = generate_corpus(&spec, &mut Pcg64::seed_from_u64(9));
        assert_eq!(a, b);
    }

    #[test]
    fn phi_rows_are_distributions() {
        let spec = SyntheticSpec::continuous_small();
        let (_, gt) = generate_with_truth(&spec, &mut Pcg64::seed_from_u64(2));
        for row in &gt.phi {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(row.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn binary_labels_are_zero_one_and_balanced_ish() {
        let spec = SyntheticSpec::binary_small();
        let c = generate_corpus(&spec, &mut Pcg64::seed_from_u64(3));
        let ys = c.responses();
        assert!(ys.iter().all(|&y| y == 0.0 || y == 1.0));
        let frac = ys.iter().sum::<f64>() / ys.len() as f64;
        assert!(frac > 0.15 && frac < 0.85, "frac={frac}");
    }

    #[test]
    fn continuous_labels_roughly_centered_at_shift() {
        let mut spec = SyntheticSpec::continuous_small();
        spec.response_shift = 1.5;
        spec.docs = 2000;
        let c = generate_corpus(&spec, &mut Pcg64::seed_from_u64(4));
        let s = Summary::from_slice(&c.responses());
        assert!((s.mean() - 1.5).abs() < 0.3, "mean={}", s.mean());
    }

    #[test]
    fn responses_correlate_with_topics() {
        // Signal check: noise-free responses must be exactly zbar . eta, so
        // with tiny noise the label variance must exceed the noise variance.
        let mut spec = SyntheticSpec::continuous_small();
        spec.noise_var = 1e-6;
        spec.docs = 500;
        let c = generate_corpus(&spec, &mut Pcg64::seed_from_u64(5));
        let s = Summary::from_slice(&c.responses());
        assert!(s.var() > 0.01, "var={}", s.var());
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Pcg64::seed_from_u64(6);
        for &mean in &[3.0, 12.0, 80.0] {
            let n = 20_000;
            let s: f64 = (0..n).map(|_| sample_poisson(&mut rng, mean) as f64).sum();
            let got = s / n as f64;
            assert!((got - mean).abs() < 0.1 * mean, "mean={mean} got={got}");
        }
        assert_eq!(sample_poisson(&mut rng, 0.0), 0);
    }

    #[test]
    fn split_sizes_follow_protocol() {
        let spec = SyntheticSpec::continuous_small();
        let mut rng = Pcg64::seed_from_u64(7);
        let ds = generate_split(&spec, 180, &mut rng);
        assert_eq!(ds.train.num_docs(), 180);
        assert_eq!(ds.test.num_docs(), spec.docs - 180);
    }
}
