//! Vocabulary: phrase <-> id maps with document-frequency pruning.
//!
//! Reproduces the paper's preprocessing decision: "we only included phrases
//! that appear in at least 2% of the total number of firms" — see
//! [`Vocab::build_pruned`] with `min_df_frac = 0.02`.

use std::collections::HashMap;

/// Bidirectional phrase <-> id mapping.
#[derive(Clone, Debug, Default)]
pub struct Vocab {
    terms: Vec<String>,
    index: HashMap<String, u32>,
}

impl Vocab {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.terms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.terms.is_empty()
    }

    /// Intern a term, returning its id.
    pub fn intern(&mut self, term: &str) -> u32 {
        if let Some(&id) = self.index.get(term) {
            return id;
        }
        let id = self.terms.len() as u32;
        self.terms.push(term.to_string());
        self.index.insert(term.to_string(), id);
        id
    }

    pub fn id(&self, term: &str) -> Option<u32> {
        self.index.get(term).copied()
    }

    pub fn term(&self, id: u32) -> Option<&str> {
        self.terms.get(id as usize).map(|s| s.as_str())
    }

    pub fn terms(&self) -> &[String] {
        &self.terms
    }

    /// Build a pruned vocabulary from tokenized documents, keeping terms
    /// whose document frequency is at least `min_df_frac` of the corpus
    /// (the paper's 2% floor) and at most `max_df_frac` (drop boilerplate).
    pub fn build_pruned(
        docs: &[Vec<String>],
        min_df_frac: f64,
        max_df_frac: f64,
    ) -> Vocab {
        let n = docs.len().max(1) as f64;
        let mut df: HashMap<&str, usize> = HashMap::new();
        for doc in docs {
            let mut seen: Vec<&str> = doc.iter().map(|s| s.as_str()).collect();
            seen.sort_unstable();
            seen.dedup();
            for t in seen {
                *df.entry(t).or_insert(0) += 1;
            }
        }
        let mut kept: Vec<&str> = df
            .iter()
            .filter(|(_, &c)| {
                let f = c as f64 / n;
                f >= min_df_frac && f <= max_df_frac
            })
            .map(|(&t, _)| t)
            .collect();
        kept.sort_unstable(); // deterministic ids
        let mut v = Vocab::new();
        for t in kept {
            v.intern(t);
        }
        v
    }

    /// Map a tokenized document onto ids, dropping out-of-vocabulary terms.
    pub fn encode(&self, doc: &[String]) -> Vec<u32> {
        doc.iter().filter_map(|t| self.id(t)).collect()
    }

    /// Rebuild a vocabulary from an ordered term list (ids = positions).
    /// Errors on duplicate terms, which would silently shift ids.
    pub fn from_terms<I: IntoIterator<Item = String>>(terms: I) -> anyhow::Result<Vocab> {
        let mut v = Vocab::new();
        let mut n = 0usize;
        for t in terms {
            let id = v.intern(&t);
            anyhow::ensure!(id as usize == n, "duplicate vocabulary term '{t}'");
            n += 1;
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn intern_roundtrip() {
        let mut v = Vocab::new();
        let a = v.intern("alpha");
        let b = v.intern("beta");
        assert_eq!(v.intern("alpha"), a);
        assert_eq!(v.len(), 2);
        assert_eq!(v.term(a), Some("alpha"));
        assert_eq!(v.id("beta"), Some(b));
        assert_eq!(v.id("gamma"), None);
    }

    #[test]
    fn prune_by_document_frequency() {
        // "common" in 3/4 docs, "rare" in 1/4, "always" in 4/4.
        let docs = vec![
            toks("common always rare"),
            toks("common always"),
            toks("common always"),
            toks("always"),
        ];
        let v = Vocab::build_pruned(&docs, 0.5, 0.9);
        assert!(v.id("common").is_some());
        assert!(v.id("rare").is_none()); // below 50% floor
        assert!(v.id("always").is_none()); // above 90% ceiling
    }

    #[test]
    fn duplicate_tokens_count_once_for_df() {
        let docs = vec![toks("x x x"), toks("y")];
        let v = Vocab::build_pruned(&docs, 0.6, 1.0);
        // df(x) = 1/2 < 0.6 even though it appears 3 times
        assert!(v.id("x").is_none());
    }

    #[test]
    fn ids_are_deterministic_sorted() {
        let docs = vec![toks("b a c"), toks("a b c")];
        let v = Vocab::build_pruned(&docs, 0.0, 1.0);
        assert_eq!(v.term(0), Some("a"));
        assert_eq!(v.term(1), Some("b"));
        assert_eq!(v.term(2), Some("c"));
    }

    #[test]
    fn from_terms_roundtrip_and_duplicates() {
        let v = Vocab::from_terms(["b", "a"].iter().map(|s| s.to_string())).unwrap();
        assert_eq!(v.term(0), Some("b"));
        assert_eq!(v.id("a"), Some(1));
        assert!(Vocab::from_terms(["x", "x"].iter().map(|s| s.to_string())).is_err());
    }

    #[test]
    fn encode_drops_oov() {
        let docs = vec![toks("a b"), toks("a b")];
        let v = Vocab::build_pruned(&docs, 0.9, 1.0);
        let enc = v.encode(&toks("a zzz b a"));
        assert_eq!(enc.len(), 3);
    }
}
