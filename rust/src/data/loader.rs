//! Corpus I/O.
//!
//! Two on-disk formats:
//!
//! * **JSONL** — one `{"text": "...", "response": 1.23}` object per line
//!   (raw-text path: tokenized + vocabulary-pruned on load), or
//!   `{"tokens": [0, 4, 4], "response": 1.23}` (pre-encoded path).
//! * **BoW** — a compact whitespace format for generated corpora:
//!   header `#cfslda-bow vocab=<V>`, then per line `y w1 w2 w3 ...`.

use super::corpus::{Corpus, Document};
use super::tokenizer::{tokenize, TokenizerConfig};
use super::vocab::Vocab;
use crate::config::json;
use anyhow::{bail, Context};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Load a raw-text JSONL corpus: builds a pruned vocabulary (df floor as in
/// the paper: fraction of documents), encodes, drops docs that end up empty.
pub fn load_text_jsonl(
    path: &Path,
    tok_cfg: &TokenizerConfig,
    min_df_frac: f64,
    max_df_frac: f64,
) -> anyhow::Result<(Corpus, Vocab)> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut texts: Vec<Vec<String>> = Vec::new();
    let mut responses: Vec<f64> = Vec::new();
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(&line)
            .with_context(|| format!("{path:?}:{} invalid json", lineno + 1))?;
        let text = v
            .get("text")
            .and_then(|t| t.as_str())
            .with_context(|| format!("{path:?}:{} missing 'text'", lineno + 1))?;
        let y = v
            .get("response")
            .and_then(|r| r.as_f64())
            .with_context(|| format!("{path:?}:{} missing 'response'", lineno + 1))?;
        texts.push(tokenize(text, tok_cfg));
        responses.push(y);
    }
    let vocab = Vocab::build_pruned(&texts, min_df_frac, max_df_frac);
    if vocab.is_empty() {
        bail!("vocabulary is empty after pruning (min_df_frac={min_df_frac})");
    }
    let mut corpus = Corpus::with_capacity(texts.len(), 0, vocab.len());
    for (toks, y) in texts.iter().zip(&responses) {
        let enc = vocab.encode(toks);
        if !enc.is_empty() {
            corpus.try_push_doc(&enc, *y)?;
        }
    }
    Ok((corpus, vocab))
}

/// Load a pre-encoded JSONL corpus (`tokens` arrays). `vocab_size` is taken
/// as 1 + max token id unless given in a leading `{"vocab_size": V}` line.
pub fn load_encoded_jsonl(path: &Path) -> anyhow::Result<Corpus> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut docs = Vec::new();
    let mut vocab_size: usize = 0;
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(&line)
            .with_context(|| format!("{path:?}:{} invalid json", lineno + 1))?;
        if let Some(vs) = v.get("vocab_size").and_then(|x| x.as_usize()) {
            vocab_size = vocab_size.max(vs);
            continue;
        }
        let toks = v
            .get("tokens")
            .and_then(|t| t.as_array())
            .with_context(|| format!("{path:?}:{} missing 'tokens'", lineno + 1))?;
        let tokens: Option<Vec<u32>> =
            toks.iter().map(|t| t.as_usize().map(|u| u as u32)).collect();
        let tokens = tokens.with_context(|| format!("{path:?}:{} bad token ids", lineno + 1))?;
        let y = v
            .get("response")
            .and_then(|r| r.as_f64())
            .with_context(|| format!("{path:?}:{} missing 'response'", lineno + 1))?;
        for &t in &tokens {
            vocab_size = vocab_size.max(t as usize + 1);
        }
        if !tokens.is_empty() {
            docs.push(Document { tokens, response: y });
        }
    }
    // vocab_size is only final after the full scan, so documents buffer as
    // construction-time records and flatten fallibly here.
    let total: usize = docs.iter().map(|d| d.tokens.len()).sum();
    let mut c = Corpus::with_capacity(docs.len(), total, vocab_size);
    for d in &docs {
        c.try_push_doc(&d.tokens, d.response)?;
    }
    c.validate()?;
    Ok(c)
}

/// Write the compact BoW format.
pub fn save_bow(corpus: &Corpus, path: &Path) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    writeln!(f, "#cfslda-bow vocab={}", corpus.vocab_size)?;
    for (tokens, response) in corpus.view().iter_docs() {
        write!(f, "{response}")?;
        for &t in tokens {
            write!(f, " {t}")?;
        }
        writeln!(f)?;
    }
    Ok(())
}

/// Read the compact BoW format.
pub fn load_bow(path: &Path) -> anyhow::Result<Corpus> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut lines = BufReader::new(file).lines();
    let header = lines.next().context("empty bow file")??;
    let vocab_size: usize = header
        .strip_prefix("#cfslda-bow vocab=")
        .context("bad bow header")?
        .trim()
        .parse()
        .context("bad vocab size in bow header")?;
    // Vocab is known from the header, so lines stream straight into the
    // token arena — no per-document Vec of the legacy layout survives.
    let mut c = Corpus::with_capacity(0, 0, vocab_size);
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let y: f64 = parts
            .next()
            .context("empty bow line")?
            .parse()
            .with_context(|| format!("bad response at data line {}", lineno + 1))?;
        let tokens: Result<Vec<u32>, _> = parts.map(|p| p.parse::<u32>()).collect();
        let tokens = tokens.with_context(|| format!("bad token at data line {}", lineno + 1))?;
        if !tokens.is_empty() {
            c.try_push_doc(&tokens, y)?;
        }
    }
    c.validate()?;
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cfslda_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn bow_roundtrip() {
        let c = Corpus::new(
            vec![
                Document { tokens: vec![0, 2, 2], response: 1.5 },
                Document { tokens: vec![1], response: -0.25 },
            ],
            3,
        );
        let p = tmpfile("roundtrip.bow");
        save_bow(&c, &p).unwrap();
        let c2 = load_bow(&p).unwrap();
        assert_eq!(c2.vocab_size, 3);
        assert_eq!(c2.num_docs(), 2);
        assert_eq!(c2.doc_tokens(0), &[0, 2, 2]);
        assert_eq!(c2.response(1), -0.25);
        assert_eq!(c2, c); // arena round-trips exactly
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn encoded_jsonl_load() {
        let p = tmpfile("enc.jsonl");
        std::fs::write(
            &p,
            "{\"vocab_size\": 10}\n{\"tokens\": [0, 3, 3], \"response\": 2.0}\n\n{\"tokens\": [9], \"response\": -1}\n",
        )
        .unwrap();
        let c = load_encoded_jsonl(&p).unwrap();
        assert_eq!(c.vocab_size, 10);
        assert_eq!(c.num_docs(), 2);
        assert_eq!(c.doc_tokens(0), &[0, 3, 3]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_jsonl_load_builds_vocab() {
        let p = tmpfile("text.jsonl");
        std::fs::write(
            &p,
            concat!(
                "{\"text\": \"strong revenue growth in operational performance\", \"response\": 1.0}\n",
                "{\"text\": \"revenue decline and operational risk\", \"response\": -1.0}\n",
                "{\"text\": \"revenue growth outlook\", \"response\": 0.5}\n",
            ),
        )
        .unwrap();
        let (c, v) = load_text_jsonl(&p, &TokenizerConfig::default(), 0.3, 1.0).unwrap();
        assert!(v.id("revenue").is_some());
        assert_eq!(c.num_docs(), 3);
        assert!(c.vocab_size > 0);
        c.validate().unwrap();
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn malformed_inputs_error() {
        let p = tmpfile("bad.jsonl");
        std::fs::write(&p, "{\"tokens\": [0], \"response\": \"x\"}\n").unwrap();
        assert!(load_encoded_jsonl(&p).is_err());
        std::fs::write(&p, "not json\n").unwrap();
        assert!(load_encoded_jsonl(&p).is_err());
        std::fs::remove_file(p).ok();

        let p2 = tmpfile("bad.bow");
        std::fs::write(&p2, "wrong header\n1 0 0\n").unwrap();
        assert!(load_bow(&p2).is_err());
        std::fs::remove_file(p2).ok();
    }
}
