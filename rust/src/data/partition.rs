//! Train/test splitting and M-way shard partitioning.
//!
//! The shard partitioner implements step 1 of the paper's parallel
//! procedure: "Partition the training documents into M subsets" — uniformly
//! at random, covering every document exactly once, with near-equal sizes
//! (|size_i − size_j| ≤ 1). Property tests in `rust/tests/properties.rs`
//! enforce the exactly-once invariant.

use super::corpus::{Corpus, CorpusView, Dataset};
use crate::util::rng::Pcg64;

/// The index permutation behind [`train_test_split`], exposed so the
/// multi-process driver can replay the exact same split (identical RNG
/// draws) against an mmapped arena without materializing sub-corpora:
/// `(train_ids, test_ids)` in selection order.
pub fn split_indices(n_docs: usize, n_train: usize, rng: &mut Pcg64) -> (Vec<usize>, Vec<usize>) {
    assert!(n_train <= n_docs, "n_train {n_train} > docs {n_docs}");
    let mut idx: Vec<usize> = (0..n_docs).collect();
    rng.shuffle(&mut idx);
    let test = idx.split_off(n_train);
    (idx, test)
}

/// Random train/test split with exactly `n_train` training documents.
pub fn train_test_split(corpus: &Corpus, n_train: usize, rng: &mut Pcg64) -> Dataset {
    let (train_ids, test_ids) = split_indices(corpus.num_docs(), n_train, rng);
    let train = corpus.select(&train_ids);
    let test = corpus.select(&test_ids);
    Dataset { train, test }
}

/// Randomly partition `n_docs` indices into `m` near-equal shards.
/// Every index appears in exactly one shard; sizes differ by at most 1.
pub fn random_shards(n_docs: usize, m: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    assert!(m > 0);
    let mut idx: Vec<usize> = (0..n_docs).collect();
    rng.shuffle(&mut idx);
    let base = n_docs / m;
    let extra = n_docs % m;
    let mut shards = Vec::with_capacity(m);
    let mut cursor = 0usize;
    for s in 0..m {
        let take = base + usize::from(s < extra);
        shards.push(idx[cursor..cursor + take].to_vec());
        cursor += take;
    }
    debug_assert_eq!(cursor, n_docs);
    shards
}

/// Zero-copy shard views over a partition: each view borrows the corpus's
/// token arena plus its shard's doc-index list — the leader/worker handoff
/// ships no token data (DESIGN.md §Memory layout). This is the parallel
/// path's shard setup.
pub fn shard_views<'a>(corpus: &'a Corpus, shards: &'a [Vec<usize>]) -> Vec<CorpusView<'a>> {
    shards.iter().map(|s| corpus.view_of(s)).collect()
}

/// Materialize shard sub-corpora from a partition (deep copies; kept as the
/// benchmark baseline and for owners that must outlive the source corpus —
/// the runtime path uses [`shard_views`]).
pub fn shard_corpora(corpus: &Corpus, shards: &[Vec<usize>]) -> Vec<Corpus> {
    shards.iter().map(|s| corpus.select(s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Document;

    fn corpus(n: usize) -> Corpus {
        Corpus::new(
            (0..n).map(|i| Document { tokens: vec![(i % 5) as u32], response: i as f64 }).collect(),
            5,
        )
    }

    #[test]
    fn split_is_a_partition() {
        let c = corpus(100);
        let ds = train_test_split(&c, 73, &mut Pcg64::seed_from_u64(1));
        assert_eq!(ds.train.num_docs(), 73);
        assert_eq!(ds.test.num_docs(), 27);
        let mut all: Vec<i64> = ds
            .train
            .responses
            .iter()
            .chain(&ds.test.responses)
            .map(|&y| y as i64)
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<i64>>());
    }

    #[test]
    fn split_indices_replays_train_test_split() {
        let c = corpus(40);
        let ds = train_test_split(&c, 29, &mut Pcg64::seed_from_u64(9));
        let (train_ids, test_ids) = split_indices(40, 29, &mut Pcg64::seed_from_u64(9));
        assert_eq!(c.select(&train_ids), ds.train);
        assert_eq!(c.select(&test_ids), ds.test);
    }

    #[test]
    fn shards_cover_exactly_once() {
        for &(n, m) in &[(100, 4), (101, 4), (7, 3), (5, 5), (3, 7)] {
            let shards = random_shards(n, m, &mut Pcg64::seed_from_u64(2));
            assert_eq!(shards.len(), m);
            let mut seen: Vec<usize> = shards.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..n).collect::<Vec<usize>>(), "n={n} m={m}");
        }
    }

    #[test]
    fn shard_sizes_near_equal() {
        let shards = random_shards(103, 4, &mut Pcg64::seed_from_u64(3));
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(max - min <= 1, "sizes={sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 103);
    }

    #[test]
    fn paper_protocol_750_each() {
        // Paper Exp I: 3000 training docs into 4 shards of 750.
        let shards = random_shards(3000, 4, &mut Pcg64::seed_from_u64(4));
        assert!(shards.iter().all(|s| s.len() == 750));
    }

    #[test]
    fn shard_corpora_select_right_docs() {
        let c = corpus(10);
        let shards = vec![vec![0, 1], vec![2, 3, 4], vec![5, 6, 7, 8, 9]];
        let subs = shard_corpora(&c, &shards);
        assert_eq!(subs[1].response(0), 2.0);
        assert_eq!(subs[2].num_docs(), 5);
    }

    #[test]
    fn shard_views_alias_arena_and_match_materialized() {
        let c = corpus(10);
        let shards = vec![vec![0, 1], vec![2, 3, 4], vec![5, 6, 7, 8, 9]];
        let views = shard_views(&c, &shards);
        let subs = shard_corpora(&c, &shards);
        assert_eq!(views.len(), subs.len());
        for (v, s) in views.iter().zip(&subs) {
            assert_eq!(v.num_docs(), s.num_docs());
            assert_eq!(v.num_tokens(), s.num_tokens());
            for i in 0..v.num_docs() {
                assert_eq!(v.doc_tokens(i), s.doc_tokens(i));
                assert_eq!(v.response(i), s.response(i));
            }
            // zero-copy: the view's slices point into the shared arena
            assert!(c.tokens.as_ptr_range().contains(&v.doc_tokens(0).as_ptr()));
        }
    }

    #[test]
    fn deterministic_partitions() {
        let a = random_shards(50, 3, &mut Pcg64::seed_from_u64(5));
        let b = random_shards(50, 3, &mut Pcg64::seed_from_u64(5));
        assert_eq!(a, b);
    }
}
