//! Corpus descriptive statistics + the Fig-5 label-distribution probe.
//!
//! Paper Fig. 5 plots the histogram of earnings per share and argues it is
//! "close to normal distribution, implying it satisfies the normal
//! assumption of the document label variable". [`label_report`] reproduces
//! that figure as an ASCII histogram plus quantitative normality evidence
//! (skewness, excess kurtosis, KS distance against the moment-fitted
//! normal).

use super::corpus::Corpus;
use crate::util::stats::{ks_vs_normal, Histogram, Summary};

/// Corpus-level statistics.
#[derive(Clone, Debug)]
pub struct CorpusStats {
    pub docs: usize,
    pub tokens: usize,
    pub vocab: usize,
    pub mean_doc_len: f64,
    pub min_doc_len: usize,
    pub max_doc_len: usize,
}

pub fn corpus_stats(c: &Corpus) -> CorpusStats {
    let d = c.num_docs();
    let lens = (0..d).map(|i| c.doc_len(i));
    CorpusStats {
        docs: d,
        tokens: c.num_tokens(),
        vocab: c.vocab_size,
        mean_doc_len: if d == 0 { 0.0 } else { c.num_tokens() as f64 / d as f64 },
        min_doc_len: lens.clone().min().unwrap_or(0),
        max_doc_len: lens.max().unwrap_or(0),
    }
}

/// Label-distribution report (the Fig-5 reproduction).
#[derive(Clone, Debug)]
pub struct LabelReport {
    pub summary: Summary,
    pub skewness: f64,
    pub kurtosis: f64,
    /// KS distance between the labels and N(mean, var).
    pub ks_normal: f64,
    pub histogram: Histogram,
}

pub fn label_report(c: &Corpus, bins: usize) -> LabelReport {
    let ys = c.responses();
    let summary = Summary::from_slice(&ys);
    let pad = 0.05 * (summary.max - summary.min).max(1e-9);
    let histogram = Histogram::build(&ys, summary.min - pad, summary.max + pad, bins);
    LabelReport {
        skewness: Summary::skewness_of(&ys),
        kurtosis: Summary::kurtosis_of(&ys),
        ks_normal: ks_vs_normal(&ys, summary.mean(), summary.var().max(1e-12)),
        summary,
        histogram,
    }
}

impl LabelReport {
    /// Render the Fig-5 style report.
    pub fn render(&self, title: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("=== {title} (n = {}) ===\n", self.summary.n));
        out.push_str(&format!(
            "mean={:.4} std={:.4} min={:.4} max={:.4}\n",
            self.summary.mean(),
            self.summary.std(),
            self.summary.min,
            self.summary.max
        ));
        out.push_str(&format!(
            "skewness={:.4} excess_kurtosis={:.4} KS_vs_normal={:.4}\n",
            self.skewness, self.kurtosis, self.ks_normal
        ));
        out.push_str(&self.histogram.render(50));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ResponseKind;
    use crate::data::synthetic::{generate_corpus, SyntheticSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn stats_on_synthetic() {
        let spec = SyntheticSpec::continuous_small();
        let c = generate_corpus(&spec, &mut Pcg64::seed_from_u64(1));
        let s = corpus_stats(&c);
        assert_eq!(s.docs, spec.docs);
        assert_eq!(s.vocab, spec.vocab);
        assert!(s.min_doc_len >= 4);
        assert!(s.mean_doc_len > 20.0);
    }

    #[test]
    fn eps_like_labels_look_normal() {
        // The Fig-5 claim: the synthetic EPS labels must be near-normal.
        let mut spec = SyntheticSpec::mdna();
        spec.docs = 2000; // keep the test fast
        let c = generate_corpus(&spec, &mut Pcg64::seed_from_u64(2));
        let r = label_report(&c, 30);
        assert!(r.skewness.abs() < 0.6, "skew={}", r.skewness);
        assert!(r.ks_normal < 0.08, "ks={}", r.ks_normal);
        assert!(r.histogram.n == 2000);
    }

    #[test]
    fn binary_labels_not_normal() {
        let mut spec = SyntheticSpec::imdb();
        spec.docs = 1000;
        spec.response = ResponseKind::Binary;
        let c = generate_corpus(&spec, &mut Pcg64::seed_from_u64(3));
        let r = label_report(&c, 10);
        assert!(r.ks_normal > 0.2, "binary labels should fail normality: {}", r.ks_normal);
    }

    #[test]
    fn render_contains_key_fields() {
        let spec = SyntheticSpec::continuous_small();
        let c = generate_corpus(&spec, &mut Pcg64::seed_from_u64(4));
        let text = label_report(&c, 12).render("labels");
        assert!(text.contains("mean="));
        assert!(text.contains("KS_vs_normal"));
        assert!(text.contains('#'));
    }
}
