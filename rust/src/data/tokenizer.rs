//! Text -> phrase tokenizer.
//!
//! Stands in for the paper's preprocessing chain (Stanford log-linear POS
//! tagger -> adjective-noun phrase mining). We implement the same *shape* of
//! pipeline without the JVM dependency: lowercasing word tokenizer, stopword
//! filter, and an adjacent-pair phrase miner driven by a suffix heuristic
//! (`-ive`, `-ous`, `-al`, ... adjectives preceding nouns become
//! `adj_noun` phrases). DESIGN.md §3 records the substitution.

/// English stopwords (compact list adequate for BoW topic modelling).
pub const STOPWORDS: &[&str] = &[
    "a", "an", "and", "are", "as", "at", "be", "been", "but", "by", "for",
    "from", "had", "has", "have", "he", "her", "his", "i", "in", "is", "it",
    "its", "may", "more", "not", "of", "on", "or", "our", "she", "such",
    "that", "the", "their", "there", "these", "they", "this", "to", "was",
    "we", "were", "which", "will", "with", "would", "you",
];

fn is_stopword(w: &str) -> bool {
    STOPWORDS.binary_search(&w).is_ok()
}

/// Crude adjective detector: common English adjectival suffixes. Plays the
/// role of the POS tag in the paper's adjective-noun phrase generation.
fn looks_adjectival(w: &str) -> bool {
    const SUF: &[&str] = &["ive", "ous", "al", "ic", "able", "ible", "ful", "less", "ent", "ant"];
    w.len() >= 4 && SUF.iter().any(|s| w.ends_with(s))
}

/// Tokenizer configuration.
#[derive(Clone, Debug)]
pub struct TokenizerConfig {
    /// Minimum single-word length kept.
    pub min_word_len: usize,
    /// Emit `adj_noun` phrases for adjectival words preceding a word.
    pub mine_phrases: bool,
    /// Drop stopwords.
    pub filter_stopwords: bool,
}

impl Default for TokenizerConfig {
    fn default() -> Self {
        TokenizerConfig { min_word_len: 2, mine_phrases: true, filter_stopwords: true }
    }
}

/// Tokenize raw text into unigrams + mined phrases.
pub fn tokenize(text: &str, cfg: &TokenizerConfig) -> Vec<String> {
    let words: Vec<String> = text
        .split(|c: char| !c.is_alphanumeric() && c != '\'')
        .map(|w| w.trim_matches('\'').to_lowercase())
        .filter(|w| w.len() >= cfg.min_word_len)
        .filter(|w| !w.chars().all(|c| c.is_ascii_digit()))
        .filter(|w| !cfg.filter_stopwords || !is_stopword(w))
        .collect();

    let mut out = Vec::with_capacity(words.len() * 2);
    for i in 0..words.len() {
        if cfg.mine_phrases && i + 1 < words.len() && looks_adjectival(&words[i]) {
            out.push(format!("{}_{}", words[i], words[i + 1]));
        }
        out.push(words[i].clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopword_list_is_sorted_for_binary_search() {
        let mut s = STOPWORDS.to_vec();
        s.sort_unstable();
        assert_eq!(s, STOPWORDS);
    }

    #[test]
    fn basic_tokenization() {
        let t = tokenize("The quick, brown fox!", &TokenizerConfig::default());
        assert!(t.contains(&"quick".to_string()));
        assert!(t.contains(&"fox".to_string()));
        assert!(!t.contains(&"the".to_string())); // stopword
    }

    #[test]
    fn numbers_and_short_tokens_dropped() {
        let t = tokenize("x 42 2012 profit", &TokenizerConfig::default());
        assert_eq!(t, vec!["profit".to_string()]);
    }

    #[test]
    fn phrase_mining() {
        let t = tokenize("operational performance improved", &TokenizerConfig::default());
        assert!(t.contains(&"operational_performance".to_string()), "{t:?}");
        assert!(t.contains(&"operational".to_string()));
        assert!(t.contains(&"performance".to_string()));
    }

    #[test]
    fn phrase_mining_can_be_disabled() {
        let cfg = TokenizerConfig { mine_phrases: false, ..Default::default() };
        let t = tokenize("operational performance", &cfg);
        assert_eq!(t, vec!["operational".to_string(), "performance".to_string()]);
    }

    #[test]
    fn case_folding_and_apostrophes() {
        let t = tokenize("Firm's REVENUE", &TokenizerConfig::default());
        assert!(t.contains(&"firm's".to_string()) || t.contains(&"firm".to_string()), "{t:?}");
        assert!(t.contains(&"revenue".to_string()));
    }

    #[test]
    fn empty_input() {
        assert!(tokenize("", &TokenizerConfig::default()).is_empty());
        assert!(tokenize("   \n\t  ", &TokenizerConfig::default()).is_empty());
    }
}
