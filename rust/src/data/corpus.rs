//! Bag-of-words corpus with per-document responses.

/// One document: token ids (with repetition, order irrelevant to the model)
/// plus the supervised response y_d (EPS, sentiment, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Document {
    pub tokens: Vec<u32>,
    pub response: f64,
}

impl Document {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// A corpus: documents + the vocabulary size they are indexed against.
#[derive(Clone, Debug, Default)]
pub struct Corpus {
    pub docs: Vec<Document>,
    pub vocab_size: usize,
}

impl Corpus {
    pub fn new(docs: Vec<Document>, vocab_size: usize) -> Self {
        debug_assert!(docs.iter().flat_map(|d| &d.tokens).all(|&w| (w as usize) < vocab_size));
        Corpus { docs, vocab_size }
    }

    pub fn num_docs(&self) -> usize {
        self.docs.len()
    }

    pub fn num_tokens(&self) -> usize {
        self.docs.iter().map(|d| d.len()).sum()
    }

    pub fn responses(&self) -> Vec<f64> {
        self.docs.iter().map(|d| d.response).collect()
    }

    /// Sub-corpus view by document indices (clones the selected docs).
    pub fn select(&self, idx: &[usize]) -> Corpus {
        Corpus {
            docs: idx.iter().map(|&i| self.docs[i].clone()).collect(),
            vocab_size: self.vocab_size,
        }
    }

    /// Structural sanity check (token ids within vocab, no empty docs).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, d) in self.docs.iter().enumerate() {
            if d.is_empty() {
                anyhow::bail!("document {i} is empty");
            }
            if let Some(&w) = d.tokens.iter().find(|&&w| w as usize >= self.vocab_size) {
                anyhow::bail!("document {i} has token id {w} >= vocab size {}", self.vocab_size);
            }
            if !d.response.is_finite() {
                anyhow::bail!("document {i} has non-finite response {}", d.response);
            }
        }
        Ok(())
    }
}

/// Train/test split of a corpus.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub train: Corpus,
    pub test: Corpus,
}

impl Dataset {
    pub fn vocab_size(&self) -> usize {
        self.train.vocab_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini() -> Corpus {
        Corpus::new(
            vec![
                Document { tokens: vec![0, 1, 1, 2], response: 0.5 },
                Document { tokens: vec![2, 2], response: -1.0 },
                Document { tokens: vec![0], response: 2.0 },
            ],
            3,
        )
    }

    #[test]
    fn counts() {
        let c = mini();
        assert_eq!(c.num_docs(), 3);
        assert_eq!(c.num_tokens(), 7);
        assert_eq!(c.responses(), vec![0.5, -1.0, 2.0]);
    }

    #[test]
    fn select_preserves_order() {
        let c = mini();
        let s = c.select(&[2, 0]);
        assert_eq!(s.num_docs(), 2);
        assert_eq!(s.docs[0].response, 2.0);
        assert_eq!(s.docs[1].response, 0.5);
        assert_eq!(s.vocab_size, 3);
    }

    #[test]
    fn validate_catches_problems() {
        let mut c = mini();
        c.validate().unwrap();
        c.docs[1].tokens.clear();
        assert!(c.validate().is_err());

        let mut c = mini();
        c.docs[0].tokens.push(99);
        assert!(c.validate().is_err());

        let mut c = mini();
        c.docs[2].response = f64::NAN;
        assert!(c.validate().is_err());
    }
}
